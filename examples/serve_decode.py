"""Serving example: batched prefill + decode of a reduced architecture,
exercising the KV-cache path that decode_32k/long_500k lower on TPU, and
cross-checking the Pallas flash-decode kernel (interpret mode) against the
model's own attention on the final step.

  PYTHONPATH=src python examples/serve_decode.py --arch qwen3-14b
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.new_tokens

    key = jax.random.PRNGKey(1)
    prompt = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                           0, cfg.vocab_size)}
    if cfg.family == "vlm":
        prompt["image_embeds"] = jax.random.normal(
            key, (args.batch, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        prompt["frames"] = jax.random.normal(
            key, (args.batch, cfg.max_source_positions, cfg.d_model))

    t0 = time.time()
    logits, cache = jax.block_until_ready(
        bundle.prefill(params, prompt, max_seq))
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"({time.time()-t0:.2f}s)")

    decode = jax.jit(bundle.decode)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seqs = np.stack([np.asarray(t) for t in out], 1)
    print(f"decoded {args.new_tokens} tokens/seq x {args.batch} seqs in "
          f"{dt:.2f}s ({args.batch*(args.new_tokens-1)/max(dt,1e-9):.1f} tok/s)")
    print("sample continuation token ids:", seqs[0][:12].tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all()


if __name__ == "__main__":
    main()
