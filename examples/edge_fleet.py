"""Fleet-scale edge FL demo: one round over 10⁵ simulated devices.

A bimodal fleet of 100 000 devices sits behind 200 gateways — but no
per-device Python object is ever built: the fleet is five numpy profile
vectors (``ArrayFleet``), the tree is a :class:`~repro.hier.StackedTopology`
whose gateways hold flat device-id arrays, the scheduler batch-dispatches
the whole cohort with one vectorized draw of its counter-based v2 RNG
stream, and each device's data shard is generated *inside* the jit
boundary from its id (:class:`~repro.data.VirtualFleetDataset`) — host
memory stays O(cohort chunk) no matter how large the fleet.  The demo
prints per-round devices/second and the per-tier byte ledger, then
cross-checks a 64-device slice against the per-device event scheduler.

  PYTHONPATH=src python examples/edge_fleet.py     (< 90 s on CPU)

EXAMPLE_SMOKE=1 runs a 4096-device variant (CI keeps examples from
rotting).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax

from repro.data import VirtualFleetDataset
from repro.edge import array_bimodal_fleet, bimodal_fleet
from repro.fl import run_hier_simulation
from repro.hier import HierConfig, stacked_two_tier, two_tier_topology
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

SMOKE = os.environ.get("EXAMPLE_SMOKE", "") == "1"
N_DEV = 4_096 if SMOKE else 100_000
N_GW = max(4, N_DEV // 500)
DIM, CLASSES, SEED = 16, 4, 42
ROUNDS = 2 if SMOKE else 3


def main():
    ds = VirtualFleetDataset(num_devices=N_DEV, samples_per_device=16,
                             dim=DIM, num_classes=CLASSES, seed=3)
    fleet = array_bimodal_fleet(N_DEV)
    topo = stacked_two_tier(fleet, N_GW)
    params = get_model(ArchConfig(name="lr", family="logreg", input_dim=DIM,
                                  num_classes=CLASSES)
                       ).init(jax.random.PRNGKey(0))
    cfg = HierConfig(aggregator="hier_contextual", lr=0.1, mu=0.0,
                     batch_size=8, min_epochs=1, max_epochs=1)
    print(f"fleet — {fleet.describe()}")
    print(f"tree  — {topo.describe()}")

    t0 = time.time()
    r = run_hier_simulation(
        "fleet", logistic_loss, logistic_apply, params, ds, cfg, topo,
        num_rounds=ROUNDS, selection_seed=SEED, eval_every=ROUNDS,
        scheduler_mode="cohort", rng_stream="v2",
        cohort_chunk=131_072 if N_DEV > 131_072 else None)
    wall = time.time() - t0
    steady = r.engine.get("steady_wall_time_per_round_s") or wall / ROUNDS

    print(f"\n{N_DEV} devices x {ROUNDS} rounds in {wall:.1f}s wall "
          f"({N_DEV / steady:,.0f} devices/s warm)")
    print(f"final train loss {r.train_loss[-1]:.4f}, "
          f"virtual round time {r.times[-1] / ROUNDS * 1e3:.1f}ms")
    for tier, traffic in sorted(r.comm.items()):
        print(f"  {tier}: up {traffic['bytes_up'] / 1e6:9.2f}MB   "
              f"down {traffic['bytes_down'] / 1e6:9.2f}MB")

    # cross-check: a 64-device slice of the same problem, run through the
    # per-device event scheduler over materialized shards, lands on the
    # same losses — the fleet path is an optimization, not a new algorithm
    ds64 = VirtualFleetDataset(num_devices=64, samples_per_device=16,
                               dim=DIM, num_classes=CLASSES, seed=3)
    kw = dict(num_rounds=ROUNDS, selection_seed=SEED, eval_every=ROUNDS,
              rng_stream="v2")
    ev = run_hier_simulation("ev", logistic_loss, logistic_apply, params,
                             ds64.materialize(), cfg,
                             two_tier_topology(bimodal_fleet(64), 4),
                             scheduler_mode="event", **kw)
    co = run_hier_simulation("co", logistic_loss, logistic_apply, params,
                             ds64, cfg, stacked_two_tier(
                                 array_bimodal_fleet(64), 4),
                             scheduler_mode="cohort", **kw)
    gap = max(abs(a - b) for a, b in zip(ev.train_loss, co.train_loss))
    same_t = co.times == ev.times
    print(f"\n64-device cross-check: max loss gap {gap:.2e}, "
          f"virtual times identical: {same_t}")
    if gap < 1e-5 and same_t:
        print("ACCEPTANCE: cohort path matches per-device event path - PASS")
    else:
        print("WARNING: cohort/event mismatch - inspect the numbers above.")


if __name__ == "__main__":
    main()
