"""End-to-end driver: federated training of a transformer LM with contextual
aggregation on the SPMD train step (deliverable b's 'train a model for a few
hundred steps' driver).

Default is a CPU-sized reduced model; pass --full-100m for the ~100M-param
configuration (slow on CPU, sized for a single TPU host).

  PYTHONPATH=src python examples/federated_lm.py --steps 100
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.shapes import InputShape
from repro.launch.steps import build_train_step
from repro.launch.train import make_batches
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--aggregator", default="contextual")
    args = ap.parse_args()

    base = get_config("olmoe-1b-7b")
    if args.full_100m:
        cfg = base.with_overrides(num_layers=6, d_model=768, num_heads=12,
                                  num_kv_heads=12, d_ff=512, vocab_size=32000,
                                  num_experts=8, experts_per_token=2,
                                  dtype="float32")
    else:
        cfg = base.reduced()
    bundle = get_model(cfg)
    print(f"model: {cfg.name} ~{cfg.param_count_estimate()/1e6:.0f}M params "
          f"(MoE {cfg.num_experts}e top-{cfg.experts_per_token})")

    mesh = make_host_mesh()
    shape = InputShape("lm", "train", args.seq, args.batch)
    step = jax.jit(build_train_step(cfg, mesh, shape,
                                    aggregator=args.aggregator, lr=0.05,
                                    remat=False))
    with mesh:
        params = bundle.init(jax.random.PRNGKey(0))
        losses = []
        t0 = time.time()
        for i, batch in enumerate(make_batches(cfg, bundle, args.batch,
                                               args.seq, args.steps)):
            params, metrics = step(params, batch)
            losses.append(float(metrics["loss"]))
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={losses[-1]:.4f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
    assert losses[-1] < losses[0], "training must reduce loss"
    print(f"done: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({args.steps} rounds, aggregator={args.aggregator})")


if __name__ == "__main__":
    main()
