"""Quickstart: the paper in one page.

Runs 30 rounds of federated logistic regression on the heterogeneous
Synthetic(1,1) dataset with FedAvg and with the paper's contextual
aggregation, printing loss/accuracy per round.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.fl import ServerConfig, run_simulation
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss


def main():
    # Synthetic(alpha=1, beta=1): strongly heterogeneous clients (paper SIV-A1)
    xs, ys = make_synthetic(1.0, 1.0, num_devices=30, samples_per_device=60,
                            dim=60, seed=2)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, 60)[:400], ys.reshape(-1)[:400], 10)
    model_cfg = ArchConfig(name="logreg", family="logreg", input_dim=60,
                           num_classes=10)
    params = get_model(model_cfg).init(jax.random.PRNGKey(0))

    results = {}
    for agg in ("fedavg", "contextual"):
        cfg = ServerConfig(aggregator=agg, num_devices=30,
                           clients_per_round=10, lr=0.2, batch_size=10,
                           min_epochs=1, max_epochs=20)  # K=10, epochs~U[1,20]
        r = run_simulation(agg, logistic_loss, logistic_apply, params, ds,
                           cfg, num_rounds=30, selection_seed=42)
        results[agg] = r
        print(f"\n=== {agg} ===")
        for i in range(0, len(r.train_loss), 5):
            print(f" round {i+1:3d}  loss={r.train_loss[i]:.4f} "
                  f"acc={r.test_acc[i]:.4f}")

    ra, rc = results["fedavg"], results["contextual"]
    print("\nsummary:")
    print(f"  fedavg      final loss={ra.train_loss[-1]:.4f} "
          f"acc={ra.test_acc[-1]:.4f} volatility={ra.loss_volatility():.4f}")
    print(f"  contextual  final loss={rc.train_loss[-1]:.4f} "
          f"acc={rc.test_acc[-1]:.4f} volatility={rc.loss_volatility():.4f}")
    print("\nTheorem 1 in action: contextual descends near-monotonically while"
          "\nFedAvg fluctuates under heterogeneity (paper Figs. 4-5).")


if __name__ == "__main__":
    main()
