"""Async edge FL demo: staleness-aware contextual aggregation vs sync FedAvg.

Simulates a bimodal phone+gateway fleet (half the devices 10× slower and
flakier) on the heterogeneous Synthetic(1,1) task.  Synchronous rounds are
gated by their slowest participant; the async runtime keeps aggregating
whatever arrives, discounting stale updates inside the contextual K×K solve.
The table compares *virtual wall-clock* to reach accuracy targets — the only
axis on which sync and async are commensurable.

  PYTHONPATH=src python examples/edge_async.py     (< 60 s on CPU)

EXAMPLE_SMOKE=1 runs a tiny-step variant (CI keeps examples from rotting).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.edge import AsyncConfig, bimodal_fleet
from repro.edge.wallclock import (model_flops_per_step, model_payload_bytes,
                                  sync_wallclock_curve)
from repro.fl import ServerConfig, run_async_simulation, run_simulation
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

SMOKE = os.environ.get("EXAMPLE_SMOKE", "") == "1"
DIM, N_DEV, SEED = 60, 30, 42
ROUNDS, AGGS, EVAL_EVERY = (6, 6, 2) if SMOKE else (40, 40, 2)
TARGETS = (0.40, 0.50, 0.55)


def fmt_time(t):
    return f"{t * 1e3:9.2f} ms" if t is not None else f"{'—':>12s}"


def main():
    xs, ys = make_synthetic(1.0, 1.0, num_devices=N_DEV, samples_per_device=60,
                            dim=DIM, seed=2)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, DIM)[:400], ys.reshape(-1)[:400], 10)
    params = get_model(ArchConfig(name="logreg", family="logreg",
                                  input_dim=DIM, num_classes=10)
                       ).init(jax.random.PRNGKey(0))
    fleet = bimodal_fleet(N_DEV, slowdown=10.0, dropout_slow=0.1, seed=0)
    print(f"fleet — {fleet.describe()}")

    fps = model_flops_per_step(params, 10)
    pb = model_payload_bytes(params)
    spe = max(ds.samples_per_device // 10, 1)
    curves = {}

    # -- synchronous baselines, converted to virtual wall-clock -------------
    for agg in ("fedavg", "contextual"):
        cfg = ServerConfig(aggregator=agg, num_devices=N_DEV,
                           clients_per_round=10, lr=0.2, batch_size=10,
                           min_epochs=1, max_epochs=20)
        r = run_simulation(f"{agg}-sync", logistic_loss, logistic_apply,
                           params, ds, cfg, num_rounds=ROUNDS,
                           selection_seed=SEED, eval_every=EVAL_EVERY)
        curves[f"{agg}-sync"] = sync_wallclock_curve(
            r, fleet, cfg, spe, ROUNDS, EVAL_EVERY, fps, pb,
            selection_seed=SEED)

    # -- async runtimes -----------------------------------------------------
    async_cfgs = {
        "contextual-async": AsyncConfig(
            aggregator="contextual_async", num_devices=N_DEV, buffer_size=5,
            concurrency=10, lr=0.2, batch_size=10, min_epochs=1,
            max_epochs=20, staleness_mode="poly", staleness_decay=0.5),
        "fedbuff-async": AsyncConfig(
            aggregator="fedbuff", num_devices=N_DEV, buffer_size=5,
            concurrency=10, server_lr=0.5, lr=0.2, batch_size=10,
            min_epochs=1, max_epochs=20, staleness_mode="poly",
            staleness_decay=0.5),
    }
    for name, cfg in async_cfgs.items():
        r = run_async_simulation(name, logistic_loss, logistic_apply, params,
                                 ds, cfg, fleet, num_aggregations=AGGS,
                                 selection_seed=SEED, eval_every=EVAL_EVERY)
        curves[name] = r.to_curve()
        print(f"{name}: {r.arrived} arrivals, {r.dropped} dropouts, "
              f"mean staleness {np.mean(r.staleness_mean):.2f} versions")

    # -- the comparison table ------------------------------------------------
    header = "virtual wall-clock to reach test accuracy"
    print(f"\n{header}\n{'-' * len(header)}")
    cols = "".join(f"  acc>={t:.2f}  " for t in TARGETS)
    print(f"{'method':<18s}{cols}  final acc")
    for name, c in curves.items():
        row = "".join(f"{fmt_time(c.time_to_accuracy(t))} " for t in TARGETS)
        print(f"{name:<18s}{row}     {max(c.test_acc):.3f}")

    t_async = curves["contextual-async"].time_to_accuracy(TARGETS[-1])
    t_sync = curves["fedavg-sync"].time_to_accuracy(TARGETS[-1])
    if t_async is not None and (t_sync is None or t_async < t_sync):
        speedup = (f"{t_sync / t_async:.1f}x faster than sync FedAvg"
                   if t_sync else "sync FedAvg never got there")
        print(f"\ncontextual-async reached acc {TARGETS[-1]:.2f} in "
              f"{t_async * 1e3:.2f} ms of virtual time — {speedup}.\n"
              "Stragglers no longer gate progress; staleness discounting in\n"
              "the contextual solve keeps the late updates from derailing it.")
    else:
        print("\nWARNING: contextual-async did not beat sync FedAvg on this "
              "seed — inspect the table above.")


if __name__ == "__main__":
    main()
