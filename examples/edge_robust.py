"""Adversarial edge FL demo: robust contextual solves under attack + churn.

A 64-device fleet with 20% of its devices compromised runs Byzantine noise
replacement (each malicious client reports Gaussian updates AND gradients
at 25x the honest norm) while a churn wave knocks half the fleet offline
mid-run.  The demo compares, on identical seeds:

  * plain contextual aggregation — the poisoned gradient columns corrupt
    the shared ĝ estimate and with it every honest client's c-term;
  * robust contextual (``contextual_mom``) — per-client update clipping
    plus median-of-means pooling on the (G, c) cross-term slots before the
    same P×P solve;
  * FedAvg — the undefended baseline, and krum / coordinate-median — the
    classical robust baselines.

Expected: the robust contextual run stays within ~10% of its own clean
loss while plain contextual and FedAvg degrade markedly, and the
hierarchical robust run rides through the churn wave.

  PYTHONPATH=src python examples/edge_robust.py     (< 90 s on CPU)

EXAMPLE_SMOKE=1 runs a tiny-step variant (CI keeps examples from rotting).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.edge import uniform_fleet
from repro.fl import ServerConfig, run_hier_simulation, run_simulation
from repro.hier import HierConfig, two_tier_topology
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss
from repro.robust import (ByzantineGauss, RobustConfig, assign_adversaries,
                          churn_schedule)

SMOKE = os.environ.get("EXAMPLE_SMOKE", "") == "1"
DIM, N_DEV, N_GW, SEED = 20, 64, 4, 42
ROUNDS = 4 if SMOKE else 12
ATTACK = ByzantineGauss(scale=25.0)
ROBUST = RobustConfig(clip=2.0, pool="mom")


def main():
    xs, ys = make_synthetic(1.0, 1.0, num_devices=N_DEV,
                            samples_per_device=30, dim=DIM, seed=5)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, DIM)[:400], ys.reshape(-1)[:400], 10)
    params = get_model(ArchConfig(name="logreg", family="logreg",
                                  input_dim=DIM, num_classes=10)
                       ).init(jax.random.PRNGKey(0))
    fleet = assign_adversaries(uniform_fleet(N_DEV), 0.2, seed=3)
    print(f"fleet — {fleet.num_devices} devices, "
          f"{len(fleet.malicious)} compromised: {fleet.malicious}")
    print(f"attack — {ATTACK.name} at {ATTACK.scale:g}x the honest norm\n")

    methods = (("contextual", None), ("contextual_mom", ROBUST),
               ("fedavg", None), ("krum", RobustConfig()),
               ("coordinate_median", None))

    def flat(agg, rob, attack):
        cfg = ServerConfig(aggregator=agg, num_devices=N_DEV,
                           clients_per_round=16, lr=0.2, batch_size=10,
                           min_epochs=1, max_epochs=4, attack=attack,
                           malicious=fleet.malicious if attack else (),
                           robust=rob)
        tag = f"{agg}-{'byz' if attack else 'clean'}"
        return run_simulation(tag, logistic_loss, logistic_apply, params,
                              ds, cfg, num_rounds=ROUNDS,
                              selection_seed=SEED, eval_every=ROUNDS)

    header = "method              clean_loss  attacked   inflation"
    print(f"{header}\n{'-' * len(header)}")
    inflations = {}
    for agg, rob in methods:
        clean = flat(agg, rob, None).train_loss[-1]
        atk = flat(agg, rob, ATTACK).train_loss[-1]
        inflations[agg] = atk / clean
        print(f"{agg:<18s} {clean:10.4f} {atk:10.4f} "
              f"{inflations[agg]:9.2f}x")

    # hierarchical: the same robust statistics inside every gateway/cloud
    # tier solve, with a churn wave taking 50% of the fleet offline
    hcfg = HierConfig(aggregator="hier_contextual", lr=0.2, batch_size=10,
                      min_epochs=1, max_epochs=4, robust=ROBUST)
    topo = two_tier_topology(fleet, N_GW)
    clean_h = run_hier_simulation("hier-clean", logistic_loss, logistic_apply,
                                  params, ds, hcfg, topo, num_rounds=ROUNDS,
                                  selection_seed=SEED, eval_every=ROUNDS)
    churn = churn_schedule("wave", N_DEV, clean_h.times[-1], seed=1)
    byz_h = run_hier_simulation("hier-byz-churn", logistic_loss,
                                logistic_apply, params, ds, hcfg, topo,
                                num_rounds=ROUNDS, selection_seed=SEED,
                                eval_every=ROUNDS, attack=ATTACK, churn=churn)
    h_infl = byz_h.train_loss[-1] / clean_h.train_loss[-1]
    print(f"\nhier robust ({N_GW} gateways) under attack + 50% churn wave: "
          f"loss {clean_h.train_loss[-1]:.4f} -> {byz_h.train_loss[-1]:.4f} "
          f"({h_infl:.2f}x), {byz_h.dropped} tasks dropped")

    ok = (inflations["contextual_mom"] <= 1.15
          and inflations["contextual"] >= 1.2
          and inflations["fedavg"] >= 1.5)
    if ok and not SMOKE:
        print("\nACCEPTANCE: robust contextual within 15% of clean while "
              "plain contextual\nand FedAvg degrade - PASS")
    elif not SMOKE:
        print("\nWARNING: expected margins not met on this seed - inspect "
              "the table above.")
    print("\nThe poisoned gradient columns corrupt the shared g_hat estimate "
          "and with it\nevery honest client's c-term; clipping bounds each "
          "row's leverage and the\nmedian-of-means pool re-estimates c from "
          "the cross-term columns, so the\nsame contextual solve prices "
          "honest updates as if the attackers were absent.")


if __name__ == "__main__":
    main()
