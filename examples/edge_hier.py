"""Hierarchical edge FL demo: gateway Gram summaries vs flat uplink.

A 64-device bimodal fleet sits behind 4 gateways.  Flat contextual
aggregation ships every raw update to the cloud — O(K·n) uplink per round.
The hierarchical runtime has each gateway run the paper's contextual solve
on its own cohort and forward only a composable summary (G_g, c_g, α_g,
ū_g, ĝ_g) — O(P·n) uplink — while the cloud solves the P×P stage over the
gateway combinations.  The demo shows the hierarchy tracks the flat
contextual loss (within 5%) while cutting cloud-uplink bytes ≥5×.

  PYTHONPATH=src python examples/edge_hier.py     (< 90 s on CPU)

EXAMPLE_SMOKE=1 runs a tiny-step variant (CI keeps examples from rotting).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.edge import bimodal_fleet
from repro.fl import run_hier_simulation
from repro.hier import HierConfig, star_topology, two_tier_topology
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

SMOKE = os.environ.get("EXAMPLE_SMOKE", "") == "1"
DIM, N_DEV, N_GW, SEED = 60, 64, 4, 42
ROUNDS, EVAL_EVERY = (5, 2) if SMOKE else (30, 2)


def main():
    xs, ys = make_synthetic(1.0, 1.0, num_devices=N_DEV, samples_per_device=60,
                            dim=DIM, seed=2)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, DIM)[:400], ys.reshape(-1)[:400], 10)
    params = get_model(ArchConfig(name="logreg", family="logreg",
                                  input_dim=DIM, num_classes=10)
                       ).init(jax.random.PRNGKey(0))
    fleet = bimodal_fleet(N_DEV, slowdown=10.0, dropout_slow=0.05, seed=0)
    flat_topo = star_topology(fleet)
    hier_topo = two_tier_topology(fleet, N_GW)
    print(f"fleet — {fleet.describe()}")
    print(f"tree  — {hier_topo.describe()}")

    base = dict(lr=0.2, batch_size=10, min_epochs=1, max_epochs=10)
    runs = {
        "flat-contextual": (flat_topo, HierConfig(
            aggregator="hier_contextual", **base)),
        "hier-contextual": (hier_topo, HierConfig(
            aggregator="hier_contextual", **base)),
        "hier-fedavg": (hier_topo, HierConfig(
            aggregator="hier_fedavg", **base)),
        "hier-relay": (hier_topo, HierConfig(
            aggregator="hier_relay", **base)),
    }
    results = {}
    for name, (topo, cfg) in runs.items():
        results[name] = run_hier_simulation(
            name, logistic_loss, logistic_apply, params, ds, cfg, topo,
            num_rounds=ROUNDS, selection_seed=SEED, eval_every=EVAL_EVERY)

    header = ("method             final_loss  final_acc  cloud_uplink "
              " round_time")
    print(f"\n{header}\n{'-' * len(header)}")
    for name, r in results.items():
        print(f"{name:<18s} {r.train_loss[-1]:10.4f} {r.test_acc[-1]:10.3f} "
              f"{r.cloud_uplink_bytes / 1e6:9.2f}MB "
              f"{r.times[-1] / ROUNDS * 1e3:9.2f}ms")

    flat, hier = results["flat-contextual"], results["hier-contextual"]
    gap = abs(hier.train_loss[-1] - flat.train_loss[-1]) / flat.train_loss[-1]
    savings = flat.cloud_uplink_bytes / hier.cloud_uplink_bytes
    print(f"\nhier-contextual final loss is within {gap * 100:.1f}% of "
          f"flat-contextual\ncloud-uplink bytes: {savings:.1f}x fewer "
          f"({flat.cloud_uplink_bytes / 1e6:.2f}MB -> "
          f"{hier.cloud_uplink_bytes / 1e6:.2f}MB)")
    if gap <= 0.05 and savings >= 5.0:
        print("ACCEPTANCE: loss within 5% AND >=5x fewer cloud-uplink bytes "
              "- PASS")
    else:
        print("WARNING: acceptance criterion not met on this seed - inspect "
              "the table above.")
    print("\nEach gateway solved its own K_g x K_g contextual system and "
          "shipped\n(G_g, c_g, alpha_g, u_bar_g, g_hat_g); the cloud solved "
          "the PxP stage over\nthe gateway combinations - the Gram "
          "statistics compose exactly, so no\ninformation the solve needs "
          "ever left the gateway tier as raw updates.")


if __name__ == "__main__":
    main()
