"""SCAFFOLD vs SCAFFOLD(Contextual): the paper's plug-and-run claim in
action on a stateful baseline it criticises (§V).

Vanilla SCAFFOLD's control variates correct client drift but the uniform
server average still oscillates under aggressive heterogeneous local
budgets; swapping in the contextual aggregation (one-line change at the
server) stabilises it.

  PYTHONPATH=src python examples/scaffold_comparison.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.fl import ServerConfig, run_scaffold
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss


def main():
    xs, ys = make_synthetic(1.0, 1.0, num_devices=30, samples_per_device=60,
                            dim=60, seed=2)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, 60)[:400], ys.reshape(-1)[:400], 10)
    cfg_m = ArchConfig(name="lr", family="logreg", input_dim=60,
                       num_classes=10)
    params = get_model(cfg_m).init(jax.random.PRNGKey(0))

    for agg, label in (("fedavg", "SCAFFOLD"),
                       ("contextual", "SCAFFOLD(Contextual)")):
        cfg = ServerConfig(aggregator=agg, num_devices=30,
                           clients_per_round=10, lr=0.2, batch_size=10,
                           min_epochs=1, max_epochs=20)
        r = run_scaffold(label, logistic_loss, logistic_apply, params, ds,
                         cfg, num_rounds=25, selection_seed=42)
        print(f"\n=== {label} ===")
        for i in range(0, len(r.train_loss), 5):
            print(f" round {i+1:3d}  loss={r.train_loss[i]:.4f} "
                  f"acc={r.test_acc[i]:.4f}")
        print(f" final loss={r.train_loss[-1]:.4f} acc={r.test_acc[-1]:.4f} "
              f"volatility={r.loss_volatility():.4f}")


if __name__ == "__main__":
    main()
