"""Hierarchical multi-pod contextual aggregation (DESIGN.md §3) on a
simulated 2x2x2 (pod, data, model) mesh of host devices.

Shows the two-stage combine: contextual aggregation of cohort updates
WITHIN each pod, then a second contextual combine ACROSS pods — the
collective schedule the 2x16x16 dry-run lowers at scale.

  python examples/multipod_hierarchical.py        # (sets its own XLA_FLAGS)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.distributed import (contextual_combine_sharded,
                                    hierarchical_contextual_combine)


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    n = 1024           # parameter slice per example
    beta = 10.0
    key = jax.random.PRNGKey(0)
    # 4 cohorts (2 pods x 2 data) each with an update; sharded over model
    g = jax.random.normal(key, (n,), jnp.float32)
    updates = -0.1 * (g[None, None, :] +
                      0.5 * jax.random.normal(jax.random.fold_in(key, 1),
                                              (2, 2, n)))

    @jax.jit
    def run(updates, g):
        def body(u_shard, g_shard):
            u = u_shard[0, 0]           # this cohort's slice
            gs = g_shard
            flat, alpha = contextual_combine_sharded(u, gs, beta,
                                                     data_axis="data",
                                                     model_axis="model")
            hier, a_intra, a_pods = hierarchical_contextual_combine(
                u, gs, beta)
            return (flat[None, None], hier[None, None],
                    alpha[None, None], a_pods[None, None])
        return shard_map(
            body, mesh=mesh,
            in_specs=(P("pod", "data", "model"), P(None, None, "model")
                      if False else P("model")),
            out_specs=(P("pod", "data", "model"), P("pod", "data", "model"),
                       P("pod", "data", None), P("pod", "data", None)),
        )(updates, g)

    flat, hier, alpha, a_pods = run(updates, g)
    print("mesh:", dict(mesh.shape))
    print("intra-pod alpha (per pod):", np.asarray(alpha)[:, 0])
    print("cross-pod alpha:", np.asarray(a_pods)[0, 0])
    # both combines live in span(updates); hierarchical applies a second
    # contextual reweighting across pods
    print("flat combine norm:   ", float(jnp.linalg.norm(flat[0, 0])))
    print("hierarchical norm:   ", float(jnp.linalg.norm(hier[0, 0])))
    assert np.isfinite(np.asarray(hier)).all()
    print("ok: two-stage (pod -> cross-pod) contextual aggregation ran on a "
          "multi-pod mesh")


if __name__ == "__main__":
    main()
