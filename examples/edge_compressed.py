"""Compressed hierarchical FL demo: sketched gateway summaries + error feedback.

The PR-2 hierarchy already cut cloud uplink from O(K·n) to O(P·n) by
shipping one contextual summary per gateway — but ū_g and ĝ_g still rode at
full model width.  Here each gateway EF-compresses both vectors (top-k with
a 75/25 ū/ĝ byte split by default) before the backhaul hop: the cloud's
P×P contextual solve runs on the sketched cross-terms and applies exactly
the decoded updates, while per-gateway error-feedback residuals re-inject
everything the wire dropped.  The demo shows ≥4× *further* cloud-uplink
reduction over the uncompressed hierarchy at <3% final-loss gap — and a
linear-sketch variant (SRHT) whose Gram stage never touches an n-vector.

  PYTHONPATH=src python examples/edge_compressed.py     (< 2 min on CPU)

EXAMPLE_SMOKE=1 runs a tiny-step variant (CI keeps examples from rotting).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.compress import CompressConfig
from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.edge import bimodal_fleet
from repro.fl import run_hier_simulation
from repro.hier import HierConfig, two_tier_topology
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

SMOKE = os.environ.get("EXAMPLE_SMOKE", "") == "1"
DIM, N_DEV, N_GW, SEED = 60, 64, 4, 42
ROUNDS, EVAL_EVERY = (5, 2) if SMOKE else (20, 2)


def main():
    xs, ys = make_synthetic(1.0, 1.0, num_devices=N_DEV, samples_per_device=60,
                            dim=DIM, seed=2)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, DIM)[:400], ys.reshape(-1)[:400], 10)
    params = get_model(ArchConfig(name="logreg", family="logreg",
                                  input_dim=DIM, num_classes=10)
                       ).init(jax.random.PRNGKey(0))
    fleet = bimodal_fleet(N_DEV, slowdown=10.0, dropout_slow=0.05, seed=0)
    topo = two_tier_topology(fleet, N_GW)
    print(f"fleet — {fleet.describe()}")
    print(f"tree  — {topo.describe()}")

    base = dict(lr=0.2, batch_size=10, min_epochs=1, max_epochs=10)
    runs = {
        "hier (PR-2)": HierConfig(aggregator="hier_contextual", **base),
        "hier+topk": HierConfig(
            aggregator="hier_contextual_sketch",
            compress=CompressConfig(scheme="topk", ratio=3.4, u_frac=0.75),
            **base),
        "hier+srht": HierConfig(
            aggregator="hier_contextual_sketch",
            compress=CompressConfig(scheme="srht", ratio=4.0), **base),
        "hier+lowrank": HierConfig(
            aggregator="hier_contextual_sketch",
            compress=CompressConfig(scheme="lowrank", ratio=8.0,
                                    u_frac=0.75), **base),
    }
    results = {}
    for name, cfg in runs.items():
        results[name] = run_hier_simulation(
            name, logistic_loss, logistic_apply, params, ds, cfg, topo,
            num_rounds=ROUNDS, selection_seed=SEED, eval_every=EVAL_EVERY)

    header = ("method          final_loss  final_acc  cloud_uplink  "
              "vs_hier")
    print(f"\n{header}\n{'-' * len(header)}")
    hier = results["hier (PR-2)"]
    for name, r in results.items():
        print(f"{name:<15s} {r.train_loss[-1]:10.4f} {r.test_acc[-1]:10.3f} "
              f"{r.cloud_uplink_bytes / 1e6:10.3f}MB "
              f"{hier.cloud_uplink_bytes / r.cloud_uplink_bytes:6.1f}x")

    best = results["hier+topk"]
    gap = abs(best.train_loss[-1] - hier.train_loss[-1]) / hier.train_loss[-1]
    savings = hier.cloud_uplink_bytes / best.cloud_uplink_bytes
    print(f"\nhier+topk final loss is within {gap * 100:.1f}% of the "
          f"uncompressed hierarchy\ncloud-uplink bytes: {savings:.1f}x fewer "
          f"again ({hier.cloud_uplink_bytes / 1e6:.3f}MB -> "
          f"{best.cloud_uplink_bytes / 1e6:.3f}MB)")
    if gap < 0.03 and savings >= 4.0 and not SMOKE:
        print("ACCEPTANCE: loss within 3% AND >=4x fewer cloud-uplink bytes "
              "than PR-2 hier - PASS")
    elif not SMOKE:
        print("WARNING: acceptance criterion not met on this seed - inspect "
              "the table above.")
    print("\nEach gateway kept its Gram block and alpha at home, EF-"
          "compressed (u_bar_g, g_hat_g)\nand shipped only the payload; the "
          "cloud solved the PxP stage on sketched\ncross-terms and applied "
          "the decoded combinations - what the wire dropped,\nper-gateway "
          "error feedback re-injected the next round.")


if __name__ == "__main__":
    main()
