"""Mamba2 block (State-Space Duality form), chunked for TPU.

The SSD recurrence per head (state N, head dim P):

    s_t = a_t · s_{t-1} + dt_t · (B_t ⊗ x_t)       a_t = exp(dt_t · A) ∈ (0,1)
    y_t = C_t · s_t + D · x_t

is evaluated chunk-parallel (chunk Q): within a chunk the contribution is an
attention-like masked matmul; across chunks a short ``lax.scan`` propagates
the (B, H, P, N) state.  This is the canonical TPU-friendly decomposition
(quadratic-in-Q intra + linear inter), matching Mamba2's reference algorithm.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .layers import dense_init, rms_norm


class SSMState(NamedTuple):
    conv: jax.Array   # (B, W-1, conv_channels) rolling conv input window
    ssm: jax.Array    # (B, H, P, N) recurrent state


def init_mamba2(cfg: ArchConfig, key: jax.Array, dtype) -> Dict:
    d, di, N = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    H = cfg.ssm_num_heads
    conv_ch = di + 2 * N
    keys = jax.random.split(key, 5)
    return {
        # order: [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": dense_init(keys[0], d, 2 * di + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(keys[1], (cfg.ssm_conv_width, conv_ch))
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(keys[2], di, d, dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. u: (B, S, C), w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i] for i in range(W))
    return out + b


def _ssd_chunked(xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, chunk: int, init_state: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """xh (B,S,H,P), dt (B,S,H), A (H,) negative, Bm/Cm (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)), constant_values=0.0) \
            if dt.ndim == 2 else jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    # chunked views: (nc, B, Q, ...)
    xc = xh.reshape(B, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(B, nc, chunk, H).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)

    alog = dtc * A                                  # (nc,B,Q,H)  ≤ 0
    cum = jnp.cumsum(alog, axis=2)                  # inclusive cumulative

    def chunk_step(state, inp):
        xq, dtq, Bq, Cq, cumq, alq = inp            # per-chunk slices
        # intra-chunk: M[b,h,q,s] = exp(cum_q - cum_s)·dt_s·(C_q·B_s), s ≤ q
        CB = jnp.einsum("bqn,bsn->bqs", Cq.astype(jnp.float32),
                        Bq.astype(jnp.float32))
        # valid (s ≤ q) exponents are ≤ 0 (cum is non-increasing), so the
        # clamp is exact there and prevents masked-pair exp overflow from
        # poisoning gradients (inf·0 → NaN in the where-backward).
        diff = jnp.minimum(cumq[:, :, None, :] - cumq[:, None, :, :], 0.0)
        decay = jnp.exp(diff)                                       # (B,q,s,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        M = jnp.where(mask[None, :, :, None], decay, 0.0) \
            * CB[:, :, :, None] * dtq[:, None, :, :]
        y_intra = jnp.einsum("bqsh,bshp->bqhp", M,
                             xq.astype(jnp.float32))
        # inter-chunk: y_inter[q] = exp(cum_q) · C_q · state
        y_inter = jnp.einsum("bqn,bhpn->bqhp", Cq.astype(jnp.float32),
                             state) * jnp.exp(cumq)[..., None]
        # state update: s' = exp(cum_Q)·s + Σ_s exp(cum_Q − cum_s)·dt_s·x_s⊗B_s
        total = cumq[:, -1, :]                       # (B,H)
        w_s = jnp.exp(total[:, None, :] - cumq) * dtq     # (B,Q,H)
        state_new = state * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bqh,bqhp,bqn->bhpn", w_s, xq.astype(jnp.float32),
            Bq.astype(jnp.float32))
        return state_new, y_intra + y_inter

    final_state, yc = lax.scan(chunk_step, init_state.astype(jnp.float32),
                               (xc, dtc, Bc, Cc, cum, alog))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, P)[:, :S]
    return y, final_state


def mamba2_forward(cfg: ArchConfig, params: Dict, x: jax.Array,
                   init_state: SSMState | None = None
                   ) -> Tuple[jax.Array, SSMState]:
    """Full-sequence forward. x: (B, S, d) → (out (B,S,d), final SSMState)."""
    B, S, d = x.shape
    di, N, H, P = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    proj = x @ params["in_proj"]
    z, xin, Bm, Cm, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    W = cfg.ssm_conv_width
    if init_state is not None:
        # continue the causal conv across segment boundaries (prefill-then-
        # continue): prepend the carried W−1 inputs instead of zero padding
        ext = jnp.concatenate([init_state.conv.astype(conv_in.dtype),
                               conv_in], axis=1)
        conv_out = jax.nn.silu(_causal_conv(ext, params["conv_w"],
                                            params["conv_b"]))[:, W - 1:]
    else:
        conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"],
                                            params["conv_b"]))
    xin, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xin.reshape(B, S, H, P)
    state0 = (init_state.ssm if init_state is not None
              else jnp.zeros((B, H, P, N), jnp.float32))
    y, state = _ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk, state0)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]

    if init_state is not None:
        hist = jnp.concatenate([init_state.conv.astype(conv_in.dtype),
                                conv_in], axis=1)
    else:
        hist = jnp.pad(conv_in, ((0, 0), (max(W - 1 - S, 0), 0), (0, 0)))
    conv_tail = hist[:, -(W - 1):, :]
    return out, SSMState(conv_tail.astype(x.dtype), state)


def init_ssm_state(cfg: ArchConfig, batch: int, dtype) -> SSMState:
    di, N = cfg.ssm_d_inner, cfg.ssm_state
    H, P = cfg.ssm_num_heads, cfg.ssm_head_dim
    conv_ch = di + 2 * N
    return SSMState(jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
                    jnp.zeros((batch, H, P, N), jnp.float32))


def mamba2_decode(cfg: ArchConfig, params: Dict, x: jax.Array,
                  state: SSMState) -> Tuple[jax.Array, SSMState]:
    """Single-token decode. x: (B, 1, d)."""
    B = x.shape[0]
    di, N, H, P = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    proj = (x[:, 0] @ params["in_proj"])
    z, xin, Bm, Cm, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)     # (B, C)
    window = jnp.concatenate([state.conv, conv_in[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32))
        + params["conv_b"].astype(jnp.float32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                    # (B,H)
    xh = xin.reshape(B, H, P).astype(jnp.float32)
    s_new = state.ssm * a[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bm.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), s_new)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None, :]
    return out, SSMState(window[:, 1:], s_new)
