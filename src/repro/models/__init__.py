from .config import ArchConfig
from .registry import ModelBundle, get_model

__all__ = ["ArchConfig", "ModelBundle", "get_model"]
