"""Model registry — one uniform bundle per architecture family.

``ModelBundle`` is the public contract consumed by the launcher, dry-run,
benchmarks and examples:

    bundle.init(key)                          -> params
    bundle.train_loss(params, batch)          -> (scalar_loss, aux)
    bundle.forward(params, batch)             -> logits
    bundle.prefill(params, batch, max_seq)    -> (last_logits, cache)
    bundle.decode(params, token, cache)       -> (logits, cache)
    bundle.init_cache(batch_size, max_seq)    -> cache
    bundle.batch_spec(batch, seq)             -> {name: (shape, dtype)}

Batch layouts per family:
    dense/moe/ssm/hybrid: {"tokens": (B, S) int32}
    vlm:                  {"tokens": (B, S−n_img) int32,
                           "image_embeds": (B, n_img, d_model)}
    audio (whisper):      {"frames": (B, T_enc, d_model),
                           "tokens": (B, S) int32}
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .encdec import (init_whisper, whisper_decode_step, whisper_forward_train,
                     whisper_prefill)
from .layers import cross_entropy_loss
from .logistic import init_logistic, logistic_apply, logistic_loss
from .transformer import (decode_step, forward_train, init_lm, init_lm_cache,
                          prefill)
from .vlm import init_vlm, vlm_forward_train, vlm_prefill

Pytree = Any


@dataclass(frozen=True)
class ModelBundle:
    config: ArchConfig
    init: Callable
    train_loss: Callable
    forward: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable
    batch_spec: Callable


def _lm_next_token_loss(cfg: ArchConfig, params: Pytree, batch: Dict,
                        window: Optional[int] = None, remat: bool = False):
    logits, aux = forward_train(cfg, params, batch["tokens"], window=window,
                                remat=remat)
    ce = cross_entropy_loss(logits[:, :-1], batch["tokens"][:, 1:])
    return ce + cfg.router_aux_coef * aux, aux


def _vlm_loss(cfg: ArchConfig, params: Pytree, batch: Dict,
              window: Optional[int] = None, remat: bool = False):
    logits, aux = vlm_forward_train(cfg, params, batch["tokens"],
                                    batch["image_embeds"], window=window,
                                    remat=remat)
    n_img = batch["image_embeds"].shape[1]
    text_logits = logits[:, n_img:-1]          # predict text tokens only
    ce = cross_entropy_loss(text_logits, batch["tokens"][:, 1:])
    return ce + cfg.router_aux_coef * aux, aux


def _whisper_loss(cfg: ArchConfig, params: Pytree, batch: Dict,
                  window: Optional[int] = None, remat: bool = False):
    logits, aux = whisper_forward_train(cfg, params, batch["frames"],
                                        batch["tokens"], remat=remat)
    ce = cross_entropy_loss(logits[:, :-1], batch["tokens"][:, 1:])
    return ce, aux


def get_model(cfg: ArchConfig) -> ModelBundle:
    if cfg.family == "logreg":
        return ModelBundle(
            config=cfg,
            init=partial(init_logistic, cfg),
            train_loss=lambda p, b: (logistic_loss(p, b), jnp.zeros(())),
            forward=lambda p, b: logistic_apply(p, b["x"]),
            prefill=None, decode=None, init_cache=None,
            batch_spec=lambda batch, seq: {
                "x": ((batch, cfg.input_dim), jnp.float32),
                "y": ((batch,), jnp.int32)})

    if cfg.family == "audio":
        def batch_spec(batch, seq):
            return {"frames": ((batch, cfg.max_source_positions, cfg.d_model),
                               jnp.bfloat16 if cfg.dtype == "bfloat16"
                               else jnp.float32),
                    "tokens": ((batch, seq), jnp.int32)}
        return ModelBundle(
            config=cfg,
            init=partial(init_whisper, cfg),
            train_loss=partial(_whisper_loss, cfg),
            forward=lambda p, b: whisper_forward_train(cfg, p, b["frames"],
                                                       b["tokens"])[0],
            prefill=lambda p, b, max_seq: whisper_prefill(
                cfg, p, b["frames"], b["tokens"], max_seq),
            decode=lambda p, tok, cache: whisper_decode_step(cfg, p, tok, cache),
            init_cache=None,   # built by prefill
            batch_spec=batch_spec)

    if cfg.family == "vlm":
        def batch_spec(batch, seq):
            n_img = cfg.num_image_tokens
            return {"tokens": ((batch, seq - n_img), jnp.int32),
                    "image_embeds": ((batch, n_img, cfg.d_model),
                                     jnp.bfloat16 if cfg.dtype == "bfloat16"
                                     else jnp.float32)}
        return ModelBundle(
            config=cfg,
            init=partial(init_vlm, cfg),
            train_loss=partial(_vlm_loss, cfg),
            forward=lambda p, b: vlm_forward_train(cfg, p, b["tokens"],
                                                   b["image_embeds"])[0],
            prefill=lambda p, b, max_seq: vlm_prefill(
                cfg, p, b["tokens"], b["image_embeds"], max_seq),
            decode=lambda p, tok, cache: decode_step(cfg, p, tok, cache),
            init_cache=lambda batch, max_seq: init_lm_cache(cfg, batch, max_seq),
            batch_spec=batch_spec)

    # dense / moe / ssm / hybrid decoder-only LMs
    return ModelBundle(
        config=cfg,
        init=partial(init_lm, cfg),
        train_loss=partial(_lm_next_token_loss, cfg),
        forward=lambda p, b: forward_train(cfg, p, b["tokens"])[0],
        prefill=lambda p, b, max_seq: prefill(cfg, p, b["tokens"], max_seq),
        decode=lambda p, tok, cache: decode_step(cfg, p, tok, cache),
        init_cache=lambda batch, max_seq: init_lm_cache(cfg, batch, max_seq),
        batch_spec=lambda batch, seq: {"tokens": ((batch, seq), jnp.int32)})
