"""Mixture-of-Experts feed-forward (DeepSeekMoE / OLMoE style).

Fine-grained routed experts (top-k, softmax gate, renormalised) plus
optional always-on shared experts, implemented with a *sort-based capacity
dispatch* (the TPU-native alternative to ragged grouped-GEMM):

  1. top-k expert choices per token → flat (T·k,) assignment list,
  2. stable-sort by expert id; position-in-expert via a running count,
  3. scatter tokens into an (E, C, d) buffer (capacity C, overflow dropped),
  4. one batched einsum per expert group — the E axis shards over the
     ``model``/``expert`` mesh axis, so under pjit the scatter/gather lowers
     to the canonical MoE all-to-all,
  5. scatter-add back, weighted by the gate probability.

The router aux (load-balance) loss follows Switch/OLMoE:
``E · Σ_e fraction_tokens_e · mean_prob_e``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import dense_init
from .mlp import init_mlp, mlp_forward


def init_moe(cfg: ArchConfig, key: jax.Array, dtype) -> Dict:
    keys = jax.random.split(key, 5)
    E, d, ff = cfg.num_experts, cfg.d_model, cfg.d_ff
    gated = cfg.activation in ("silu", "geglu")
    p = {
        "router": dense_init(keys[0], d, E, jnp.float32, scale=0.02),
        "w_up": (jax.random.normal(keys[1], (E, d, ff)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(keys[2], (E, ff, d)) * ff ** -0.5).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(keys[3], (E, d, ff)) * d ** -0.5).astype(dtype)
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(cfg, keys[4], dtype,
                               d_ff=cfg.d_ff * cfg.num_shared_experts)
    return p


def moe_forward(cfg: ArchConfig, params: Dict, x: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.experts_per_token
    cap = int(T * k / E * cfg.moe_capacity_factor) + 1
    if T <= 256:
        # decode / tiny batches: worst-case capacity (an expert can receive at
        # most T tokens since per-token choices are distinct) → drop-free,
        # keeping decode bit-consistent with the full forward.
        cap = max(cap, T)

    xt = x.reshape(T, d)
    router_logits = xt.astype(jnp.float32) @ params["router"]      # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                          # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch): E · Σ_e f_e · P_e
    pos_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(pos_frac / k * mean_prob)

    # ---- sort-based dispatch
    flat_e = top_e.reshape(-1)                                      # (T·k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    # position of each entry within its expert
    ones = jnp.ones_like(se)
    csum = jnp.cumsum(ones) - 1
    starts = jnp.cumsum(jnp.bincount(se, length=E)) - jnp.bincount(se, length=E)
    pos_in_e = csum - starts[se]
    keep = (pos_in_e < cap).astype(x.dtype)
    slot = se * cap + jnp.minimum(pos_in_e, cap - 1)                # (T·k,)

    buf = jnp.zeros((E * cap, d), x.dtype).at[slot].add(xt[st] * keep[:, None])
    buf = buf.reshape(E, cap, d)

    # ---- expert computation (batched over E; shards over the expert axis)
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    if "w_gate" in params:
        gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        up = jax.nn.silu(gate) * up
    else:
        up = jax.nn.gelu(up)
    out_buf = jnp.einsum("ecf,efd->ecd", up, params["w_down"]).reshape(E * cap, d)

    # ---- combine back
    contrib = out_buf[slot] * (keep * sp.astype(x.dtype))[:, None]
    yt = jnp.zeros((T, d), x.dtype).at[st].add(contrib)

    if cfg.num_shared_experts:
        yt = yt + mlp_forward(cfg, params["shared"], xt)
    return yt.reshape(B, S, d), aux.astype(jnp.float32)
