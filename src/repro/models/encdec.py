"""Whisper-style encoder-decoder backbone (audio family).

The mel-spectrogram + conv feature extractor is a STUB per the brief:
``input_specs`` supplies precomputed frame embeddings (B, T_enc, d_model)
(what the conv frontend would emit at 50 Hz).  This module implements the
transformer backbone: bidirectional encoder + causal decoder with
cross-attention, sinusoidal positions (no RoPE).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .attention import (KVCache, attention_decode, attention_forward,
                        cross_attention_forward, init_attention,
                        init_kv_cache)
from .config import ArchConfig
from .layers import dtype_of, embed_init, rms_norm, sinusoidal_positions
from .mlp import init_mlp, mlp_forward

Pytree = Any


def init_whisper(cfg: ArchConfig, key: jax.Array) -> Pytree:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 6)

    def enc_block(k):
        ks = jax.random.split(k, 2)
        return {"ln1": jnp.zeros((cfg.d_model,), dtype),
                "attn": init_attention(cfg, ks[0], dtype),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "mlp": init_mlp(cfg, ks[1], dtype)}

    def dec_block(k):
        ks = jax.random.split(k, 3)
        return {"ln1": jnp.zeros((cfg.d_model,), dtype),
                "attn": init_attention(cfg, ks[0], dtype),
                "ln_x": jnp.zeros((cfg.d_model,), dtype),
                "cross": init_attention(cfg, ks[1], dtype),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "mlp": init_mlp(cfg, ks[2], dtype)}

    return {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(enc_block)(jax.random.split(keys[1], cfg.encoder_layers)),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "dec_blocks": jax.vmap(dec_block)(jax.random.split(keys[2], cfg.num_layers)),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": embed_init(keys[3], cfg.vocab_size, cfg.d_model, dtype).T,
    }


def encode(cfg: ArchConfig, params: Pytree, frames: jax.Array,
           remat=False) -> jax.Array:
    """frames: (B, T_enc, d_model) stub conv-frontend embeddings."""
    B, T, _ = frames.shape
    pos = sinusoidal_positions(T, cfg.d_model).astype(frames.dtype)
    x = frames + pos
    positions = jnp.arange(T)

    def body(h, p):
        a = rms_norm(h, p["ln1"], cfg.norm_eps)
        h = h + attention_forward(cfg, p["attn"], a, positions, mode="bidir",
                                  use_rope=False)
        m = rms_norm(h, p["ln2"], cfg.norm_eps)
        return h + mlp_forward(cfg, p["mlp"], m), None

    from .transformer import remat_wrap
    body = remat_wrap(body, remat)
    x, _ = lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(cfg: ArchConfig, p: Dict, enc_out: jax.Array):
    B, T, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(B, T, cfg.num_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(B, T, cfg.num_kv_heads, hd)
    return k, v


def _decoder(cfg: ArchConfig, params: Pytree, tokens: jax.Array,
             enc_out: jax.Array, remat=False) -> jax.Array:
    B, S = tokens.shape
    pos = sinusoidal_positions(S, cfg.d_model).astype(params["embed"].dtype)
    x = params["embed"][tokens] + pos
    positions = jnp.arange(S)

    def body(h, p):
        a = rms_norm(h, p["ln1"], cfg.norm_eps)
        h = h + attention_forward(cfg, p["attn"], a, positions, mode="causal",
                                  use_rope=False)
        c = rms_norm(h, p["ln_x"], cfg.norm_eps)
        ek, ev = _cross_kv(cfg, p["cross"], enc_out)
        h = h + cross_attention_forward(cfg, p["cross"], c, ek, ev)
        m = rms_norm(h, p["ln2"], cfg.norm_eps)
        return h + mlp_forward(cfg, p["mlp"], m), None

    from .transformer import remat_wrap
    body = remat_wrap(body, remat)
    x, _ = lax.scan(body, x, params["dec_blocks"])
    return x


def whisper_forward_train(cfg: ArchConfig, params: Pytree, frames: jax.Array,
                          tokens: jax.Array, remat=False
                          ) -> Tuple[jax.Array, jax.Array]:
    enc_out = encode(cfg, params, frames, remat)
    x = _decoder(cfg, params, tokens, enc_out, remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"], jnp.zeros((), jnp.float32)


class WhisperCache(NamedTuple):
    self_kv: KVCache       # (L, B, S_max, KV, hd)
    cross_k: jax.Array     # (L, B, T_enc, KV, hd)
    cross_v: jax.Array
    position: jax.Array


def whisper_prefill(cfg: ArchConfig, params: Pytree, frames: jax.Array,
                    tokens: jax.Array, max_seq: int
                    ) -> Tuple[jax.Array, WhisperCache]:
    """Encode audio + run the decoder prompt, building both caches."""
    dtype = dtype_of(cfg.dtype)
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    pos = sinusoidal_positions(S, cfg.d_model).astype(params["embed"].dtype)
    x = params["embed"][tokens] + pos
    positions = jnp.arange(S)

    def body(h, p):
        a = rms_norm(h, p["ln1"], cfg.norm_eps)
        attn, (k, v) = attention_forward(cfg, p["attn"], a, positions,
                                         mode="causal", use_rope=False,
                                         return_kv=True)
        h = h + attn
        c = rms_norm(h, p["ln_x"], cfg.norm_eps)
        ek, ev = _cross_kv(cfg, p["cross"], enc_out)
        h = h + cross_attention_forward(cfg, p["cross"], c, ek, ev)
        m = rms_norm(h, p["ln2"], cfg.norm_eps)
        h = h + mlp_forward(cfg, p["mlp"], m)
        return h, (k, v, ek, ev)

    x, (ks, vs, eks, evs) = lax.scan(body, x, params["dec_blocks"])
    pad = max_seq - S
    kc = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype)
    vc = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype)
    x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, WhisperCache(KVCache(kc, vc), eks, evs,
                                jnp.asarray(S, jnp.int32))


def whisper_decode_step(cfg: ArchConfig, params: Pytree, token: jax.Array,
                        cache: WhisperCache
                        ) -> Tuple[jax.Array, WhisperCache]:
    B = token.shape[0]
    posv = sinusoidal_positions(cache.self_kv.k.shape[2], cfg.d_model)
    x = params["embed"][token][:, None, :] + \
        lax.dynamic_slice_in_dim(posv, cache.position, 1, axis=0
                                 ).astype(params["embed"].dtype)
    pos = cache.position

    def body(h, inp):
        p, ck, cv, ek, ev = inp
        a = rms_norm(h, p["ln1"], cfg.norm_eps)
        attn, new_kv = attention_decode(cfg, p["attn"], a, KVCache(ck, cv),
                                        pos, use_rope=False)
        h = h + attn
        c = rms_norm(h, p["ln_x"], cfg.norm_eps)
        h = h + cross_attention_forward(cfg, p["cross"], c, ek, ev)
        m = rms_norm(h, p["ln2"], cfg.norm_eps)
        return h + mlp_forward(cfg, p["mlp"], m), new_kv

    x, new_kv = lax.scan(body, x, (params["dec_blocks"], cache.self_kv.k,
                                   cache.self_kv.v, cache.cross_k,
                                   cache.cross_v))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, cache._replace(self_kv=KVCache(new_kv.k, new_kv.v),
                                  position=pos + 1)
