"""Attention: GQA/MQA with RoPE, qk-norm, QKV-bias, logit softcap, causal /
sliding-window / bidirectional masking, flash-style chunked computation, and
single-token decode against a KV cache.

The chunked path (``flash_attention``) is the portable jnp mirror of the
Pallas TPU kernel in ``repro.kernels.decode_attn`` — double ``lax.scan``
(query blocks × KV blocks) with online-softmax accumulators, so peak memory
is O(block_q × block_k) per head rather than O(S²).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .layers import apply_rope, dense_init, rms_norm, softcap

NEG_INF = -1e30


def init_attention(cfg: ArchConfig, key: jax.Array, dtype) -> Dict:
    hd = cfg.resolved_head_dim
    keys = jax.random.split(key, 6)
    p = {
        "wq": dense_init(keys[0], cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": dense_init(keys[1], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(keys[2], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(keys[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(cfg: ArchConfig, params: Dict, x: jax.Array,
                 positions: Optional[jax.Array], use_rope: bool = True):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,KV,hd) with rope/qk-norm applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if use_rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, mode: str,
               window: Optional[int]) -> jax.Array:
    """(Sq, Sk) additive bias: 0 where attendable, NEG_INF elsewhere.
    Padded KV slots carry the sentinel position 2^30 and padded queries −1;
    both must stay masked in every mode (incl. bidir)."""
    valid_k = (k_pos >= 0) & (k_pos < 2 ** 29)
    if mode == "bidir":
        return jnp.where(valid_k[None, :], 0.0, NEG_INF) * jnp.ones(
            (q_pos.shape[0], 1), jnp.float32)
    diff = q_pos[:, None] - k_pos[None, :]
    ok = (diff >= 0) & valid_k[None, :]
    if mode == "window" and window is not None:
        ok = ok & (diff < window)
    return jnp.where(ok, 0.0, NEG_INF)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_positions: jax.Array, k_positions: jax.Array,
                    mode: str = "causal", window: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    block_q: int = 512, block_k: int = 1024) -> jax.Array:
    """Grouped-query flash attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); H = KV * G.
    Returns (B, Sq, H, hd). Online softmax over KV blocks; both sequence
    axes are processed in blocks via lax.scan so peak memory is
    O(B · H · block_q · block_k).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5

    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    kpos = jnp.pad(k_positions, (0, pad_k), constant_values=2**30)

    nq, nk = (Sq + pad_q) // block_q, (Sk + pad_k) // block_k
    # (nq, B, KV, G, bq, hd)
    qb = qp.reshape(B, nq, block_q, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = kp.reshape(B, nk, block_k, KV, hd).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(B, nk, block_k, KV, hd).transpose(1, 0, 3, 2, 4)
    qpb = qpos.reshape(nq, block_q)
    kpb = kpos.reshape(nk, block_k)

    def q_block(carry, q_in):
        qi, qpos_i = q_in                      # (B,KV,G,bq,hd), (bq,)
        qi32 = qi.astype(jnp.float32) * scale

        def kv_block(acc, kv_in):
            m, l, o = acc
            ki, vi, kpos_i = kv_in             # (B,KV,bk,hd) ×2, (bk,)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi32, ki.astype(jnp.float32))
            s = softcap(s, logit_softcap)
            s = s + _mask_bias(qpos_i, kpos_i, mode, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        init = (jnp.full((B, KV, G, block_q), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, block_q), jnp.float32),
                jnp.zeros((B, KV, G, block_q, hd), jnp.float32))
        (m, l, o), _ = lax.scan(kv_block, init, (kb, vb, kpb))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return carry, out

    _, ob = lax.scan(q_block, None, (qb, qpb))   # (nq, B, KV, G, bq, hd)
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq + pad_q, H, hd)
    return out[:, :Sq].astype(q.dtype)


def naive_attention(q, k, v, *, q_positions, k_positions, mode="causal",
                    window=None, logit_softcap=None) -> jax.Array:
    """Reference O(S²) path (smoke tests / oracles)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = softcap(s, logit_softcap)
    s = s + _mask_bias(q_positions, k_positions, mode, window)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_forward(cfg: ArchConfig, params: Dict, x: jax.Array,
                      positions: jax.Array, *, mode: str = "causal",
                      window: Optional[int] = None, use_rope: bool = True,
                      return_kv: bool = False, flash_threshold: int = 1024):
    """Full-sequence attention (train / prefill).  Returns out (B,S,d) and
    optionally the (k, v) tensors for KV-cache seeding."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x, positions, use_rope)
    kwargs = dict(q_positions=positions, k_positions=positions, mode=mode,
                  window=window, logit_softcap=cfg.attn_logit_softcap)
    if S <= flash_threshold:
        o = naive_attention(q, k, v, **kwargs)
    else:
        o = flash_attention(q, k, v, **kwargs)
    out = o.reshape(B, S, -1) @ params["wo"]
    if return_kv:
        return out, (k, v)
    return out


def cross_attention_forward(cfg: ArchConfig, params: Dict, x: jax.Array,
                            enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V (whisper)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, cfg.num_heads, hd)
    if cfg.qkv_bias:
        q = q + params["bq"].reshape(cfg.num_heads, hd)
    Se = enc_k.shape[1]
    o = naive_attention(q, enc_k, enc_v,
                        q_positions=jnp.arange(S), k_positions=jnp.arange(Se),
                        mode="bidir") if Se <= 2048 else flash_attention(
        q, enc_k, enc_v, q_positions=jnp.arange(S),
        k_positions=jnp.arange(Se), mode="bidir")
    return o.reshape(B, S, -1) @ params["wo"]


class KVCache(NamedTuple):
    k: jax.Array   # (B, S_max, KV, hd)
    v: jax.Array


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype
                  ) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, max_seq, cfg.num_kv_heads, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attention_decode(cfg: ArchConfig, params: Dict, x: jax.Array,
                     cache: KVCache, position: jax.Array, *,
                     window: Optional[int] = None, use_rope: bool = True
                     ) -> Tuple[jax.Array, KVCache]:
    """Single-token decode. x: (B, 1, d); position: scalar int (current index).

    The new K/V row is written with ``dynamic_update_slice``; attention runs
    over the whole cache with a position mask (window-limited when set).
    The KV cache may be sharded over its seq axis — the einsum + masked
    softmax lower to a sharded reduction (the Pallas flash-decode kernel is
    the TPU-optimized variant of this contraction).

    RING MODE (§Perf iteration 3): when the cache capacity is ≤ the sliding
    window, the cache is treated as a ring buffer — the new row lands at
    ``position % W`` and every resident slot is within the window by
    construction (slot j holds the unique p ≡ j (mod W) with p ≤ position),
    so HBM traffic per step is O(W), not O(max_seq).  Keys keep their
    absolute-position RoPE, so scores are identical to the dense cache.
    """
    B = x.shape[0]
    S = cache.k.shape[1]
    ring = window is not None and S <= window
    pos_arr = jnp.full((B, 1), position, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, params, x, pos_arr if use_rope else None,
                                   use_rope)
    write_at = (position % S) if ring else position
    k = lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                 (0, write_at, 0, 0))
    v = lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                 (0, write_at, 0, 0))

    hd = cfg.resolved_head_dim
    KV = cfg.num_kv_heads
    G = cfg.num_heads // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    s = softcap(s, cfg.attn_logit_softcap)
    kpos = jnp.arange(S)
    if ring:
        ok = (kpos <= position) | (position >= S)   # all slots valid once full
    else:
        ok = kpos <= position
        if window is not None:
            ok = ok & (kpos > position - window)
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    out = o.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype) @ params["wo"]
    return out, KVCache(k, v)


def attention_decode_slots(cfg: ArchConfig, params: Dict, x: jax.Array,
                           cache: KVCache, positions: jax.Array, *,
                           window: Optional[int] = None,
                           use_rope: bool = True,
                           active: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, KVCache]:
    """Continuous-batching decode: one token per slot at per-slot positions.

    x: (B, 1, d); positions: (B,) int32, each slot's current index (its row
    count so far); active: (B,) bool, which slots hold a live decoding
    request.  Unlike :func:`attention_decode` the batch rows are
    independent requests at different depths, so the new K/V row is
    scattered per slot and the contraction runs through the registry's
    ``flash_decode`` op, whose per-batch ``lengths`` masking is exactly the
    per-slot contract (window masking included; no ring mode — the serve
    cache is allocated at full ``max_seq``).  Rows at index ≥ a slot's
    length may hold garbage from retired requests or padded prefill chunks;
    they are never attended and are overwritten before becoming visible
    (the engine writes row ``p`` exactly when a slot's position reaches
    ``p``).  Inactive slots must not write at all — their ``positions`` may
    be stale (a retired request's stop index, or 0 for a fresh slot) and a
    scatter there would corrupt rows another request is concurrently
    chunk-prefilling into the slot — so their writes are routed to the
    out-of-bounds row ``S`` and dropped."""
    from ..kernels import ops as kops    # deferred: models must import light
    B = x.shape[0]
    pos_arr = positions[:, None]                       # (B, 1) for RoPE
    q, k_new, v_new = _project_qkv(cfg, params, x,
                                   pos_arr if use_rope else None, use_rope)
    b_idx = jnp.arange(B)
    S = cache.k.shape[1]
    write_at = positions if active is None else jnp.where(active, positions, S)
    k = cache.k.at[b_idx, write_at].set(k_new[:, 0].astype(cache.k.dtype),
                                        mode="drop")
    v = cache.v.at[b_idx, write_at].set(v_new[:, 0].astype(cache.v.dtype),
                                        mode="drop")

    hd = cfg.resolved_head_dim
    KV = cfg.num_kv_heads
    G = cfg.num_heads // KV
    o, _ = kops.flash_decode(q.reshape(B, KV, G, hd), k, v, positions + 1,
                             window=window, softcap=cfg.attn_logit_softcap)
    out = o.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype) @ params["wo"]
    return out, KVCache(k, v)


def ring_place(k_stack: jax.Array, capacity: int) -> jax.Array:
    """Place prompt K/V rows (…, S, KV, hd) into a ring cache of ``capacity``
    slots: the last ``capacity`` rows land at their position-mod-W slots."""
    S = k_stack.shape[-3]
    if S <= capacity:
        pad = [(0, 0)] * k_stack.ndim
        pad[-3] = (0, capacity - S)
        return jnp.pad(k_stack, pad)
    rows = k_stack[..., S - capacity:, :, :]
    slots = jnp.arange(S - capacity, S) % capacity
    out = jnp.zeros(k_stack.shape[:-3] + (capacity,) + k_stack.shape[-2:],
                    k_stack.dtype)
    return out.at[..., slots, :, :].set(rows)
