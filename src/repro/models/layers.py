"""Shared layer primitives (pure JAX, pytree params)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def dtype_of(name: str) -> jnp.dtype:
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------- init utils

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------- norms

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------- RoPE

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                     # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num_positions: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (f32)."""
    pos = jnp.arange(num_positions, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ----------------------------------------------------------------- activations

def activation_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "geglu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


# -------------------------------------------------------------------- losses

def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       weights: Optional[jax.Array] = None) -> jax.Array:
    """Mean token-level CE. logits (..., V), labels (...,) int."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if weights is None:
        return jnp.mean(nll)
    w = weights.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
