"""Multinomial logistic regression — the paper's own experimental model."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import cross_entropy_loss

Pytree = Any


def init_logistic(cfg: ArchConfig, key: jax.Array) -> Pytree:
    return {
        "w": (jax.random.normal(key, (cfg.input_dim, cfg.num_classes)) * 0.01
              ).astype(jnp.float32),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }


def logistic_apply(params: Pytree, x: jax.Array) -> jax.Array:
    return x @ params["w"] + params["b"]


def logistic_loss(params: Pytree, batch) -> jax.Array:
    """batch = (x, y, sample_weights)."""
    x, y, w = batch
    return cross_entropy_loss(logistic_apply(params, x), y, w)


def make_mlp_classifier(cfg: ArchConfig, hidden: int = 128):
    """2-layer MLP classifier (a DNN variant for the last-layer-scope
    experiments — the paper's §III-B efficiency note targets DNNs)."""
    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "hidden": {"w": (jax.random.normal(k1, (cfg.input_dim, hidden))
                             * cfg.input_dim ** -0.5).astype(jnp.float32),
                       "b": jnp.zeros((hidden,), jnp.float32)},
            "head": {"w": (jax.random.normal(k2, (hidden, cfg.num_classes))
                           * hidden ** -0.5).astype(jnp.float32),
                     "b": jnp.zeros((cfg.num_classes,), jnp.float32)},
        }

    def apply(params, x):
        h = jax.nn.relu(x @ params["hidden"]["w"] + params["hidden"]["b"])
        return h @ params["head"]["w"] + params["head"]["b"]

    def loss(params, batch):
        x, y, w = batch
        return cross_entropy_loss(apply(params, x), y, w)

    return init, apply, loss
