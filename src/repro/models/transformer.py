"""Decoder-only LM assembly for the dense / MoE / SSM / hybrid families.

Uniform layers are *stacked* (leading L axis) and driven with ``lax.scan`` so
the lowered HLO stays compact for 40-64 layer architectures; the Zamba2
hybrid interleaves scanned Mamba2 groups with a single SHARED attention
block (its defining feature) applied every ``attn_every`` layers.

Three entry points per model (what the dry-run lowers):
  * ``forward_train`` — full-sequence teacher-forced logits (+ MoE aux loss)
  * ``prefill``       — full sequence, returns last-token logits + caches
  * ``decode_step``   — one token against the cache (serve_step)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .attention import (KVCache, _project_qkv, attention_decode,
                        attention_decode_slots, attention_forward,
                        flash_attention, init_attention, init_kv_cache,
                        naive_attention)
from .config import ArchConfig
from .layers import dtype_of, embed_init, rms_norm
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward
from .rwkv import (RWKVState, init_rwkv6, init_rwkv_state, rwkv6_decode,
                   rwkv6_forward)
from .ssd import (SSMState, init_mamba2, init_ssm_state, mamba2_decode,
                  mamba2_forward)

Pytree = Any


# --------------------------------------------------------------------- init

def _init_block(cfg: ArchConfig, key: jax.Array, dtype) -> Dict:
    """One layer's params for the uniform-stack families."""
    keys = jax.random.split(key, 3)
    if cfg.family == "ssm" and cfg.rwkv:
        return {"ln1": jnp.zeros((cfg.d_model,), dtype),
                "rwkv": init_rwkv6(cfg, keys[0], dtype)}
    if cfg.family == "ssm":
        return {"ln1": jnp.zeros((cfg.d_model,), dtype),
                "mamba": init_mamba2(cfg, keys[0], dtype)}
    block = {"ln1": jnp.zeros((cfg.d_model,), dtype),
             "ln2": jnp.zeros((cfg.d_model,), dtype),
             "attn": init_attention(cfg, keys[0], dtype)}
    if cfg.family == "moe":
        block["moe"] = init_moe(cfg, keys[1], dtype)
    else:
        block["mlp"] = init_mlp(cfg, keys[1], dtype)
    return block


def init_lm(cfg: ArchConfig, key: jax.Array) -> Pytree:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], cfg.vocab_size, cfg.d_model,
                                       dtype).T

    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.attn_every
        rem = cfg.num_layers % cfg.attn_every
        def make_mamba(k):
            return {"ln1": jnp.zeros((cfg.d_model,), dtype),
                    "mamba": init_mamba2(cfg, k, dtype)}
        gk = jax.random.split(keys[2], n_groups * cfg.attn_every)
        params["blocks"] = jax.vmap(make_mamba)(
            gk.reshape(n_groups * cfg.attn_every, -1))
        # reshape leading axis to (n_groups, attn_every)
        params["blocks"] = jax.tree_util.tree_map(
            lambda p: p.reshape((n_groups, cfg.attn_every) + p.shape[1:]),
            params["blocks"])
        if rem:
            rk = jax.random.split(keys[3], rem)
            params["blocks_rem"] = jax.vmap(make_mamba)(rk)
        # the SHARED transformer block (attention + MLP)
        params["shared_attn"] = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(cfg, keys[4], dtype),
            "mlp": init_mlp(cfg, keys[5], dtype),
        }
    else:
        lk = jax.random.split(keys[2], cfg.num_layers)
        params["blocks"] = jax.vmap(lambda k: _init_block(cfg, k, dtype))(lk)
    return params


# ------------------------------------------------------------------ forward

def _block_forward(cfg: ArchConfig, p: Dict, x: jax.Array,
                   positions: jax.Array, window: Optional[int],
                   state_in=None, return_kv: bool = False):
    """One layer. Returns (x, aux, extra) where extra is kv or new ssm state."""
    aux = jnp.zeros((), jnp.float32)
    extra = None
    if "rwkv" in p:
        out, extra = rwkv6_forward(cfg, p["rwkv"],
                                   rms_norm(x, p["ln1"], cfg.norm_eps),
                                   state_in)
        return x + out, aux, extra
    if "mamba" in p:
        out, extra = mamba2_forward(cfg, p["mamba"],
                                    rms_norm(x, p["ln1"], cfg.norm_eps),
                                    state_in)
        return x + out, aux, extra
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    mode = "window" if window is not None else "causal"
    if return_kv:
        attn, kv = attention_forward(cfg, p["attn"], h, positions, mode=mode,
                                     window=window, return_kv=True)
        extra = kv
    else:
        attn = attention_forward(cfg, p["attn"], h, positions, mode=mode,
                                 window=window)
    x = x + attn
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        ff, aux = moe_forward(cfg, p["moe"], h)
    else:
        ff = mlp_forward(cfg, p["mlp"], h)
    return x + ff, aux, extra


def _logits(cfg: ArchConfig, params: Pytree, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def remat_wrap(body, remat):
    """Apply the activation-checkpoint policy to a scanned layer body.

    ``remat``: False/None → no remat; True/"full" → checkpoint everything
    (maximum recompute, minimum memory — the baseline policy);
    "dots" → ``dots_with_no_batch_dims_saveable`` (save matmul outputs,
    recompute only cheap elementwise ops — §Perf iteration 2)."""
    if not remat:
        return body
    if remat in (True, "full"):
        return jax.checkpoint(body)
    if remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(f"unknown remat policy {remat!r}")


def forward_train(cfg: ArchConfig, params: Pytree, tokens: jax.Array,
                  window: Optional[int] = None, remat=False
                  ) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) → (logits (B,S,V), aux_loss).

    ``remat`` selects the per-layer activation-checkpoint policy
    (see :func:`remat_wrap`)."""
    B, S = tokens.shape
    window = window if window is not None else cfg.sliding_window
    x = params["embed"][tokens]
    positions = jnp.arange(S)

    if cfg.family == "hybrid":
        x, aux = _hybrid_forward(cfg, params, x, positions, window, remat)
    else:
        def body(carry, layer_p):
            h, aux = carry
            h, a, _ = _block_forward(cfg, layer_p, h, positions, window)
            return (h, aux + a), None
        body = remat_wrap(body, remat)
        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return _logits(cfg, params, x), aux


def _hybrid_forward(cfg: ArchConfig, params: Pytree, x: jax.Array,
                    positions: jax.Array, window: Optional[int],
                    remat=False):
    """Zamba2: scanned Mamba2 groups + the shared attention block between
    groups (same weights every invocation)."""
    shared = params["shared_attn"]

    def mamba_body(h, layer_p):
        h, _, _ = _block_forward(cfg, layer_p, h, positions, None)
        return h, None

    mamba_body = remat_wrap(mamba_body, remat)

    def group_body(h, group_p):
        h, _ = lax.scan(mamba_body, h, group_p)
        # shared attention block
        a = rms_norm(h, shared["ln1"], cfg.norm_eps)
        mode = "window" if window is not None else "causal"
        h = h + attention_forward(cfg, shared["attn"], a, positions,
                                  mode=mode, window=window)
        m = rms_norm(h, shared["ln2"], cfg.norm_eps)
        h = h + mlp_forward(cfg, shared["mlp"], m)
        return h, None

    x, _ = lax.scan(group_body, x, params["blocks"])
    if "blocks_rem" in params:
        x, _ = lax.scan(mamba_body, x, params["blocks_rem"])
    return x, jnp.zeros((), jnp.float32)


# ------------------------------------------------------------ prefill/decode

class LMCache(NamedTuple):
    """Per-family cache container (unused fields are None)."""
    kv: Optional[KVCache]            # (L, B, S, KVH, hd) stacked over layers
    ssm: Optional[Any]               # stacked SSMState / RWKVState
    shared_kv: Optional[KVCache]     # hybrid: (G, B, S, KVH, hd)
    position: jax.Array


def cache_capacity(cfg: ArchConfig, max_seq: int) -> int:
    """KV-cache slots: ring-buffer bounded by the sliding window (§Perf #3)."""
    if cfg.sliding_window is not None:
        return min(max_seq, cfg.sliding_window)
    return max_seq


def init_lm_cache(cfg: ArchConfig, batch: int, max_seq: int,
                  ring: bool = True) -> LMCache:
    """``ring=False`` allocates the full ``max_seq`` capacity even for
    window-bounded archs — the serve engine's layout, where per-slot
    absolute positions index rows directly and the window is enforced by
    ``flash_decode`` masking instead of ring placement."""
    dtype = dtype_of(cfg.dtype)
    cap = cache_capacity(cfg, max_seq) if ring else max_seq
    stack = lambda tree, n: jax.tree_util.tree_map(
        lambda z: jnp.broadcast_to(z, (n,) + z.shape), tree)
    kv = ssm = shared = None
    if cfg.family in ("dense", "moe", "vlm"):
        kv = stack(init_kv_cache(cfg, batch, cap, dtype), cfg.num_layers)
    elif cfg.family == "ssm" and cfg.rwkv:
        ssm = stack(init_rwkv_state(cfg, batch, dtype), cfg.num_layers)
    elif cfg.family == "ssm":
        ssm = stack(init_ssm_state(cfg, batch, dtype), cfg.num_layers)
    elif cfg.family == "hybrid":
        ssm = stack(init_ssm_state(cfg, batch, dtype), cfg.num_layers)
        n_groups = cfg.num_layers // cfg.attn_every
        shared = stack(init_kv_cache(cfg, batch, cap, dtype), n_groups)
    return LMCache(kv, ssm, shared, jnp.zeros((), jnp.int32))


def prefill(cfg: ArchConfig, params: Pytree, tokens: jax.Array,
            max_seq: int, window: Optional[int] = None
            ) -> Tuple[jax.Array, LMCache]:
    """Run the full prompt, build the cache, return last-position logits."""
    B, S = tokens.shape
    window = window if window is not None else cfg.sliding_window
    x = params["embed"][tokens]
    positions = jnp.arange(S)
    cache = init_lm_cache(cfg, B, max_seq)

    if cfg.family == "hybrid":
        x, new_ssm, new_shared = _hybrid_prefill(cfg, params, x, positions,
                                                 window, cache, S)
        cache = cache._replace(ssm=new_ssm, shared_kv=new_shared,
                               position=jnp.asarray(S, jnp.int32))
    elif cfg.family == "ssm":
        def body(carry, inp):
            h = carry
            layer_p, st = inp
            h, _, new_state = _block_forward(cfg, layer_p, h, positions, None,
                                             state_in=st)
            return h, new_state
        x, new_states = lax.scan(body, x, (params["blocks"], cache.ssm))
        cache = cache._replace(ssm=new_states,
                               position=jnp.asarray(S, jnp.int32))
    else:
        def body(carry, layer_p):
            h = carry
            h, _, kv = _block_forward(cfg, layer_p, h, positions, window,
                                      return_kv=True)
            return h, kv
        x, kvs = lax.scan(body, x, params["blocks"])
        k_stack, v_stack = kvs
        # place prompt K/V into the cache (ring-placed when window-bounded)
        from .attention import ring_place
        cap = cache_capacity(cfg, max_seq)
        kc = ring_place(k_stack, cap)
        vc = ring_place(v_stack, cap)
        cache = cache._replace(kv=KVCache(kc.astype(dtype_of(cfg.dtype)),
                                          vc.astype(dtype_of(cfg.dtype))),
                               position=jnp.asarray(S, jnp.int32))
    logits = _logits(cfg, params, x[:, -1:, :])
    return logits[:, 0], cache


def _hybrid_prefill(cfg, params, x, positions, window, cache, S):
    from .attention import ring_place
    shared = params["shared_attn"]
    n_groups = cfg.num_layers // cfg.attn_every
    capacity = cache.shared_kv.k.shape[2]

    # remainder layers' states live at the tail of cache.ssm
    main_ssm = jax.tree_util.tree_map(
        lambda z: z[:n_groups * cfg.attn_every].reshape(
            (n_groups, cfg.attn_every) + z.shape[1:]), cache.ssm)
    rem = cfg.num_layers % cfg.attn_every
    rem_ssm = jax.tree_util.tree_map(lambda z: z[n_groups * cfg.attn_every:],
                                     cache.ssm)

    def mamba_body(h, inp):
        layer_p, st = inp
        h, _, new_state = _block_forward(cfg, layer_p, h, positions, None,
                                         state_in=st)
        return h, new_state

    def group_body(h, inp):
        group_p, g_ssm = inp
        h, new_states = lax.scan(mamba_body, h, (group_p, g_ssm))
        a = rms_norm(h, shared["ln1"], cfg.norm_eps)
        mode = "window" if window is not None else "causal"
        attn, (k, v) = attention_forward(cfg, shared["attn"], a, positions,
                                         mode=mode, window=window,
                                         return_kv=True)
        h = h + attn
        m = rms_norm(h, shared["ln2"], cfg.norm_eps)
        h = h + mlp_forward(cfg, shared["mlp"], m)
        return h, (new_states, KVCache(ring_place(k, capacity),
                                       ring_place(v, capacity)))

    x, (new_main_ssm, shared_kv) = lax.scan(group_body, x,
                                            (params["blocks"], main_ssm))
    new_ssm_flat = jax.tree_util.tree_map(
        lambda z: z.reshape((n_groups * cfg.attn_every,) + z.shape[2:]),
        new_main_ssm)
    if rem:
        x, new_rem = lax.scan(mamba_body, x, (params["blocks_rem"], rem_ssm))
        new_ssm_flat = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), new_ssm_flat, new_rem)
    return x, new_ssm_flat, shared_kv


def decode_step(cfg: ArchConfig, params: Pytree, token: jax.Array,
                cache: LMCache, window: Optional[int] = None
                ) -> Tuple[jax.Array, LMCache]:
    """token (B,) int32 → (logits (B,V), updated cache)."""
    B = token.shape[0]
    window = window if window is not None else cfg.sliding_window
    x = params["embed"][token][:, None, :]     # (B,1,d)
    pos = cache.position

    if cfg.family == "hybrid":
        x, new_ssm, new_shared = _hybrid_decode(cfg, params, x, cache, window)
        new_cache = cache._replace(ssm=new_ssm, shared_kv=new_shared,
                                   position=pos + 1)
    elif cfg.family == "ssm":
        step = rwkv6_decode if cfg.rwkv else mamba2_decode
        name = "rwkv" if cfg.rwkv else "mamba"
        def body(h, inp):
            layer_p, st = inp
            out, new_state = step(cfg, layer_p[name],
                                  rms_norm(h, layer_p["ln1"], cfg.norm_eps), st)
            return h + out, new_state
        x, new_states = lax.scan(body, x, (params["blocks"], cache.ssm))
        new_cache = cache._replace(ssm=new_states, position=pos + 1)
    else:
        def body(h, inp):
            layer_p, ck, cv = inp
            a = rms_norm(h, layer_p["ln1"], cfg.norm_eps)
            attn, new_kv = attention_decode(cfg, layer_p["attn"], a,
                                            KVCache(ck, cv), pos,
                                            window=window)
            h = h + attn
            m = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
            if "moe" in layer_p:
                ff, _ = moe_forward(cfg, layer_p["moe"], m)
            else:
                ff = mlp_forward(cfg, layer_p["mlp"], m)
            return h + ff, new_kv
        x, new_kv = lax.scan(body, x, (params["blocks"], cache.kv.k,
                                       cache.kv.v))
        new_cache = cache._replace(kv=KVCache(new_kv.k, new_kv.v),
                                   position=pos + 1)
    logits = _logits(cfg, params, x)
    return logits[:, 0], new_cache


def decode_slots(cfg: ArchConfig, params: Pytree, token: jax.Array,
                 cache: LMCache, positions: jax.Array,
                 window: Optional[int] = None,
                 active: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, LMCache]:
    """Continuous-batching decode step: token (B,), positions (B,) int32 —
    each batch row is an independent request at its own depth (the serve
    engine's per-slot contract).  KV-cache families only (dense/moe/vlm
    text decode); ``cache.position`` is ignored — the engine owns per-slot
    positions.  ``active`` (B,) bool marks slots holding a live request;
    inactive slots' K/V writes are dropped (their positions may be stale
    and the row can belong to a request being chunk-prefilled into the
    slot).  Returns (logits (B, V), updated cache)."""
    if cache.kv is None:
        raise ValueError("decode_slots needs a KV-cache family "
                         f"(dense/moe/vlm), got {cfg.family!r}")
    window = window if window is not None else cfg.sliding_window

    x = params["embed"][token][:, None, :]     # (B,1,d)

    def body(h, inp):
        layer_p, ck, cv = inp
        a = rms_norm(h, layer_p["ln1"], cfg.norm_eps)
        attn, new_kv = attention_decode_slots(cfg, layer_p["attn"], a,
                                              KVCache(ck, cv), positions,
                                              window=window, active=active)
        h = h + attn
        m = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
        if "moe" in layer_p:
            ff, _ = moe_forward(cfg, layer_p["moe"], m)
        else:
            ff = mlp_forward(cfg, layer_p["mlp"], m)
        return h + ff, new_kv

    x, new_kv = lax.scan(body, x, (params["blocks"], cache.kv.k, cache.kv.v))
    new_cache = cache._replace(kv=KVCache(new_kv.k, new_kv.v))
    logits = _logits(cfg, params, x)
    return logits[:, 0], new_cache


def prefill_chunk(cfg: ArchConfig, params: Pytree, tokens: jax.Array,
                  cache: LMCache, slot: jax.Array, start: jax.Array,
                  window: Optional[int] = None
                  ) -> Tuple[jax.Array, LMCache]:
    """One chunk of an incremental single-request prefill into ``slot``.

    tokens (C,) int32 occupy absolute positions [start, start+C) of the
    slot's row space; K/V rows are written into the engine cache (allocated
    ``ring=False``) and the chunk's queries attend to the slot's whole row
    space under a causal/window mask — rows at positions ≥ start+C are
    unwritten (or retired-request garbage) but carry k-positions above every
    query position, so the causal mask excludes them.  Long prompts are fed
    as successive chunks, so resident decode slots never stall behind one
    monolithic prompt.  Returns (logits (C, V), cache)."""
    if cache.kv is None:
        raise ValueError("prefill_chunk needs a KV-cache family "
                         f"(dense/moe), got {cfg.family!r}")
    window = window if window is not None else cfg.sliding_window
    C = tokens.shape[0]
    S = cache.kv.k.shape[2]
    positions = start + jnp.arange(C)
    x = params["embed"][tokens][None]          # (1, C, d)
    mode = "window" if window is not None else "causal"

    def body(h, inp):
        layer_p, ck, cv = inp                  # ck/cv (B, S, KV, hd)
        a = rms_norm(h, layer_p["ln1"], cfg.norm_eps)
        q, k_new, v_new = _project_qkv(cfg, layer_p["attn"], a,
                                       positions[None])
        ck = lax.dynamic_update_slice(ck, k_new.astype(ck.dtype),
                                      (slot, start, 0, 0))
        cv = lax.dynamic_update_slice(cv, v_new.astype(cv.dtype),
                                      (slot, start, 0, 0))
        ks = lax.dynamic_slice(ck, (slot, 0, 0, 0), (1,) + ck.shape[1:])
        vs = lax.dynamic_slice(cv, (slot, 0, 0, 0), (1,) + cv.shape[1:])
        kwargs = dict(q_positions=positions, k_positions=jnp.arange(S),
                      mode=mode, window=window,
                      logit_softcap=cfg.attn_logit_softcap)
        if S <= 1024:
            o = naive_attention(q, ks, vs, **kwargs)
        else:
            o = flash_attention(q, ks, vs, **kwargs)
        h = h + o.reshape(1, C, -1) @ layer_p["attn"]["wo"]
        m = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
        if "moe" in layer_p:
            ff, _ = moe_forward(cfg, layer_p["moe"], m)
        else:
            ff = mlp_forward(cfg, layer_p["mlp"], m)
        return h + ff, (ck, cv)

    x, (k_all, v_all) = lax.scan(body, x, (params["blocks"], cache.kv.k,
                                           cache.kv.v))
    new_cache = cache._replace(kv=KVCache(k_all, v_all))
    logits = _logits(cfg, params, x)
    return logits[0], new_cache


def _hybrid_decode(cfg, params, x, cache: LMCache, window):
    shared = params["shared_attn"]
    n_groups = cfg.num_layers // cfg.attn_every
    rem = cfg.num_layers % cfg.attn_every
    pos = cache.position

    main_ssm = jax.tree_util.tree_map(
        lambda z: z[:n_groups * cfg.attn_every].reshape(
            (n_groups, cfg.attn_every) + z.shape[1:]), cache.ssm)
    rem_ssm = jax.tree_util.tree_map(lambda z: z[n_groups * cfg.attn_every:],
                                     cache.ssm)

    def mamba_body(h, inp):
        layer_p, st = inp
        out, new_state = mamba2_decode(
            cfg, layer_p["mamba"], rms_norm(h, layer_p["ln1"], cfg.norm_eps), st)
        return h + out, new_state

    def group_body(h, inp):
        group_p, g_ssm, ck, cv = inp
        h, new_states = lax.scan(mamba_body, h, (group_p, g_ssm))
        a = rms_norm(h, shared["ln1"], cfg.norm_eps)
        attn, new_kv = attention_decode(cfg, shared["attn"], a,
                                        KVCache(ck, cv), pos, window=window)
        h = h + attn
        m = rms_norm(h, shared["ln2"], cfg.norm_eps)
        h = h + mlp_forward(cfg, shared["mlp"], m)
        return h, (new_states, new_kv)

    x, (new_main, new_shared) = lax.scan(
        group_body, x, (params["blocks"], main_ssm,
                        cache.shared_kv.k, cache.shared_kv.v))
    new_flat = jax.tree_util.tree_map(
        lambda z: z.reshape((n_groups * cfg.attn_every,) + z.shape[2:]),
        new_main)
    if rem:
        x, new_rem = lax.scan(mamba_body, x, (params["blocks_rem"], rem_ssm))
        new_flat = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), new_flat, new_rem)
    return x, new_flat, KVCache(new_shared.k, new_shared.v)
