"""Gated MLP (SwiGLU / GeGLU) and plain GELU feed-forward."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import activation_fn, dense_init


def init_mlp(cfg: ArchConfig, key: jax.Array, dtype, d_ff: int = 0) -> Dict:
    d_ff = d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    p = {"w_up": dense_init(keys[0], cfg.d_model, d_ff, dtype),
         "w_down": dense_init(keys[1], d_ff, cfg.d_model, dtype)}
    if cfg.activation in ("silu", "geglu"):
        p["w_gate"] = dense_init(keys[2], cfg.d_model, d_ff, dtype)
    return p


def mlp_forward(cfg: ArchConfig, params: Dict, x: jax.Array) -> jax.Array:
    act = activation_fn(cfg.activation)
    up = x @ params["w_up"]
    if "w_gate" in params:
        up = act(x @ params["w_gate"]) * up
    else:
        up = act(up)
    return up @ params["w_down"]
