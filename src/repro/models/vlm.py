"""Chameleon-style early-fusion VLM backbone.

Chameleon tokenizes images into VQ codes consumed by the same decoder as
text.  Per the brief the image tokenizer is a STUB: ``input_specs`` provides
precomputed patch/code embeddings (B, n_img, d_model); this module projects
and concatenates them ahead of the text tokens in one causal stream — the
defining early-fusion pattern — and otherwise reuses the dense decoder.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import dense_init, dtype_of
from .transformer import (LMCache, _logits, decode_step, forward_train,
                          init_lm, init_lm_cache, prefill)

Pytree = Any


def init_vlm(cfg: ArchConfig, key: jax.Array) -> Pytree:
    k1, k2 = jax.random.split(key)
    params = init_lm(cfg, k1)
    params["img_proj"] = dense_init(k2, cfg.d_model, cfg.d_model,
                                    dtype_of(cfg.dtype))
    return params


def _fuse(cfg: ArchConfig, params: Pytree, tokens: jax.Array,
          image_embeds: jax.Array) -> jax.Array:
    """[projected image embeddings ; text embeddings] along the seq axis."""
    img = image_embeds.astype(params["embed"].dtype) @ params["img_proj"]
    txt = params["embed"][tokens]
    return jnp.concatenate([img, txt], axis=1)


def vlm_forward_train(cfg: ArchConfig, params: Pytree, tokens: jax.Array,
                      image_embeds: jax.Array, window=None,
                      remat=False) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S_text), image_embeds (B, n_img, d).  Returns logits over
    the FULL fused sequence (loss masks the image positions)."""
    from jax import lax

    from .transformer import _block_forward

    window = window if window is not None else cfg.sliding_window
    x = _fuse(cfg, params, tokens, image_embeds)
    positions = jnp.arange(x.shape[1])

    def body(carry, layer_p):
        h, aux = carry
        h, a, _ = _block_forward(cfg, layer_p, h, positions, window)
        return (h, aux + a), None

    from .transformer import remat_wrap
    body = remat_wrap(body, remat)
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                           params["blocks"])
    return _logits(cfg, params, x), aux


def vlm_prefill(cfg: ArchConfig, params: Pytree, tokens: jax.Array,
                image_embeds: jax.Array, max_seq: int, window=None
                ) -> Tuple[jax.Array, LMCache]:
    """Prefill over the fused stream; decode then continues text-only."""
    from jax import lax

    from .attention import KVCache
    from .layers import rms_norm
    from .transformer import _block_forward

    window = window if window is not None else cfg.sliding_window
    x = _fuse(cfg, params, tokens, image_embeds)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    cache = init_lm_cache(cfg, B, max_seq)

    def body(h, layer_p):
        h, _, kv = _block_forward(cfg, layer_p, h, positions, window,
                                  return_kv=True)
        return h, kv

    x, (ks, vs) = lax.scan(body, x, params["blocks"])
    from .attention import ring_place
    from .transformer import cache_capacity
    cap = cache_capacity(cfg, max_seq)
    kc, vc = ring_place(ks, cap), ring_place(vs, cap)
    dt = dtype_of(cfg.dtype)
    cache = cache._replace(kv=KVCache(kc.astype(dt), vc.astype(dt)),
                           position=jnp.asarray(S, jnp.int32))
    logits = _logits(cfg, params, x[:, -1:, :])
    return logits[:, 0], cache


vlm_decode_step = decode_step   # decode continues text-only — same as dense
