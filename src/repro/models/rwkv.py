"""RWKV6 ("Finch") block — linear attention with data-dependent per-channel
decay, plus the channel-mix FFN.

Time-mix recurrence per head (key/value dim P, state S ∈ R^{P×P}):

    S_t = diag(w_t) · S_{t-1} + k_t ⊗ v_t
    y_t = r_t · (diag(u) · (k_t ⊗ v_t) + S_{t-1})

with w_t ∈ (0,1)^P produced by the token-shifted LoRA decay path (the
"data-dependent decay" that distinguishes RWKV6 from RWKV4/5).

Training uses a chunk-parallel evaluation: within a chunk the pairwise
decay products are materialised per channel on (Q, Q, P) tiles (Q small),
across chunks a ``lax.scan`` carries the state.  Decode is the O(1)
recurrence.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .layers import dense_init, layer_norm, rms_norm


class RWKVState(NamedTuple):
    shift: jax.Array   # (B, d) previous token's features (token shift)
    wkv: jax.Array     # (B, H, P, P) linear-attention state
    shift_ffn: jax.Array  # (B, d) token shift for channel-mix


def init_rwkv6(cfg: ArchConfig, key: jax.Array, dtype) -> Dict:
    d, P = cfg.d_model, cfg.rwkv_head_dim
    H = cfg.rwkv_num_heads
    lora = max(d // 16, 32)
    keys = jax.random.split(key, 12)
    return {
        # token-shift interpolation coefficients for r,k,v,g,w
        "mu": (jax.random.uniform(keys[0], (5, d)) * 0.5 + 0.25).astype(dtype),
        "wr": dense_init(keys[1], d, d, dtype),
        "wk": dense_init(keys[2], d, d, dtype),
        "wv": dense_init(keys[3], d, d, dtype),
        "wg": dense_init(keys[4], d, d, dtype),
        "wo": dense_init(keys[5], d, d, dtype),
        # data-dependent decay LoRA: w = exp(−exp(w0 + tanh(x·A)·B))
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w_A": dense_init(keys[6], d, lora, dtype),
        "w_B": dense_init(keys[7], lora, d, dtype, scale=0.01),
        "u": (jax.random.normal(keys[8], (H, P)) * 0.1).astype(jnp.float32),
        "ln_x_w": jnp.ones((d,), dtype),
        "ln_x_b": jnp.zeros((d,), dtype),
        # channel-mix
        "mu_ffn": (jax.random.uniform(keys[9], (2, d)) * 0.5 + 0.25).astype(dtype),
        "ffn_k": dense_init(keys[10], d, cfg.d_ff, dtype),
        "ffn_v": dense_init(keys[11], cfg.d_ff, d, dtype),
        "ffn_r": dense_init(jax.random.fold_in(keys[10], 1), d, d, dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x (B,S,d) -> x shifted right by one, first slot = prev (B,d)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_chunked(r, k, v, logw, u, chunk: int, init_state):
    """r,k,v,logw: (B,S,H,P) (logw ≤ 0); u: (H,P).
    Returns (y (B,S,H,P), final_state (B,H,P,P))."""
    B, S, H, P = r.shape
    pad = (-S) % chunk
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        logw = jnp.pad(logw, z)  # log 1 = 0 → identity decay on padding
    Sp = S + pad
    nc = Sp // chunk
    resh = lambda t: t.reshape(B, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, lw = map(resh, (r, k, v, logw))
    cum = jnp.cumsum(lw, axis=2)          # (nc,B,Q,H,P) inclusive

    def chunk_step(state, inp):
        rq, kq, vq, cumq, lwq = inp       # (B,Q,H,P) …
        rq32, kq32, vq32 = (t.astype(jnp.float32) for t in (rq, kq, vq))
        # y_q reads S_{q−1}: pair (q,s) with s<q is decayed by w_{s+1}..w_{q−1}
        # = exp(cum_{q−1} − cum_s) = exp((cum_q − logw_q) − cum_s)
        cum_pre = cumq - lwq
        # valid (s < q) exponents ≤ 0; clamp kills masked-pair overflow
        dec = jnp.exp(jnp.minimum(
            cum_pre[:, :, None] - cumq[:, None, :, :, :], 0.0))  # (B,q,s,H,P)
        att = jnp.einsum("bqhi,bshi,bqshi->bhqs", rq32, kq32, dec)
        # strict causal (s<q) plus the diagonal "bonus" term diag(u)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        diag = jnp.einsum("bqhi,bqhi,hi->bhq", rq32, kq32,
                          u.astype(jnp.float32))
        y_intra = jnp.einsum("bhqs,bshj->bqhj", att, vq32)
        y_intra = y_intra + diag[..., None].transpose(0, 2, 1, 3) * vq32
        # inter-chunk: y += (r_q · exp(cum_{q−1})) @ state  (state BEFORE tok q)
        # cum is inclusive; decay from chunk start to before q = cum_{q} − lw_q
        pre = jnp.exp(cumq - lwq)
        y_inter = jnp.einsum("bqhi,bhij->bqhj", rq32 * pre, state)
        # state' = diag(exp(cum_Q)) state + Σ_s exp(cum_Q − cum_s) k_s ⊗ v_s
        total = cumq[:, -1]               # (B,H,P)
        wk = kq32 * jnp.exp(total[:, None] - cumq)
        state_new = state * jnp.exp(total)[..., None] + jnp.einsum(
            "bqhi,bqhj->bhij", wk, vq32)
        return state_new, y_intra + y_inter

    final, yc = lax.scan(chunk_step, init_state.astype(jnp.float32),
                         (rc, kc, vc, cum, lw))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, P)[:, :S]
    return y, final


def rwkv6_forward(cfg: ArchConfig, params: Dict, x: jax.Array,
                  init_state: RWKVState | None = None
                  ) -> Tuple[jax.Array, RWKVState]:
    """Time-mix + channel-mix for a full sequence. x: (B,S,d)."""
    B, S, d = x.shape
    H, P = cfg.rwkv_num_heads, cfg.rwkv_head_dim
    prev = (init_state.shift if init_state is not None
            else jnp.zeros((B, d), x.dtype))
    state0 = (init_state.wkv if init_state is not None
              else jnp.zeros((B, H, P, P), jnp.float32))
    xs = _token_shift(x, prev)
    mix = lambda i: x + (xs - x) * params["mu"][i]
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))
    r = (xr @ params["wr"]).reshape(B, S, H, P)
    k = (xk @ params["wk"]).reshape(B, S, H, P)
    v = (xv @ params["wv"]).reshape(B, S, H, P)
    g = jax.nn.silu(xg @ params["wg"])
    logw = -jnp.exp(params["w0"] +
                    (jnp.tanh(xw @ params["w_A"]) @ params["w_B"])
                    .astype(jnp.float32))           # (B,S,d), ≤ 0
    logw = logw.reshape(B, S, H, P)

    y, wkv = _wkv_chunked(r, k, v, logw, params["u"],
                          max(cfg.ssm_chunk // 4, 16), state0)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = layer_norm(y, params["ln_x_w"], params["ln_x_b"], cfg.norm_eps) * g
    out = y @ params["wo"]

    # channel-mix (the RWKV FFN) with its own token shift
    prev_f = (init_state.shift_ffn if init_state is not None
              else jnp.zeros((B, d), x.dtype))
    xs_f = _token_shift(x, prev_f)
    xk_f = x + (xs_f - x) * params["mu_ffn"][0]
    xr_f = x + (xs_f - x) * params["mu_ffn"][1]
    kf = jnp.square(jax.nn.relu(xk_f @ params["ffn_k"]))
    ffn = jax.nn.sigmoid(xr_f @ params["ffn_r"]) * (kf @ params["ffn_v"])

    new_state = RWKVState(x[:, -1, :], wkv, x[:, -1, :])
    return out + ffn, new_state


def init_rwkv_state(cfg: ArchConfig, batch: int, dtype) -> RWKVState:
    H, P = cfg.rwkv_num_heads, cfg.rwkv_head_dim
    return RWKVState(jnp.zeros((batch, cfg.d_model), dtype),
                     jnp.zeros((batch, H, P, P), jnp.float32),
                     jnp.zeros((batch, cfg.d_model), dtype))


def rwkv6_decode(cfg: ArchConfig, params: Dict, x: jax.Array,
                 state: RWKVState) -> Tuple[jax.Array, RWKVState]:
    """Single-token step. x: (B, 1, d)."""
    B, _, d = x.shape
    H, P = cfg.rwkv_num_heads, cfg.rwkv_head_dim
    xt = x[:, 0]
    mix = lambda i: xt + (state.shift - xt) * params["mu"][i]
    r = (mix(0) @ params["wr"]).reshape(B, H, P).astype(jnp.float32)
    k = (mix(1) @ params["wk"]).reshape(B, H, P).astype(jnp.float32)
    v = (mix(2) @ params["wv"]).reshape(B, H, P).astype(jnp.float32)
    g = jax.nn.silu(mix(3) @ params["wg"])
    logw = -jnp.exp(params["w0"] +
                    (jnp.tanh(mix(4) @ params["w_A"]) @ params["w_B"])
                    .astype(jnp.float32)).reshape(B, H, P)
    w = jnp.exp(logw)

    kv = jnp.einsum("bhi,bhj->bhij", k, v)
    y = jnp.einsum("bhi,bhij->bhj", r,
                   params["u"][None, :, :, None] * kv + state.wkv)
    wkv_new = state.wkv * w[..., None] + kv
    y = y.reshape(B, d).astype(x.dtype)
    y = layer_norm(y, params["ln_x_w"], params["ln_x_b"], cfg.norm_eps) * g
    out = y @ params["wo"]

    xk_f = xt + (state.shift_ffn - xt) * params["mu_ffn"][0]
    xr_f = xt + (state.shift_ffn - xt) * params["mu_ffn"][1]
    kf = jnp.square(jax.nn.relu(xk_f @ params["ffn_k"]))
    ffn = jax.nn.sigmoid(xr_f @ params["ffn_r"]) * (kf @ params["ffn_v"])

    return (out + ffn)[:, None, :], RWKVState(xt, wkv_new, xt)
