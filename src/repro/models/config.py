"""Unified architecture configuration.

One dataclass covers the whole assigned pool (dense / MoE / SSM / hybrid /
VLM / audio / the paper's own logistic model); family-specific fields are
zero/None when unused.  ``src/repro/configs/<id>.py`` instantiates one of
these per assigned architecture, exactly matching the public spec.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio | logreg
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0               # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0

    # attention details
    rope_theta: float = 10_000.0
    qk_norm: bool = False           # qwen3
    qkv_bias: bool = False          # qwen2.5
    attn_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None   # set -> windowed attention variant
    activation: str = "silu"        # silu (SwiGLU) | geglu | gelu

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # RWKV6
    rwkv: bool = False
    rwkv_head_dim: int = 64

    # hybrid (zamba2): shared attention block applied every `attn_every` layers
    attn_every: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    max_source_positions: int = 1500
    max_target_positions: int = 448

    # VLM (chameleon): leading image-patch embeddings consumed via projector
    num_image_tokens: int = 0

    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # logistic-regression (paper model)
    input_dim: int = 0
    num_classes: int = 0

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def rwkv_num_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k eligibility (DESIGN.md §5): SSM/hybrid natively; dense /
        moe / vlm via the sliding-window variant; whisper never."""
        return self.family != "audio"

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests (≤2 layers,
        d_model ≤ 512, ≤4 experts)."""
        small = dict(
            num_layers=min(self.num_layers, 2) or self.num_layers,
            d_model=min(self.d_model, 256) if self.d_model else self.d_model,
            d_ff=min(self.d_ff, 512) if self.d_ff else self.d_ff,
            vocab_size=min(self.vocab_size, 512) if self.vocab_size else self.vocab_size,
            dtype="float32",
        )
        if self.num_heads:
            small["num_heads"] = min(self.num_heads, 4)
            small["num_kv_heads"] = min(self.num_kv_heads, min(self.num_heads, 4))
            small["head_dim"] = 64 if self.head_dim else 0
        if self.num_experts:
            small["num_experts"] = min(self.num_experts, 4)
            small["experts_per_token"] = min(self.experts_per_token, 2)
            small["num_shared_experts"] = min(self.num_shared_experts, 1)
        if self.ssm_state:
            small["ssm_state"] = min(self.ssm_state, 16)
            small["ssm_chunk"] = 32
        if self.rwkv:
            small["rwkv_head_dim"] = 32
        if self.encoder_layers:
            small["encoder_layers"] = min(self.encoder_layers, 2)
            small["max_source_positions"] = 64
        if self.attn_every:
            small["attn_every"] = 2
        if self.num_image_tokens:
            small["num_image_tokens"] = 16
        if self.sliding_window:
            small["sliding_window"] = min(self.sliding_window, 64)
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-smoke", **small)

    def with_overrides(self, **overrides) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)

    def param_count_estimate(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6·N·D)."""
        if self.family == "logreg":
            return self.input_dim * self.num_classes + self.num_classes
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm" and self.rwkv:
            per = 4 * d * d + 2 * d * self.d_ff + d * d // 8
            return emb + L * per
        if self.family in ("ssm", "hybrid") and self.ssm_state:
            di = self.ssm_d_inner
            per_m = d * (2 * di + 2 * self.ssm_state + self.ssm_num_heads) + di * d
            if self.family == "hybrid":
                attn = (d * (self.num_heads + 2 * self.num_kv_heads) * hd
                        + self.num_heads * hd * d + 3 * d * self.d_ff)
                return emb + L * per_m + attn
            return emb + L * per_m
        attn = d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d
        n_gate = 2 if self.activation in ("silu", "geglu") else 1
        if self.family == "moe":
            ff = (self.num_experts + self.num_shared_experts) * (n_gate + 1) * d * self.d_ff
            ff += d * self.num_experts  # router
        else:
            ff = (n_gate + 1) * d * self.d_ff
        layers = L * (attn + ff)
        if self.is_encoder_decoder:
            layers += self.encoder_layers * (attn + ff) + L * attn  # cross-attn
        return emb + layers

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count_estimate()
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        attn = d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d
        n_gate = 2 if self.activation in ("silu", "geglu") else 1
        ff_active = (self.experts_per_token + self.num_shared_experts) * (n_gate + 1) * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + ff_active + d * self.num_experts)
