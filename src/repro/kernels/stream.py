"""Streaming round-statistics kernel: fused ``G = D Dᵀ`` + ``C = D GMᵀ``.

The streamed hierarchical round engine (``repro.hier.streamed``) reduces an
entire round's tier tree to the device-level pair

    G = D Dᵀ ∈ R^{P×P}      (update-update inner products)
    C = D GMᵀ ∈ R^{P×P}     (update-gradient inner products)

where D stacks the P flattened client updates and GM the matching gradient
estimates.  Every tier's Gram block is a sub-block of G, every c-term is a
row-mix of C, so one pass over the parameter axis feeds the whole tree.
Like the PR-2 Gram kernels this is a memory-bound tall-skinny contraction
(arithmetic intensity ≈ P FLOP/byte); fusing the two products reads the D
stream once instead of twice, and the GM stream rides the same pass.

Both streaming implementations keep the working set at O(P·block_n):

  * :func:`stream_stats_xla` — ``lax.scan`` over the full ``block_n``-column
    windows read via ``lax.dynamic_slice`` (no padded/transposed copy of
    the inputs, unlike ``core.gram.gram_and_cross_chunked``'s reshape —
    that copy is exactly what transformer-width rounds cannot afford), plus
    one statically-sliced remainder tile: no masking, no window ever pays
    more than its own bandwidth.
  * :func:`stream_stats_pallas` — grid over column tiles, both (P, block_n)
    operand tiles ride one HBM→VMEM stream, outputs accumulate in VMEM f32
    across the grid (constant index_map).  Inputs are padded to the tile
    boundary like the other Pallas kernels — compiled on TPU only, where
    the pad is a device-side copy the VMEM budget tolerates.

Inputs may be any float dtype (bf16 transformer updates upcast per tile);
accumulation is always f32.  The eager oracle lives in ``kernels.ref``
(``stream_stats_ref``); dispatch + autotune (``block_n`` participates in
the shape bucket) in ``kernels.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _accum_tile(G, C, d, g):
    d = d.astype(jnp.float32)
    g = g.astype(jnp.float32)
    G = G + jax.lax.dot_general(d, d, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    C = C + jax.lax.dot_general(d, g, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    return G, C


@functools.partial(jax.jit, static_argnames=("block_n",))
def stream_stats_xla(deltas: jax.Array, grads: jax.Array, *,
                     block_n: int = 1 << 16):
    """(G, C) in one ``lax.scan`` pass of ``block_n`` columns, O(P·block_n)
    working set, no input copies.  Full windows scan unmasked; the
    ``n % block_n`` remainder is a single statically-sliced tile, so the
    memory-bound hot loop never pays a mask pass."""
    P, n = deltas.shape
    if grads.shape != deltas.shape:
        raise ValueError(f"deltas/grads disagree: {deltas.shape} vs "
                         f"{grads.shape}")
    G = jnp.zeros((P, P), jnp.float32)
    C = jnp.zeros((P, P), jnp.float32)
    if n == 0:
        return G, C
    bn = min(int(block_n), n)
    full, rem = divmod(n, bn)

    if full == 1:
        G, C = _accum_tile(G, C, deltas[:, :bn], grads[:, :bn])
    elif full > 1:
        def body(carry, i):
            start = i * bn
            d = jax.lax.dynamic_slice(deltas, (0, start), (P, bn))
            g = jax.lax.dynamic_slice(grads, (0, start), (P, bn))
            return _accum_tile(*carry, d, g), None

        (G, C), _ = jax.lax.scan(body, (G, C), jnp.arange(full))
    if rem:
        G, C = _accum_tile(G, C, deltas[:, full * bn:], grads[:, full * bn:])
    return G, C


def _stream_stats_kernel(d_ref, g_ref, G_ref, C_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        G_ref[...] = jnp.zeros_like(G_ref)
        C_ref[...] = jnp.zeros_like(C_ref)

    d = d_ref[...].astype(jnp.float32)            # (Pp, bn)
    g = g_ref[...].astype(jnp.float32)            # (Pp, bn)
    G_ref[...] += jax.lax.dot_general(
        d, d, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    C_ref[...] += jax.lax.dot_general(
        d, g, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def stream_stats_pallas(deltas: jax.Array, grads: jax.Array, *,
                        block_n: int = 2048, interpret: bool = True):
    """Pallas twin: grid over column tiles, (G, C) resident in VMEM f32.
    P is padded to the 8-sublane boundary, n to a ``block_n`` multiple
    (zero columns contribute nothing to either product)."""
    P, n = deltas.shape
    if grads.shape != deltas.shape:
        raise ValueError(f"deltas/grads disagree: {deltas.shape} vs "
                         f"{grads.shape}")
    padP, padN = (-P) % 8, (-n) % block_n
    d = jnp.pad(deltas, ((0, padP), (0, padN)))
    g = jnp.pad(grads, ((0, padP), (0, padN)))
    Pp = P + padP

    grid = ((n + padN) // block_n,)
    G, C = pl.pallas_call(
        _stream_stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Pp, block_n), lambda i: (0, i)),
            pl.BlockSpec((Pp, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((Pp, Pp), lambda i: (0, 0)),
            pl.BlockSpec((Pp, Pp), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Pp, Pp), jnp.float32),
            jax.ShapeDtypeStruct((Pp, Pp), jnp.float32),
        ],
        interpret=interpret,
    )(d, g)
    return G[:P, :P], C[:P, :P]
