"""Pallas TPU kernel: α-weighted update combine (paper eq. 4).

    w' = w + Σ_k α_k U_k

One streaming pass: grid over n-chunks; each step loads a (K, block_n) tile
of the stacked updates plus the matching (1, block_n) slice of w, forms the
α-weighted reduction on the MXU ((1,K) @ (K,bn)) in f32, and writes the
updated slice.  No HBM round-trip per client — FedAvg-style K-pass
aggregation reads U K times; this reads it once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(alpha_ref, u_ref, w_ref, out_ref):
    a = alpha_ref[...].astype(jnp.float32)        # (1, K)
    u = u_ref[...].astype(jnp.float32)            # (K, bn)
    w = w_ref[...].astype(jnp.float32)            # (1, bn)
    comb = jax.lax.dot_general(
        a, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    out_ref[...] = (w + comb).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def combine_pallas(params_vec: jax.Array, updates: jax.Array,
                   alpha: jax.Array, *, block_n: int = 2048,
                   interpret: bool = True) -> jax.Array:
    """``params_vec (n,)``, ``updates (K, n)``, ``alpha (K,)`` → ``(n,)``."""
    K, n = updates.shape
    padK = (-K) % 8
    padN = (-n) % block_n
    u = jnp.pad(updates, ((0, padK), (0, padN)))
    w = jnp.pad(params_vec, (0, padN)).reshape(1, n + padN)
    a = jnp.pad(alpha, (0, padK)).reshape(1, K + padK)

    grid = ((n + padN) // block_n,)
    out = pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K + padK), lambda i: (0, 0)),
            pl.BlockSpec((K + padK, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n + padN), params_vec.dtype),
        interpret=interpret,
    )(a, u, w)
    return out[0, :n]
