"""Pallas TPU kernel: chunked top-k magnitude selection.

Global top-k over an n-vector decomposes exactly: every global top-k entry
is a top-k entry of its own chunk, so the kernel streams (1, block_n) tiles
and emits each chunk's k largest-|v| candidates (value + global index);
a final O(k·n/block_n) merge on the host side selects the true top k.
This keeps the n-axis traffic to one streaming read — the same HBM-bound
shape as the Gram/sketch kernels — while the candidate set stays tiny.

Padding note: n pads to ``block_n`` with zeros, so a padded slot can tie a
genuine zero entry inside its chunk; the merge masks candidates with index
≥ n to magnitude −1 before the final select, so no pad ever wins over any
real coordinate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(v_ref, vals_ref, idx_ref, *, kp: int, block_n: int):
    v = v_ref[...].astype(jnp.float32)[0]                    # (bn,)
    mags, local = jax.lax.top_k(jnp.abs(v), kp)
    del mags
    vals_ref[...] = jnp.take(v, local)[None, :]
    idx_ref[...] = (local + pl.program_id(0) * block_n
                    ).astype(jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def topk_select_pallas(vec: jax.Array, k: int, *, block_n: int = 2048,
                       interpret: bool = True):
    """``vec (n,)`` → ``(values (k,) f32, indices (k,) i32)`` of the k
    largest-magnitude entries.  Requires ``k <= block_n`` (the per-chunk
    candidate count); ``ops.topk_select`` falls back to the reference path
    otherwise."""
    n = vec.shape[0]
    if k > block_n:
        raise ValueError(f"k={k} exceeds block_n={block_n}; use the "
                         "reference path or raise block_n")
    padN = (-n) % block_n
    v = jnp.pad(vec.astype(jnp.float32), (0, padN)).reshape(1, n + padN)
    chunks = (n + padN) // block_n
    kp = min(k + ((-k) % 8), block_n)        # sublane-pad the candidate axis

    vals, idx = pl.pallas_call(
        functools.partial(_topk_kernel, kp=kp, block_n=block_n),
        grid=(chunks,),
        in_specs=[pl.BlockSpec((1, block_n), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, kp), lambda i: (i, 0)),
            pl.BlockSpec((1, kp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((chunks, kp), jnp.float32),
            jax.ShapeDtypeStruct((chunks, kp), jnp.int32),
        ],
        interpret=interpret,
    )(v)

    cand_vals = vals.reshape(-1)
    cand_idx = idx.reshape(-1)
    mags = jnp.where(cand_idx < n, jnp.abs(cand_vals), -1.0)
    _, pick = jax.lax.top_k(mags, k)
    return jnp.take(cand_vals, pick), jnp.take(cand_idx, pick)
