"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the kernels run compiled (``interpret=False``); everywhere else they
run in interpret mode or fall back to the jnp oracle.  ``backend()`` picks
automatically; tests exercise both paths.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .combine import combine_pallas
from .decode_attn import flash_decode_pallas
from .gram import gram_block_pallas, gram_pallas
from .sketch import sketch_apply_pallas
from .topk import topk_select_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gram_and_cross(updates: jax.Array, grad: jax.Array, *,
                   use_pallas: Optional[bool] = None,
                   block_n: int = 2048) -> Tuple[jax.Array, jax.Array]:
    """Fused G = U Uᵀ, c = U g.  updates (K, n), grad (n,)."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas or not on_tpu():
        # interpret=True on CPU validates the kernel path end-to-end; on TPU
        # the same call compiles for real.
        return gram_pallas(updates, grad, block_n=block_n,
                           interpret=not on_tpu())
    return ref.gram_ref(updates, grad)


def gram_block_and_cross(ua: jax.Array, ub: jax.Array, grad: jax.Array, *,
                         use_pallas: Optional[bool] = None,
                         block_n: int = 2048) -> Tuple[jax.Array, jax.Array]:
    """One fused hierarchical-merge block: G_ab = U_a U_bᵀ AND c_a = U_a g
    (named apart from ``core.gram.gram_block``, which returns G alone)."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas or not on_tpu():
        return gram_block_pallas(ua, ub, grad, block_n=block_n,
                                 interpret=not on_tpu())
    return ref.gram_block_ref(ua, ub, grad)


def sketch_apply(updates: jax.Array, sketch: jax.Array, *,
                 use_pallas: Optional[bool] = None,
                 block_n: int = 2048) -> jax.Array:
    """Stacked sketch-apply ``U Rᵀ``.  updates (K, n), sketch (m, n).

    Unlike the older wrappers above, ``use_pallas=None`` runs the jnp
    reference off-TPU (this sits on the per-round compression hot path, so
    interpret-mode validation is opt-in via ``use_pallas=True``)."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return sketch_apply_pallas(updates, sketch, block_n=block_n,
                                   interpret=not on_tpu())
    return ref.sketch_ref(updates, sketch)


def topk_select(vec: jax.Array, k: int, *,
                use_pallas: Optional[bool] = None,
                block_n: int = 2048) -> Tuple[jax.Array, jax.Array]:
    """k largest-|v| entries as (values, indices i32); same dispatch default
    as :func:`sketch_apply` (reference off-TPU, compiled kernel on TPU).
    Falls back to the reference when k exceeds the per-chunk candidate
    budget ``block_n``."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas and k <= block_n:
        return topk_select_pallas(vec, k, block_n=block_n,
                                  interpret=not on_tpu())
    return ref.topk_ref(vec, k)


def weighted_combine(params_vec: jax.Array, updates: jax.Array,
                     alpha: jax.Array, *, use_pallas: Optional[bool] = None,
                     block_n: int = 2048) -> jax.Array:
    """w + Σ α_k U_k.  params_vec (n,), updates (K, n), alpha (K,)."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas or not on_tpu():
        return combine_pallas(params_vec, updates, alpha, block_n=block_n,
                              interpret=not on_tpu())
    return ref.combine_ref(params_vec, updates, alpha)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 lengths: jax.Array, *, window: Optional[int] = None,
                 block_s: int = 512, use_pallas: Optional[bool] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Single-token attention vs a long cache; returns (o, lse) partials."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return flash_decode_pallas(q, k, v, lengths, block_s=block_s,
                                   window=window, interpret=not on_tpu())
    return ref.flash_decode_ref(q, k, v, lengths, window=window)


def lse_merge(o_parts: jax.Array, lse_parts: jax.Array):
    """Combine per-shard (o, lse) partials — used after a sharded
    flash_decode where each mesh slice scanned its local cache shard."""
    return ref.lse_merge_ref(o_parts, lse_parts)
