"""Public kernel entry points, dispatched through the backend registry.

Each op registers three implementations (see :mod:`repro.kernels.registry`):
``pallas`` (compiled on TPU, interpret-mode validation elsewhere), ``xla``
(jit-compiled pure-jnp — the off-TPU production path) and ``ref`` (the eager
jnp oracle).  The first call per (op, shape-bucket, platform) micro-autotunes
among the eligible backends and caches the winner in-process; interpret-mode
Pallas is never an autotune candidate off-TPU, so off-TPU runs never pay
interpret overhead — the PR-3 wrappers' inconsistent ``use_pallas or not
on_tpu()`` defaults are gone.

Back-compat forcing: ``use_pallas=True`` pins the Pallas path (interpret
off-TPU — the end-to-end kernel validation tests), ``use_pallas=False`` pins
the reference oracle; ``backend=`` names any registered backend directly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .combine import combine_pallas
from .decode_attn import flash_decode_pallas
from .gram import gram_block_pallas, gram_pallas
from .registry import (backends, dispatch, force_backend, on_tpu,
                       register_impl, select_impl)
from .rng_sketch import rng_sketch_pallas, rng_sketch_xla, \
    rng_sketch_adjoint_xla
from .sketch import sketch_apply_pallas
from .stream import stream_stats_pallas, stream_stats_xla
from .topk import topk_select_pallas

__all__ = ["on_tpu", "gram_and_cross", "gram_block_and_cross",
           "stream_stats", "sketch_apply", "topk_select",
           "weighted_combine", "sign_sketch", "sign_sketch_adjoint",
           "flash_decode", "lse_merge",
           "backends", "dispatch", "force_backend", "select_impl"]


def _not_interpret() -> bool:
    # Pallas autotune eligibility: compiled on TPU only; interpret mode is a
    # correctness path, never a contender
    return on_tpu()


# autotune-ineligible marker: backends that could win a micro-timing at
# small/capped shapes but materialize memory the op exists to avoid
_never = (lambda: False)


def _backend_for(use_pallas: Optional[bool],
                 backend: Optional[str]) -> Optional[str]:
    if backend is not None:
        return backend
    if use_pallas is None:
        return None                   # registry decides (autotune)
    return "pallas" if use_pallas else "ref"


# --------------------------------------------------------------- gram ops

register_impl("gram", "pallas",
              lambda u, g, block_n=2048: gram_pallas(
                  u, g, block_n=block_n, interpret=not on_tpu()),
              eligible=_not_interpret)
_gram_xla_jit = jax.jit(ref.gram_ref)
register_impl("gram", "xla",
              lambda u, g, block_n=2048: _gram_xla_jit(u, g))
register_impl("gram", "ref", lambda u, g, block_n=2048: ref.gram_ref(u, g))


def gram_and_cross(updates: jax.Array, grad: jax.Array, *,
                   use_pallas: Optional[bool] = None,
                   block_n: int = 2048,
                   backend: Optional[str] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Fused G = U Uᵀ, c = U g.  updates (K, n), grad (n,)."""
    return dispatch("gram", updates, grad, block_n=block_n,
                    backend=_backend_for(use_pallas, backend))


register_impl("gram_block", "pallas",
              lambda ua, ub, g, block_n=2048: gram_block_pallas(
                  ua, ub, g, block_n=block_n, interpret=not on_tpu()),
              eligible=_not_interpret)
_gram_block_xla_jit = jax.jit(ref.gram_block_ref)
register_impl("gram_block", "xla",
              lambda ua, ub, g, block_n=2048: _gram_block_xla_jit(ua, ub, g))
register_impl("gram_block", "ref",
              lambda ua, ub, g, block_n=2048: ref.gram_block_ref(ua, ub, g))


def gram_block_and_cross(ua: jax.Array, ub: jax.Array, grad: jax.Array, *,
                         use_pallas: Optional[bool] = None,
                         block_n: int = 2048,
                         backend: Optional[str] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """One fused hierarchical-merge block: G_ab = U_a U_bᵀ AND c_a = U_a g
    (named apart from ``core.gram.gram_block``, which returns G alone)."""
    return dispatch("gram_block", ua, ub, grad, block_n=block_n,
                    backend=_backend_for(use_pallas, backend))


def _same_2d(d, g, block_n=0) -> bool:
    return (getattr(d, "ndim", 0) == 2 and tuple(d.shape) == tuple(g.shape))


def _stream_pallas_ok(d, g, block_n=2048) -> bool:
    # the pallas wrapper pads to (8-row, block_n-column) tiles with jnp.pad
    # — an O(P·n) input copy that would break the streamed engine's
    # O(P·chunk) memory model, so dispatch/autotune only offer it on
    # already-aligned shapes (explicit backend="pallas" still runs the
    # padded path for validation)
    return (_same_2d(d, g, block_n) and d.shape[0] % 8 == 0
            and d.shape[1] % block_n == 0)


register_impl("stream_stats", "pallas",
              lambda d, g, block_n=2048: stream_stats_pallas(
                  d, g, block_n=block_n, interpret=not on_tpu()),
              supports=_stream_pallas_ok, eligible=_not_interpret)
register_impl("stream_stats", "xla",
              lambda d, g, block_n=1 << 16: stream_stats_xla(
                  d, g, block_n=block_n),
              supports=_same_2d)
# like sign_sketch's ref: the oracle materializes full-width f32 upcasts —
# the very copies the op exists to avoid — so it must never win an
# autotune timing at capped shapes and then OOM at production ones; reach
# it only via backend="ref" / force_backend, as tests do
register_impl("stream_stats", "ref",
              lambda d, g, block_n=1 << 16: ref.stream_stats_ref(d, g),
              supports=_same_2d, eligible=_never)


def stream_stats(deltas: jax.Array, grads: jax.Array, *,
                 use_pallas: Optional[bool] = None,
                 block_n: int = 1 << 16,
                 backend: Optional[str] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Fused round statistics G = D Dᵀ, C = D GMᵀ in one streaming pass.
    deltas/grads (P, n), any float dtype; f32 accumulation, O(P·block_n)
    working set on the streaming backends.  ``block_n`` is the column chunk
    and participates in the autotune shape bucket, so the tuner picks the
    winning (backend, chunk) pair per (P, n) bucket."""
    return dispatch("stream_stats", deltas, grads, block_n=block_n,
                    backend=_backend_for(use_pallas, backend))


# ------------------------------------------------------------ compression

register_impl("sketch", "pallas",
              lambda u, r, block_n=2048: sketch_apply_pallas(
                  u, r, block_n=block_n, interpret=not on_tpu()),
              eligible=_not_interpret)
_sketch_xla_jit = jax.jit(ref.sketch_ref)
register_impl("sketch", "xla",
              lambda u, r, block_n=2048: _sketch_xla_jit(u, r))
register_impl("sketch", "ref",
              lambda u, r, block_n=2048: ref.sketch_ref(u, r))


def sketch_apply(updates: jax.Array, sketch: jax.Array, *,
                 use_pallas: Optional[bool] = None,
                 block_n: int = 2048,
                 backend: Optional[str] = None) -> jax.Array:
    """Stacked sketch-apply ``U Rᵀ`` against an explicit sketch matrix.
    updates (K, n), sketch (m, n).  For the counter-based sign sketch that
    never materializes R, use :func:`sign_sketch`."""
    return dispatch("sketch", updates, sketch, block_n=block_n,
                    backend=_backend_for(use_pallas, backend))


register_impl("topk", "pallas",
              lambda v, k, block_n=2048: topk_select_pallas(
                  v, k, block_n=block_n, interpret=not on_tpu()),
              supports=lambda v, k, block_n=2048: k <= block_n,
              eligible=_not_interpret)
_topk_xla_jit = jax.jit(ref.topk_ref, static_argnums=1)
register_impl("topk", "xla",
              lambda v, k, block_n=2048: _topk_xla_jit(v, k))
register_impl("topk", "ref",
              lambda v, k, block_n=2048: ref.topk_ref(v, k))


def topk_select(vec: jax.Array, k: int, *,
                use_pallas: Optional[bool] = None,
                block_n: int = 2048,
                backend: Optional[str] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """k largest-|v| entries as (values, indices i32).

    ``use_pallas=True`` keeps the PR-3 semantics: it silently falls back to
    the autotuned path when k exceeds the kernel's per-chunk candidate
    budget ``block_n`` (the op's ``supports`` constraint — forced backends
    via ``force_backend``/env fall back the same way).  An explicit
    ``backend="pallas"`` is a hard requirement and raises instead."""
    be = _backend_for(use_pallas, backend)
    if backend is None and be == "pallas" and k > block_n:
        be = None                     # legacy silent fallback (tested)
    return dispatch("topk", vec, k, block_n=block_n, backend=be)


# ----------------------------------------------------------- combine / rng

register_impl("combine", "pallas",
              lambda w, u, a, block_n=2048: combine_pallas(
                  w, u, a, block_n=block_n, interpret=not on_tpu()),
              eligible=_not_interpret)
_combine_xla_jit = jax.jit(ref.combine_ref)
register_impl("combine", "xla",
              lambda w, u, a, block_n=2048: _combine_xla_jit(w, u, a))
register_impl("combine", "ref",
              lambda w, u, a, block_n=2048: ref.combine_ref(w, u, a))


def weighted_combine(params_vec: jax.Array, updates: jax.Array,
                     alpha: jax.Array, *, use_pallas: Optional[bool] = None,
                     block_n: int = 2048,
                     backend: Optional[str] = None) -> jax.Array:
    """w + Σ α_k U_k.  params_vec (n,), updates (K, n), alpha (K,)."""
    return dispatch("combine", params_vec, updates, alpha, block_n=block_n,
                    backend=_backend_for(use_pallas, backend))


# The ref oracle materializes the full m×n R — the very thing this op
# exists to avoid — so it is NEVER an autotune candidate (it could win a
# micro-timing at toy shapes and OOM at production ones); reach it only via
# backend="ref" / force_backend, as tests do.
register_impl("sign_sketch", "pallas",
              lambda u, seed, m, block_n=2048: rng_sketch_pallas(
                  u, seed, m=m, block_n=block_n, interpret=not on_tpu()),
              eligible=_not_interpret)
register_impl("sign_sketch", "xla",
              lambda u, seed, m, block_n=4096: rng_sketch_xla(
                  u, seed, m=m, block_n=block_n))
register_impl("sign_sketch", "ref",
              lambda u, seed, m, block_n=4096: ref.rng_sketch_ref(
                  u, seed, m=m),
              eligible=_never)


def sign_sketch(updates: jax.Array, seed, m: int, *,
                use_pallas: Optional[bool] = None, block_n: int = 4096,
                backend: Optional[str] = None) -> jax.Array:
    """Counter-based sign sketch ``U Rᵀ/√m`` (K, n) → (K, m): the Rademacher
    matrix is generated on the fly from (row, col, seed) counters and never
    materialized (see :mod:`repro.kernels.rng_sketch`).  ``seed`` is a
    uint32 scalar (array or int)."""
    seed = jnp.asarray(seed, jnp.uint32)
    return dispatch("sign_sketch", updates, seed, m, block_n=block_n,
                    backend=_backend_for(use_pallas, backend))


register_impl("sign_sketch_adjoint", "xla",
              lambda s, seed, n, block_n=4096: rng_sketch_adjoint_xla(
                  s, seed, n=n, block_n=block_n))
register_impl("sign_sketch_adjoint", "ref",
              lambda s, seed, n, block_n=4096: ref.rng_sketch_adjoint_ref(
                  s, seed, n=n),
              eligible=_never)


def sign_sketch_adjoint(coords: jax.Array, seed, n: int, *,
                        block_n: int = 4096,
                        backend: Optional[str] = None) -> jax.Array:
    """Decode-side adjoint ``Rᵀ s/√m`` (m,) → (n,), same implicit R."""
    seed = jnp.asarray(seed, jnp.uint32)
    return dispatch("sign_sketch_adjoint", coords, seed, n,
                    block_n=block_n, backend=backend)


# ------------------------------------------------------------ decode attn

register_impl("flash_decode", "pallas",
              lambda q, k, v, lengths, window=None, softcap=None,
              block_s=512: flash_decode_pallas(
                  q, k, v, lengths, block_s=block_s, window=window,
                  softcap=softcap, interpret=not on_tpu()),
              eligible=_not_interpret)
_flash_decode_xla_jit = jax.jit(ref.flash_decode_ref,
                                static_argnames=("window", "softcap"))
register_impl("flash_decode", "xla",
              lambda q, k, v, lengths, window=None, softcap=None,
              block_s=512: _flash_decode_xla_jit(
                  q, k, v, lengths, window=window, softcap=softcap))
register_impl("flash_decode", "ref",
              lambda q, k, v, lengths, window=None, softcap=None,
              block_s=512: ref.flash_decode_ref(
                  q, k, v, lengths, window=window, softcap=softcap))


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 lengths: jax.Array, *, window: Optional[int] = None,
                 softcap: Optional[float] = None, block_s: int = 512,
                 use_pallas: Optional[bool] = None,
                 backend: Optional[str] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Single-token attention vs a long cache; returns (o, lse) partials.

    The serving hot path (``repro.serve.DecodeEngine`` calls this per layer
    per step): q (B, KV, G, hd) against k/v (B, S, KV, hd) with per-slot
    ``lengths`` (B,) masking — exactly the continuous-batching contract.
    Dispatched through the autotune registry like the aggregation ops; the
    former manual interpret-mode branch (pallas on TPU, eager ref elsewhere
    — the eager oracle on every off-TPU decode step) is gone."""
    return dispatch("flash_decode", q, k, v, lengths, window=window,
                    softcap=softcap, block_s=block_s,
                    backend=_backend_for(use_pallas, backend))


def lse_merge(o_parts: jax.Array, lse_parts: jax.Array):
    """Combine per-shard (o, lse) partials — used after a sharded
    flash_decode where each mesh slice scanned its local cache shard."""
    return ref.lse_merge_ref(o_parts, lse_parts)
