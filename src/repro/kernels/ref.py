"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gram_ref(updates: jax.Array, grad: jax.Array):
    """(G, c) in f32 — oracle for kernels.gram."""
    u = updates.astype(jnp.float32)
    g = grad.astype(jnp.float32)
    return u @ u.T, u @ g


def gram_block_ref(ua: jax.Array, ub: jax.Array, grad: jax.Array):
    """(G_ab, c_a) in f32 — oracle for kernels.gram.gram_block_pallas."""
    a = ua.astype(jnp.float32)
    b = ub.astype(jnp.float32)
    return a @ b.T, a @ grad.astype(jnp.float32)


def stream_stats_ref(deltas: jax.Array, grads: jax.Array):
    """(G = D Dᵀ, C = D GMᵀ) in f32 — oracle for kernels.stream."""
    d = deltas.astype(jnp.float32)
    g = grads.astype(jnp.float32)
    return d @ d.T, d @ g.T


def sketch_ref(updates: jax.Array, sketch: jax.Array) -> jax.Array:
    """U Rᵀ in f32 — oracle for kernels.sketch (stacked sketch-apply)."""
    return updates.astype(jnp.float32) @ sketch.astype(jnp.float32).T


def topk_ref(vec: jax.Array, k: int):
    """(values, indices i32) of the k largest-|v| entries — oracle for
    kernels.topk."""
    v = vec.astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(v), k)
    return jnp.take(v, idx), idx.astype(jnp.int32)


def combine_ref(params_vec: jax.Array, updates: jax.Array,
                alpha: jax.Array) -> jax.Array:
    """w + Σ α_k U_k — oracle for kernels.combine."""
    comb = jnp.einsum("k,kn->n", alpha.astype(jnp.float32),
                      updates.astype(jnp.float32))
    return (params_vec.astype(jnp.float32) + comb).astype(params_vec.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, window: int | None = None,
                     softcap: float | None = None):
    """(o, lse) — oracle for kernels.decode_attn.

    q (B, KV, G, hd); k, v (B, S, KV, hd); lengths (B,).  ``softcap`` applies
    the tanh logit cap (gemma-style) before masking, matching
    ``models.layers.softcap``."""
    B, S, KV, hd = k.shape
    scale = hd ** -0.5
    q32 = q.astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", q32, k.astype(jnp.float32))
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(S)[None, None, None, :]
    ok = kpos < lengths[:, None, None, None]
    if window is not None:
        ok = ok & (kpos > lengths[:, None, None, None] - 1 - window)
    s = jnp.where(ok, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30),
                   v.astype(jnp.float32))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return o, lse


def rng_sketch_ref(updates: jax.Array, seed, *, m: int,
                   block_n: int = 4096) -> jax.Array:
    """Materialized-R oracle for kernels.rng_sketch: builds the full sign
    matrix from the same counter-based hash, then one matmul."""
    from .rng_sketch import rng_sign_matrix
    del block_n                       # the oracle needs no tiling
    R = rng_sign_matrix(seed, m, updates.shape[1])
    return (updates.astype(jnp.float32) @ R.T) / jnp.sqrt(jnp.float32(m))


def rng_sketch_adjoint_ref(coords: jax.Array, seed, *, n: int,
                           block_n: int = 4096) -> jax.Array:
    """Materialized-R oracle for the decode-side adjoint ``Rᵀ s/√m``."""
    from .rng_sketch import rng_sign_matrix
    del block_n
    R = rng_sign_matrix(seed, coords.shape[0], n)
    return (R.T @ coords.astype(jnp.float32)) / jnp.sqrt(
        jnp.float32(coords.shape[0]))


def lse_merge_ref(o_parts: jax.Array, lse_parts: jax.Array):
    """Merge per-shard flash-decode partials.

    o_parts (P, B, KV, G, hd), lse_parts (P, B, KV, G, 1) → (o, lse)."""
    m = jnp.max(lse_parts, axis=0, keepdims=True)
    w = jnp.exp(lse_parts - m)                       # (P, …, 1)
    denom = jnp.sum(w, axis=0)
    o = jnp.sum(o_parts * w, axis=0) / jnp.maximum(denom, 1e-30)
    lse = m[0] + jnp.log(jnp.maximum(denom, 1e-30))
    return o, lse
