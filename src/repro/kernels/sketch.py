"""Pallas TPU kernel: fused sketch-apply for summary compression.

Computes ``S_U = U Rᵀ`` for a stacked batch ``U (K, n)`` against a sketch
matrix ``R (m, n)`` in ONE streaming pass over the parameter axis —
the same memory-bound tall-skinny shape as the Gram kernel (n is 10⁶–10¹⁰,
K and m small), so the win is identical: each (K, block_n) tile of U and
(m, block_n) tile of R ride a single HBM→VMEM stream and the (K, m) result
stays resident in VMEM across the whole grid.  The *fusion* is the batch
axis: a gateway stacks ū_g and ĝ_g (and any number of member vectors) as
rows of U and sketches them all in the one pass, instead of one pass per
vector.

Off-TPU the jnp reference path (``ref.sketch_ref``) is the default via
``ops.sketch_apply``; ``interpret=True`` here validates the kernel
end-to-end in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sketch_kernel(u_ref, r_ref, su_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        su_ref[...] = jnp.zeros_like(su_ref)

    u = u_ref[...].astype(jnp.float32)            # (K, bn)
    r = r_ref[...].astype(jnp.float32)            # (m, bn)
    # MXU contraction over the streamed parameter axis: (K, bn)·(m, bn)ᵀ
    su_ref[...] += jax.lax.dot_general(
        u, r, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sketch_apply_pallas(updates: jax.Array, sketch: jax.Array, *,
                        block_n: int = 2048, interpret: bool = True):
    """``updates (K, n)``, ``sketch (m, n)`` → ``S_U (K, m) f32``.

    K and m are padded to the 8-sublane boundary independently (cohorts and
    sketch dims are rarely MXU-aligned); n pads to ``block_n`` with zero
    columns (exact — they contribute nothing to the contraction)."""
    K, n = updates.shape
    m, ns = sketch.shape
    if n != ns:
        raise ValueError(f"sketch operands disagree on n: {n} vs {ns}")
    padK, padM, padN = (-K) % 8, (-m) % 8, (-n) % block_n
    u = jnp.pad(updates, ((0, padK), (0, padN)))
    r = jnp.pad(sketch, ((0, padM), (0, padN)))
    Kp, Mp = K + padK, m + padM

    grid = ((n + padN) // block_n,)
    su = pl.pallas_call(
        _sketch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Kp, block_n), lambda i: (0, i)),
            pl.BlockSpec((Mp, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((Kp, Mp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Kp, Mp), jnp.float32),
        interpret=interpret,
    )(u, r)
    return su[:K, :m]
