"""Pallas TPU kernel: fused Gram matrix + cross term for contextual aggregation.

Computes in ONE streaming pass over the parameter axis (HBM → VMEM):

    G = U Uᵀ ∈ R^{K×K}   and   c = U g ∈ R^{K}

where U (K, n) stacks the round's client updates and g (n,) is the global
gradient estimate.  This is the paper's server-side hot spot (DESIGN.md §2):
n is 10⁶–10¹⁰, K ≤ 64, so the computation is a memory-bound tall-skinny
contraction — arithmetic intensity ≈ K FLOP/byte — and the win over two
separate jnp contractions is reading U once instead of twice.

Tiling: grid over n-chunks of ``block_n`` columns; each step loads a
(K, block_n) tile of U and a (1, block_n) tile of g into VMEM and
accumulates the (K, K) / (K, 1) results in VMEM (f32) across the whole
grid — outputs have a constant index_map, so they stay resident.  block_n
is a multiple of 128 (lane dim) and K is padded to a multiple of 8
(sublane dim) by the ops.py wrapper for MXU/VPU alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(u_ref, g_ref, G_ref, c_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        G_ref[...] = jnp.zeros_like(G_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    u = u_ref[...].astype(jnp.float32)            # (K, bn)
    g = g_ref[...].astype(jnp.float32)            # (1, bn)
    # MXU contraction: (K, bn) @ (bn, K) accumulated in f32
    G_ref[...] += jax.lax.dot_general(
        u, u, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    c_ref[...] += jax.lax.dot_general(
        u, g, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def _gram_block_kernel(ua_ref, ub_ref, g_ref, G_ref, c_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        G_ref[...] = jnp.zeros_like(G_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    ua = ua_ref[...].astype(jnp.float32)          # (Ka, bn)
    ub = ub_ref[...].astype(jnp.float32)          # (Kb, bn)
    g = g_ref[...].astype(jnp.float32)            # (1, bn)
    G_ref[...] += jax.lax.dot_general(
        ua, ub, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    c_ref[...] += jax.lax.dot_general(
        ua, g, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram_block_pallas(ua: jax.Array, ub: jax.Array, grad: jax.Array, *,
                      block_n: int = 2048, interpret: bool = True):
    """One Gram block for the hierarchical merge (``repro.hier``):

        G_ab = U_a U_bᵀ (Ka, Kb)   and   c_a = U_a g (Ka,)

    in a single streaming pass over the shared parameter axis — the two
    operand tiles ride the same HBM→VMEM stream, so merging P gateway
    groups reads each U_g ~P/2 times instead of P times with separate
    contractions.  Row/column counts are padded to the 8-sublane boundary
    independently (gateway cohorts are rarely MXU-aligned)."""
    Ka, n = ua.shape
    Kb, nb = ub.shape
    if n != nb:
        raise ValueError(f"block operands disagree on n: {n} vs {nb}")
    padA, padB, padN = (-Ka) % 8, (-Kb) % 8, (-n) % block_n
    a = jnp.pad(ua, ((0, padA), (0, padN)))
    b = jnp.pad(ub, ((0, padB), (0, padN)))
    g = jnp.pad(grad, (0, padN)).reshape(1, n + padN)
    Kap, Kbp = Ka + padA, Kb + padB

    grid = ((n + padN) // block_n,)
    G, c = pl.pallas_call(
        _gram_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Kap, block_n), lambda i: (0, i)),
            pl.BlockSpec((Kbp, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((Kap, Kbp), lambda i: (0, 0)),
            pl.BlockSpec((Kap, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Kap, Kbp), jnp.float32),
            jax.ShapeDtypeStruct((Kap, 1), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, g)
    return G[:Ka, :Kb], c[:Ka, 0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram_pallas(updates: jax.Array, grad: jax.Array, *, block_n: int = 2048,
                interpret: bool = True):
    """``updates (K, n)``, ``grad (n,)`` → ``(G (K,K) f32, c (K,) f32)``.

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container has no TPU); on TPU pass ``interpret=False``.
    """
    K, n = updates.shape
    padK = (-K) % 8
    padN = (-n) % block_n
    u = jnp.pad(updates, ((0, padK), (0, padN)))
    g = jnp.pad(grad, (0, padN)).reshape(1, n + padN)
    Kp = K + padK

    grid = ((n + padN) // block_n,)
    G, c = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Kp, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((Kp, Kp), lambda i: (0, 0)),
            pl.BlockSpec((Kp, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Kp, Kp), jnp.float32),
            jax.ShapeDtypeStruct((Kp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(u, g)
    return G[:K, :K], c[:K, 0]
