"""Counter-based RNG sign sketch: ``S_U = U Rᵀ/√m`` without materializing R.

The PR-3 sign sketch regenerated the dense m×n Rademacher matrix R from its
seed on every encode — O(m·n) memory traffic for a matrix whose entries are
a pure function of (row, column, seed).  Here the signs are generated
*inside* the contraction from a counter-based hash (a murmur3-style integer
mixer over the global (row, column, seed) counters — plain uint32 ops that
lower on every backend, unlike ``jax.random`` inside a TPU Pallas kernel):

    R[i, j] = 1 − 2·msb(mix32(j ⊕ mix32(i ⊕ seed)))

so every backend produces the *identical* R without ever holding more than
one (m, block_n) tile of it:

  * ``rng_sketch_pallas``   — the tile is generated in-kernel (VMEM) per
    grid step and contracted on the MXU; only U streams from HBM.
  * ``rng_sketch_xla``      — a jit-compiled ``lax.scan`` over n-chunks with
    the same tile function; the off-TPU production path.
  * ``rng_sign_matrix``     — materializes R (the oracle the property tests
    pin the streaming paths against at fixed seed).

``rng_sketch_adjoint_xla`` applies ``Rᵀ s/√m`` the same chunked way for the
decode side.  All paths fold the 1/√m scaling in, so the sketch operator is
``S = R/√m`` with ``E[SᵀS] = I`` exactly as ``repro.compress`` assumes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MIX1 = 0x85EBCA6B
_MIX2 = 0xC2B2AE35


def _mix32(x: jax.Array) -> jax.Array:
    """murmur3 finalizer: a 4-round avalanche mixer on uint32 counters."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_MIX1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_MIX2)
    x = x ^ (x >> 16)
    return x


def sign_tile(seed: jax.Array, row0, col0, rows: int, cols: int) -> jax.Array:
    """±1 f32 tile ``R[row0:row0+rows, col0:col0+cols]`` of the implicit
    sign matrix R(seed).  Entries depend only on the *global* (row, column)
    counters, so any tiling of the same matrix agrees exactly."""
    r = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    r = r + jnp.asarray(row0, jnp.uint32)
    c = c + jnp.asarray(col0, jnp.uint32)
    h = _mix32(c ^ _mix32(r ^ jnp.asarray(seed, jnp.uint32)))
    return 1.0 - 2.0 * (h >> 31).astype(jnp.float32)


def rng_sign_matrix(seed, m: int, n: int) -> jax.Array:
    """Materialized ``R (m, n)`` — the oracle for the streaming paths (and
    the only place the full matrix ever exists; tests only)."""
    return sign_tile(seed, 0, 0, m, n)


# --------------------------------------------------------------- XLA paths

@functools.partial(jax.jit, static_argnames=("m", "block_n"))
def rng_sketch_xla(updates: jax.Array, seed, *, m: int,
                   block_n: int = 4096) -> jax.Array:
    """``updates (K, n)`` → ``U Rᵀ/√m (K, m)``, one compiled scan over
    n-chunks; the sign tile is regenerated per chunk and never stored."""
    K, n = updates.shape
    pad = (-n) % block_n
    u = jnp.pad(updates.astype(jnp.float32), ((0, 0), (0, pad)))
    steps = (n + pad) // block_n
    u = u.reshape(K, steps, block_n).transpose(1, 0, 2)

    def body(acc, xs):
        j, uc = xs
        r = sign_tile(seed, 0, j * block_n, m, block_n)
        acc = acc + jax.lax.dot_general(
            uc, r, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, None

    acc0 = jnp.zeros((K, m), jnp.float32)
    S, _ = jax.lax.scan(body, acc0,
                        (jnp.arange(steps, dtype=jnp.uint32), u))
    return S / jnp.sqrt(jnp.float32(m))


@functools.partial(jax.jit, static_argnames=("n", "block_n"))
def rng_sketch_adjoint_xla(coords: jax.Array, seed, *, n: int,
                           block_n: int = 4096) -> jax.Array:
    """``coords (m,)`` → ``Rᵀ coords/√m (n,)`` — the decode-side adjoint,
    chunked the same way (zero-padded tail sliced off exactly)."""
    m = coords.shape[0]
    pad = (-n) % block_n
    steps = (n + pad) // block_n
    s32 = coords.astype(jnp.float32)

    def body(carry, j):
        r = sign_tile(seed, 0, j * block_n, m, block_n)   # (m, bn)
        return carry, s32 @ r

    _, out = jax.lax.scan(body, 0, jnp.arange(steps, dtype=jnp.uint32))
    return out.reshape(-1)[:n] / jnp.sqrt(jnp.float32(m))


# ------------------------------------------------------------- Pallas path

def _rng_sketch_kernel(seed_ref, u_ref, su_ref, *, mp: int, block_n: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        su_ref[...] = jnp.zeros_like(su_ref)

    u = u_ref[...].astype(jnp.float32)                 # (Kp, bn)
    col0 = pl.program_id(0) * block_n
    # in-kernel counter-based RNG: the (mp, bn) sign tile is born in VMEM
    r = sign_tile(seed_ref[0, 0], 0, col0, mp, block_n)
    su_ref[...] += jax.lax.dot_general(
        u, r, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("m", "block_n", "interpret"))
def rng_sketch_pallas(updates: jax.Array, seed, *, m: int,
                      block_n: int = 2048, interpret: bool = True
                      ) -> jax.Array:
    """Pallas twin of :func:`rng_sketch_xla`: U streams HBM→VMEM once, the
    sign tile is generated in-kernel per grid step, the (K, m) accumulator
    stays VMEM-resident.  Row-pad rows of the tile (m → mp) produce extra
    output rows that are sliced off; zero-padded U columns contribute
    nothing — both exact."""
    K, n = updates.shape
    padK, padM, padN = (-K) % 8, (-m) % 8, (-n) % block_n
    u = jnp.pad(updates, ((0, padK), (0, padN)))
    seed2d = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    Kp, Mp = K + padK, m + padM

    grid = ((n + padN) // block_n,)
    su = pl.pallas_call(
        functools.partial(_rng_sketch_kernel, mp=Mp, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((Kp, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((Kp, Mp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Kp, Mp), jnp.float32),
        interpret=interpret,
    )(seed2d, u)
    return su[:K, :m] / jnp.sqrt(jnp.float32(m))
