"""Pallas TPU kernels for the paper's compute hot spots.

  * ``gram``        — fused U Uᵀ / U g streaming contraction (server agg.)
  * ``combine``     — α-weighted update combine (paper eq. 4)
  * ``decode_attn`` — flash-decode attention with LSE partials for
                      seq-sharded KV caches

Validated on CPU with ``interpret=True`` against ``ref.py`` oracles;
``ops.py`` wrappers dispatch compiled kernels on TPU.
"""
from .ops import flash_decode, gram_and_cross, lse_merge, weighted_combine

__all__ = ["flash_decode", "gram_and_cross", "lse_merge", "weighted_combine"]
