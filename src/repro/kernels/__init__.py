"""Backend-aware kernel engine for the paper's compute hot spots.

Ops (each with ``pallas`` / ``xla`` / ``ref`` backends, autotune-dispatched
through :mod:`repro.kernels.registry` — see ``ops.py``):

  * ``gram`` / ``gram_block`` — fused U Uᵀ / U g streaming contractions
    (server + hierarchical-merge aggregation)
  * ``combine``     — α-weighted update combine (paper eq. 4)
  * ``sketch``      — fused stacked sketch-apply U Rᵀ (explicit matrix)
  * ``sign_sketch`` — counter-based RNG sign sketch; R generated in-kernel,
    never materialized (``rng_sketch.py``)
  * ``topk``        — chunked top-k magnitude selection
  * ``decode_attn`` — flash-decode attention with LSE partials for
    seq-sharded KV caches (legacy dispatch, serving path)

Pallas kernels are validated on CPU with ``interpret=True`` against the
``ref.py`` oracles and compile for real on TPU; off-TPU the autotuner picks
the jit-compiled pure-XLA formulation, never interpret mode.
"""
from .ops import (backends, dispatch, flash_decode, force_backend,
                  gram_and_cross, gram_block_and_cross, lse_merge,
                  sign_sketch, sign_sketch_adjoint, sketch_apply,
                  topk_select, weighted_combine)
from .registry import (autotune_records, available_ops,
                       clear_autotune_cache, register_impl, select_impl)

__all__ = ["autotune_records", "available_ops", "backends",
           "clear_autotune_cache", "dispatch", "flash_decode",
           "force_backend", "gram_and_cross", "gram_block_and_cross",
           "lse_merge", "register_impl", "select_impl", "sign_sketch",
           "sign_sketch_adjoint", "sketch_apply", "topk_select",
           "weighted_combine"]
