"""Pallas TPU kernels for the paper's compute hot spots.

  * ``gram``        — fused U Uᵀ / U g streaming contraction (server agg.)
  * ``combine``     — α-weighted update combine (paper eq. 4)
  * ``sketch``      — fused stacked sketch-apply U Rᵀ (summary compression)
  * ``topk``        — chunked top-k magnitude selection (summary compression)
  * ``decode_attn`` — flash-decode attention with LSE partials for
                      seq-sharded KV caches

Validated on CPU with ``interpret=True`` against ``ref.py`` oracles;
``ops.py`` wrappers dispatch compiled kernels on TPU.
"""
from .ops import (flash_decode, gram_and_cross, lse_merge, sketch_apply,
                  topk_select, weighted_combine)

__all__ = ["flash_decode", "gram_and_cross", "lse_merge", "sketch_apply",
           "topk_select", "weighted_combine"]
