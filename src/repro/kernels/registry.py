"""Backend-aware kernel dispatch with micro-autotuned selection.

Every compute hot spot (``gram``, ``gram_block``, ``stream_stats``,
``sketch``, ``topk``, ``combine``, ``sign_sketch``/``sign_sketch_adjoint``)
registers one implementation per *backend*:

  * ``pallas`` — the Pallas TPU kernel, compiled on TPU.  Off-TPU the same
    kernel only runs in interpret mode (Python-per-element), so it is
    *ineligible for autotuning* there and runs only when forced — the
    correctness path for tests, never a production path.
  * ``xla``    — a jit-compiled pure-jnp formulation.  Off-TPU this is the
    production path: XLA fuses the whole op into one compiled loop nest, so
    CPU/GPU runs never pay interpret-mode or per-op dispatch overhead.
  * ``ref``    — the un-jitted jnp oracle (``kernels.ref``): eager, simple,
    the numerical ground truth everything else is tested against.

Selection is a micro-autotune pass: the first call for a given
(op, shape-bucket, platform) times every *eligible* candidate on the real
arguments (one warm-up to compile, then a few timed reps) and caches the
winner in-process.  Shape buckets round each dimension up to the next power
of two so e.g. n = 60 000 and n = 65 536 share one entry; integer keyword
parameters bucket the same way, so a streaming op's column-chunk size
(``block_n``) is part of the bucket and the tuner effectively picks the
winning (backend, chunk) pair.  The cache is
dumpable (:func:`autotune_records`) — ``benchmarks/kernel_bench.py`` writes
it to ``BENCH_kernels.json`` so the per-backend picture rides CI.

Forcing a backend (tests, debugging, benchmarks):

  * per call:   ``ops.gram_and_cross(U, g, backend="xla")``
  * scoped:     ``with registry.force_backend("ref"): ...``
  * process:    ``REPRO_KERNEL_BACKEND=xla`` in the environment

Calls made under a jit trace cannot time anything, so tracer arguments fall
back to the cached winner for the bucket, or a static preference order
(pallas on TPU, else xla) when the bucket was never tuned.  Fused round
engines instead pick eagerly at build time via :func:`select_impl` and close
over the winning implementation.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..obs import current_tracker, spans

# preference order used when timing is impossible (tracer args, no cache)
_STATIC_ORDER = ("pallas", "xla", "ref")

AUTOTUNE_WARMUP = 1
AUTOTUNE_ITERS = 3


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclass(frozen=True)
class KernelImpl:
    """One (op, backend) implementation."""
    op: str
    backend: str
    fn: Callable
    # supports(*args, **kw) -> bool: shape/parameter constraints (e.g. the
    # chunked top-k kernel needs k <= block_n)
    supports: Optional[Callable[..., bool]] = None
    # eligible() -> bool: platform gate for *autotuning* (interpret-mode
    # Pallas off-TPU is never a candidate; forcing bypasses this)
    eligible: Optional[Callable[[], bool]] = None

    def ok_for(self, *args: Any, **kw: Any) -> bool:
        return self.supports is None or bool(self.supports(*args, **kw))

    def is_eligible(self) -> bool:
        return self.eligible is None or bool(self.eligible())


@dataclass
class AutotuneEntry:
    op: str
    bucket: Tuple
    backend: str                      # the winner
    timings_us: Dict[str, float] = field(default_factory=dict)


_IMPLS: Dict[str, Dict[str, KernelImpl]] = {}
_CACHE: Dict[Tuple, AutotuneEntry] = {}
_FORCED: List[Tuple[Optional[str], str]] = []   # (op or None, backend) stack
_EMITTED: set = set()      # (op, bucket, backend, forced) already streamed


def _emit_decision(op: str, bucket: Tuple, backend: str,
                   timings_us: Dict[str, float], forced: bool) -> None:
    """Stream a dispatch decision the moment a bucket is resolved: the
    autotune winner with its candidate timings, or the backend a
    ``force_backend``/env override pinned.  Emitted at most once per
    (op, bucket, backend, forced) so the hot dispatch path never re-logs;
    with the default noop tracker this is one attribute check."""
    tr = current_tracker()
    if not tr.active:
        return
    key = (op, bucket, backend, forced)
    if key in _EMITTED:
        return
    _EMITTED.add(key)
    event: Dict[str, Any] = {"op": op, "bucket": repr(bucket),
                             "backend": backend, "forced": forced}
    for name, us in sorted(timings_us.items()):
        event[f"us_per_call_{name}"] = us
    tr.scope("kernels/autotune").log(event)


def register_impl(op: str, backend: str, fn: Callable, *,
                  supports: Optional[Callable[..., bool]] = None,
                  eligible: Optional[Callable[[], bool]] = None,
                  overwrite: bool = False) -> None:
    impls = _IMPLS.setdefault(op, {})
    if backend in impls and not overwrite:
        raise KeyError(f"kernel impl '{op}/{backend}' already registered")
    impls[backend] = KernelImpl(op, backend, fn, supports, eligible)


def available_ops() -> Tuple[str, ...]:
    return tuple(sorted(_IMPLS))


def backends(op: str) -> Tuple[str, ...]:
    if op not in _IMPLS:
        raise KeyError(f"unknown kernel op '{op}'; have {available_ops()}")
    return tuple(sorted(_IMPLS[op]))


class force_backend:
    """Context manager pinning dispatch to one backend (optionally one op).

    Forcing is a *preference*: a forced backend whose ``supports`` check
    rejects the call's shapes (e.g. the chunked top-k kernel with
    ``k > block_n``) falls back to normal selection instead of crashing.
    To hard-require a backend, pass ``backend=`` at the call site — that
    path runs the implementation unconditionally and lets it raise."""

    def __init__(self, backend: str, op: Optional[str] = None):
        self.entry = (op, backend)

    def __enter__(self):
        _FORCED.append(self.entry)
        return self

    def __exit__(self, *exc):
        _FORCED.remove(self.entry)
        return False


def _forced_backend(op: str) -> Optional[str]:
    for forced_op, backend in reversed(_FORCED):
        if forced_op is None or forced_op == op:
            return backend
    return os.environ.get("REPRO_KERNEL_BACKEND") or None


def _pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


def _bucket(args: Tuple, kw: Dict) -> Tuple:
    """Shape bucket: pow2-rounded dims per array arg + static scalars."""
    parts: List = []
    for a in args:
        if hasattr(a, "shape") and hasattr(a, "dtype"):
            parts.append(tuple(_pow2(d) for d in a.shape) + (str(a.dtype),))
        elif isinstance(a, (int, np.integer)):
            parts.append(("i", _pow2(int(a))))
        else:
            parts.append(("x",))
    for k in sorted(kw):
        v = kw[k]
        parts.append((k, _pow2(int(v)) if isinstance(v, (int, np.integer))
                      else str(v)))
    return tuple(parts)


# jax.core.Tracer moved across jax versions; fall back to duck typing
_TRACER = getattr(jax.core, "Tracer", None)


def _has_tracer(args: Tuple) -> bool:
    if _TRACER is not None:
        return any(isinstance(a, _TRACER) for a in args)
    return any(isinstance(a, jax.Array) and hasattr(a, "_trace")
               for a in args)


def _time_impl(impl: KernelImpl, args: Tuple, kw: Dict) -> float:
    """Median wall time per call in µs (one warm-up to compile first)."""
    for _ in range(AUTOTUNE_WARMUP):
        jax.block_until_ready(impl.fn(*args, **kw))
    ts = []
    for _ in range(AUTOTUNE_ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(impl.fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _candidates(op: str, args: Tuple, kw: Dict) -> List[KernelImpl]:
    return [impl for impl in _IMPLS[op].values()
            if impl.is_eligible() and impl.ok_for(*args, **kw)]


def _autotune(op: str, bucket: Tuple, args: Tuple, kw: Dict) -> AutotuneEntry:
    cands = _candidates(op, args, kw)
    if not cands:
        raise RuntimeError(f"no eligible backend for kernel op '{op}' "
                           f"(registered: {backends(op)})")
    entry = AutotuneEntry(op=op, bucket=bucket, backend=cands[0].backend)
    if len(cands) > 1:
        # one parent span per bucket resolution; each candidate timing
        # (compile warm-up + timed reps) is a child span so the autotune
        # cost inside a round's first stage is attributable per backend
        with spans.span("autotune", op=op, bucket=repr(bucket)):
            for impl in cands:
                try:
                    with spans.span("candidate", op=op,
                                    backend=impl.backend) as h:
                        us = _time_impl(impl, args, kw)
                        if h is not None:
                            h.tags["us_per_call"] = us
                    entry.timings_us[impl.backend] = us
                except Exception:       # a candidate that crashes never wins
                    continue
        if entry.timings_us:
            entry.backend = min(entry.timings_us, key=entry.timings_us.get)
    _CACHE[(op, bucket)] = entry
    _emit_decision(op, bucket, entry.backend, entry.timings_us, forced=False)
    return entry


def select_impl(op: str, *args: Any, **kw: Any) -> KernelImpl:
    """Resolve (eagerly, with timing if needed) the implementation dispatch
    would use for these arguments — for callers that build jit-compiled
    stages and close over the winning fn."""
    if op not in _IMPLS:
        raise KeyError(f"unknown kernel op '{op}'; have {available_ops()}")
    forced = _forced_backend(op)
    if forced is not None:
        if forced not in _IMPLS[op]:
            raise KeyError(f"forced backend '{forced}' not registered for "
                           f"'{op}' (have {backends(op)})")
        impl = _IMPLS[op][forced]
        if impl.ok_for(*args, **kw):
            if current_tracker().active:
                _emit_decision(op, _bucket(args, kw), forced, {},
                               forced=True)
            return impl
        # forced backend cannot run these shapes (supports() rejected):
        # fall through to normal selection — forcing is a preference, the
        # call-site backend= arg is the hard requirement
    bucket = _bucket(args, kw)
    entry = _CACHE.get((op, bucket))
    if entry is None:
        if _has_tracer(args):           # cannot time under a jit trace
            for name in _STATIC_ORDER:
                impl = _IMPLS[op].get(name)
                if impl and impl.is_eligible() and impl.ok_for(*args, **kw):
                    return impl
            return next(iter(_IMPLS[op].values()))
        entry = _autotune(op, bucket, args, kw)
    impl = _IMPLS[op].get(entry.backend)
    if impl is None or not impl.ok_for(*args, **kw):
        cands = _candidates(op, args, kw)
        if not cands:
            raise RuntimeError(f"no eligible backend for kernel op '{op}'")
        impl = cands[0]
    return impl


def select_impl_for(op: str, *specs: "jax.ShapeDtypeStruct",
                    **kw: Any) -> KernelImpl:
    """:func:`select_impl` over shape/dtype specs instead of live arrays —
    for stage builders that need the winning backend cheaply on every cache
    lookup.  Specs carry .shape/.dtype, so the supports() checks and shape
    buckets work on them directly; dense zero arrays are synthesized ONLY
    when an autotune-cache miss actually needs something to time."""
    if op not in _IMPLS:
        raise KeyError(f"unknown kernel op '{op}'; have {available_ops()}")
    forced = _forced_backend(op)
    if forced is not None:
        if forced not in _IMPLS[op]:
            raise KeyError(f"forced backend '{forced}' not registered for "
                           f"'{op}' (have {backends(op)})")
        impl = _IMPLS[op][forced]
        if impl.ok_for(*specs, **kw):
            if current_tracker().active:
                _emit_decision(op, _bucket(specs, kw), forced, {},
                               forced=True)
            return impl                 # preference honored, no arrays built
    bucket = _bucket(specs, kw)
    entry = _CACHE.get((op, bucket))
    if entry is None:
        import jax.numpy as jnp
        args = tuple(jnp.zeros(s.shape, s.dtype) for s in specs)
        return select_impl(op, *args, **kw)
    impl = _IMPLS[op].get(entry.backend)
    if impl is None or not impl.ok_for(*specs, **kw):
        cands = _candidates(op, specs, kw)
        if not cands:
            raise RuntimeError(f"no eligible backend for kernel op '{op}'")
        impl = cands[0]
    return impl


def dispatch(op: str, *args: Any, backend: Optional[str] = None,
             **kw: Any) -> Any:
    """Run ``op`` on the chosen backend (autotuned unless ``backend`` or a
    force is in effect)."""
    if backend is not None:
        impls = _IMPLS.get(op, {})
        if backend not in impls:
            raise KeyError(f"backend '{backend}' not registered for '{op}' "
                           f"(have {backends(op)})")
        return impls[backend].fn(*args, **kw)
    return select_impl(op, *args, **kw).fn(*args, **kw)


def autotune_records() -> List[Dict[str, Any]]:
    """JSON-ready dump of the in-process autotune cache (one record per
    (op, bucket)): the selected backend plus per-backend timings.  Timing
    fields embed ``us_per_call`` so the bench-regression gate ignores them
    (machine-dependent); the selection itself is ignored via ``selected``."""
    records = []
    for (op, bucket), entry in sorted(_CACHE.items(), key=lambda x: x[0]):
        rec: Dict[str, Any] = {"op": op, "bucket": repr(bucket),
                               "num_backends": len(_IMPLS[op]),
                               "num_candidates_timed": len(entry.timings_us),
                               "backend_selected": entry.backend}
        for name, us in sorted(entry.timings_us.items()):
            rec[f"us_per_call_{name}"] = us
        records.append(rec)
    return records


def clear_autotune_cache() -> None:
    _CACHE.clear()
    _EMITTED.clear()
