"""Pallas TPU kernel: flash-decode attention for long KV caches.

One new token attends to a cache of S entries (decode_32k / long_500k serve
steps).  The contraction is memory-bound (reads the whole cache once), so
the kernel streams KV blocks HBM→VMEM with online-softmax accumulators in
VMEM and emits BOTH the attention output and the log-sum-exp, enabling the
cross-shard combine when the cache's seq axis is sharded over the mesh
(`ops.flash_decode_sharded` merges per-shard partials with an LSE-weighted
sum — the collective-efficient alternative to all-gathering the cache).

Grid: (B, KV, S/block_s) — the seq axis is innermost so accumulators stay
resident in VMEM scratch across that loop.  Blocks: q (1,1,G,hd),
k/v (1, block_s, 1, hd), per-batch lengths in SMEM-like (1,1) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                   m_scr, l_scr, acc_scr, *, block_s: int, window, softcap):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
    length = len_ref[0, 0]                         # valid entries = pos+1

    scale = q.shape[-1] ** -0.5
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    kpos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = kpos < length
    if window is not None:
        ok = ok & (kpos > length - 1 - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                            # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(s_idx == pl.num_programs(2) - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l)).astype(lse_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "window", "softcap",
                                    "interpret"))
def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        lengths: jax.Array, *, block_s: int = 512,
                        window: int | None = None,
                        softcap: float | None = None,
                        interpret: bool = True):
    """q (B, KV, G, hd); k, v (B, S, KV, hd); lengths (B,) int32 (= pos+1).

    Returns ``(o (B, KV, G, hd) f32, lse (B, KV, G, 1) f32)`` — partials
    suitable for LSE-merge across seq shards.  ``softcap`` applies the tanh
    logit cap before masking (gemma-family serving).
    """
    B, S, KV, hd = k.shape
    G = q.shape[2]
    pad = (-S) % block_s
    if pad:
        zk = ((0, 0), (0, pad), (0, 0), (0, 0))
        k, v = jnp.pad(k, zk), jnp.pad(v, zk)
    Sp = S + pad
    lengths2d = lengths.reshape(B, 1).astype(jnp.int32)

    grid = (B, KV, Sp // block_s)
    kernel = functools.partial(_decode_kernel, block_s=block_s, window=window,
                               softcap=softcap)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, s: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # running max m
            pltpu.VMEM((G, 1), jnp.float32),    # running denom l
            pltpu.VMEM((G, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(lengths2d, q, k, v)
    return o, lse
