"""Streamed hierarchical round engine — big-model rounds without (P, n)
round matrices.

The fused engine (``repro.hier.fused``) flattens a round's P client updates
into dense (P, n) f32 matrices.  At logreg width that is the fastest thing
to do; at transformer width it means holding P extra full-width f32 model
copies (plus another P for the gradient estimates) just to run K×K solves.
This engine exploits the identity the whole tier tree already lives on:

    every Gram block, c-term and combined update of EVERY tier is a pure
    function of the device-level pair  G = D Dᵀ,  C = D GMᵀ  ∈ R^{P×P}
    and small per-tier weight vectors.

Concretely, a gateway cohort's Gram is a sub-block ``G[idx][:, idx]``; its
c-term is a row-mix ``C[idx] @ w`` (ĝ estimates are weighted means of GM
rows); a parent tier over child combinations ``ū_g = α_g @ U_g`` has Gram
``W G Wᵀ`` where row g of W scatters α_g — and the cloud's final step is a
single effective row-mix ``Σ_g γ_g α_g`` applied to D.  So one streamed
pass over the parameter axis (leaf-aligned column chunks through the
``stream_stats`` kernel op — XLA ``lax.scan`` off-TPU, the Pallas tile
kernel on TPU) accumulates everything the round needs, the tier solves run
in P-dimensional space, and a second streamed pass writes ``α @ U``
leaf-by-leaf into the (donated, off-CPU) parameter buffers.  Peak
round-matrix memory is O(P·chunk + P²) instead of O(P·n).

Payload vectors (ū_g, ĝ_g) are **symbolic** :class:`RowMix` refs — weight
vectors over the round's P rows — until something genuinely needs n floats.
That something is the compression pipeline (``repro.compress``): sketch/
top-k encodes and error-feedback residuals consume real vectors, so
``materialize`` produces them with one chunked combine (the sketch itself
stays streaming — the counter-based RNG sketch never materializes R).
Above the first compression hop, decoded summaries are dense (n,) vectors
again; those merges delegate to the fused ``stack=True`` stages over the
small (#children, n) stacks the dense pipeline also holds.  Per-sender EF
residuals likewise remain O(#senders · n) exactly as in the dense path —
#senders is the gateway count, not P.

``run_hier_simulation`` selects this engine automatically when the dense
footprint ``2·P·n·4`` bytes exceeds ``REPRO_DENSE_ROUND_BYTES`` (default
1 GiB); ``engine=`` overrides.  Numerical parity with the fused/reference
stages (same solves, same info keys, f32 accumulation in a different
summation order) is pinned by ``tests/test_streamed_engine.py``.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, replace
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.flatten import ChunkedFlatView, mix_rows
from ..core.solve import SolveConfig, bound_value, solve_alpha
from ..kernels.registry import force_backend, select_impl_for
from ..obs import current_tracker, spans
from . import fused as _fused

Pytree = Any

DEFAULT_CHUNK = 1 << 16
# autotune candidates are timed on specs capped to this many columns: the
# backend that wins at 4M columns wins at 400M (same memory-bound regime),
# and timing must never allocate a transformer-width dense zero array
AUTOTUNE_CAP_COLS = 1 << 22


def dense_round_bytes(P: int, n: int) -> float:
    """What the dense engine's round matrices would occupy: D + GM f32."""
    return float(2 * P * n * 4)


@dataclass
class RowMix:
    """A symbolic n-vector: ``w`` weights over the round's P stacked rows of
    the update (``src='delta'``) or gradient (``src='grad'``) pytree.  All
    uncompressed tier payloads are RowMixes; composition up the tree is
    P-dimensional algebra and never touches the parameter axis."""
    w: Any                      # (P,) numpy or jax array
    src: str                    # 'delta' | 'grad'


def _is_mix(ref) -> bool:
    return isinstance(ref, RowMix)


# ---------------------------------------------------------------------------
# process-wide compiled-stage caches (mirrors fused._STAGES)
# ---------------------------------------------------------------------------

_STAGES: Dict[Tuple, Callable] = {}
_ACCUM: Dict[Tuple, Callable] = {}


def clear_stage_cache() -> None:
    _STAGES.clear()
    _ACCUM.clear()


def _adjust(cfg: SolveConfig, *, scale: float = 1.0,
            sum_to: Optional[float] = None) -> SolveConfig:
    if scale != 1.0:
        cfg = replace(cfg, expectation_scale=cfg.expectation_scale * scale)
    if sum_to is not None:
        cfg = replace(cfg, sum_to=sum_to)
    return cfg


def _solve_info(Gs, c, cfg, mode, wts):
    """The per-tier solve + diagnostics shared by every streamed stage —
    the same math (and the same ``fused.solve_diagnostics`` info keys) as
    ``fused.summary_stage``'s body."""
    if mode == "contextual":
        alpha = solve_alpha(Gs, c, cfg)
        info = _fused.solve_diagnostics(Gs, c, alpha, cfg.beta)
    else:                                       # "mean" (hier-FedAvg tier)
        alpha = wts
        info = {"bound": bound_value(Gs, c, alpha, cfg.beta)}
    return alpha, info


def _cloud_solve_info(Gs, c, cfg):
    """Final-tier contextual solve + the cloud info keys (γ alias,
    gram_diag) — shared by the raw and combo cloud stages, mirroring
    ``fused.cloud_stage``'s body."""
    gamma = solve_alpha(Gs, c, cfg)
    info = {"alpha": gamma, "gamma": gamma,
            **_fused.solve_diagnostics(Gs, c, gamma, cfg.beta),
            "gram_diag": jnp.diag(Gs)}
    return gamma, info


def tier_stage(P: int, K: int, solve_cfg: SolveConfig, mode: str, *,
               pool_scale: float = 1.0, robust=None) -> Callable:
    """Device-tier stage over row indices: ``fn(G, C, idx (K,), counts,
    g_w?) -> {G, c, alpha, u_w, ghat_w, info}``.

    With ``robust`` (a RobustConfig) the cohort's cross sub-block
    ``C[idx][:, idx]`` — exactly the fused engine's ``Us @ GRsᵀ`` — feeds
    clip + pooling before the solve; the shipped ĝ mix stays the plain
    weighted mean (the streamed statistics hold no per-member grad norms,
    and fused/streamed parity pins that choice)."""
    if robust is not None and (mode != "contextual"
                               or not getattr(robust, "enabled", False)):
        robust = None
    key = ("stier", P, K, solve_cfg, mode, pool_scale, robust)
    fn = _STAGES.get(key)
    if fn is not None:
        return fn
    cfg = _adjust(solve_cfg, scale=pool_scale)
    if robust is not None:
        from ..robust.gramstats import robustify

    @jax.jit
    def stage(G, C, idx, counts, g_w=None):
        wts = counts / jnp.maximum(jnp.sum(counts), 1e-12)
        ghat_w = jnp.zeros((P,), jnp.float32).at[idx].set(wts)
        Gs = G[idx][:, idx]
        if robust is not None:
            Gr, cr, s = robustify(Gs, C[idx][:, idx], wts, robust)
            alpha = solve_alpha(Gr, cr, cfg)
            eff = s * alpha
            info = _fused.solve_diagnostics(Gr, cr, alpha, cfg.beta)
            info["clip_scale"] = s
            u_w = jnp.zeros((P,), jnp.float32).at[idx].set(eff)
            return {"G": Gr, "c": cr, "alpha": eff, "u_w": u_w,
                    "ghat_w": ghat_w, "info": info}
        g_solve = ghat_w if g_w is None else g_w
        c = C[idx] @ g_solve
        alpha, info = _solve_info(Gs, c, cfg, mode, wts)
        u_w = jnp.zeros((P,), jnp.float32).at[idx].set(alpha)
        return {"G": Gs, "c": c, "alpha": alpha, "u_w": u_w,
                "ghat_w": ghat_w, "info": info}

    _STAGES[key] = stage
    return stage


def merge_stage(P: int, K: int, solve_cfg: SolveConfig, mode: str, *,
                sum_to: Optional[float] = 1.0) -> Callable:
    """Parent-tier stage over child row-mixes: ``fn(G, C, W (K,P),
    GW (K,P), counts, g_w?)`` — Gram ``W G Wᵀ``, c-term ``(W C) ĝ_w``."""
    key = ("smerge", P, K, solve_cfg, mode, sum_to)
    fn = _STAGES.get(key)
    if fn is not None:
        return fn
    cfg = _adjust(solve_cfg, sum_to=sum_to)

    @jax.jit
    def stage(G, C, W, GW, counts, g_w=None):
        wts = counts / jnp.maximum(jnp.sum(counts), 1e-12)
        ghat_w = wts @ GW
        g_solve = ghat_w if g_w is None else g_w
        Gs = W @ G @ W.T
        c = (W @ C) @ g_solve
        alpha, info = _solve_info(Gs, c, cfg, mode, wts)
        return {"G": Gs, "c": c, "alpha": alpha, "u_w": alpha @ W,
                "ghat_w": ghat_w, "info": info}

    _STAGES[key] = stage
    return stage


def cloud_raw_stage(P: int, K: int, solve_cfg: SolveConfig, kind: str, *,
                    solve_scale: float = 1.0, robust=None) -> Callable:
    """Final tier over raw device rows (star / relay): ``fn(G, C, idx,
    counts) -> {u_w, info}`` — fused ``cloud_stage``'s math on sub-blocks,
    with the same robust clip+pool hook on the cross sub-block."""
    if robust is not None and (kind != "raw"
                               or not getattr(robust, "enabled", False)):
        robust = None
    key = ("scloud_raw", P, K, solve_cfg, kind, solve_scale, robust)
    fn = _STAGES.get(key)
    if fn is not None:
        return fn
    cfg = _adjust(solve_cfg, scale=solve_scale)
    if robust is not None:
        from ..robust.gramstats import robustify

    @jax.jit
    def stage(G, C, idx, counts):
        wts = counts / jnp.maximum(jnp.sum(counts), 1e-12)
        if kind == "fedavg":
            alpha = wts
            info = {"alpha": alpha, "gamma": alpha}
        elif robust is not None:
            Gr, cr, s = robustify(G[idx][:, idx], C[idx][:, idx], wts,
                                  robust)
            gamma = solve_alpha(Gr, cr, cfg)
            alpha = s * gamma
            info = {"alpha": alpha, "gamma": alpha,
                    **_fused.solve_diagnostics(Gr, cr, gamma, cfg.beta),
                    "gram_diag": jnp.diag(Gr), "clip_scale": s}
        else:
            ghat_w = jnp.zeros((P,), jnp.float32).at[idx].set(wts)
            Gs = G[idx][:, idx]
            c = C[idx] @ ghat_w
            alpha, info = _cloud_solve_info(Gs, c, cfg)
        u_w = jnp.zeros((P,), jnp.float32).at[idx].set(alpha)
        return {"u_w": u_w, "info": info}

    _STAGES[key] = stage
    return stage


def cloud_combo_stage(P: int, K: int, solve_cfg: SolveConfig,
                      kind: str) -> Callable:
    """Final tier over child combinations: ``fn(G, C, W (K,P), g_w, counts)
    -> {eff_w, info}`` with the mass-conserving Σγ=1 solve; ``eff_w`` is
    the round's one effective row-mix ``γ @ W``."""
    key = ("scloud_combo", P, K, solve_cfg, kind)
    fn = _STAGES.get(key)
    if fn is not None:
        return fn
    cfg = _adjust(solve_cfg, sum_to=1.0 if kind == "combo" else None)

    @jax.jit
    def stage(G, C, W, g_w, counts):
        wts = counts / jnp.maximum(jnp.sum(counts), 1e-12)
        if kind == "fedavg":
            gamma = wts
            info = {"alpha": gamma, "gamma": gamma}
        else:
            Gs = W @ G @ W.T
            c = (W @ C) @ g_w
            gamma, info = _cloud_solve_info(Gs, c, cfg)
        return {"eff_w": gamma @ W, "info": info}

    _STAGES[key] = stage
    return stage


# ---------------------------------------------------------------------------
# streamed passes (accumulate / materialize / apply)
# ---------------------------------------------------------------------------

def _accum_for(P: int, slabs_key: Tuple, chunk: int,
               impls: Tuple) -> Callable:
    """One jitted accumulate pass per (shapes, chunk, backend picks): sums
    the kernel op's per-leaf (G, C) partials under a single jit boundary —
    one dispatch per round regardless of leaf count."""
    key = (P, slabs_key, chunk, tuple(i.backend for i in impls))
    fn = _ACCUM.get(key)
    if fn is not None:
        return fn
    impl_fns = tuple(i.fn for i in impls)

    @jax.jit
    def accumulate(d_mats, g_mats):
        G = jnp.zeros((P, P), jnp.float32)
        C = jnp.zeros((P, P), jnp.float32)
        for dm, gm, f in zip(d_mats, g_mats, impl_fns):
            Gp, Cp = f(dm, gm, block_n=chunk)
            G = G + Gp
            C = C + Cp
        return G, C

    _ACCUM[key] = accumulate
    return accumulate


@jax.jit
def _materialize_mix(mats, w):
    """``w @ [slab matrices]`` concatenated to one (n,) f32 vector — the
    only place the streamed pipeline builds a full-width vector, and only
    when compression genuinely needs one."""
    return jnp.concatenate([mix_rows(w, m) for m in mats])


def _apply_fn(donate: bool) -> Callable:
    @partial(jax.jit, donate_argnums=(0,) if donate else ())
    def apply_mix(params, stacked, w):
        return jax.tree_util.tree_map(
            lambda p, s: (p + jnp.reshape(mix_rows(w, s), p.shape)
                          ).astype(p.dtype),
            params, stacked)
    return apply_mix


# CPU XLA cannot donate buffers (it would warn per compile); elsewhere the
# combine writes straight into the donated parameter allocation — but ONLY
# when the caller opted in (donation invalidates the argument buffers, so a
# caller that reuses its params across apply calls must not enable it)
_APPLY: Dict[bool, Callable] = {}


def _apply_mix(params, stacked, w, donate: bool):
    donate = donate and jax.default_backend() != "cpu"
    fn = _APPLY.get(donate)
    if fn is None:
        fn = _APPLY[donate] = _apply_fn(donate)
    return fn(params, stacked, w)


# ---------------------------------------------------------------------------
# engine / round context
# ---------------------------------------------------------------------------

class StreamedRoundEngine:
    """Drop-in peer of :class:`repro.hier.fused.HierRoundEngine`: same
    constructor signature plus ``chunk`` (column-chunk size, also the
    ``stream_stats`` autotune knob) and ``mesh`` (shard the chunk axis over
    a ``jax.sharding.Mesh`` when one is available; a ``'fleet'`` mesh axis
    additionally shards the leading P device axis of the round matrices —
    see :func:`repro.sharding.specs.stream_round_shardings`)."""

    name = "streamed"

    def __init__(self, params_template: Pytree, solve_cfg: SolveConfig,
                 tier_mode: str, gram_scope: Optional[str] = None, *,
                 chunk: Optional[int] = None,
                 mesh: Optional["jax.sharding.Mesh"] = None,
                 donate_params: bool = False, robust=None):
        self.n = int(sum(l.size for l in
                         jax.tree_util.tree_leaves(params_template)))
        self.solve_cfg = solve_cfg
        self.tier_mode = tier_mode
        self.gram_scope = gram_scope
        # RobustConfig (or None), applied at the member-level stages only —
        # same placement as the fused engine
        self.robust = robust
        self.chunk = int(chunk if chunk is not None else
                         os.environ.get("REPRO_STREAM_CHUNK", DEFAULT_CHUNK))
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        self.mesh = mesh
        # opt-in: the combine donates the params argument off-CPU.  Off by
        # default — donation deletes the caller's buffers, so only enable
        # it when every apply() consumes params the caller will replace
        # (run_hier_simulation does, and copies the caller's init_params
        # before the first round for exactly this reason).
        self.donate_params = bool(donate_params)
        # same scoped-column bookkeeping as the fused engine (int32 — reused
        # here for the dense-fallback stages of the compressed pipeline)
        self._scope_idx = _fused.scope_indices(params_template, gram_scope)
        self._scope_key = (None if self._scope_idx is None else
                           (gram_scope, len(self._scope_idx),
                            hash(self._scope_idx.tobytes())))

    # -- memory model --------------------------------------------------------

    def peak_round_bytes(self, P: int, dense_fallback_members: int = 0
                         ) -> float:
        """Estimated peak round-matrix working set: two (P, chunk) f32
        column tiles in flight plus the two (P, P) f32 accumulators.

        ``dense_fallback_members`` accounts for the compressed pipeline:
        above a compression hop the members are decoded (n,) vectors and
        merges run on fused stack stages, so the largest summary-tier
        fan-in contributes two dense (members, n) f32 stacks (ū and ĝ) —
        the caller passes the max fan-in when compression is active (EF
        residual state is the compression pipeline's own and identical to
        the dense engine's, so it is not a round-matrix cost)."""
        bn = min(self.chunk, self.n)
        return float(2 * P * bn * 4 + 2 * P * P * 4
                     + 2 * dense_fallback_members * self.n * 4)

    # -- round entry ---------------------------------------------------------

    def begin_round(self, stacked_deltas: Pytree,
                    stacked_grads: Pytree) -> "StreamedRoundContext":
        if self.mesh is not None:
            from ..sharding.specs import stream_round_shardings
            stacked_deltas = jax.device_put(
                stacked_deltas,
                stream_round_shardings(self.mesh, stacked_deltas))
            stacked_grads = jax.device_put(
                stacked_grads,
                stream_round_shardings(self.mesh, stacked_grads))
        dview = ChunkedFlatView(stacked_deltas, self.gram_scope)
        gview = ChunkedFlatView(stacked_grads, self.gram_scope)
        P = dview.K
        scoped = dview.scoped_slabs
        if scoped:
            specs, impls, slabs_key = [], [], []
            for s in scoped:
                # timing cap preserves the width residue mod chunk so
                # alignment-based supports() checks see the true shape's
                # divisibility, and the winner at ~4M cols is the winner at
                # full width (same memory-bound regime).  When the chunk
                # itself exceeds the cap no capped width can stay
                # chunk-aligned — cap hard instead of synthesizing a spec
                # wider than the slab (which would defeat the cap's whole
                # point: select_impl_for times dense zeros of spec size).
                w = s.width
                if w > AUTOTUNE_CAP_COLS:
                    if self.chunk <= AUTOTUNE_CAP_COLS:
                        w = min(w, (AUTOTUNE_CAP_COLS // self.chunk)
                                * self.chunk + w % self.chunk)
                    else:
                        w = AUTOTUNE_CAP_COLS
                spec = jax.ShapeDtypeStruct((P, w), s.matrix.dtype)
                impl = select_impl_for("stream_stats", spec, spec,
                                       block_n=self.chunk)
                true_spec = jax.ShapeDtypeStruct((P, s.width),
                                                 s.matrix.dtype)
                if not impl.ok_for(true_spec, true_spec,
                                   block_n=self.chunk):
                    # the capped pick cannot run the real slab (e.g. the
                    # pallas tile kernel on an unaligned width — its pad
                    # would be the O(P·n) copy this engine exists to
                    # avoid): take the streaming XLA path instead
                    with force_backend("xla", op="stream_stats"):
                        impl = select_impl_for("stream_stats", spec, spec,
                                               block_n=self.chunk)
                impls.append(impl)
                slabs_key.append((P, s.width, str(s.matrix.dtype)))
            accumulate = _accum_for(P, tuple(slabs_key), self.chunk,
                                    tuple(impls))
            # the chunked column pass: the streamed engine's per-round hot
            # spot (walks every chunk of every slab under one jit call)
            n_chunks = sum(-(-s.width // self.chunk) for s in scoped)
            with spans.span("stream_accumulate", P=P, chunks=n_chunks,
                            chunk_cols=self.chunk, slabs=len(scoped)):
                G, C = accumulate(tuple(s.matrix for s in scoped),
                                  tuple(gview.slabs[s.index].matrix
                                        for s in scoped))
        else:                       # scope matched nothing: degenerate zeros
            G = C = jnp.zeros((P, P), jnp.float32)
        tr = current_tracker()
        if tr.active:
            # the streamed engine's memory story, per round: how many column
            # chunks the accumulate pass walks and the deterministic peak
            # working set it holds instead of the dense (P, n) matrices
            chunks = sum(-(-s.width // self.chunk) for s in scoped)
            tr.scope("hier/streamed").log({
                "P": P, "chunk_cols": self.chunk, "num_chunks": chunks,
                "num_slabs": len(scoped),
                "peak_round_matrix_bytes": self.peak_round_bytes(P),
                "dense_round_matrix_bytes": dense_round_bytes(P, self.n)})
        return StreamedRoundContext(self, stacked_deltas, stacked_grads,
                                    dview, gview, G, C)


class StreamedRoundContext:
    """One round's state: the (P, P) statistics plus views of the stacked
    update/gradient pytrees.  Mirrors :class:`FusedRoundContext`'s surface;
    refs are :class:`RowMix` until compression dense-ifies them."""

    name = "streamed"

    def __init__(self, engine: StreamedRoundEngine, stacked_deltas: Pytree,
                 stacked_grads: Pytree, dview: ChunkedFlatView,
                 gview: ChunkedFlatView, G: jax.Array, C: jax.Array):
        self.engine = engine
        self._deltas, self._grads = stacked_deltas, stacked_grads
        self._dview, self._gview = dview, gview
        self.G, self.C = G, C
        self.P = dview.K

    # -- device-uplink decodes (dense-engine feature) ------------------------

    def add_decoded_row(self, i: int, d_vec, g_vec) -> None:
        raise NotImplementedError(
            "device-uplink decode rows need the dense round matrices; "
            "run_hier_simulation rejects engine='streamed' for that config "
            "and auto-selects the fused engine")

    # -- gradient refs -------------------------------------------------------

    def mean_grad(self, idxs) -> RowMix:
        w = np.zeros((self.P,), np.float32)
        w[np.asarray(idxs, np.int64)] = 1.0 / len(idxs)
        return RowMix(w, "grad")

    def compose_grads(self, refs, counts):
        refs = list(refs)
        if all(_is_mix(r) for r in refs):
            w = np.asarray(counts, np.float64)
            w = w / max(float(w.sum()), 1e-12)
            acc = sum(float(wi) * jnp.asarray(r.w, jnp.float32)
                      for wi, r in zip(w, refs))
            return RowMix(acc, refs[0].src)
        vecs = tuple(self.materialize(r) for r in refs)
        return _fused.weighted_mean_rows(
            vecs, jnp.asarray(np.asarray(counts, np.float32)))

    # -- tier stages ---------------------------------------------------------

    def _mix_matrix(self, refs) -> jax.Array:
        return jnp.stack([jnp.asarray(r.w, jnp.float32) for r in refs])

    def _wrap(self, out) -> Dict[str, Any]:
        return {"G": out["G"], "c": out["c"], "alpha": out["alpha"],
                "u_bar": RowMix(out["u_w"], "delta"),
                "ghat": RowMix(out["ghat_w"], "grad"), "info": out["info"]}

    def gateway(self, idxs, *, solve_grad=None,
                pool_scale: float = 1.0) -> Dict[str, Any]:
        stage = tier_stage(self.P, len(idxs), self.engine.solve_cfg,
                           self.engine.tier_mode, pool_scale=pool_scale,
                           robust=self.engine.robust)
        g_w = (None if solve_grad is None
               else jnp.asarray(solve_grad.w, jnp.float32))
        out = stage(self.G, self.C, jnp.asarray(np.asarray(idxs, np.int32)),
                    jnp.ones((len(idxs),), jnp.float32), g_w)
        return self._wrap(out)

    def merge(self, u_refs, g_refs, counts, *,
              solve_grad=None) -> Dict[str, Any]:
        u_refs, g_refs = list(u_refs), list(g_refs)
        dense = (any(not _is_mix(r) for r in u_refs + g_refs)
                 or (solve_grad is not None and not _is_mix(solve_grad)))
        if dense:
            # above a compression hop the children are decoded (n,) vectors:
            # delegate to the fused stack-inside-jit stage over the small
            # (#children, n) member set the dense pipeline also holds
            stage = _fused.summary_stage(
                len(u_refs), self.engine.n, self.engine.solve_cfg,
                self.engine.tier_mode, sum_to=1.0, stack=True,
                scope_key=self.engine._scope_key,
                scope_idx=self.engine._scope_idx)
            return stage(tuple(self.materialize(r) for r in u_refs),
                         tuple(self.materialize(r) for r in g_refs),
                         jnp.asarray(np.asarray(counts, np.float32)),
                         None if solve_grad is None
                         else self.materialize(solve_grad))
        stage = merge_stage(self.P, len(u_refs), self.engine.solve_cfg,
                            self.engine.tier_mode, sum_to=1.0)
        g_w = (None if solve_grad is None
               else jnp.asarray(solve_grad.w, jnp.float32))
        out = stage(self.G, self.C, self._mix_matrix(u_refs),
                    self._mix_matrix(g_refs),
                    jnp.asarray(np.asarray(counts, np.float32)), g_w)
        return self._wrap(out)

    def cloud_raw(self, idxs, kind: str, *,
                  solve_scale: float = 1.0) -> Tuple[RowMix, Dict]:
        stage = cloud_raw_stage(self.P, len(idxs), self.engine.solve_cfg,
                                kind, solve_scale=solve_scale,
                                robust=self.engine.robust)
        out = stage(self.G, self.C,
                    jnp.asarray(np.asarray(idxs, np.int32)),
                    jnp.ones((len(idxs),), jnp.float32))
        return RowMix(out["u_w"], "delta"), out["info"]

    def cloud_combo(self, u_refs, counts, ghat, *, kind: str = "combo",
                    override=None) -> Tuple[Any, Dict]:
        u_refs = list(u_refs)
        dense = (override is not None
                 or any(not _is_mix(r) for r in u_refs)
                 or (ghat is not None and not _is_mix(ghat)))
        if dense:
            stage = _fused.cloud_stage(
                len(u_refs), self.engine.n, self.engine.solve_cfg, kind,
                stack=True, scope_key=self.engine._scope_key,
                scope_idx=self.engine._scope_idx)
            return stage(tuple(self.materialize(r) for r in u_refs),
                         self.materialize(ghat),
                         jnp.asarray(np.asarray(counts, np.float32)),
                         override=override)
        stage = cloud_combo_stage(self.P, len(u_refs),
                                  self.engine.solve_cfg, kind)
        out = stage(self.G, self.C, self._mix_matrix(u_refs),
                    jnp.asarray(ghat.w, jnp.float32),
                    jnp.asarray(np.asarray(counts, np.float32)))
        return RowMix(out["eff_w"], "delta"), out["info"]

    # -- vector materialization / final apply --------------------------------

    def materialize(self, ref) -> jax.Array:
        if not _is_mix(ref):
            return ref
        view = self._dview if ref.src == "delta" else self._gview
        with spans.span("stream_materialize", src=ref.src, P=self.P):
            return _materialize_mix(tuple(s.matrix for s in view.slabs),
                                    jnp.asarray(ref.w, jnp.float32))

    def apply(self, params: Pytree, delta_ref) -> Pytree:
        if not _is_mix(delta_ref):
            return _fused.apply_delta(params, delta_ref)
        with spans.span("stream_apply", P=self.P):
            return _apply_mix(params, self._deltas,
                              jnp.asarray(delta_ref.w, jnp.float32),
                              self.engine.donate_params)
