"""Hierarchical contextual aggregation: multi-tier edge topologies with
composable Gram summaries.

Submodules:
  * topology    — device→gateway→regional→cloud trees over ``edge.Fleet``
                  profiles with per-link bandwidth/latency (star, two-tier
                  bimodal, geo-partitioned non-IID canonical forms)
  * gateway     — tier-local contextual solve emitting composable
                  (G_g, c_g, α_g, ū_g, ĝ_g) summaries; summaries of
                  summaries compose recursively up the tree
  * hier_server — cloud-side P×P contextual solve over summaries, plus
                  ``hier_fedavg`` and summary-free ``hier_relay`` baselines
                  registered in ``core.aggregation``
  * comm        — per-tier byte/latency ledger (the ≥5× cloud-uplink saving
                  the subsystem exists to deliver, and the true serialized
                  sizes of ``repro.compress`` summary payloads)
  * fused       — dense (P, n) round matrices + shape-keyed jit stages: the
                  fastest path at small model width
  * streamed    — big-model twin: chunked column passes accumulate the
                  (P, P) round statistics, tier solves run in P-space, and
                  one streamed combine applies the step — peak round-matrix
                  memory O(P·chunk) instead of O(P·n)

The entry point is :func:`repro.fl.run_hier_simulation`, which drives these
through the PR-1 event scheduler with multi-hop link events against the same
datasets/metrics as the flat sync and async paths.
"""
from .comm import (CommLedger, TierTraffic, compressed_summary_bytes,
                   model_size, summary_bytes, update_bytes)
from .gateway import (CompressedSummary, GatewaySummary, merge_summaries,
                      summarize_updates, tier_contextual, tier_mean)
from .hier_server import (HierConfig, aggregate_hier_contextual,
                          aggregate_hier_contextual_sketch,
                          aggregate_hier_fedavg, blockdiag_diagnostics,
                          cloud_aggregate)
from .streamed import RowMix, StreamedRoundEngine, dense_round_bytes
from .topology import (Link, StackedTopology, TopoNode, Topology,
                       geo_partitioned_topology, get_topology, stacked_two_tier,
                       star_topology, two_tier_topology)

__all__ = [
    "RowMix", "StreamedRoundEngine", "dense_round_bytes",
    "CommLedger", "TierTraffic", "compressed_summary_bytes", "model_size",
    "summary_bytes", "update_bytes",
    "CompressedSummary", "GatewaySummary", "merge_summaries",
    "summarize_updates", "tier_contextual", "tier_mean",
    "HierConfig", "aggregate_hier_contextual",
    "aggregate_hier_contextual_sketch", "aggregate_hier_fedavg",
    "blockdiag_diagnostics", "cloud_aggregate",
    "Link", "StackedTopology", "TopoNode", "Topology",
    "geo_partitioned_topology", "get_topology", "stacked_two_tier",
    "star_topology", "two_tier_topology",
]
