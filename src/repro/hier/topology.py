"""Multi-tier edge aggregation trees over :class:`repro.edge.Fleet` profiles.

A :class:`Topology` is a rooted tree: tier 0 holds the fleet's devices (one
leaf per :class:`~repro.edge.profiles.DeviceProfile`), interior tiers hold
aggregation points (gateways, regional servers), and the root is the cloud.
Every non-root node owns the :class:`Link` to its parent — per-link bandwidth
and latency are what make multi-hop timing and byte accounting (``comm.py``)
meaningful.  Leaf→gateway traffic keeps using the *device profile's* own
up/down bandwidth (that link already exists in ``repro.edge``); ``Link``
models the backhaul tiers above it.

Canonical topologies (cf. Gao et al., FL-as-a-Service for hierarchical edge
networks; Wang et al., resource-constrained edge control):

  * :func:`star_topology`          — every device reports straight to the
    cloud: depth 1, the flat baseline every hierarchy is compared against.
  * :func:`two_tier_topology`      — device → gateway → cloud with a fixed
    gateway count; the canonical "bimodal" instance pairs it with
    :func:`~repro.edge.profiles.bimodal_fleet` (phones behind gateways).
  * :func:`geo_partitioned_topology` — device → gateway → regional → cloud;
    devices are assigned *contiguously*, so with a Dirichlet-partitioned
    dataset each region sees a correlated (non-IID) label slice — the
    geo-skew regime hierarchical aggregation has to survive.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..edge.profiles import Fleet, bimodal_fleet, uniform_fleet

# Backhaul reference magnitudes: a metro gateway uplink sustains ~100 Mbit/s,
# a regional→cloud trunk ~1 Gbit/s; WAN hops add milliseconds of latency.
GATEWAY_BW = 1.25e7
TRUNK_BW = 1.25e8


@dataclass(frozen=True)
class Link:
    """A backhaul link (child → parent): bytes/s each way plus fixed latency."""
    up_bw: float                 # bytes/s toward the parent
    down_bw: float               # bytes/s toward the child
    latency: float = 0.0         # seconds, charged per transfer

    def __post_init__(self):
        if self.up_bw <= 0 or self.down_bw <= 0:
            raise ValueError(f"link bandwidth must be positive, got "
                             f"up={self.up_bw} down={self.down_bw}")

    def uplink_time(self, nbytes: float) -> float:
        return self.latency + nbytes / self.up_bw

    def downlink_time(self, nbytes: float) -> float:
        return self.latency + nbytes / self.down_bw


@dataclass(frozen=True)
class TopoNode:
    """One tree node.  Devices occupy node ids ``[0, fleet.num_devices)`` and
    tier 0; interior/root nodes get ids above the fleet."""
    node_id: int
    tier: int
    parent: Optional[int]                # None only for the cloud root
    children: Tuple[int, ...]            # empty only for device leaves
    uplink: Optional[Link] = None        # link to parent (None for root and
                                         # for devices, whose profile is the link)


@dataclass(frozen=True)
class Topology:
    name: str
    fleet: Fleet
    nodes: Dict[int, TopoNode]
    cloud_id: int

    def __post_init__(self):
        n = self.fleet.num_devices
        cloud = self.nodes[self.cloud_id]
        if cloud.parent is not None:
            raise ValueError("cloud node must be the root (parent=None)")
        for i in range(n):
            node = self.nodes.get(i)
            if node is None or node.tier != 0 or node.children:
                raise ValueError(f"device {i} must be a tier-0 leaf")
            # every device must reach the cloud through consistent tiers
            seen, cur = 0, node
            while cur.parent is not None:
                parent = self.nodes.get(cur.parent)
                if parent is None:
                    raise ValueError(f"node {cur.node_id} has dangling parent "
                                     f"{cur.parent}")
                if parent.tier != cur.tier + 1:
                    raise ValueError(
                        f"tier skip on edge {cur.node_id}->{parent.node_id}: "
                        f"{cur.tier}->{parent.tier}")
                if cur.node_id not in parent.children:
                    raise ValueError(f"{parent.node_id} does not list child "
                                     f"{cur.node_id}")
                cur, seen = parent, seen + 1
                if seen > len(self.nodes):
                    raise ValueError("cycle in topology")
            if cur.node_id != self.cloud_id:
                raise ValueError(f"device {i} does not reach the cloud")
        for node in self.nodes.values():
            if node.node_id != self.cloud_id and node.tier > 0 \
                    and node.uplink is None:
                raise ValueError(f"interior node {node.node_id} needs an uplink")

    # -- structure helpers --------------------------------------------------
    @property
    def depth(self) -> int:
        """Number of aggregation hops from a device to the cloud."""
        return self.nodes[self.cloud_id].tier

    @property
    def num_devices(self) -> int:
        return self.fleet.num_devices

    def tier_nodes(self, tier: int) -> List[TopoNode]:
        return sorted((n for n in self.nodes.values() if n.tier == tier),
                      key=lambda n: n.node_id)

    @property
    def gateways(self) -> List[TopoNode]:
        """The tier-1 aggregation points (parents of the device leaves).
        For a star topology this is just ``[cloud]``."""
        return self.tier_nodes(1)

    def devices_under(self, node_id: int) -> List[int]:
        """All device ids in the subtree of ``node_id`` (sorted)."""
        node = self.nodes[node_id]
        if node.tier == 0:
            return [node.node_id]
        out: List[int] = []
        for ch in node.children:
            out.extend(self.devices_under(ch))
        return sorted(out)

    def describe(self) -> str:
        tiers = [len(self.tier_nodes(t)) for t in range(self.depth + 1)]
        return (f"{self.name}: depth={self.depth} "
                f"tier_sizes={'x'.join(str(t) for t in tiers)} "
                f"({self.fleet.describe()})")


@dataclass(frozen=True, eq=False)
class StackedTopology:
    """Array-native topology for fleet-scale runs: only interior nodes and
    the cloud exist as :class:`TopoNode` objects; device membership lives in
    a numpy id array on each gateway's ``children`` field.  A million-device
    tree is O(gateways) objects and O(1) validation per gateway instead of
    one frozen dataclass + an O(depth) path walk per device (the
    :class:`Topology` ``__post_init__``), which at 10⁶ devices costs seconds
    and ~0.5 GB before the first round starts.

    Duck-compatible with :class:`Topology` everywhere the hierarchical
    runtime looks: ``fleet``/``nodes``/``cloud_id``/``depth``/
    ``num_devices``/``tier_nodes``/``gateways``/``describe``; gateway
    ``children`` supports ``len`` and numpy indexing.  Nodes holding array
    children are not hashable — never used as dict keys."""
    name: str
    fleet: Fleet
    nodes: Dict[int, TopoNode]           # interior + cloud ONLY
    cloud_id: int

    def __post_init__(self):
        n = self.fleet.num_devices
        cloud = self.nodes[self.cloud_id]
        if cloud.parent is not None:
            raise ValueError("cloud node must be the root (parent=None)")
        covered = 0
        for node in self.nodes.values():
            if node.tier == 0:
                raise ValueError("stacked topology holds no device nodes")
            parent = self.nodes.get(node.parent) if node.parent is not None \
                else None
            if node.node_id != self.cloud_id:
                if parent is None:
                    raise ValueError(f"node {node.node_id} has dangling "
                                     f"parent {node.parent}")
                if parent.tier != node.tier + 1:
                    raise ValueError(f"tier skip on edge {node.node_id}->"
                                     f"{parent.node_id}")
                if node.uplink is None:
                    raise ValueError(f"interior node {node.node_id} needs "
                                     "an uplink")
            if node.tier == 1:
                devs = np.asarray(node.children)
                if devs.size and (devs.min() < 0 or devs.max() >= n):
                    raise ValueError(f"gateway {node.node_id} references "
                                     "devices outside the fleet")
                covered += devs.size
        if covered != n:
            raise ValueError(f"gateways cover {covered} of {n} devices")

    @property
    def depth(self) -> int:
        return self.nodes[self.cloud_id].tier

    @property
    def num_devices(self) -> int:
        return self.fleet.num_devices

    def tier_nodes(self, tier: int) -> List[TopoNode]:
        return sorted((n for n in self.nodes.values() if n.tier == tier),
                      key=lambda n: n.node_id)

    @property
    def gateways(self) -> List[TopoNode]:
        return self.tier_nodes(1)

    def devices_under(self, node_id: int) -> List[int]:
        node = self.nodes[node_id]
        if node.tier == 1:
            return sorted(int(d) for d in np.asarray(node.children))
        out: List[int] = []
        for ch in node.children:
            out.extend(self.devices_under(int(ch)))
        return sorted(out)

    def describe(self) -> str:
        tiers = [len(self.tier_nodes(t)) for t in range(1, self.depth + 1)]
        return (f"{self.name}: depth={self.depth} "
                f"tier_sizes={self.num_devices}x"
                f"{'x'.join(str(t) for t in tiers)} "
                f"({self.fleet.describe()})")


def stacked_two_tier(fleet: Fleet, num_gateways: int,
                     gw_up_bw: float = GATEWAY_BW,
                     gw_down_bw: float = GATEWAY_BW,
                     gw_latency: float = 0.01,
                     assignment: str = "contiguous",
                     seed: int = 0) -> StackedTopology:
    """:func:`two_tier_topology` in stacked form — same device→gateway
    partition, links, node ids and tiers, minus the per-device leaf nodes."""
    n = fleet.num_devices
    if not (1 <= num_gateways <= n):
        raise ValueError(f"num_gateways must be in [1, {n}], got {num_gateways}")
    groups = _partition(n, num_gateways, assignment, seed)
    link = Link(gw_up_bw, gw_down_bw, gw_latency)
    cloud_id = n + num_gateways
    nodes: Dict[int, TopoNode] = {}
    for g, devs in enumerate(groups):
        gid = n + g
        nodes[gid] = TopoNode(gid, 1, cloud_id,
                              np.ascontiguousarray(devs, np.int32),
                              uplink=link)
    nodes[cloud_id] = TopoNode(cloud_id, 2, None,
                               tuple(range(n, n + num_gateways)))
    return StackedTopology(f"two_tier(g{num_gateways})", fleet, nodes,
                           cloud_id)


def _partition(num_devices: int, num_groups: int,
               assignment: str, seed: int) -> List[np.ndarray]:
    """Split device ids into ``num_groups`` groups."""
    ids = np.arange(num_devices)
    if assignment == "contiguous":
        return [g for g in np.array_split(ids, num_groups)]
    if assignment == "roundrobin":
        return [ids[g::num_groups] for g in range(num_groups)]
    if assignment == "random":
        rng = np.random.RandomState(seed)
        return [np.sort(g) for g in
                np.array_split(rng.permutation(ids), num_groups)]
    raise KeyError(f"unknown assignment '{assignment}' "
                   "(contiguous|roundrobin|random)")


def star_topology(fleet: Fleet) -> Topology:
    """Every device uploads straight to the cloud — the flat baseline."""
    n = fleet.num_devices
    cloud = TopoNode(n, tier=1, parent=None, children=tuple(range(n)))
    nodes = {i: TopoNode(i, 0, n, ()) for i in range(n)}
    nodes[n] = cloud
    return Topology("star", fleet, nodes, cloud_id=n)


def two_tier_topology(fleet: Fleet, num_gateways: int,
                      gw_up_bw: float = GATEWAY_BW,
                      gw_down_bw: float = GATEWAY_BW,
                      gw_latency: float = 0.01,
                      assignment: str = "contiguous",
                      seed: int = 0) -> Topology:
    """device → gateway → cloud with ``num_gateways`` gateways."""
    n = fleet.num_devices
    if not (1 <= num_gateways <= n):
        raise ValueError(f"num_gateways must be in [1, {n}], got {num_gateways}")
    groups = _partition(n, num_gateways, assignment, seed)
    link = Link(gw_up_bw, gw_down_bw, gw_latency)
    cloud_id = n + num_gateways
    nodes: Dict[int, TopoNode] = {}
    gw_ids = []
    for g, devs in enumerate(groups):
        gid = n + g
        gw_ids.append(gid)
        nodes[gid] = TopoNode(gid, 1, cloud_id, tuple(int(d) for d in devs),
                              uplink=link)
        for d in devs:
            nodes[int(d)] = TopoNode(int(d), 0, gid, ())
    nodes[cloud_id] = TopoNode(cloud_id, 2, None, tuple(gw_ids))
    return Topology(f"two_tier(g{num_gateways})", fleet, nodes, cloud_id)


def geo_partitioned_topology(fleet: Fleet, num_regions: int,
                             gateways_per_region: int,
                             gw_up_bw: float = GATEWAY_BW,
                             trunk_bw: float = TRUNK_BW,
                             gw_latency: float = 0.01,
                             trunk_latency: float = 0.05) -> Topology:
    """device → gateway → regional → cloud, devices assigned contiguously so
    regions correlate with a Dirichlet-partitioned dataset's label skew."""
    n = fleet.num_devices
    num_gateways = num_regions * gateways_per_region
    if num_gateways > n:
        raise ValueError(f"{num_gateways} gateways exceed {n} devices")
    groups = _partition(n, num_gateways, "contiguous", 0)
    gw_link = Link(gw_up_bw, gw_up_bw, gw_latency)
    trunk = Link(trunk_bw, trunk_bw, trunk_latency)
    cloud_id = n + num_gateways + num_regions
    nodes: Dict[int, TopoNode] = {}
    region_ids = []
    for r in range(num_regions):
        rid = n + num_gateways + r
        region_ids.append(rid)
        gw_ids = []
        for j in range(gateways_per_region):
            g = r * gateways_per_region + j
            gid = n + g
            gw_ids.append(gid)
            devs = groups[g]
            nodes[gid] = TopoNode(gid, 1, rid, tuple(int(d) for d in devs),
                                  uplink=gw_link)
            for d in devs:
                nodes[int(d)] = TopoNode(int(d), 0, gid, ())
        nodes[rid] = TopoNode(rid, 2, cloud_id, tuple(gw_ids), uplink=trunk)
    nodes[cloud_id] = TopoNode(cloud_id, 3, None, tuple(region_ids))
    return Topology(f"geo(r{num_regions}xg{gateways_per_region})", fleet,
                    nodes, cloud_id)


def get_topology(name: str, num_devices: int, seed: int = 0, **kw) -> Topology:
    """Canonical (fleet, tree) pairs by name.

      * ``star``            — uniform fleet, flat.
      * ``two_tier_bimodal``— bimodal phone+gateway fleet behind
        ``num_gateways`` (default 4) gateways, contiguous assignment.
      * ``geo``             — uniform fleet, 2 regions × 2 gateways (3 tiers),
        contiguous (non-IID-correlated) assignment.
    """
    if name == "star":
        return star_topology(uniform_fleet(num_devices))
    if name == "two_tier_bimodal":
        gws = kw.pop("num_gateways", 4)
        fleet = bimodal_fleet(num_devices, seed=seed,
                              **{k: kw.pop(k) for k in
                                 ("slowdown", "slow_frac", "dropout_slow")
                                 if k in kw})
        return two_tier_topology(fleet, gws, seed=seed, **kw)
    if name == "geo":
        regions = kw.pop("num_regions", 2)
        gpr = kw.pop("gateways_per_region", 2)
        return geo_partitioned_topology(uniform_fleet(num_devices), regions,
                                        gpr, **kw)
    raise KeyError(f"unknown topology '{name}' (star|two_tier_bimodal|geo)")
