"""Fused jit-compiled stages for the hierarchical round hot path.

PR-3's ``run_hier_simulation`` did its per-round tier walk in pure Python
over pytrees: per-member ``tree_map`` slicing, eager Gram/solve/combine per
gateway, float-by-float tree means — thousands of op dispatches per round,
plus recompiles whenever a dropout changed a cohort shape.  This module
replaces all of it with a small set of **shape-keyed compiled stages** over
flat update matrices:

  * the round's stacked client updates/gradients are flattened ONCE into
    (P, n) f32 matrices (:func:`flatten_stacked`, jit);
  * every tier node (gateway summary, regional/cloud merge, cloud apply)
    runs ONE jitted stage call — cohort slicing is a single gather, the
    Gram/solve/combine and all bound diagnostics live inside the stage;
  * stages are cached process-wide by their static key (kind, K, n, solve
    config, tier mode, pool scale, scope), so a fleet whose cohort sizes
    repeat across rounds compiles each distinct shape exactly once — the
    ledger/event bookkeeping stays in Python, but every array op crosses
    the jit boundary once per round shape.

The Gram reduction inside each stage is the implementation the kernel
registry selected for that shape (:func:`repro.kernels.select_impl`), so the
backend-aware dispatch of ``kernels/`` carries into the fused path: Pallas
on TPU, compiled pure-XLA elsewhere.

Numerically each stage mirrors ``gateway.summarize_updates`` /
``hier_server.cloud_aggregate`` (same solve, same combine, same info keys);
``tests/test_hier.py`` pins stage outputs against the reference functions.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.flatten import select_scope, tree_add, vector_to_tree
from ..core.solve import (SolveConfig, bound_value, solve_alpha,
                          theorem1_reduction)
from ..core.gram import gram_residual
from ..kernels.registry import select_impl_for

Pytree = Any


def _gram_impl(K: int, ns: int):
    """The registry's pick for a (K, ns) Gram reduction — resolved on every
    stage lookup (cheap: shape specs, no arrays) so an active
    ``force_backend``/env override reaches the fused path too; the stage
    cache below keys on the chosen backend, so a different choice compiles
    its own stage instead of silently reusing the old one."""
    return select_impl_for(
        "gram", jax.ShapeDtypeStruct((K, ns), jnp.float32),
        jax.ShapeDtypeStruct((ns,), jnp.float32))


@jax.jit
def flatten_stacked(stacked: Pytree) -> jax.Array:
    """Stacked update pytree (leading K axis per leaf) → (K, n) f32 matrix.
    Delegates to the aggregation registry's flattener so the 'leaf order
    matches ``core.flatten.tree_to_vector``' invariant lives in one place
    (``scope_indices`` and ``apply_delta`` both depend on it)."""
    from ..core.aggregation import _stacked_to_matrix
    return _stacked_to_matrix(stacked, None)


@jax.jit
def apply_delta(params: Pytree, delta_vec: jax.Array) -> Pytree:
    """``w ← w + Δ`` with a flat Δ — the single tree conversion per round."""
    return tree_add(params, vector_to_tree(delta_vec, params))


def scope_indices(template: Pytree, scope: Optional[str]
                  ) -> Optional[np.ndarray]:
    """Flat-vector column indices selected by ``gram_scope`` (None → full)."""
    if scope is None or scope == "full":
        return None
    leaves = jax.tree_util.tree_leaves(template)
    kept = [l.size > 0
            for l in jax.tree_util.tree_leaves(select_scope(template, scope))]
    idx, offset = [], 0
    for leaf, keep in zip(leaves, kept):
        if keep:
            idx.append(np.arange(offset, offset + leaf.size, dtype=np.int64))
        offset += leaf.size
    return np.concatenate(idx) if idx else np.zeros((0,), np.int64)


# process-wide stage cache: same static key → same compiled callable.  The
# key includes the gram backend the registry selected, so backend forcing
# or a different autotune outcome gets its own compiled stage.
_STAGES: Dict[Tuple, Callable] = {}


def clear_stage_cache() -> None:
    _STAGES.clear()


def _scoped(U: jax.Array, g: jax.Array, idx) -> Tuple[jax.Array, jax.Array]:
    return (U, g) if idx is None else (U[:, idx], g[idx])


@jax.jit
def gather_mean(M: jax.Array, sel: jax.Array) -> jax.Array:
    """Mean of the selected rows, gathered inside jit (one dispatch)."""
    return jnp.mean(M[sel], axis=0)


def summary_stage(K: int, n: int, solve_cfg: SolveConfig, mode: str, *,
                  pool_scale: float = 1.0, sum_to: Optional[float] = None,
                  gather: bool = False, scope_key=None,
                  scope_idx=None) -> Callable:
    """Compiled tier stage — the fused equivalent of
    ``gateway.summarize_updates`` (``sum_to=1`` makes it the parent-tier
    merge).  Returns a dict with keys G, c, alpha, u_bar, ghat, info.

    ``gather=False``: ``fn(U (K,n), GR (K,n), counts (K,), g?)`` over
    pre-stacked members.  ``gather=True``: ``fn(D (P,n), GM (P,n),
    sel (K,), counts, g?)`` — the cohort rows are gathered *inside* the jit
    boundary (an eager advanced-index on the round matrices costs a full
    dispatch per tier node; fused it is free)."""
    ns = n if scope_idx is None else len(scope_idx)
    gram_impl = _gram_impl(K, ns)
    key = ("summary", K, n, solve_cfg, mode, pool_scale, sum_to, gather,
           scope_key, gram_impl.backend)
    fn = _STAGES.get(key)
    if fn is not None:
        return fn

    cfg = solve_cfg
    if pool_scale != 1.0:
        cfg = replace(cfg, expectation_scale=cfg.expectation_scale
                      * pool_scale)
    if sum_to is not None:
        cfg = replace(cfg, sum_to=sum_to)
    gram_fn = gram_impl.fn
    idx = None if scope_idx is None else jnp.asarray(scope_idx)
    beta = cfg.beta

    def body(U, GR, counts, g):
        w = counts / jnp.maximum(jnp.sum(counts), 1e-12)
        ghat = w @ GR
        g_solve = ghat if g is None else g
        Us, gs = _scoped(U, g_solve, idx)
        G, c = gram_fn(Us, gs)
        if mode == "contextual":
            alpha = solve_alpha(G, c, cfg)
            info = {
                "bound": bound_value(G, c, alpha, beta),
                "theorem1_reduction": theorem1_reduction(G, alpha, beta),
                "stationarity_residual": jnp.linalg.norm(
                    gram_residual(G, c, alpha, beta)),
            }
        else:                                   # "mean" (hier-FedAvg tier)
            alpha = w
            info = {"bound": bound_value(G, c, alpha, beta)}
        u_bar = alpha @ U
        return {"G": G, "c": c, "alpha": alpha, "u_bar": u_bar,
                "ghat": ghat, "info": info}

    if gather:
        @jax.jit
        def stage(D, GM, sel, counts, g=None):
            return body(D[sel], GM[sel], counts, g)
    else:
        @jax.jit
        def stage(U, GR, counts, g=None):
            return body(U, GR, counts, g)

    _STAGES[key] = stage
    return stage


def cloud_stage(P: int, n: int, solve_cfg: SolveConfig, kind: str, *,
                solve_scale: float = 1.0, gather: bool = False,
                scope_key=None, scope_idx=None) -> Callable:
    """Compiled final tier: ``fn(U (P,n), ghat (n,), counts, override?) →
    (delta (n,), info)`` — the fused equivalent of
    ``hier_server.cloud_aggregate``.

    ``kind``: "combo" (mass-conserving Σγ=1 solve over child combinations),
    "raw" (unconstrained paper solve over raw updates — star/relay; with the
    §III-C ``solve_scale`` for fan-in-sampled star clouds), or "fedavg"
    (count-weighted mean).  ``override`` supplies sketched (G₂, c₂) for the
    compressed pipeline.  With ``gather=True`` the signature becomes
    ``fn(D (Pr,n), GM (Pr,n), sel (P,), counts)``: cohort rows are gathered
    and the ∇f estimate averaged inside the jit boundary."""
    ns = n if scope_idx is None else len(scope_idx)
    gram_impl = _gram_impl(P, ns)
    key = ("cloud", P, n, solve_cfg, kind, solve_scale, gather, scope_key,
           gram_impl.backend)
    fn = _STAGES.get(key)
    if fn is not None:
        return fn

    cfg = solve_cfg
    if kind == "combo":
        cfg = replace(cfg, sum_to=1.0)
    elif solve_scale != 1.0:
        cfg = replace(cfg, expectation_scale=cfg.expectation_scale
                      * solve_scale)
    gram_fn = gram_impl.fn
    idx = None if scope_idx is None else jnp.asarray(scope_idx)
    beta = cfg.beta

    def body(U, ghat, counts, override):
        if kind == "fedavg":
            alpha = counts / jnp.maximum(jnp.sum(counts), 1e-12)
            info = {"alpha": alpha, "gamma": alpha}
            return alpha @ U, info
        if override is not None:
            G, c = override
        else:
            Us, gs = _scoped(U, ghat, idx)
            G, c = gram_fn(Us, gs)
        alpha = solve_alpha(G, c, cfg)
        info = {
            "alpha": alpha,
            "gamma": alpha,
            "bound": bound_value(G, c, alpha, beta),
            "theorem1_reduction": theorem1_reduction(G, alpha, beta),
            "stationarity_residual": jnp.linalg.norm(
                gram_residual(G, c, alpha, beta)),
            "gram_diag": jnp.diag(G),
        }
        return alpha @ U, info

    if gather:
        @jax.jit
        def stage(D, GM, sel, counts, override=None):
            return body(D[sel], jnp.mean(GM[sel], axis=0), counts, override)
    else:
        @jax.jit
        def stage(U, ghat, counts, override=None):
            return body(U, ghat, counts, override)

    _STAGES[key] = stage
    return stage


class HierRoundEngine:
    """Per-run façade over the stage cache: resolves the static keys
    (model width, solve config, tier mode, gram scope) once, then hands the
    runtime one-call compiled stages."""

    def __init__(self, params_template: Pytree, solve_cfg: SolveConfig,
                 tier_mode: str, gram_scope: Optional[str] = None):
        self.n = int(sum(l.size for l in
                         jax.tree_util.tree_leaves(params_template)))
        self.solve_cfg = solve_cfg
        self.tier_mode = tier_mode
        self.gram_scope = gram_scope
        self._scope_idx = scope_indices(params_template, gram_scope)
        self._scope_key = (None if self._scope_idx is None else
                           (gram_scope, len(self._scope_idx),
                            hash(self._scope_idx.tobytes())))

    # -- stage accessors ----------------------------------------------------

    def tier(self, K: int, *, pool_scale: float = 1.0,
             sum_to: Optional[float] = None,
             gather: bool = False) -> Callable:
        return summary_stage(K, self.n, self.solve_cfg, self.tier_mode,
                             pool_scale=pool_scale, sum_to=sum_to,
                             gather=gather, scope_key=self._scope_key,
                             scope_idx=self._scope_idx)

    def cloud(self, P: int, kind: str, *, solve_scale: float = 1.0,
              gather: bool = False) -> Callable:
        return cloud_stage(P, self.n, self.solve_cfg, kind,
                           solve_scale=solve_scale, gather=gather,
                           scope_key=self._scope_key,
                           scope_idx=self._scope_idx)
