"""Fused jit-compiled stages for the hierarchical round hot path.

PR-3's ``run_hier_simulation`` did its per-round tier walk in pure Python
over pytrees: per-member ``tree_map`` slicing, eager Gram/solve/combine per
gateway, float-by-float tree means — thousands of op dispatches per round,
plus recompiles whenever a dropout changed a cohort shape.  This module
replaces all of it with a small set of **shape-keyed compiled stages** over
flat update matrices:

  * the round's stacked client updates/gradients are flattened ONCE into
    (P, n) f32 matrices (:func:`flatten_stacked`, jit);
  * every tier node (gateway summary, regional/cloud merge, cloud apply)
    runs ONE jitted stage call — cohort slicing is a single gather, the
    Gram/solve/combine and all bound diagnostics live inside the stage;
  * stages are cached process-wide by their static key (kind, K, n, solve
    config, tier mode, pool scale, scope), so a fleet whose cohort sizes
    repeat across rounds compiles each distinct shape exactly once — the
    ledger/event bookkeeping stays in Python, but every array op crosses
    the jit boundary once per round shape.

The Gram reduction inside each stage is the implementation the kernel
registry selected for that shape (:func:`repro.kernels.select_impl`), so the
backend-aware dispatch of ``kernels/`` carries into the fused path: Pallas
on TPU, compiled pure-XLA elsewhere.

Numerically each stage mirrors ``gateway.summarize_updates`` /
``hier_server.cloud_aggregate`` (same solve, same combine, same info keys);
``tests/test_hier.py`` pins stage outputs against the reference functions.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.flatten import select_scope, tree_add, vector_to_tree
from ..core.solve import (SolveConfig, bound_value, solve_alpha,
                          theorem1_reduction)
from ..core.gram import gram_residual
from ..kernels.registry import select_impl_for
from ..obs import current_tracker, spans

Pytree = Any


def _gram_impl(K: int, ns: int):
    """The registry's pick for a (K, ns) Gram reduction — resolved on every
    stage lookup (cheap: shape specs, no arrays) so an active
    ``force_backend``/env override reaches the fused path too; the stage
    cache below keys on the chosen backend, so a different choice compiles
    its own stage instead of silently reusing the old one."""
    return select_impl_for(
        "gram", jax.ShapeDtypeStruct((K, ns), jnp.float32),
        jax.ShapeDtypeStruct((ns,), jnp.float32))


@jax.jit
def flatten_stacked(stacked: Pytree) -> jax.Array:
    """Stacked update pytree (leading K axis per leaf) → (K, n) f32 matrix.
    Delegates to the aggregation registry's flattener so the 'leaf order
    matches ``core.flatten.tree_to_vector``' invariant lives in one place
    (``scope_indices`` and ``apply_delta`` both depend on it)."""
    from ..core.aggregation import _stacked_to_matrix
    return _stacked_to_matrix(stacked, None)


@jax.jit
def apply_delta(params: Pytree, delta_vec: jax.Array) -> Pytree:
    """``w ← w + Δ`` with a flat Δ — the single tree conversion per round."""
    return tree_add(params, vector_to_tree(delta_vec, params))


def scope_indices(template: Pytree, scope: Optional[str]
                  ) -> Optional[np.ndarray]:
    """Flat-vector column indices selected by ``gram_scope`` (None → full).

    int32 on purpose: x64 is disabled in production runs, so int64 indices
    would pay a silent downcast on every scoped gather — and 2³¹ columns
    bounds the *scoped* axis only (the streamed engine handles full width
    without ever building an index array)."""
    if scope is None or scope == "full":
        return None
    leaves = jax.tree_util.tree_leaves(template)
    kept = [l.size > 0
            for l in jax.tree_util.tree_leaves(select_scope(template, scope))]
    idx, offset = [], 0
    for leaf, keep in zip(leaves, kept):
        if keep:
            idx.append(np.arange(offset, offset + leaf.size, dtype=np.int32))
        offset += leaf.size
    return np.concatenate(idx) if idx else np.zeros((0,), np.int32)


def solve_diagnostics(G: jax.Array, c: jax.Array, alpha: jax.Array,
                      beta) -> Dict[str, jax.Array]:
    """The contextual-solve info keys every tier stage reports — ONE
    definition shared by the fused bodies below and the streamed stages
    (``repro.hier.streamed``), so fused/streamed info parity cannot drift."""
    return {
        "bound": bound_value(G, c, alpha, beta),
        "theorem1_reduction": theorem1_reduction(G, alpha, beta),
        "stationarity_residual": jnp.linalg.norm(
            gram_residual(G, c, alpha, beta)),
    }


# process-wide stage cache: same static key → same compiled callable.  The
# key includes the gram backend the registry selected, so backend forcing
# or a different autotune outcome gets its own compiled stage.
_STAGES: Dict[Tuple, Callable] = {}


def clear_stage_cache() -> None:
    _STAGES.clear()


def _log_stage_build(kind: str, K: int, n: int, backend: str) -> None:
    """Stream a stage-cache miss: each event is one new shape-keyed jit
    stage about to compile — the per-shape story behind the hier runtime's
    ``compile_wall_time_s`` vs steady-state split."""
    tr = current_tracker()
    if tr.active:
        tr.scope("hier/fused").log({"stage_build": kind, "K": K, "n": n,
                                    "gram_backend": backend})


def _traced_stage(kind: str, K: int, n: int, backend: str,
                  stage: Callable) -> Callable:
    """Wrap a freshly built stage so every invocation is a span: the FIRST
    call (which pays the jit trace+compile synchronously) emits
    ``stage_<kind>_compile``, steady-state calls emit ``stage_<kind>`` —
    separate span paths, so ``trace_diff`` attributes compile cost apart
    from dispatch cost.  Cached per compiled stage (the wrapper IS the
    cache entry), and with the noop tracker the cost is one ``active``
    check per call."""
    first = [True]

    def traced(*args, **kw):
        tr = current_tracker()
        if not tr.active:
            first[0] = False           # compile happened untracked
            return stage(*args, **kw)
        name = f"stage_{kind}_compile" if first[0] else f"stage_{kind}"
        first[0] = False
        with spans.span(name, K=K, n=n, backend=backend):
            return stage(*args, **kw)

    return traced


def _scoped(U: jax.Array, g: jax.Array, idx) -> Tuple[jax.Array, jax.Array]:
    return (U, g) if idx is None else (U[:, idx], g[idx])


@jax.jit
def gather_mean(M: jax.Array, sel: jax.Array) -> jax.Array:
    """Mean of the selected rows, gathered inside jit (one dispatch)."""
    return jnp.mean(M[sel], axis=0)


def summary_stage(K: int, n: int, solve_cfg: SolveConfig, mode: str, *,
                  pool_scale: float = 1.0, sum_to: Optional[float] = None,
                  gather: bool = False, stack: bool = False, scope_key=None,
                  scope_idx=None, robust=None) -> Callable:
    """Compiled tier stage — the fused equivalent of
    ``gateway.summarize_updates`` (``sum_to=1`` makes it the parent-tier
    merge).  Returns a dict with keys G, c, alpha, u_bar, ghat, info.

    ``gather=False``: ``fn(U (K,n), GR (K,n), counts (K,), g?)`` over
    pre-stacked members.  ``gather=True``: ``fn(D (P,n), GM (P,n),
    sel (K,), counts, g?)`` — the cohort rows are gathered *inside* the jit
    boundary (an eager advanced-index on the round matrices costs a full
    dispatch per tier node; fused it is free).  ``stack=True``: ``fn(us
    (K-tuple of (n,)), grs (K-tuple of (n,)), counts, g?)`` — the member
    vectors are stacked *inside* the jit boundary, so a tier merge over
    child summaries costs one dispatch instead of an eager ``jnp.stack``
    per matrix per node."""
    if gather and stack:
        raise ValueError("summary_stage: gather and stack are exclusive")
    # robust statistics only harden a contextual solve (and are a
    # RobustConfig — frozen, hence a valid piece of the stage key)
    if robust is not None and (mode != "contextual"
                               or not getattr(robust, "enabled", False)):
        robust = None
    ns = n if scope_idx is None else len(scope_idx)
    gram_impl = _gram_impl(K, ns)
    key = ("summary", K, n, solve_cfg, mode, pool_scale, sum_to, gather,
           stack, scope_key, robust, gram_impl.backend)
    fn = _STAGES.get(key)
    if fn is not None:
        return fn
    _log_stage_build("summary", K, n, gram_impl.backend)

    cfg = solve_cfg
    if pool_scale != 1.0:
        cfg = replace(cfg, expectation_scale=cfg.expectation_scale
                      * pool_scale)
    if sum_to is not None:
        cfg = replace(cfg, sum_to=sum_to)
    gram_fn = gram_impl.fn
    idx = None if scope_idx is None else jnp.asarray(scope_idx)
    beta = cfg.beta

    if robust is not None:
        from ..robust.gramstats import robustify

    def body(U, GR, counts, g):
        w = counts / jnp.maximum(jnp.sum(counts), 1e-12)
        ghat = w @ GR
        if robust is not None:
            # robust tier solve: the full (K, K) cross matrix Δ·gᵀ replaces
            # the premixed c so the pooling can down-vote poisoned gradient
            # columns; the shipped ĝ stays the plain weighted mean (parity
            # with the streamed engine, which holds no per-member norms)
            Us = U if idx is None else U[:, idx]
            GRs = GR if idx is None else GR[:, idx]
            Gr, cr, s = robustify(Us @ Us.T, Us @ GRs.T, w, robust)
            alpha = solve_alpha(Gr, cr, cfg)
            eff = s * alpha
            info = solve_diagnostics(Gr, cr, alpha, beta)
            info["clip_scale"] = s
            return {"G": Gr, "c": cr, "alpha": eff, "u_bar": eff @ U,
                    "ghat": ghat, "info": info}
        g_solve = ghat if g is None else g
        Us, gs = _scoped(U, g_solve, idx)
        G, c = gram_fn(Us, gs)
        if mode == "contextual":
            alpha = solve_alpha(G, c, cfg)
            info = solve_diagnostics(G, c, alpha, beta)
        else:                                   # "mean" (hier-FedAvg tier)
            alpha = w
            info = {"bound": bound_value(G, c, alpha, beta)}
        u_bar = alpha @ U
        return {"G": G, "c": c, "alpha": alpha, "u_bar": u_bar,
                "ghat": ghat, "info": info}

    if gather:
        @jax.jit
        def stage(D, GM, sel, counts, g=None):
            return body(D[sel], GM[sel], counts, g)
    elif stack:
        @jax.jit
        def stage(us, grs, counts, g=None):
            return body(jnp.stack(us), jnp.stack(grs), counts, g)
    else:
        @jax.jit
        def stage(U, GR, counts, g=None):
            return body(U, GR, counts, g)

    stage = _traced_stage("summary", K, n, gram_impl.backend, stage)
    _STAGES[key] = stage
    return stage


def cloud_stage(P: int, n: int, solve_cfg: SolveConfig, kind: str, *,
                solve_scale: float = 1.0, gather: bool = False,
                stack: bool = False, scope_key=None,
                scope_idx=None, robust=None) -> Callable:
    """Compiled final tier: ``fn(U (P,n), ghat (n,), counts, override?) →
    (delta (n,), info)`` — the fused equivalent of
    ``hier_server.cloud_aggregate``.

    ``kind``: "combo" (mass-conserving Σγ=1 solve over child combinations),
    "raw" (unconstrained paper solve over raw updates — star/relay; with the
    §III-C ``solve_scale`` for fan-in-sampled star clouds), or "fedavg"
    (count-weighted mean).  ``override`` supplies sketched (G₂, c₂) for the
    compressed pipeline.  With ``gather=True`` the signature becomes
    ``fn(D (Pr,n), GM (Pr,n), sel (P,), counts)``: cohort rows are gathered
    and the ∇f estimate averaged inside the jit boundary.  With
    ``stack=True`` it is ``fn(us (P-tuple of (n,)), ghat, counts,
    override?)`` — child combinations stacked inside the jit boundary."""
    if gather and stack:
        raise ValueError("cloud_stage: gather and stack are exclusive")
    # robust applies to the raw-update solve only (star/relay — the cohort
    # the attacker sits in); combo children are already robustified below
    if robust is not None and (kind != "raw"
                               or not getattr(robust, "enabled", False)):
        robust = None
    ns = n if scope_idx is None else len(scope_idx)
    gram_impl = _gram_impl(P, ns)
    key = ("cloud", P, n, solve_cfg, kind, solve_scale, gather, stack,
           scope_key, robust, gram_impl.backend)
    fn = _STAGES.get(key)
    if fn is not None:
        return fn
    _log_stage_build("cloud", P, n, gram_impl.backend)

    cfg = solve_cfg
    if kind == "combo":
        cfg = replace(cfg, sum_to=1.0)
    elif solve_scale != 1.0:
        cfg = replace(cfg, expectation_scale=cfg.expectation_scale
                      * solve_scale)
    gram_fn = gram_impl.fn
    idx = None if scope_idx is None else jnp.asarray(scope_idx)
    beta = cfg.beta

    if robust is not None:
        from ..robust.gramstats import robustify

    def body(U, ghat, counts, override):
        if kind == "fedavg":
            alpha = counts / jnp.maximum(jnp.sum(counts), 1e-12)
            info = {"alpha": alpha, "gamma": alpha}
            return alpha @ U, info
        if robust is not None:
            # ``ghat`` is the (K, n) per-member gradient matrix here — the
            # gather wrapper skips the mean so the pooling sees the columns
            GR = ghat
            w = counts / jnp.maximum(jnp.sum(counts), 1e-12)
            Us = U if idx is None else U[:, idx]
            GRs = GR if idx is None else GR[:, idx]
            Gr, cr, s = robustify(Us @ Us.T, Us @ GRs.T, w, robust)
            alpha = solve_alpha(Gr, cr, cfg)
            eff = s * alpha
            info = {"alpha": eff, "gamma": eff,
                    **solve_diagnostics(Gr, cr, alpha, beta),
                    "gram_diag": jnp.diag(Gr), "clip_scale": s}
            return eff @ U, info
        if override is not None:
            G, c = override
        else:
            Us, gs = _scoped(U, ghat, idx)
            G, c = gram_fn(Us, gs)
        alpha = solve_alpha(G, c, cfg)
        info = {"alpha": alpha, "gamma": alpha,
                **solve_diagnostics(G, c, alpha, beta),
                "gram_diag": jnp.diag(G)}
        return alpha @ U, info

    if gather:
        @jax.jit
        def stage(D, GM, sel, counts, override=None):
            if robust is not None:
                return body(D[sel], GM[sel], counts, override)
            return body(D[sel], jnp.mean(GM[sel], axis=0), counts, override)
    elif stack:
        @jax.jit
        def stage(us, ghat, counts, override=None):
            return body(jnp.stack(us), ghat, counts, override)
    else:
        @jax.jit
        def stage(U, ghat, counts, override=None):
            return body(U, ghat, counts, override)

    stage = _traced_stage("cloud", P, n, gram_impl.backend, stage)
    _STAGES[key] = stage
    return stage


@jax.jit
def gather_override(M: jax.Array, sel: jax.Array, pos: jax.Array,
                    vals) -> jax.Array:
    """``M[sel]`` with rows ``pos`` replaced by ``vals`` — the decoded-row
    path (device-uplink compression) as ONE gathered array update: gather,
    stack and scatter all happen inside the jit boundary instead of a
    per-row ``D[int(i)]`` dispatch-and-sync loop."""
    return M[sel].at[pos].set(jnp.stack(vals))


@jax.jit
def weighted_mean_rows(vecs, w: jax.Array) -> jax.Array:
    """Count-weighted mean of a tuple of (n,) vectors, stacked in-jit.
    Owns the normalization — pass raw counts."""
    return (w / jnp.maximum(jnp.sum(w), 1e-12)) @ jnp.stack(vecs)


class HierRoundEngine:
    """Per-run façade over the stage cache: resolves the static keys
    (model width, solve config, tier mode, gram scope) once, then hands the
    runtime one-call compiled stages.  ``begin_round`` wraps a round's
    stacked updates as a :class:`FusedRoundContext` — the engine-agnostic
    API ``run_hier_simulation`` drives (its streamed twin is
    ``repro.hier.streamed.StreamedRoundEngine``)."""

    name = "fused"

    def __init__(self, params_template: Pytree, solve_cfg: SolveConfig,
                 tier_mode: str, gram_scope: Optional[str] = None,
                 robust=None):
        self.n = int(sum(l.size for l in
                         jax.tree_util.tree_leaves(params_template)))
        self.solve_cfg = solve_cfg
        self.tier_mode = tier_mode
        self.gram_scope = gram_scope
        # RobustConfig (or None): hardened tier solves for the member-level
        # stages — the context passes it to gateway/cloud_raw only (merge and
        # combo stages act on children that are already robustified)
        self.robust = robust
        self._scope_idx = scope_indices(params_template, gram_scope)
        self._scope_key = (None if self._scope_idx is None else
                           (gram_scope, len(self._scope_idx),
                            hash(self._scope_idx.tobytes())))

    # -- stage accessors ----------------------------------------------------

    def tier(self, K: int, *, pool_scale: float = 1.0,
             sum_to: Optional[float] = None, gather: bool = False,
             stack: bool = False, robust=None) -> Callable:
        return summary_stage(K, self.n, self.solve_cfg, self.tier_mode,
                             pool_scale=pool_scale, sum_to=sum_to,
                             gather=gather, stack=stack,
                             scope_key=self._scope_key,
                             scope_idx=self._scope_idx, robust=robust)

    def cloud(self, P: int, kind: str, *, solve_scale: float = 1.0,
              gather: bool = False, stack: bool = False,
              robust=None) -> Callable:
        return cloud_stage(P, self.n, self.solve_cfg, kind,
                           solve_scale=solve_scale, gather=gather,
                           stack=stack, scope_key=self._scope_key,
                           scope_idx=self._scope_idx, robust=robust)

    # -- engine-agnostic round API ------------------------------------------

    def peak_round_bytes(self, P: int, dense_fallback_members: int = 0
                         ) -> float:
        """The dense engine's round-matrix footprint: D and GM as (P, n)
        f32 (what the streamed engine exists to avoid).
        ``dense_fallback_members`` is a streamed-engine concept (summary
        stacks are already inside the dense budget here)."""
        del dense_fallback_members
        return float(2 * P * self.n * 4)

    def begin_round(self, stacked_deltas: Pytree,
                    stacked_grads: Pytree) -> "FusedRoundContext":
        return FusedRoundContext(self, flatten_stacked(stacked_deltas),
                                 flatten_stacked(stacked_grads))


class FusedRoundContext:
    """One round's worth of state for the dense engine: the flat (P, n)
    round matrices plus any decoded device rows, behind the same method
    surface as ``StreamedRoundContext`` — refs are plain (n,) vectors here.
    """

    name = "fused"

    def __init__(self, engine: HierRoundEngine, D: jax.Array, GM: jax.Array):
        self.engine = engine
        self.D, self.GM = D, GM
        self.P = int(D.shape[0])
        self._dec: Dict[int, jax.Array] = {}
        self._dec_g: Dict[int, jax.Array] = {}

    # -- device-uplink decodes ---------------------------------------------

    def add_decoded_row(self, i: int, d_vec: jax.Array,
                        g_vec: jax.Array) -> None:
        self._dec[i] = d_vec
        self._dec_g[i] = g_vec

    def _rows(self, idxs) -> Optional[Tuple[jax.Array, jax.Array]]:
        """(U, GR) for a cohort whose rows were (partly) replaced by
        device-uplink decodes; None when no row was decoded (the common
        path gathers inside the jitted stage instead)."""
        dec = [k for k, i in enumerate(idxs) if int(i) in self._dec]
        if not dec:
            return None
        sel = jnp.asarray(np.asarray(idxs, np.int32))
        pos = jnp.asarray(np.asarray(dec, np.int32))
        U = gather_override(self.D, sel, pos,
                            tuple(self._dec[int(idxs[k])] for k in dec))
        GR = gather_override(self.GM, sel, pos,
                             tuple(self._dec_g[int(idxs[k])] for k in dec))
        return U, GR

    # -- gradient refs ------------------------------------------------------

    def mean_grad(self, idxs) -> jax.Array:
        return gather_mean(self.GM, jnp.asarray(np.asarray(idxs, np.int32)))

    def compose_grads(self, refs, counts) -> jax.Array:
        return weighted_mean_rows(tuple(refs),
                                  jnp.asarray(np.asarray(counts,
                                                         np.float32)))

    # -- tier stages ---------------------------------------------------------

    def gateway(self, idxs, *, solve_grad=None,
                pool_scale: float = 1.0) -> Dict[str, Any]:
        ones = jnp.ones((len(idxs),), jnp.float32)
        rows = self._rows(idxs)
        if rows is None:
            stage = self.engine.tier(len(idxs), pool_scale=pool_scale,
                                     gather=True, robust=self.engine.robust)
            return stage(self.D, self.GM,
                         jnp.asarray(np.asarray(idxs, np.int32)), ones,
                         solve_grad)
        stage = self.engine.tier(len(idxs), pool_scale=pool_scale,
                                 robust=self.engine.robust)
        return stage(rows[0], rows[1], ones, solve_grad)

    def merge(self, u_refs, g_refs, counts, *,
              solve_grad=None) -> Dict[str, Any]:
        stage = self.engine.tier(len(u_refs), sum_to=1.0, stack=True)
        return stage(tuple(u_refs), tuple(g_refs),
                     jnp.asarray(np.asarray(counts, np.float32)), solve_grad)

    def cloud_raw(self, idxs, kind: str, *,
                  solve_scale: float = 1.0) -> Tuple[jax.Array, Dict]:
        ones = jnp.ones((len(idxs),), jnp.float32)
        rows = self._rows(idxs)
        robust = self.engine.robust
        if rows is None:
            stage = self.engine.cloud(len(idxs), kind,
                                      solve_scale=solve_scale, gather=True,
                                      robust=robust)
            return stage(self.D, self.GM,
                         jnp.asarray(np.asarray(idxs, np.int32)), ones)
        stage = self.engine.cloud(len(idxs), kind, solve_scale=solve_scale,
                                  robust=robust)
        if (robust is not None and kind == "raw"
                and getattr(robust, "enabled", False)):
            return stage(rows[0], rows[1], ones)
        return stage(rows[0], jnp.mean(rows[1], axis=0), ones)

    def cloud_combo(self, u_refs, counts, ghat, *, kind: str = "combo",
                    override=None) -> Tuple[jax.Array, Dict]:
        stage = self.engine.cloud(len(u_refs), kind, stack=True)
        return stage(tuple(u_refs), ghat,
                     jnp.asarray(np.asarray(counts, np.float32)),
                     override=override)

    # -- vector materialization / final apply --------------------------------

    def materialize(self, ref) -> jax.Array:
        return ref

    def apply(self, params: Pytree, delta_ref) -> Pytree:
        return apply_delta(params, delta_ref)
