"""Tier-local contextual aggregation producing composable Gram summaries.

A gateway holding K_g member updates runs the paper's contextual solve on its
*own* cohort — Gram block ``G_g = U_g U_gᵀ``, cross term ``c_g = U_g ĝ_g``,
stationary ``α_g`` — and emits a :class:`GatewaySummary`:

    (G_g, c_g, α_g, ū_g = Σ_k α_gk Δ_k, ĝ_g, count)

The summary is *composable*: a parent tier treats the children's ū vectors as
its member updates and runs the identical solve one level up (its gradient
estimate is the count-weighted mean of the children's ĝ).  Because the Gram
statistics compose exactly (``core.gram.merge_gram_blocks``), the parent's
stage is again the paper's bound-optimal solve — restricted to the subspace
``{α : α|_g ∝ α_g}`` of per-group rescalings of each child's local optimum.
That subspace contains 0 and every child's own solution, so Theorem 1 holds
per tier: each aggregation hop can only improve the bound over forwarding any
single child's combination unchanged.

With a single gateway containing the whole fleet the two-stage solve
collapses to the flat one *exactly* (the cloud rescale γ = 1 at the gateway's
stationary point) — tested in ``tests/test_hier.py``.

These pytree-level functions are the REFERENCE implementation: the runtime
(``run_hier_simulation``) executes the same math through the fused
jit-compiled stages of ``repro.hier.fused`` over flat update matrices, and
``tests/test_backend_equiv.py`` pins the fused stages against these
functions.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.flatten import scope_vector, stacked_weighted_sum
from ..core.gram import gram_residual
from ..kernels.ops import gram_and_cross
from ..core.solve import SolveConfig, bound_value, solve_alpha, theorem1_reduction

Pytree = Any


@dataclass
class GatewaySummary:
    """What one aggregation node ships to its parent (see ``comm.summary_bytes``)."""
    node_id: int
    num_updates: int               # devices under this summary (all tiers below)
    member_ids: np.ndarray         # immediate children that contributed
    G: jax.Array                   # (K_g, K_g) tier-local Gram block
    c: jax.Array                   # (K_g,) tier-local cross term
    alpha: jax.Array               # (K_g,) tier-local solve weights
    u_bar: Pytree                  # Σ_k α_k Δ_k, same structure as params
    grad_est: Pytree               # this subtree's ∇f estimate
    info: Dict[str, jax.Array]


@dataclass
class CompressedSummary:
    """A :class:`GatewaySummary` as it rides a compressed uplink
    (``repro.compress``): ``summary`` holds the *decoded* ū_g / ĝ_g — what
    the receiver reconstructs and every downstream solve consistently uses —
    while ``comp_u`` / ``comp_g`` are the payloads that actually crossed the
    wire (serialized size → ``comm.compressed_summary_bytes``; sketch-space
    cross-terms → ``compress.payload_gram``)."""
    summary: GatewaySummary
    comp_u: Any                    # repro.compress.Compressed
    comp_g: Any                    # repro.compress.Compressed


def _stack_trees(trees: Sequence[Pytree]) -> Pytree:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def weighted_mean_trees(trees: Sequence[Pytree], weights: np.ndarray) -> Pytree:
    """Count-weighted mean of pytrees — how subtree gradient estimates
    compose up the tree (also used by the runtime's gradient pre-pass)."""
    w = np.asarray(weights, np.float64)
    w = w / max(float(w.sum()), 1e-12)
    return jax.tree_util.tree_map(
        lambda *xs: sum(float(wi) * x for wi, x in zip(w, xs)), *trees)


def tier_contextual(stacked_updates: Pytree, grad_tree: Pytree,
                    solve_cfg: SolveConfig,
                    gram_scope: Optional[str] = None
                    ) -> Tuple[Pytree, jax.Array, jax.Array, jax.Array,
                               Dict[str, jax.Array]]:
    """One tier's contextual solve: ``(ū, α, G, c, info)`` from stacked
    member updates and the tier's gradient estimate."""
    from ..core.aggregation import _stacked_to_matrix
    U = _stacked_to_matrix(stacked_updates, gram_scope)
    g = scope_vector(grad_tree, gram_scope)
    G, c = gram_and_cross(U, g)
    alpha = solve_alpha(G, c, solve_cfg)
    u_bar = stacked_weighted_sum(stacked_updates, alpha)
    beta = solve_cfg.beta
    info = {
        "bound": bound_value(G, c, alpha, beta),
        "theorem1_reduction": theorem1_reduction(G, alpha, beta),
        "stationarity_residual": jnp.linalg.norm(
            gram_residual(G, c, alpha, beta)),
    }
    return u_bar, alpha, G, c, info


def tier_mean(stacked_updates: Pytree, counts: np.ndarray
              ) -> Tuple[Pytree, jax.Array]:
    """Count-weighted mean — the hier-FedAvg tier rule.  Weighting by the
    number of devices under each member makes the composition exact: the
    cloud's result equals flat FedAvg over all participants."""
    w = jnp.asarray(counts, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)
    return stacked_weighted_sum(stacked_updates, w), w


def summarize_updates(node_id: int, member_ids: Sequence[int],
                      updates: Sequence[Pytree], grads: Sequence[Pytree],
                      counts: Sequence[int], solve_cfg: SolveConfig,
                      mode: str = "contextual",
                      gram_scope: Optional[str] = None,
                      solve_grad: Optional[Pytree] = None,
                      pool_size: Optional[int] = None) -> GatewaySummary:
    """Aggregate one node's member updates into its upstream summary.

    ``updates[i]`` is member i's update (a raw device Δ at tier 1, a child's
    ū above), ``grads[i]`` its subtree gradient estimate, ``counts[i]`` the
    devices it speaks for.  ``mode``: "contextual" (tier-local solve) or
    "mean" (count-weighted FedAvg tier rule).

    ``solve_grad`` is the gradient the c-term is computed against; default is
    this subtree's own estimate.  The hierarchical runtime's gradient
    pre-pass supplies the round's *global* ĝ here — a gateway cohort is a
    skewed sample of the fleet, and optimizing the bound against a skewed
    ∇f estimate misweights the whole cohort in a way the parent's γ rescale
    cannot repair (it scales the cohort jointly).

    ``pool_size`` applies the §III-C expected-bound correction when the
    cohort is a random sample of a larger pool (fan-in sampling): the
    contextual solve is scaled by (N−1)/(K−1) so a sampled cohort prices the
    pool it stands in for, exactly as ``contextual_expected`` does for the
    flat server.  No-op for the "mean" tier rule (FedAvg's weights are
    already selection-unbiased).
    """
    if not updates:
        raise ValueError(f"node {node_id}: cannot summarize zero updates")
    counts = np.asarray(counts, np.int64)
    stacked = _stack_trees(updates)
    grad_est = weighted_mean_trees(grads, counts)
    if pool_size is not None and pool_size < len(updates):
        raise ValueError(f"node {node_id}: pool_size {pool_size} smaller "
                         f"than the cohort ({len(updates)})")
    if mode == "contextual" and pool_size is not None:
        scale = (pool_size - 1) / max(len(updates) - 1, 1)
        solve_cfg = replace(
            solve_cfg, expectation_scale=solve_cfg.expectation_scale * scale)
    if mode == "contextual":
        u_bar, alpha, G, c, info = tier_contextual(
            stacked, grad_est if solve_grad is None else solve_grad,
            solve_cfg, gram_scope)
    elif mode == "mean":
        u_bar, alpha = tier_mean(stacked, counts)
        from ..core.aggregation import _stacked_to_matrix
        U = _stacked_to_matrix(stacked, gram_scope)
        G, c = gram_and_cross(U, scope_vector(grad_est, gram_scope))
        info = {"bound": bound_value(G, c, alpha, solve_cfg.beta)}
    else:
        raise KeyError(f"unknown tier mode '{mode}' (contextual|mean)")
    return GatewaySummary(
        node_id=node_id, num_updates=int(counts.sum()),
        member_ids=np.asarray(list(member_ids), np.int64),
        G=G, c=c, alpha=alpha, u_bar=u_bar, grad_est=grad_est, info=info)


def merge_summaries(node_id: int, children: Sequence[GatewaySummary],
                    solve_cfg: SolveConfig, mode: str = "contextual",
                    gram_scope: Optional[str] = None,
                    solve_grad: Optional[Pytree] = None) -> GatewaySummary:
    """Compose child summaries one tier up (regional / cloud stage): the
    children's ū vectors become this node's member updates.

    Parent-tier solves conserve mass (``sum_to=1``): each child combination
    already carries its own 1/β calibration, and the restricted span of P
    combinations systematically underprices alignment, so an unconstrained
    solve shrinks the aggregate step round after round.  Constrained, the
    tier only *reallocates* weight across children — every corner γ = e_g is
    feasible, so the merged bound is never worse than promoting any single
    child's combination unchanged."""
    return summarize_updates(
        node_id, [s.node_id for s in children],
        [s.u_bar for s in children], [s.grad_est for s in children],
        [s.num_updates for s in children],
        replace(solve_cfg, sum_to=1.0), mode, gram_scope, solve_grad)
