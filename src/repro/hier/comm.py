"""Per-tier traffic accounting for hierarchical aggregation.

The whole point of the hierarchy is the uplink: a gateway that forwards its
K_g raw updates costs the backhaul ``K_g·n`` floats per round, while a
contextual summary costs ``2n + K_g² + 2K_g`` (combined update ū_g, local
gradient estimate ĝ_g, Gram block G_g, cross term c_g, tier weights α_g) —
for n ≫ K² that is
a ~K_g/2× reduction *per gateway*, i.e. fleet-wide cloud-uplink shrinks from
O(K·n) to O(P·n).  :class:`CommLedger` records every transfer by tier so
examples/benchmarks can report the measured ratio instead of the formula.

Byte conventions follow ``repro.edge.wallclock``: float32 on the wire, the
model payload is ``4·|w|`` bytes, and a device upload is the update only (the
first-step gradient rides along inside the same payload in the K₂=0 scheme,
exactly as the PR-1 async accounting assumes).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

from ..core.flatten import tree_size
from ..obs import Tracker, record_span

FLOAT_BYTES = 4.0


def update_bytes(n: int) -> float:
    """One raw update (or one model broadcast): n float32."""
    return FLOAT_BYTES * n


def summary_bytes(k: int, n: int, include_grad: bool = False) -> float:
    """One gateway summary: ū_g (n) + G_g (k²) + c_g (k) + α_g (k) + counts;
    with ``include_grad`` the subtree gradient estimate ĝ_g (n) rides inside
    the summary instead of travelling in the gradient pre-pass (the per-round
    uplink total is identical either way — 2n + k² + 2k — the pre-pass only
    reorders it so the solve can use the *global* ĝ)."""
    return FLOAT_BYTES * ((2 if include_grad else 1) * n + k * k + 2 * k + 2)


def compressed_summary_bytes(payload_bytes: float) -> float:
    """One *compressed* gateway summary (``repro.compress``): the ū_g / ĝ_g
    payloads ride at their serialized sketch/top-k/low-rank size instead of
    2n floats, plus the device count and node id.  The K_g² Gram block, the
    cross term and the tier weights α_g all stay at the gateway — the parent
    solve needs only (ū, ĝ, counts); everything else ever only backed
    cloud-side diagnostics.  ``payload_bytes`` is the summed
    ``Compressed.nbytes`` of the two payloads — the ledger records true
    serialized sizes, not a formula (tested)."""
    return payload_bytes + FLOAT_BYTES * 2


def model_size(params) -> int:
    return tree_size(params)


@dataclass
class TierTraffic:
    """Aggregate traffic crossing into one tier (child → parent direction is
    ``up``; parent → child is ``down``)."""
    bytes_up: float = 0.0
    bytes_down: float = 0.0
    transfers_up: int = 0
    transfers_down: int = 0
    link_seconds: float = 0.0      # summed transfer durations (not wall-clock)


class CommLedger:
    """Accumulates per-tier traffic over a simulation.

    Tier t records transfers whose *receiver* sits on tier t — so the cloud
    tier's ``bytes_up`` is exactly the cloud-uplink volume the acceptance
    criterion bounds.

    With a ``tracker`` (``repro.obs``), every transfer is ALSO streamed the
    moment it is recorded — one event per record call with the tier,
    direction, bytes, link seconds and (when a ``clock`` callable is given,
    normally the event scheduler's ``lambda: scheduler.now``) the virtual
    timestamp — so long runs expose their traffic live instead of only in
    the end-of-run :meth:`report`.  Timed transfers additionally emit a
    virtual-time ``link/up``/``link/down`` span (``repro.obs.spans``) so
    link occupancy shows on the Perfetto virtual track.  A noop/absent
    tracker costs one attribute check per record.
    """

    def __init__(self, depth: int, tracker: Optional[Tracker] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.depth = depth
        self.tiers: Dict[int, TierTraffic] = {
            t: TierTraffic() for t in range(depth + 1)}
        self._tracker = tracker
        self._clock = clock

    def _stream(self, tier: int, direction: str, nbytes: float,
                seconds: float, count: int = 1) -> None:
        event = {"tier": tier, "dir": direction, "bytes": nbytes,
                 "link_seconds": seconds}
        if count != 1:
            event["count"] = count
        if self._clock is not None:
            now = self._clock()
            event["t_virtual"] = now
            if seconds > 0:
                # the transfer's whole virtual interval is known at record
                # time: emit it as one span so link occupancy lands on the
                # virtual track next to the round/stage spans
                record_span(f"link/{direction}", t0_virtual=now,
                            dur_virtual_s=seconds, tier=tier, bytes=nbytes)
        self._tracker.log(event)

    def record_up(self, tier: int, nbytes: float, seconds: float = 0.0,
                  count: int = 1) -> None:
        """Record ``count`` identical transfers in one call (the fleet-scale
        cohort path accounts a whole tier's device traffic at once; totals
        equal ``count`` single-record calls, streamed as one event carrying
        the summed bytes)."""
        if count == 0:
            return
        tt = self.tiers[tier]
        tt.bytes_up += nbytes * count
        tt.transfers_up += count
        tt.link_seconds += seconds * count
        if self._tracker is not None and self._tracker.active:
            self._stream(tier, "up", nbytes * count, seconds * count, count)

    def record_down(self, tier: int, nbytes: float, seconds: float = 0.0,
                    count: int = 1) -> None:
        if count == 0:
            return
        tt = self.tiers[tier]
        tt.bytes_down += nbytes * count
        tt.transfers_down += count
        tt.link_seconds += seconds * count
        if self._tracker is not None and self._tracker.active:
            self._stream(tier, "down", nbytes * count, seconds * count, count)

    @property
    def cloud_uplink_bytes(self) -> float:
        return self.tiers[self.depth].bytes_up

    def total_bytes(self) -> float:
        return sum(t.bytes_up + t.bytes_down for t in self.tiers.values())

    def savings_vs(self, flat_cloud_uplink_bytes: float) -> Optional[float]:
        """How many × fewer cloud-uplink bytes than a flat run that moved
        ``flat_cloud_uplink_bytes``; None until something was recorded."""
        if self.cloud_uplink_bytes <= 0:
            return None
        return flat_cloud_uplink_bytes / self.cloud_uplink_bytes

    def report(self) -> Dict[str, Dict[str, float]]:
        return {
            f"tier_{t}": {
                "bytes_up": tt.bytes_up, "bytes_down": tt.bytes_down,
                "transfers_up": tt.transfers_up,
                "transfers_down": tt.transfers_down,
                "link_seconds": round(tt.link_seconds, 6),
            } for t, tt in sorted(self.tiers.items())
        }
