"""Cloud-side aggregation over gateway summaries + hierarchical baselines.

The cloud never sees a raw device update (except in relay mode): it receives
one :class:`~repro.hier.gateway.GatewaySummary` per reporting top-tier child
and solves the P×P contextual system over their combined updates,

    G₂ = [⟨ū_g, ū_h⟩],   c₂ = [⟨ū_g, ĝ⟩],   γ* = −(1/β) G₂⁺ c₂,

then applies ``w ← w + Σ_g γ_g ū_g``.  Block-wise this is the full-fleet K×K
solve restricted to ``α_k = γ_g α_{g,k}`` — the diagonal blocks (G_g, c_g)
arrive inside the summaries and back the block-diagonal bound diagnostics
(:func:`blockdiag_diagnostics`; the exact flat reassembly they support is
``core.gram.merge_gram_blocks``, tested against the flat reductions), while
the γ stage's Theorem-1 reduction ``(β/2) γᵀG₂γ`` is *exact* for the final
combined update.

Four strategies are registered in ``core.aggregation`` (same calling
convention as every other aggregator; the stacked leading axis is the
top-tier children instead of devices):

  * ``hier_contextual`` — contextual solve at every tier (this module's γ
    stage at the cloud, ``gateway.tier_contextual`` below it).
  * ``hier_fedavg``     — count-weighted mean at every tier; composes to
    exactly flat FedAvg over all participants (tested).
  * ``hier_relay``      — summary-free baseline: gateways forward raw
    updates, the cloud runs the flat contextual solve.  Same loss as flat,
    full O(K·n) cloud uplink — the byte-accounting comparator.
  * ``hier_contextual_sketch`` — compressed-summary variant
    (``repro.compress``): summaries ride the uplink as EF-compressed
    sketch/top-k/low-rank payloads and the γ stage solves on sketched
    cross-terms supplied via ``AggregatorConfig.gram_override``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..compress import CompressConfig
from ..core.aggregation import (AggregatorConfig, aggregate,
                                aggregate_contextual, aggregate_fedavg,
                                register_aggregator)
from ..core.solve import SolveConfig, bound_value, theorem1_reduction
from .gateway import GatewaySummary

Pytree = Any


# ---------------------------------------------------------------------------
# registry entries (cloud stage, standard aggregator signature)
# ---------------------------------------------------------------------------

def aggregate_hier_contextual(params: Pytree, stacked_updates: Pytree,
                              grad_tree: Pytree, cfg: AggregatorConfig
                              ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    """Cloud γ-solve over stacked child combinations (the P×P stage).  The
    math is the paper's contextual solve — registered under its own name so
    configs state the tier structure explicitly and the info dict carries
    ``gamma``."""
    new, info = aggregate_contextual(params, stacked_updates, grad_tree, cfg)
    info = dict(info)
    info["gamma"] = info["alpha"]
    return new, info


def aggregate_hier_fedavg(params: Pytree, stacked_updates: Pytree,
                          grad_tree: Optional[Pytree], cfg: AggregatorConfig
                          ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    """Count-weighted mean of child combinations (weights via
    ``cfg.client_weights`` = devices under each child)."""
    return aggregate_fedavg(params, stacked_updates, grad_tree, cfg)


def aggregate_hier_contextual_sketch(params: Pytree, stacked_updates: Pytree,
                                     grad_tree: Pytree, cfg: AggregatorConfig
                                     ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    """γ-solve over *compressed* child combinations: the runtime supplies the
    sketched cross-terms through ``cfg.gram_override`` (see
    ``repro.compress.payload_gram``) and the decoded updates as the stacked
    members, so the solve prices exactly what crossed the wire while never
    re-touching the parameter axis for the Gram stage."""
    return aggregate_hier_contextual(params, stacked_updates, grad_tree, cfg)


register_aggregator("hier_contextual", aggregate_hier_contextual)
register_aggregator("hier_fedavg", aggregate_hier_fedavg)
register_aggregator("hier_relay", aggregate_contextual)
register_aggregator("hier_contextual_sketch", aggregate_hier_contextual_sketch)


# ---------------------------------------------------------------------------
# summary-level cloud apply (what run_hier_simulation drives)
# ---------------------------------------------------------------------------

def cloud_aggregate(params: Pytree, stacked_members: Pytree,
                    grad_est: Pytree, member_counts: Sequence[int],
                    cfg: "HierConfig", combos: bool = True,
                    gram_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    solve_scale: float = 1.0
                    ) -> Tuple[Pytree, Dict[str, Any]]:
    """Final tier, routed through the ``core.aggregation`` registry.

    ``stacked_members`` stacks the cloud's direct children along the leading
    axis — gateway/regional ū trees in summary mode (``combos=True``), raw
    device updates for a star topology or relay mode (``combos=False``); the
    same registry entry covers both because the γ stage *is* the paper's
    solve one level up.  Over combos the solve conserves mass (Σγ = 1, see
    :func:`repro.hier.gateway.merge_summaries`); over raw updates it is the
    paper's unconstrained solve — the members carry no 1/β calibration yet.
    """
    solve = cfg.solve_config()
    if combos:
        solve = replace(solve, sum_to=1.0)
    if solve_scale != 1.0:
        # §III-C pool pricing for a fan-in-sampled raw cohort (star clouds
        # are the fleet's single gateway); parent-tier combo solves conserve
        # mass instead, and sum_to overrides expectation_scale by design
        solve = replace(solve,
                        expectation_scale=solve.expectation_scale * solve_scale)
    weights = None
    if cfg.aggregator == "hier_fedavg":
        weights = jnp.asarray(list(member_counts), jnp.float32)
    agg_cfg = AggregatorConfig(name=cfg.aggregator, solve=solve,
                               gram_scope=cfg.gram_scope,
                               client_weights=weights,
                               gram_override=gram_override)
    new_params, info = aggregate(cfg.aggregator)(params, stacked_members,
                                                 grad_est, agg_cfg)
    info = dict(info)
    info.setdefault("gamma", info["alpha"])
    return new_params, info


def blockdiag_diagnostics(summaries: Sequence[GatewaySummary],
                          gamma: jax.Array, beta: float) -> Dict[str, Any]:
    """Block-wise view of the induced device-level solve.

    The effective full-fleet weights are ``α_k = γ_g α_{g,k}``; stacking the
    shipped diagonal blocks (the cross-gateway blocks are exactly what the
    hierarchy elides — zero in this view) prices that α under the
    block-diagonal Gram, giving the cloud a full-fleet bound estimate
    without ever seeing a raw update.

    Computed in numpy on purpose: the block sizes change whenever a dropout
    changes a cohort, and a jnp ``block_diag`` re-compiles per shape combo —
    on the per-round hot path that recompile dwarfed the O(K²) arithmetic.
    """
    gam = np.asarray(gamma)
    Gs = [np.asarray(s.G, np.float64) for s in summaries]
    cs = [np.asarray(s.c, np.float64) for s in summaries]
    als = [np.asarray(s.alpha, np.float64) for s in summaries]
    alpha_full = np.concatenate([gam[g] * a for g, a in enumerate(als)])
    c_full = np.concatenate(cs)
    quad = sum(float(a @ G @ a) * gam[g] * gam[g]
               for g, (G, a) in enumerate(zip(Gs, als)))
    return {
        "alpha_effective": alpha_full,
        "blockdiag_bound": float(c_full @ alpha_full) + 0.5 * beta * quad,
        "tier1_theorem1_reductions": np.asarray(
            [0.5 * beta * float(a @ G @ a) for G, a in zip(Gs, als)]),
        "devices_represented": int(sum(s.num_updates for s in summaries)),
    }


# ---------------------------------------------------------------------------
# hierarchical run configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HierConfig:
    """Configuration of a hierarchical run (mirrors ``ServerConfig`` /
    ``AsyncConfig`` where concepts coincide)."""
    aggregator: str = "hier_contextual"  # hier_contextual | hier_fedavg |
                                         # hier_relay | hier_contextual_sketch
    fan_in: Optional[int] = None         # devices sampled per gateway per
                                         # round (None → every child; when
                                         # sampling, the gateway solve prices
                                         # its pool via §III-C)
    compress: Optional[CompressConfig] = None
                                         # summary compression (repro.compress);
                                         # requires the _sketch aggregator —
                                         # defaulted when that name is chosen
    gateway_grad: str = "local"          # gradient the gateway solves price
                                         # the c-term against: "local" (each
                                         # subtree's own ĝ — composes best
                                         # empirically; the γ stage handles
                                         # cross-cohort skew) or "global"
                                         # (gradient pre-pass: same uplink
                                         # bytes, +2 backhaul hops latency)
    lr: float = 0.03                     # client learning rate l
    beta: Optional[float] = None         # None → paper's β = 1/l
    mu: float = 0.0                      # FedProx proximal coefficient
    batch_size: int = 32
    min_epochs: int = 1                  # per-round epoch draw ~ U[min,max]
    max_epochs: int = 20
    gram_scope: Optional[str] = None
    ridge: float = 1e-6
    robust: Optional[Any] = None         # repro.robust RobustConfig: clip +
                                         # median-of-means/trimmed pooling on
                                         # the tier (G, c) statistics before
                                         # each contextual solve

    def __post_init__(self):
        if self.aggregator not in ("hier_contextual", "hier_fedavg",
                                   "hier_relay", "hier_contextual_sketch"):
            raise ValueError(f"unknown hier aggregator '{self.aggregator}' "
                             "(hier_contextual|hier_fedavg|hier_relay|"
                             "hier_contextual_sketch)")
        if self.fan_in is not None and self.fan_in < 1:
            raise ValueError(f"fan_in must be >= 1 (or None for all "
                             f"children), got {self.fan_in}")
        if self.gateway_grad not in ("global", "local"):
            raise ValueError(f"gateway_grad must be 'global' or 'local', "
                             f"got '{self.gateway_grad}'")
        if self.aggregator == "hier_contextual_sketch" and self.compress is None:
            object.__setattr__(self, "compress", CompressConfig())
        if self.compress is not None:
            if self.aggregator != "hier_contextual_sketch":
                raise ValueError("summary compression requires the "
                                 "'hier_contextual_sketch' aggregator, got "
                                 f"'{self.aggregator}'")
            if self.gateway_grad != "local":
                raise ValueError("summary compression composes with "
                                 "gateway_grad='local' only: the gradient "
                                 "pre-pass would ship full-width ĝ both ways "
                                 "and defeat the uplink budget")
        if self.robust is not None:
            from ..robust.gramstats import RobustConfig
            if not isinstance(self.robust, RobustConfig):
                raise TypeError("HierConfig.robust must be a "
                                "repro.robust.RobustConfig, got "
                                f"{type(self.robust).__name__}")
            if self.aggregator != "hier_contextual":
                raise ValueError("robust tier statistics require the "
                                 "'hier_contextual' aggregator (the solve "
                                 "they harden), got "
                                 f"'{self.aggregator}'")
            if self.gateway_grad != "local":
                raise ValueError("robust tier statistics require "
                                 "gateway_grad='local': median-of-means/"
                                 "trimmed pooling acts on the per-member "
                                 "gradient columns, which the global "
                                 "pre-pass pre-averages away")

    @property
    def smoothness(self) -> float:
        return self.beta if self.beta is not None else 1.0 / self.lr

    @property
    def tier_mode(self) -> str:
        """Per-tier rule below the cloud: contextual solves everywhere except
        the hier-FedAvg baseline's count-weighted means."""
        return "mean" if self.aggregator == "hier_fedavg" else "contextual"

    @property
    def compressing(self) -> bool:
        return self.compress is not None

    def solve_config(self) -> SolveConfig:
        return SolveConfig(beta=self.smoothness, ridge=self.ridge)
