"""Pytree <-> flat-vector utilities used by the aggregation math.

The contextual aggregation (paper eq. 4-8) operates on flattened update
vectors ``Δ_k = w_k^{t+1} - w^t``.  These helpers convert between model
parameter pytrees and flat vectors, and implement the paper's "last layer"
efficiency scoping (§III-B, Note on efficiency): only a named subset of the
pytree participates in the Gram/solve, while the *combine* still applies the
resulting α to the full update.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Callable, Iterable, List, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


def tree_to_vector(tree: Pytree, dtype: jnp.dtype | None = jnp.float32) -> jax.Array:
    """Flatten a pytree of arrays into a single 1-D vector."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,), dtype=dtype or jnp.float32)
    parts = [jnp.ravel(x).astype(dtype) if dtype is not None else jnp.ravel(x) for x in leaves]
    return jnp.concatenate(parts)


def vector_to_tree(vec: jax.Array, like: Pytree) -> Pytree:
    """Inverse of :func:`tree_to_vector` given a structural template."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    offset = 0
    for leaf in leaves:
        size = leaf.size
        out.append(jnp.reshape(vec[offset:offset + size], leaf.shape).astype(leaf.dtype))
        offset += size
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_size(tree: Pytree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def select_scope(tree: Pytree, scope: str | Sequence[str] | None) -> Pytree:
    """Return a sub-pytree whose leaf paths match ``scope``.

    ``scope`` semantics:
      * ``None`` or ``"full"``   -> the whole tree (identity).
      * ``"last_layer"``        -> leaves whose path matches common head names
        (``lm_head``, ``head``, ``out``, ``final``, ``unembed``, ``logits``,
        ``w``/``b`` at top level for the logistic model); falls back to the
        lexicographically last top-level key if nothing matches.
      * a regex string or list of regex strings -> leaves whose '/'-joined
        path matches any pattern.

    Non-matching leaves are replaced by zero-size arrays so the result is a
    valid pytree with stable structure (flattening simply skips them).
    """
    if scope is None or scope == "full":
        return tree

    if scope == "last_layer":
        patterns = [r"(^|/)(lm_head|head|out_proj|final|unembed|logits)(/|$)"]
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        if not any(re.search(patterns[0], _path_str(path)) for path, _ in flat):
            # Fallback: last top-level key in sorted order.
            keys = sorted({_path_str(path).split("/")[0] for path, _ in flat})
            patterns = [r"^" + re.escape(keys[-1]) + r"(/|$)"]
    elif isinstance(scope, str):
        patterns = [scope]
    else:
        patterns = list(scope)

    def keep(path_str: str) -> bool:
        return any(re.search(p, path_str) for p in patterns)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    new_leaves = [
        leaf if keep(_path_str(path)) else jnp.zeros((0,), leaf.dtype)
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def scope_vector(tree: Pytree, scope: str | Sequence[str] | None,
                 dtype: jnp.dtype | None = jnp.float32) -> jax.Array:
    """Flatten only the scoped subset of ``tree``."""
    return tree_to_vector(select_scope(tree, scope), dtype=dtype)


@dataclass(frozen=True)
class LeafSlab:
    """One pytree leaf as a column slab of the flat ``(K, n)`` row-major
    view: ``matrix`` is ``leaf.reshape(K, -1)`` (a cheap view for contiguous
    leaves, never a cross-leaf concatenation), occupying flat columns
    ``[offset, offset + width)`` in ``tree_to_vector`` order."""
    index: int            # leaf position in tree_leaves order
    offset: int           # first flat column
    width: int            # columns (= leaf.size / K)
    in_scope: bool        # participates in the Gram scope
    matrix: jax.Array     # (K, width) view of the stacked leaf


class ChunkedFlatView:
    """Leaf-aligned column-chunk view of a *stacked* pytree (leading K axis
    per leaf) — the streaming alternative to the full ``jnp.concatenate``
    copy in ``core.aggregation._stacked_to_matrix``.

    The flat column order matches :func:`tree_to_vector` exactly (leaf
    order, row-major ravel per leaf), so a consumer that sweeps the slabs
    (or :meth:`chunks`) left to right sees the same (K, n) matrix the dense
    path materializes — without ever holding more than one chunk.  Scope is
    *leaf-granular* by construction (``select_scope`` keeps or drops whole
    leaves), so scoped reductions simply skip ``in_scope=False`` slabs
    instead of gathering columns.
    """

    def __init__(self, stacked: Pytree, scope: str | Sequence[str] | None = None):
        leaves = jax.tree_util.tree_leaves(stacked)
        if not leaves:
            raise ValueError("cannot build a flat view of an empty pytree")
        self.K = int(leaves[0].shape[0])
        bad = [tuple(l.shape) for l in leaves
               if l.ndim < 1 or l.shape[0] != self.K]
        if bad:
            raise ValueError(f"stacked pytree leaves must share the leading "
                             f"K={self.K} axis; offending shapes: {bad}")
        kept = [l.size > 0 for l in
                jax.tree_util.tree_leaves(select_scope(stacked, scope))]
        self.slabs: List[LeafSlab] = []
        offset = 0
        for i, (leaf, keep) in enumerate(zip(leaves, kept)):
            width = leaf.size // self.K
            self.slabs.append(LeafSlab(
                index=i, offset=offset, width=width, in_scope=bool(keep),
                matrix=jnp.reshape(leaf, (self.K, width))))
            offset += width
        self.n = offset

    @property
    def scoped_slabs(self) -> List[LeafSlab]:
        return [s for s in self.slabs if s.in_scope]

    @property
    def n_scoped(self) -> int:
        return sum(s.width for s in self.scoped_slabs)

    def chunks(self, chunk_cols: int, scoped_only: bool = False):
        """Yield ``(offset, in_scope, (K, w) matrix)`` column chunks with
        ``w <= chunk_cols``, never crossing a leaf boundary (leaf-aligned:
        a leaf wider than ``chunk_cols`` is split, narrower leaves come out
        whole).  Offsets are flat columns of the full view."""
        if chunk_cols < 1:
            raise ValueError(f"chunk_cols must be >= 1, got {chunk_cols}")
        for slab in self.slabs:
            if scoped_only and not slab.in_scope:
                continue
            for start in range(0, slab.width, chunk_cols):
                w = min(chunk_cols, slab.width - start)
                yield (slab.offset + start, slab.in_scope,
                       jax.lax.dynamic_slice(slab.matrix, (0, start),
                                             (self.K, w)))

    def materialize(self, dtype: jnp.dtype | None = jnp.float32) -> jax.Array:
        """Dense (K, n) matrix — tests / small models only; the streaming
        consumers exist so production never calls this at transformer width."""
        parts = [s.matrix.astype(dtype) if dtype is not None else s.matrix
                 for s in self.slabs]
        return jnp.concatenate(parts, axis=1)


def mix_rows(weights: jax.Array, leaf: jax.Array) -> jax.Array:
    """``Σ_k w_k · leaf[k]`` flattened to the leaf's (width,) columns, with
    f32 accumulation and **no** materialized f32 upcast of the leaf — the
    per-leaf primitive of the streamed combine pass (``α @ U`` one leaf at a
    time).

    The weights are cast to the leaf dtype so the contraction never copies
    the leaf: for bf16 update leaves that rounds each f32 solve weight to 8
    mantissa bits, a deliberate trade — second-order next to the bf16
    quantization already baked into the update values themselves (f32
    leaves contract exactly; the fused/streamed parity tests pin that
    case)."""
    m = jnp.reshape(leaf, (leaf.shape[0], -1))
    out = jax.lax.dot_general(
        weights.astype(m.dtype)[None, :], m, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out[0]


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_weighted_sum(trees: Iterable[Pytree], weights: jax.Array) -> Pytree:
    """``Σ_k weights[k] * trees[k]`` over a list of pytrees (stacks lazily)."""
    trees = list(trees)
    assert len(trees) > 0
    def comb(*leaves):
        stacked = jnp.stack(leaves)  # (K, ...)
        w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1)).astype(stacked.dtype)
        return jnp.sum(stacked * w, axis=0)
    return jax.tree_util.tree_map(comb, *trees)


def stacked_weighted_sum(stacked: Pytree, weights: jax.Array) -> Pytree:
    """Same as :func:`tree_weighted_sum` but for pre-stacked pytrees whose
    leaves have a leading K axis.  Contracts via :func:`mix_rows` (a dot
    with f32 accumulation) instead of broadcasting ``leaf * w`` — no
    K-times-leaf temporary, which matters at transformer width."""
    def comb(leaf):
        return jnp.reshape(mix_rows(weights, leaf),
                           leaf.shape[1:]).astype(leaf.dtype)
    return jax.tree_util.tree_map(comb, stacked)
