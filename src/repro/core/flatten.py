"""Pytree <-> flat-vector utilities used by the aggregation math.

The contextual aggregation (paper eq. 4-8) operates on flattened update
vectors ``Δ_k = w_k^{t+1} - w^t``.  These helpers convert between model
parameter pytrees and flat vectors, and implement the paper's "last layer"
efficiency scoping (§III-B, Note on efficiency): only a named subset of the
pytree participates in the Gram/solve, while the *combine* still applies the
resulting α to the full update.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


def tree_to_vector(tree: Pytree, dtype: jnp.dtype | None = jnp.float32) -> jax.Array:
    """Flatten a pytree of arrays into a single 1-D vector."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,), dtype=dtype or jnp.float32)
    parts = [jnp.ravel(x).astype(dtype) if dtype is not None else jnp.ravel(x) for x in leaves]
    return jnp.concatenate(parts)


def vector_to_tree(vec: jax.Array, like: Pytree) -> Pytree:
    """Inverse of :func:`tree_to_vector` given a structural template."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    offset = 0
    for leaf in leaves:
        size = leaf.size
        out.append(jnp.reshape(vec[offset:offset + size], leaf.shape).astype(leaf.dtype))
        offset += size
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_size(tree: Pytree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def select_scope(tree: Pytree, scope: str | Sequence[str] | None) -> Pytree:
    """Return a sub-pytree whose leaf paths match ``scope``.

    ``scope`` semantics:
      * ``None`` or ``"full"``   -> the whole tree (identity).
      * ``"last_layer"``        -> leaves whose path matches common head names
        (``lm_head``, ``head``, ``out``, ``final``, ``unembed``, ``logits``,
        ``w``/``b`` at top level for the logistic model); falls back to the
        lexicographically last top-level key if nothing matches.
      * a regex string or list of regex strings -> leaves whose '/'-joined
        path matches any pattern.

    Non-matching leaves are replaced by zero-size arrays so the result is a
    valid pytree with stable structure (flattening simply skips them).
    """
    if scope is None or scope == "full":
        return tree

    if scope == "last_layer":
        patterns = [r"(^|/)(lm_head|head|out_proj|final|unembed|logits)(/|$)"]
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        if not any(re.search(patterns[0], _path_str(path)) for path, _ in flat):
            # Fallback: last top-level key in sorted order.
            keys = sorted({_path_str(path).split("/")[0] for path, _ in flat})
            patterns = [r"^" + re.escape(keys[-1]) + r"(/|$)"]
    elif isinstance(scope, str):
        patterns = [scope]
    else:
        patterns = list(scope)

    def keep(path_str: str) -> bool:
        return any(re.search(p, path_str) for p in patterns)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    new_leaves = [
        leaf if keep(_path_str(path)) else jnp.zeros((0,), leaf.dtype)
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def scope_vector(tree: Pytree, scope: str | Sequence[str] | None,
                 dtype: jnp.dtype | None = jnp.float32) -> jax.Array:
    """Flatten only the scoped subset of ``tree``."""
    return tree_to_vector(select_scope(tree, scope), dtype=dtype)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_weighted_sum(trees: Iterable[Pytree], weights: jax.Array) -> Pytree:
    """``Σ_k weights[k] * trees[k]`` over a list of pytrees (stacks lazily)."""
    trees = list(trees)
    assert len(trees) > 0
    def comb(*leaves):
        stacked = jnp.stack(leaves)  # (K, ...)
        w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1)).astype(stacked.dtype)
        return jnp.sum(stacked * w, axis=0)
    return jax.tree_util.tree_map(comb, *trees)


def stacked_weighted_sum(stacked: Pytree, weights: jax.Array) -> Pytree:
    """Same as :func:`tree_weighted_sum` but for pre-stacked pytrees whose
    leaves have a leading K axis."""
    def comb(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(leaf * w, axis=0)
    return jax.tree_util.tree_map(comb, stacked)
