"""Server-side model aggregation strategies.

All strategies share one signature and act on *stacked* update pytrees
(every leaf has a leading K axis — the participating devices of the round,
i.e. the paper's "context", Definition 1):

    new_params, info = aggregate(name)(params, stacked_updates, grad_tree, cfg)

Implemented:
  * ``fedavg``               — uniform average of client models (paper eq. 2).
  * ``weighted``             — p_k-weighted average (|D_k|/|D| weights).
  * ``folb``                 — FOLB-style inner-product weighting [11].
  * ``contextual``           — the paper's optimal context-dependent bound
                               aggregation (Alg. 2, via the K×K solve).
  * ``contextual_expected``  — §III-C expected-bound variant.

``grad_tree`` is the estimate of ∇f(w^t): mean of the K₂-sample local
gradients (or, for K₂=0, of the round's own first-step gradients). FedAvg
ignores it.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .flatten import (scope_vector, select_scope, stacked_weighted_sum,
                      tree_add, tree_to_vector)
from .gram import gram_residual
# Gram reductions route through the backend-aware kernel registry
# (repro.kernels): autotuned pallas/xla/ref dispatch, never interpret-mode
from ..kernels.ops import gram_and_cross
from .solve import SolveConfig, bound_value, solve_alpha, theorem1_reduction

Pytree = Any


@dataclass(frozen=True)
class AggregatorConfig:
    name: str = "contextual"
    solve: SolveConfig = field(default_factory=SolveConfig)
    # Paper §III-B "Note on efficiency": compute α from a scoped slice of the
    # updates/gradient ("last_layer") but apply it to the full update.
    gram_scope: Optional[str] = None
    # client weights p_k = |D_k|/|D| for the weighted baseline
    client_weights: Optional[jax.Array] = None
    # per-update staleness discounts s_k ∈ (0, 1], set by the async runtime
    # (repro.edge): damps Gram cross-terms / effective weights of old updates
    staleness: Optional[jax.Array] = None
    # precomputed (G, c) for the contextual solve, set by the compressed
    # hierarchical runtime (repro.compress): the cloud's Gram stage runs on
    # sketched cross-terms without re-touching the parameter axis, while the
    # combine still applies the stacked (decoded) updates
    gram_override: Optional[Tuple[jax.Array, jax.Array]] = None
    # robustness knobs consumed by the repro.robust aggregators (a
    # repro.robust.gramstats.RobustConfig — typed opaquely so core stays
    # import-free of the subsystems that register into it)
    robust: Optional[Any] = None


def _stacked_to_matrix(stacked: Pytree, scope: Optional[str]) -> jax.Array:
    """Flatten stacked updates (leading K axis per leaf) to U (K, n_scope)."""
    scoped = select_scope(stacked, scope)
    leaves = [l for l in jax.tree_util.tree_leaves(scoped) if l.size > 0]
    K = leaves[0].shape[0]
    return jnp.concatenate(
        [jnp.reshape(l, (K, -1)).astype(jnp.float32) for l in leaves], axis=1)


def _num_clients(stacked: Pytree) -> int:
    return jax.tree_util.tree_leaves(stacked)[0].shape[0]


def aggregate_fedavg(params: Pytree, stacked_updates: Pytree,
                     grad_tree: Optional[Pytree], cfg: AggregatorConfig
                     ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    K = _num_clients(stacked_updates)
    if cfg.client_weights is not None:
        w = cfg.client_weights / jnp.sum(cfg.client_weights)
    else:
        w = jnp.full((K,), 1.0 / K)
    new = tree_add(params, stacked_weighted_sum(stacked_updates, w))
    return new, {"alpha": w}


def aggregate_folb(params: Pytree, stacked_updates: Pytree,
                   grad_tree: Pytree, cfg: AggregatorConfig
                   ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    """FOLB [11]: weight each update by the (normalised) inner product between
    its implied local gradient and the global-gradient estimate.  Updates that
    oppose ∇f receive negative weight (the paper's "opposite direction")."""
    U = _stacked_to_matrix(stacked_updates, cfg.gram_scope)
    g = scope_vector(grad_tree, cfg.gram_scope)
    # Δ_k ≈ −lr·∇F_k ⇒ alignment score s_k = ⟨−Δ_k, g⟩ (positive when aligned)
    s = -(U @ g)
    denom = jnp.maximum(jnp.sum(jnp.abs(s)), 1e-12)
    alpha = s / denom
    new = tree_add(params, stacked_weighted_sum(stacked_updates, alpha))
    return new, {"alpha": alpha, "alignment": s}


def aggregate_contextual(params: Pytree, stacked_updates: Pytree,
                         grad_tree: Pytree, cfg: AggregatorConfig
                         ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    """Paper Algorithm 2 via the K×K normal equations (DESIGN.md §2)."""
    if cfg.gram_override is not None:
        G, c = cfg.gram_override
    else:
        U = _stacked_to_matrix(stacked_updates, cfg.gram_scope)
        g = scope_vector(grad_tree, cfg.gram_scope)
        G, c = gram_and_cross(U, g)
    alpha = solve_alpha(G, c, cfg.solve)
    new = tree_add(params, stacked_weighted_sum(stacked_updates, alpha))
    beta = cfg.solve.beta
    info = {
        "alpha": alpha,
        "bound": bound_value(G, c, alpha, beta),
        "theorem1_reduction": theorem1_reduction(G, alpha, beta),
        "stationarity_residual": jnp.linalg.norm(gram_residual(G, c, alpha, beta)),
        "gram_diag": jnp.diag(G),
    }
    return new, info


def aggregate_contextual_expected(params: Pytree, stacked_updates: Pytree,
                                  grad_tree: Pytree, cfg: AggregatorConfig,
                                  pool_size: Optional[int] = None
                                  ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    """§III-C: optimal expected bound over random selection.  The stationarity
    solve is the contextual one scaled by (N−1)/(K−1); ``pool_size`` is N (or
    the sampled pool N')."""
    K = _num_clients(stacked_updates)
    N = pool_size if pool_size is not None else K
    scale = (N - 1) / max(K - 1, 1)
    cfg2 = replace(cfg, name="contextual",
                   solve=replace(cfg.solve, expectation_scale=scale))
    return aggregate_contextual(params, stacked_updates, grad_tree, cfg2)


_REGISTRY: Dict[str, Callable] = {
    "fedavg": aggregate_fedavg,
    "fedprox": aggregate_fedavg,     # FedProx differs client-side only
    "weighted": aggregate_fedavg,    # weights via cfg.client_weights
    "folb": aggregate_folb,
    "contextual": aggregate_contextual,
    "contextual_expected": aggregate_contextual_expected,
}


def register_aggregator(name: str, fn: Callable, *,
                        overwrite: bool = False) -> None:
    """Register an aggregation strategy under ``name`` (used by subsystems
    like ``repro.edge`` to plug in async variants without core knowing them)."""
    if name in _REGISTRY and not overwrite:
        raise KeyError(f"aggregator '{name}' already registered")
    _REGISTRY[name] = fn


def aggregate(name: str) -> Callable:
    if name not in _REGISTRY:
        raise KeyError(f"unknown aggregator '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_aggregators() -> Sequence[str]:
    return tuple(sorted(_REGISTRY))
