"""Gram-matrix / cross-term computation for contextual aggregation.

The contextual solve needs only two reductions over the (huge) parameter
axis (see DESIGN.md §2):

    G = U Uᵀ ∈ R^{K×K}      (pairwise inner products of client updates)
    c = U g  ∈ R^{K}        (inner products with the global-gradient estimate)

``U`` stacks the K flattened updates.  Everything downstream (the α solve,
Theorem-1 bound) is O(K²) and replicated.

Execution paths:
  * ``gram_and_cross``            — pure jnp (reference / small models).
  * ``gram_and_cross_chunked``    — lax.scan streaming over n-chunks, the
    memory-bound formulation mirrored by the Pallas kernel in
    ``repro.kernels.gram``.
  * the production call sites (``core.aggregation``, ``hier.gateway``, the
    fused round stages in ``hier.fused``) route through the backend-aware
    registry ``repro.kernels.ops.gram_and_cross`` — autotuned dispatch over
    compiled Pallas (TPU) / jit-compiled XLA (everywhere else) / this
    module's reference math.

Block composition (the hierarchical-aggregation identity, ``repro.hier``):
partition the fleet's K updates into P groups U = [U_1; …; U_P].  Then G is
the P×P block matrix with blocks ``G_gh = U_g U_hᵀ`` and c concatenates the
per-group ``c_g = U_g g`` — the Gram statistics compose *exactly*, so a
gateway can compute its diagonal block locally and the full-fleet (G, c) is
reassembled block-wise (:func:`merge_gram_blocks`) without ever re-touching
the parameter axis.  ``gram_block`` / ``gram_block_chunked`` compute one
block; the Pallas twin lives in ``repro.kernels.gram.gram_block_pallas``.
"""
from __future__ import annotations

from functools import partial
from typing import Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp


def gram_and_cross(updates: jax.Array, grad: jax.Array,
                   dtype: jnp.dtype = jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Compute ``(G, c)`` from stacked updates ``U (K, n)`` and gradient ``g (n,)``."""
    u = updates.astype(dtype)
    g = grad.astype(dtype)
    G = u @ u.T
    c = u @ g
    return G, c


@partial(jax.jit, static_argnames=("chunk",))
def gram_and_cross_chunked(updates: jax.Array, grad: jax.Array,
                           chunk: int = 1 << 16) -> Tuple[jax.Array, jax.Array]:
    """Streaming version: one pass over the parameter axis in ``chunk`` columns.

    Pads n to a multiple of ``chunk`` with zeros (exact: zero columns do not
    change inner products) and accumulates in f32.
    """
    K, n = updates.shape
    pad = (-n) % chunk
    u = jnp.pad(updates, ((0, 0), (0, pad)))
    g = jnp.pad(grad, (0, pad))
    steps = (n + pad) // chunk
    u = u.reshape(K, steps, chunk).transpose(1, 0, 2)   # (steps, K, chunk)
    g = g.reshape(steps, chunk)

    def body(carry, xs):
        G, c = carry
        uc, gc = xs
        uc32 = uc.astype(jnp.float32)
        G = G + uc32 @ uc32.T
        c = c + uc32 @ gc.astype(jnp.float32)
        return (G, c), None

    init = (jnp.zeros((K, K), jnp.float32), jnp.zeros((K,), jnp.float32))
    (G, c), _ = jax.lax.scan(body, init, (u, g))
    return G, c


def gram_block(ua: jax.Array, ub: jax.Array,
               dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """One off-diagonal Gram block ``G_ab = U_a U_bᵀ (K_a, K_b)``."""
    return ua.astype(dtype) @ ub.astype(dtype).T


@partial(jax.jit, static_argnames=("chunk",))
def gram_block_chunked(ua: jax.Array, ub: jax.Array,
                       chunk: int = 1 << 16) -> jax.Array:
    """Streaming ``U_a U_bᵀ``: one pass over the shared parameter axis."""
    Ka, n = ua.shape
    Kb, nb = ub.shape
    if n != nb:
        raise ValueError(f"block operands disagree on n: {n} vs {nb}")
    pad = (-n) % chunk
    a = jnp.pad(ua, ((0, 0), (0, pad)))
    b = jnp.pad(ub, ((0, 0), (0, pad)))
    steps = (n + pad) // chunk
    a = a.reshape(Ka, steps, chunk).transpose(1, 0, 2)
    b = b.reshape(Kb, steps, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        ac, bc = xs
        return acc + ac.astype(jnp.float32) @ bc.astype(jnp.float32).T, None

    out, _ = jax.lax.scan(body, jnp.zeros((Ka, Kb), jnp.float32), (a, b))
    return out


def merge_gram_blocks(diag: Sequence[jax.Array],
                      cross: Mapping[Tuple[int, int], jax.Array],
                      cross_terms: Sequence[jax.Array]
                      ) -> Tuple[jax.Array, jax.Array]:
    """Reassemble full-fleet ``(G, c)`` from per-group pieces.

    ``diag[g]`` is group g's local Gram block ``U_g U_gᵀ``; ``cross[(g, h)]``
    (g < h) is the off-diagonal block ``U_g U_hᵀ`` (the transpose fills
    (h, g) — G is symmetric by construction); ``cross_terms[g]`` is ``U_g g``.
    Group order fixes the row/column order of the result, so merging the
    groups of a :class:`repro.hier.Topology` in gateway order reproduces the
    flat-fleet :func:`gram_and_cross` exactly (tested, incl. uneven groups).
    """
    P = len(diag)
    if len(cross_terms) != P:
        raise ValueError(f"{P} diagonal blocks but {len(cross_terms)} "
                         "cross-term segments")
    rows = []
    for g in range(P):
        row = []
        for h in range(P):
            if g == h:
                blk = diag[g]
            elif g < h:
                blk = cross[(g, h)]
            else:
                blk = cross[(h, g)].T
            row.append(blk)
        rows.append(jnp.concatenate(row, axis=1))
    return jnp.concatenate(rows, axis=0), jnp.concatenate(list(cross_terms))


def blockwise_gram_and_cross(groups: Sequence[jax.Array], grad: jax.Array,
                             block_fn=None, diag_fn=None
                             ) -> Tuple[jax.Array, jax.Array]:
    """Compute full ``(G, c)`` from per-group update matrices via block
    composition — the reference for what a gateway tier computes in pieces.

    ``diag_fn(U_g, g) -> (G_gg, c_g)`` defaults to :func:`gram_and_cross`;
    ``block_fn(U_g, U_h) -> G_gh`` defaults to :func:`gram_block`.  Passing
    the chunked/Pallas variants exercises those paths (see tests).
    """
    diag_fn = diag_fn or gram_and_cross
    block_fn = block_fn or gram_block
    diag, cross_terms, cross = [], [], {}
    for g, ug in enumerate(groups):
        Gg, cg = diag_fn(ug, grad)
        diag.append(Gg)
        cross_terms.append(cg)
        for h in range(g + 1, len(groups)):
            cross[(g, h)] = block_fn(ug, groups[h])
    return merge_gram_blocks(diag, cross, cross_terms)


def gram_residual(G: jax.Array, c: jax.Array, alpha: jax.Array, beta) -> jax.Array:
    """Paper eq. (10) residual: ``r_k = ⟨Δ_k, ∇f + β Σ α_j Δ_j⟩ = c + β G α``.

    Zero at the optimum — used by tests and as a numerical health metric.
    """
    return c + beta * (G @ alpha)
