"""Gram-matrix / cross-term computation for contextual aggregation.

The contextual solve needs only two reductions over the (huge) parameter
axis (see DESIGN.md §2):

    G = U Uᵀ ∈ R^{K×K}      (pairwise inner products of client updates)
    c = U g  ∈ R^{K}        (inner products with the global-gradient estimate)

``U`` stacks the K flattened updates.  Everything downstream (the α solve,
Theorem-1 bound) is O(K²) and replicated.

Two execution paths:
  * ``gram_and_cross``            — pure jnp (reference / small models).
  * ``gram_and_cross_chunked``    — lax.scan streaming over n-chunks, the
    memory-bound formulation mirrored by the Pallas kernel in
    ``repro.kernels.gram`` (which ops.py dispatches to on TPU).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def gram_and_cross(updates: jax.Array, grad: jax.Array,
                   dtype: jnp.dtype = jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Compute ``(G, c)`` from stacked updates ``U (K, n)`` and gradient ``g (n,)``."""
    u = updates.astype(dtype)
    g = grad.astype(dtype)
    G = u @ u.T
    c = u @ g
    return G, c


@partial(jax.jit, static_argnames=("chunk",))
def gram_and_cross_chunked(updates: jax.Array, grad: jax.Array,
                           chunk: int = 1 << 16) -> Tuple[jax.Array, jax.Array]:
    """Streaming version: one pass over the parameter axis in ``chunk`` columns.

    Pads n to a multiple of ``chunk`` with zeros (exact: zero columns do not
    change inner products) and accumulates in f32.
    """
    K, n = updates.shape
    pad = (-n) % chunk
    u = jnp.pad(updates, ((0, 0), (0, pad)))
    g = jnp.pad(grad, (0, pad))
    steps = (n + pad) // chunk
    u = u.reshape(K, steps, chunk).transpose(1, 0, 2)   # (steps, K, chunk)
    g = g.reshape(steps, chunk)

    def body(carry, xs):
        G, c = carry
        uc, gc = xs
        uc32 = uc.astype(jnp.float32)
        G = G + uc32 @ uc32.T
        c = c + uc32 @ gc.astype(jnp.float32)
        return (G, c), None

    init = (jnp.zeros((K, K), jnp.float32), jnp.zeros((K,), jnp.float32))
    (G, c), _ = jax.lax.scan(body, init, (u, g))
    return G, c


def gram_residual(G: jax.Array, c: jax.Array, alpha: jax.Array, beta) -> jax.Array:
    """Paper eq. (10) residual: ``r_k = ⟨Δ_k, ∇f + β Σ α_j Δ_j⟩ = c + β G α``.

    Zero at the optimum — used by tests and as a numerical health metric.
    """
    return c + beta * (G @ alpha)
