"""Core contextual-aggregation library (the paper's contribution).

Public API:
  * flatten utilities  — pytree/vector conversion + last-layer scoping
  * gram               — Gram/cross reductions (jnp, chunked, Pallas-backed)
  * solve              — optimal α (context-dependent + expected bounds)
  * aggregation        — strategy registry (fedavg/fedprox/folb/contextual/…)
  * distributed        — shard_map SPMD forms (incl. hierarchical multi-pod)
"""
from .aggregation import (AggregatorConfig, aggregate, aggregate_contextual,
                          aggregate_contextual_expected, aggregate_fedavg,
                          aggregate_folb, available_aggregators,
                          register_aggregator)
from .distributed import (contextual_combine_sharded,
                          hierarchical_contextual_combine, sharded_combine,
                          sharded_gram_cross)
from .flatten import (scope_vector, select_scope, stacked_weighted_sum,
                      tree_add, tree_scale, tree_size, tree_sub,
                      tree_to_vector, tree_weighted_sum, vector_to_tree)
from .gram import (blockwise_gram_and_cross, gram_and_cross,
                   gram_and_cross_chunked, gram_block, gram_block_chunked,
                   gram_residual, merge_gram_blocks)
from .solve import (SolveConfig, bound_value, solve_alpha, solve_alpha_simple,
                    theorem1_reduction)

__all__ = [
    "AggregatorConfig", "aggregate", "aggregate_contextual",
    "aggregate_contextual_expected", "aggregate_fedavg", "aggregate_folb",
    "available_aggregators", "register_aggregator",
    "contextual_combine_sharded",
    "hierarchical_contextual_combine", "sharded_combine", "sharded_gram_cross",
    "scope_vector", "select_scope", "stacked_weighted_sum", "tree_add",
    "tree_scale", "tree_size", "tree_sub", "tree_to_vector",
    "tree_weighted_sum", "vector_to_tree", "blockwise_gram_and_cross",
    "gram_and_cross", "gram_and_cross_chunked", "gram_block",
    "gram_block_chunked", "gram_residual", "merge_gram_blocks",
    "SolveConfig", "bound_value",
    "solve_alpha", "solve_alpha_simple", "theorem1_reduction",
]
