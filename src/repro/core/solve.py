"""Optimal aggregation-weight solve (paper eq. 7-8, reformulated).

Stationarity of the context-dependent bound g(α) gives the K×K system

    β (U Uᵀ) α = −U ∇f        ⇔       β G α = −c

so   α* = −(1/β) G⁺ c.   We solve with Tikhonov-damped Cholesky (G is PSD by
construction; damping `ridge·tr(G)/K` keeps the solve well-posed when client
updates are nearly collinear — e.g. IID data late in training) and fall back
to an eigendecomposition pseudo-inverse when requested.

The expected-bound variant (§III-C) has stationarity

    (K/N) c + β K(K−1)/(N(N−1)) G α = 0
    ⇒ α* = −(1/β) · (N−1)/(K−1) · G⁺ c

i.e. the same solve scaled by (N−1)/(K−1) — implemented via ``expectation_scale``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SolveConfig:
    beta: float = 10.0              # smoothness constant; paper sets β = 1/lr
    ridge: float = 1e-6             # Tikhonov damping, relative to mean diag
    method: str = "cholesky"        # "cholesky" | "pinv"
    expectation_scale: float = 1.0  # (N-1)/(K-1) for the §III-C variant
    clip_norm: Optional[float] = None  # optional safety clip on ‖α‖ (beyond-paper)


def solve_alpha(G: jax.Array, c: jax.Array, cfg: SolveConfig) -> jax.Array:
    """Return α* minimising the context-dependent bound."""
    K = G.shape[0]
    scale = jnp.maximum(jnp.trace(G) / K, 1e-30)
    if cfg.method == "pinv":
        alpha = -jnp.linalg.pinv(G, rtol=1e-6) @ c / cfg.beta
    else:
        A = G + (cfg.ridge * scale) * jnp.eye(K, dtype=G.dtype)
        # PSD solve via Cholesky; jnp.linalg.solve is fine on CPU/TPU for K<=64
        alpha = -jnp.linalg.solve(A, c) / cfg.beta
    alpha = alpha * cfg.expectation_scale
    if cfg.clip_norm is not None:
        norm = jnp.linalg.norm(alpha)
        alpha = alpha * jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(norm, 1e-30))
    return alpha


@partial(jax.jit, static_argnames=("beta", "ridge"))
def solve_alpha_simple(G: jax.Array, c: jax.Array, beta: float, ridge: float = 1e-6) -> jax.Array:
    """Jit-friendly functional form used inside distributed train steps."""
    K = G.shape[0]
    scale = jnp.maximum(jnp.trace(G) / K, 1e-30)
    A = G + (ridge * scale) * jnp.eye(K, dtype=G.dtype)
    return -jnp.linalg.solve(A, c) / beta


def bound_value(G: jax.Array, c: jax.Array, alpha: jax.Array, beta) -> jax.Array:
    """The lower-bound function g(α) = ⟨∇f, Σα_kΔ_k⟩ + (β/2)‖Σα_kΔ_k‖²
    expressed through (G, c):  g(α) = cᵀα + (β/2) αᵀGα.  Negative at α*."""
    return c @ alpha + 0.5 * beta * alpha @ G @ alpha


def theorem1_reduction(G: jax.Array, alpha: jax.Array, beta) -> jax.Array:
    """Theorem 1 guaranteed loss reduction: (β/2)‖Σ α_k Δ_k‖² = (β/2) αᵀGα."""
    return 0.5 * beta * alpha @ G @ alpha
