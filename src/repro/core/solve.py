"""Optimal aggregation-weight solve (paper eq. 7-8, reformulated).

Stationarity of the context-dependent bound g(α) gives the K×K system

    β (U Uᵀ) α = −U ∇f        ⇔       β G α = −c

so   α* = −(1/β) G⁺ c.   We solve with Tikhonov-damped Cholesky (G is PSD by
construction; damping `ridge·tr(G)/K` keeps the solve well-posed when client
updates are nearly collinear — e.g. IID data late in training) and fall back
to an eigendecomposition pseudo-inverse when requested.

The expected-bound variant (§III-C) has stationarity

    (K/N) c + β K(K−1)/(N(N−1)) G α = 0
    ⇒ α* = −(1/β) · (N−1)/(K−1) · G⁺ c

i.e. the same solve scaled by (N−1)/(K−1) — implemented via ``expectation_scale``.

``sum_to`` switches to the mass-conserving variant used by the hierarchical
cloud stage (``repro.hier``): minimise g(α) subject to Σ α_k = s, via the KKT
system

    [ β(G + ρI)   1 ] [α]   [−c]
    [    1ᵀ       0 ] [λ] = [ s ].

When the solve's members are *already β-calibrated tier combinations* (each
carries its own 1/β factor), the unconstrained restricted optimum
systematically shrinks the aggregate step — the restricted span underprices
alignment — so the parent tier only reallocates mass, never rescales it.
Every corner γ = e_g is feasible, so the constrained bound is at least as
good as promoting any single child's combination (or any convex mix, e.g.
hier-FedAvg's count weighting).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SolveConfig:
    beta: float = 10.0              # smoothness constant; paper sets β = 1/lr
    ridge: float = 1e-6             # Tikhonov damping, relative to mean diag
    method: str = "cholesky"        # "cholesky" | "pinv"
    expectation_scale: float = 1.0  # (N-1)/(K-1) for the §III-C variant
    clip_norm: Optional[float] = None  # optional safety clip on ‖α‖ (beyond-paper)
    sum_to: Optional[float] = None  # mass-conserving Σα = s constraint (the
                                    # hierarchical parent-tier solve; see
                                    # module docstring — overrides
                                    # expectation_scale, which would break it)

    def __post_init__(self):
        if self.sum_to is not None and self.clip_norm is not None:
            raise ValueError("clip_norm cannot be combined with sum_to: "
                             "rescaling α would silently break the Σα mass "
                             "constraint")


def solve_alpha(G: jax.Array, c: jax.Array, cfg: SolveConfig) -> jax.Array:
    """Return α* minimising the context-dependent bound."""
    K = G.shape[0]
    scale = jnp.maximum(jnp.trace(G) / K, 1e-30)
    if cfg.sum_to is not None:
        A = cfg.beta * (G + (cfg.ridge * scale) * jnp.eye(K, dtype=G.dtype))
        ones = jnp.ones((K,), G.dtype)
        kkt = jnp.block([[A, ones[:, None]],
                         [ones[None, :], jnp.zeros((1, 1), G.dtype)]])
        rhs = jnp.concatenate([-c, jnp.full((1,), cfg.sum_to, G.dtype)])
        alpha = jnp.linalg.solve(kkt, rhs)[:K]
    elif cfg.method == "pinv":
        alpha = -jnp.linalg.pinv(G, rtol=1e-6) @ c / cfg.beta
        alpha = alpha * cfg.expectation_scale
    else:
        A = G + (cfg.ridge * scale) * jnp.eye(K, dtype=G.dtype)
        # PSD solve via Cholesky; jnp.linalg.solve is fine on CPU/TPU for K<=64
        alpha = -jnp.linalg.solve(A, c) / cfg.beta
        alpha = alpha * cfg.expectation_scale
    if cfg.clip_norm is not None:
        norm = jnp.linalg.norm(alpha)
        alpha = alpha * jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(norm, 1e-30))
    return alpha


@partial(jax.jit, static_argnames=("beta", "ridge"))
def solve_alpha_simple(G: jax.Array, c: jax.Array, beta: float, ridge: float = 1e-6) -> jax.Array:
    """Jit-friendly functional form used inside distributed train steps."""
    K = G.shape[0]
    scale = jnp.maximum(jnp.trace(G) / K, 1e-30)
    A = G + (ridge * scale) * jnp.eye(K, dtype=G.dtype)
    return -jnp.linalg.solve(A, c) / beta


def bound_value(G: jax.Array, c: jax.Array, alpha: jax.Array, beta) -> jax.Array:
    """The lower-bound function g(α) = ⟨∇f, Σα_kΔ_k⟩ + (β/2)‖Σα_kΔ_k‖²
    expressed through (G, c):  g(α) = cᵀα + (β/2) αᵀGα.  Negative at α*."""
    return c @ alpha + 0.5 * beta * alpha @ G @ alpha


def theorem1_reduction(G: jax.Array, alpha: jax.Array, beta) -> jax.Array:
    """Theorem 1 guaranteed loss reduction: (β/2)‖Σ α_k Δ_k‖² = (β/2) αᵀGα."""
    return 0.5 * beta * alpha @ G @ alpha
