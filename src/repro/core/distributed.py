"""Distributed (SPMD) forms of the contextual aggregation.

These helpers are written to be called INSIDE ``shard_map`` (or any context
with named mesh axes).  The data layout follows DESIGN.md §3:

  * each cohort (FL client) k lives on one slice of the ``data`` axis and
    holds its own update vector, sharded over the ``model`` axis;
  * the Gram matrix needs all-pairs inner products → ``all_gather`` the
    (scoped) update slices over ``data``, contract locally, ``psum`` over
    ``model``;
  * the combine is an α-weighted ``psum`` over ``data`` — the same wire
    bytes as FedAvg's all-reduce.

The hierarchical variant adds a second contextual stage across the ``pod``
axis for the multi-pod mesh (edge-site aggregation → cross-site aggregation).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .solve import solve_alpha_simple


def sharded_gram_cross(u_shard: jax.Array, g_shard: jax.Array,
                       data_axis: str = "data", model_axis: Optional[str] = "model"
                       ) -> Tuple[jax.Array, jax.Array]:
    """Per-device inputs: this cohort's update slice ``u_shard (n_m,)`` and the
    global-gradient-estimate slice ``g_shard (n_m,)`` for this model shard.

    Returns the replicated ``G (K, K)`` and ``c (K,)`` (f32).
    """
    u32 = u_shard.astype(jnp.float32)
    g32 = g_shard.astype(jnp.float32)
    U_all = lax.all_gather(u32, data_axis)          # (K, n_m)
    G = U_all @ U_all.T                             # local partial Gram
    c = U_all @ g32
    if model_axis is not None:
        G = lax.psum(G, model_axis)
        c = lax.psum(c, model_axis)
    return G, c


def sharded_combine(u_shard: jax.Array, alpha: jax.Array,
                    data_axis: str = "data") -> jax.Array:
    """α-weighted combine: Σ_k α_k u_k, returned on every device (psum)."""
    k = lax.axis_index(data_axis)
    return lax.psum(alpha[k].astype(u_shard.dtype) * u_shard, data_axis)


def contextual_combine_sharded(u_shard: jax.Array, g_shard: jax.Array,
                               beta: float, ridge: float = 1e-6,
                               data_axis: str = "data",
                               model_axis: Optional[str] = "model",
                               gram_u_shard: Optional[jax.Array] = None,
                               gram_g_shard: Optional[jax.Array] = None
                               ) -> Tuple[jax.Array, jax.Array]:
    """Full contextual aggregation, SPMD: gram → K×K solve (replicated) →
    weighted combine.  If ``gram_u_shard``/``gram_g_shard`` are given, the α
    solve uses those (e.g. the paper's last-layer slice) while the combine
    applies α to the full ``u_shard``.

    Returns ``(combined_update_shard, alpha)``.
    """
    gu = u_shard if gram_u_shard is None else gram_u_shard
    gg = g_shard if gram_g_shard is None else gram_g_shard
    G, c = sharded_gram_cross(gu, gg, data_axis, model_axis)
    alpha = solve_alpha_simple(G, c, beta, ridge)
    return sharded_combine(u_shard, alpha, data_axis), alpha


def hierarchical_contextual_combine(u_shard: jax.Array, g_shard: jax.Array,
                                    beta: float, ridge: float = 1e-6,
                                    pod_axis: str = "pod",
                                    data_axis: str = "data",
                                    model_axis: Optional[str] = "model"
                                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Two-stage aggregation for multi-pod meshes (DESIGN.md §3):

      stage 1 — contextual combine within each pod over ``data`` (K cohorts);
      stage 2 — contextual combine across pods over ``pod`` (P pod-updates),
                using the pod-mean gradient estimate.

    Returns ``(combined_update_shard, alpha_intra (K,), alpha_pods (P,))``.
    Stage-2 Gram is P×P (P = #pods) — negligible compute, one extra
    cross-pod collective round.
    """
    intra, alpha_intra = contextual_combine_sharded(
        u_shard, g_shard, beta, ridge, data_axis, model_axis)
    # Cross-pod: each pod now holds one aggregated update (replicated over
    # data within the pod). Gradient estimate averaged across pods.
    g_global = lax.pmean(g_shard.astype(jnp.float32), pod_axis)
    G2, c2 = sharded_gram_cross(intra.astype(jnp.float32), g_global,
                                data_axis=pod_axis, model_axis=model_axis)
    # stage-2 gram also needs reduction over the data axis (the update slices
    # are replicated over data, so mean keeps magnitudes consistent)
    if model_axis is not None:
        pass  # already psum'd over model in sharded_gram_cross
    alpha_pods = solve_alpha_simple(G2, c2, beta, ridge)
    combined = sharded_combine(intra, alpha_pods, pod_axis)
    return combined, alpha_intra, alpha_pods
