"""Adversarial & churn robustness suite (PR 8).

Three composable pieces:

  * :mod:`.attacks`    — ``AttackModel`` adversaries (byzantine_gauss,
    sign_flip, scaled_update, label_flip), per-device adversary assignment
    on the :class:`~repro.edge.profiles.Fleet`, and the stacked-corruption
    helpers the three simulation loops share.
  * :mod:`.churn`      — time-scheduled mass-dropout/rejoin waves layered
    on the PR-1 event scheduler.
  * :mod:`.gramstats`  — clipping + median-of-means/trimmed pooling on the
    contextual (G, c) statistics, usable inside the fused/streamed jit
    stages; :mod:`.aggregators` registers the flat robust variants
    (``contextual_clipped``, ``contextual_mom``, ``krum``,
    ``coordinate_median``) in ``core.aggregation``.

Importing this package registers the robust aggregators.
"""
from . import aggregators as _aggregators  # noqa: F401 (registry side effect)
from .attacks import (AttackModel, ByzantineGauss, LabelFlip, ScaledUpdate,
                      SignFlip, assign_adversaries, available_attacks,
                      corrupt_one_jit, corrupt_stacked, corrupt_stacked_jit,
                      get_attack, poison_labels)
from .churn import ChurnSchedule, ChurnWave, churn_schedule
from .gramstats import RobustConfig, clip_scales, pool_cross, robustify

__all__ = [
    "AttackModel", "ByzantineGauss", "SignFlip", "ScaledUpdate", "LabelFlip",
    "assign_adversaries", "available_attacks", "corrupt_one_jit",
    "corrupt_stacked", "corrupt_stacked_jit", "get_attack", "poison_labels",
    "ChurnSchedule", "ChurnWave", "churn_schedule",
    "RobustConfig", "clip_scales", "pool_cross", "robustify",
]
