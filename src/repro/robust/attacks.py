"""Adversarial client models for the edge runtime.

An attack is a frozen, hashable dataclass implementing the
:class:`AttackModel` protocol: ``corrupt(delta, grad, key)`` maps one
client's honest (update, gradient) pytrees to the adversarial pair it
reports instead.  Hashability matters — attacks ride inside
``ServerConfig`` (an ``lru_cache`` key for the compiled round function) and
are jit-static, so the sync path corrupts *inside* the compiled round.

Taxonomy (cf. "FL Aggregation: New Robust Algorithms with Guarantees",
arXiv:2205.10864):

  * ``byzantine_gauss`` — replaces BOTH the update and the gradient report
    with Gaussian noise scaled to ``scale ×`` the honest norm.  Corrupting
    the gradient too is what makes plain contextual degrade: adversarial
    gradient reports poison the ĝ estimate and through it every honest
    client's c-term, not just the attacker's row.
  * ``sign_flip``      — reports ``−factor·Δ, −factor·g`` (directed attack).
  * ``scaled_update``  — model-replacement boost ``factor·Δ`` (gradient
    report left honest — the stealthier variant clipping is built for).
  * ``label_flip``     — data poisoning: ``corrupts_data`` attacks leave the
    update path alone and instead flip the malicious shards' training
    labels before the run (:func:`poison_labels`).

Adversary placement is a seeded draw on the :class:`~repro.edge.profiles.Fleet`
(:func:`assign_adversaries` → ``fleet.malicious``), so every runtime — sync,
async, hierarchical — sees the same compromised devices for a given
(fleet, fraction, seed).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import lru_cache
from typing import Any, ClassVar, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from ..data.federated import FederatedDataset
from ..edge.profiles import Fleet

Pytree = Any


@runtime_checkable
class AttackModel(Protocol):
    """What the runtimes require of an adversary."""
    name: str
    corrupts_data: bool

    def corrupt(self, delta: Pytree, grad: Pytree,
                key: jax.Array) -> Tuple[Pytree, Pytree]:
        ...


def _tree_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)) + 1e-30)


def _noise_like(tree: Pytree, key: jax.Array, target_norm: jax.Array
                ) -> Pytree:
    """Gaussian pytree with global norm ``target_norm`` (direction uniform
    on the sphere — carries zero signal, maximal ĝ damage per byte)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noise = [jax.random.normal(k, l.shape, jnp.float32)
             for k, l in zip(keys, leaves)]
    nn = _tree_norm(noise)
    scaled = [(n * (target_norm / nn)).astype(l.dtype)
              for n, l in zip(noise, leaves)]
    return jax.tree_util.tree_unflatten(treedef, scaled)


@dataclass(frozen=True)
class ByzantineGauss:
    """Noise replacement at ``scale ×`` the honest norms, on update AND
    gradient report."""
    scale: float = 10.0
    name: ClassVar[str] = "byzantine_gauss"
    corrupts_data: ClassVar[bool] = False

    def corrupt(self, delta, grad, key):
        kd, kg = jax.random.split(key)
        return (_noise_like(delta, kd, self.scale * _tree_norm(delta)),
                _noise_like(grad, kg, self.scale * _tree_norm(grad)))


@dataclass(frozen=True)
class SignFlip:
    """Reports the negated (optionally boosted) update and gradient."""
    factor: float = 1.0
    name: ClassVar[str] = "sign_flip"
    corrupts_data: ClassVar[bool] = False

    def corrupt(self, delta, grad, key):
        del key
        neg = lambda l: (-self.factor * l.astype(jnp.float32)).astype(l.dtype)
        return (jax.tree_util.tree_map(neg, delta),
                jax.tree_util.tree_map(neg, grad))


@dataclass(frozen=True)
class ScaledUpdate:
    """Model-replacement boost: ``factor × Δ``, honest gradient report."""
    factor: float = 10.0
    name: ClassVar[str] = "scaled_update"
    corrupts_data: ClassVar[bool] = False

    def corrupt(self, delta, grad, key):
        del key
        boost = lambda l: (self.factor * l.astype(jnp.float32)).astype(l.dtype)
        return jax.tree_util.tree_map(boost, delta), grad


@dataclass(frozen=True)
class LabelFlip:
    """Data poisoning: training labels of malicious shards are flipped to
    ``(num_classes − 1) − y`` before the run (:func:`poison_labels`); the
    update path itself is honest."""
    name: ClassVar[str] = "label_flip"
    corrupts_data: ClassVar[bool] = True

    def corrupt(self, delta, grad, key):
        del key
        return delta, grad


_ATTACKS = {"byzantine_gauss": ByzantineGauss, "sign_flip": SignFlip,
            "scaled_update": ScaledUpdate, "label_flip": LabelFlip}


def get_attack(name: str, **kw) -> AttackModel:
    if name not in _ATTACKS:
        raise KeyError(f"unknown attack '{name}'; have {sorted(_ATTACKS)}")
    return _ATTACKS[name](**kw)


def available_attacks() -> Tuple[str, ...]:
    return tuple(sorted(_ATTACKS))


# ---------------------------------------------------------------------------
# adversary placement + corruption helpers shared by the three runtimes
# ---------------------------------------------------------------------------

def assign_adversaries(fleet: Fleet, frac: float, seed: int = 0) -> Fleet:
    """Seeded draw of ``round(frac · N)`` compromised devices onto the fleet
    (``fleet.malicious``).  Deterministic per (fleet size, frac, seed) and
    independent of the data/selection RNGs, like the slow-cohort draw in
    :func:`~repro.edge.profiles.bimodal_fleet`."""
    if not (0.0 <= frac < 1.0):
        raise ValueError(f"malicious fraction must be in [0, 1), got {frac}")
    m = int(round(frac * fleet.num_devices))
    if m == 0:
        return dataclasses.replace(fleet, malicious=())
    rng = np.random.RandomState(seed)
    ids = rng.choice(fleet.num_devices, m, replace=False)
    return dataclasses.replace(fleet,
                               malicious=tuple(sorted(int(i) for i in ids)))


def poison_labels(dataset: FederatedDataset, malicious) -> FederatedDataset:
    """Label-flip poisoning of the malicious device shards: ``y ← (C−1) − y``
    on train labels only (test set stays clean — accuracy is measured
    against the truth the attacker is trying to move the model away from)."""
    mal = np.asarray(sorted(set(int(i) for i in malicious)), np.int64)
    if mal.size == 0:
        return dataset
    y = np.array(dataset.y)
    y[mal] = (dataset.num_classes - 1) - y[mal]
    return FederatedDataset(x=dataset.x, y=y, mask=dataset.mask,
                            test_x=dataset.test_x, test_y=dataset.test_y,
                            num_classes=dataset.num_classes)


def corrupt_stacked(attack: AttackModel, deltas: Pytree, grads: Pytree,
                    mask: jax.Array, key: jax.Array
                    ) -> Tuple[Pytree, Pytree]:
    """Apply ``attack`` to the masked rows of stacked (K-leading) update /
    gradient pytrees: vmapped corruption + a where-select, so honest rows
    are bit-identical to the clean path.  Pure jax — runs inside the sync
    round jit and is itself jitted for the eager hier/async paths."""
    K = mask.shape[0]
    keys = jax.random.split(key, K)
    cd, cg = jax.vmap(lambda d, g, k: attack.corrupt(d, g, k)
                      )(deltas, grads, keys)

    def mix(c, o):
        m = jnp.reshape(mask, (-1,) + (1,) * (o.ndim - 1))
        return jnp.where(m, c, o)

    return (jax.tree_util.tree_map(mix, cd, deltas),
            jax.tree_util.tree_map(mix, cg, grads))


@lru_cache(maxsize=16)
def _corrupt_stacked_jit(attack: AttackModel):
    return jax.jit(lambda d, g, m, k: corrupt_stacked(attack, d, g, m, k))


def corrupt_stacked_jit(attack: AttackModel, deltas, grads, mask, key):
    """Compiled :func:`corrupt_stacked` (one cache entry per attack, one
    compile per cohort shape) for the eager hier call site."""
    return _corrupt_stacked_jit(attack)(deltas, grads, mask, key)


@lru_cache(maxsize=16)
def _corrupt_one_jit(attack: AttackModel):
    return jax.jit(lambda d, g, k: attack.corrupt(d, g, k))


def corrupt_one_jit(attack: AttackModel, delta, grad, key):
    """Compiled single-client corruption for the async per-arrival path."""
    return _corrupt_one_jit(attack)(delta, grad, key)
