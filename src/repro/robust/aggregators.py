"""Robust aggregation strategies for the flat registry.

Registered in ``core.aggregation`` (same signature as every aggregator):

  * ``contextual_clipped`` — the paper's contextual solve on clipped Gram
    statistics (``RobustConfig(pool="mean")``: norm clipping only).
  * ``contextual_mom``     — clipping + median-of-means pooling of the
    c cross-terms (the full :mod:`~repro.robust.gramstats` defense).
  * ``krum``               — (multi-)Krum [Blanchard et al.] selection,
    computed entirely from the Gram matrix:
    ``‖Δ_i − Δ_j‖² = G_ii + G_jj − 2 G_ij``.
  * ``coordinate_median``  — coordinate-wise median of the stacked updates.

The contextual variants advertise ``grad_stack = True``: the round builder
passes the *stacked per-client gradient reports* as ``grad_tree`` (instead
of their pre-averaged ĝ), so the (K, J) cross matrix the pooling defends is
actually available.  Robust knobs ride on ``AggregatorConfig.robust`` (an
opaque field to ``core`` — this module owns the type).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.aggregation import (AggregatorConfig, _stacked_to_matrix,
                                register_aggregator)
from ..core.flatten import stacked_weighted_sum, tree_add, vector_to_tree
from ..core.gram import gram_residual
from ..core.solve import bound_value, solve_alpha, theorem1_reduction
from .gramstats import RobustConfig, robustify

_CLIP_ONLY = RobustConfig(clip=2.0, pool="mean")
_CLIP_MOM = RobustConfig(clip=2.0, pool="mom")


def _robust_cfg(cfg: AggregatorConfig, default: RobustConfig) -> RobustConfig:
    rob = getattr(cfg, "robust", None)
    return rob if isinstance(rob, RobustConfig) else default


def _contextual_robust(params, stacked_updates, grad_tree,
                       cfg: AggregatorConfig, rob: RobustConfig):
    U = _stacked_to_matrix(stacked_updates, cfg.gram_scope)
    Gm = _stacked_to_matrix(grad_tree, cfg.gram_scope)
    G = U @ U.T
    C = U @ Gm.T                              # (K, J) per-client cross-terms
    w = jnp.full((C.shape[1],), 1.0 / C.shape[1], U.dtype)
    Gr, cr, s = robustify(G, C, w, rob)
    alpha = solve_alpha(Gr, cr, cfg.solve)
    eff = s * alpha                           # combine uses the clipped rows
    new = tree_add(params, stacked_weighted_sum(stacked_updates, eff))
    beta = cfg.solve.beta
    info = {
        "alpha": eff,
        "clip_scale": s,
        "bound": bound_value(Gr, cr, alpha, beta),
        "theorem1_reduction": theorem1_reduction(Gr, alpha, beta),
        "stationarity_residual": jnp.linalg.norm(
            gram_residual(Gr, cr, alpha, beta)),
        "gram_diag": jnp.diag(Gr),
    }
    return new, info


def aggregate_contextual_clipped(params, stacked_updates, grad_tree, cfg):
    return _contextual_robust(params, stacked_updates, grad_tree, cfg,
                              _robust_cfg(cfg, _CLIP_ONLY))


def aggregate_contextual_mom(params, stacked_updates, grad_tree, cfg):
    return _contextual_robust(params, stacked_updates, grad_tree, cfg,
                              _robust_cfg(cfg, _CLIP_MOM))


aggregate_contextual_clipped.grad_stack = True
aggregate_contextual_mom.grad_stack = True


def aggregate_krum(params, stacked_updates, grad_tree, cfg):
    """Multi-Krum from G only: score_i = Σ of the K−f−2 smallest squared
    distances to other updates; average the K−f lowest-scoring clients.
    Needs no gradient estimate at all."""
    rob = _robust_cfg(cfg, RobustConfig())
    U = _stacked_to_matrix(stacked_updates, cfg.gram_scope)
    K = U.shape[0]
    f = rob.krum_f if rob.krum_f is not None else max(1, -(-K // 5))
    f = min(f, max(K - 3, 0))
    nb = max(1, K - f - 2)
    m_sel = max(1, K - f)
    G = U @ U.T
    d = jnp.diag(G)
    D2 = jnp.maximum(d[:, None] + d[None, :] - 2.0 * G, 0.0)
    # the self-distance is exactly 0 and always the row minimum, so the
    # nb nearest *other* neighbors are sort positions 1..nb
    scores = jnp.sum(jnp.sort(D2, axis=1)[:, 1:nb + 1], axis=1)
    sel = jnp.argsort(scores)[:m_sel]
    alpha = jnp.zeros((K,), U.dtype).at[sel].set(1.0 / m_sel)
    new = tree_add(params, stacked_weighted_sum(stacked_updates, alpha))
    return new, {"alpha": alpha, "krum_scores": scores, "krum_f": f}


def aggregate_coordinate_median(params, stacked_updates, grad_tree, cfg):
    """Coordinate-wise median of the stacked updates (applied full-width —
    a median is not a weighted row sum, so gram_scope does not apply)."""
    U = _stacked_to_matrix(stacked_updates, None)
    med = jnp.median(U, axis=0)
    new = tree_add(params, vector_to_tree(med, params))
    K = U.shape[0]
    return new, {"alpha": jnp.full((K,), 1.0 / K, U.dtype)}


register_aggregator("contextual_clipped", aggregate_contextual_clipped)
register_aggregator("contextual_mom", aggregate_contextual_mom)
register_aggregator("krum", aggregate_krum)
register_aggregator("coordinate_median", aggregate_coordinate_median)
