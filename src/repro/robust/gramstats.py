"""Robust statistics on the contextual (G, c) slots.

Every contextual solve in the repo — flat registry, fused tier stages,
streamed accumulated statistics — consumes the pair

    G = U Uᵀ   (K×K update Gram),    c_k = ⟨Δ_k, ĝ⟩,

and ĝ is itself a mean of per-client gradient reports, so c is a row-mean
of the cross matrix ``C = U Gᵀ`` (``C[k, j] = ⟨Δ_k, g_j⟩``).  Both slots are
where a Byzantine client does its damage:

  * a scaled/noised **update** inflates row/column k of G and row k of C
    (and through α ∝ −G⁻¹c, the whole solve);
  * a corrupted **gradient report** poisons every client's c_k through the
    mean over columns j — the honest clients' prices, not just the
    attacker's.

:func:`robustify` defends both, purely in K-dimensional statistics space
(never touching the parameter axis, so it composes with the streamed
engine's accumulated ``C = D GMᵀ`` exactly as with the fused dense path):

  * **clipping** — per-client scales ``s_k = min(1, τ/‖Δ_k‖)`` with
    ``τ = clip × median ‖Δ‖`` read off ``diag G``; ``G ← s sᵀ ⊙ G``,
    ``C ← diag(s) C``.  The caller applies ``α_eff = s ⊙ α`` so the
    combine uses the *clipped* updates the solve priced.
  * **pooling** — c_k is re-estimated from row k of the (clipped) cross
    matrix with median-of-means over index buckets or a trimmed mean,
    instead of the poisoning-prone plain mean over gradient columns.

Breakdown point: MoM with B buckets tolerates < B/2 poisoned buckets; the
auto default (largest odd ``B <= J``, i.e. singleton buckets — a straight
column median) survives any f < 50% of gradient columns poisoned, the best
the family offers at round-cohort sizes.  The trimmed mean tolerates
f < trim_frac.  With defenses disabled
(``clip=None, pool="mean"``) :func:`robustify` is an exact identity on
(G, c) — tested.

All functions are pure jax with static shapes, usable inside the fused /
streamed jit stages; :class:`RobustConfig` is frozen and hashable so it can
join shape-keyed stage-cache keys and ``ServerConfig`` lru_cache keys.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


@dataclass(frozen=True)
class RobustConfig:
    """Knobs of the robustified contextual statistics (and the krum
    baseline's f parameter).  Frozen + hashable by design: instances key
    compiled-stage caches."""
    clip: Optional[float] = 2.0   # τ = clip × median‖Δ‖; None disables
    pool: str = "mom"             # c-pooling over gradient columns:
                                  #   "mean" | "mom" | "trimmed"
    mom_buckets: int = 0          # 0 → auto: largest odd B <= J (a
                                  #   straight column median)
    trim_frac: float = 0.25       # per-side trim fraction for "trimmed"
    krum_f: Optional[int] = None  # krum: assumed #byzantine (None → ⌈0.2K⌉)

    def __post_init__(self):
        if self.pool not in ("mean", "mom", "trimmed"):
            raise ValueError(f"pool must be mean|mom|trimmed, got "
                             f"'{self.pool}'")
        if self.clip is not None and self.clip <= 0:
            raise ValueError(f"clip must be positive or None, got {self.clip}")
        if not (0.0 <= self.trim_frac < 0.5):
            raise ValueError(f"trim_frac must be in [0, 0.5), got "
                             f"{self.trim_frac}")
        if self.mom_buckets < 0:
            raise ValueError(f"mom_buckets must be >= 0, got "
                             f"{self.mom_buckets}")

    @property
    def enabled(self) -> bool:
        return self.clip is not None or self.pool != "mean"


def clip_scales(G: jax.Array, cfg: RobustConfig) -> jax.Array:
    """Per-client clip scales from ``diag G`` alone: ``s_k = min(1, τ/‖Δ_k‖)``
    with ``τ = clip × median ‖Δ‖``.  Ones when clipping is disabled."""
    norms = jnp.sqrt(jnp.maximum(jnp.diag(G), 0.0))
    if cfg.clip is None:
        return jnp.ones_like(norms)
    tau = cfg.clip * jnp.median(norms)
    return jnp.minimum(1.0, tau / jnp.maximum(norms, _EPS))


def pool_cross(C: jax.Array, w: jax.Array, cfg: RobustConfig) -> jax.Array:
    """Robust row-pooling of the (K, J) cross matrix over gradient columns.

    ``"mean"`` is the plain estimate ``C @ w`` (w = the ĝ mixing weights);
    the robust pools assume near-uniform weights — true at the device tiers
    where they are deployed (every participant reports one gradient) — and
    estimate the row location ignoring up to their breakdown point of
    poisoned columns.  Static shapes throughout (J is a trace-time int)."""
    J = C.shape[1]
    if cfg.pool == "mean" or J < 3:
        return C @ w
    if cfg.pool == "trimmed":
        t = int(cfg.trim_frac * J)
        if J - 2 * t < 1:
            return C @ w
        Cs = jnp.sort(C, axis=1)
        return jnp.mean(Cs[:, t:J - t], axis=1)
    # median-of-means over index buckets j % B (bucket membership must not
    # depend on values, or an adaptive attacker chooses its bucket).  Auto B
    # is the largest odd number <= J — singleton buckets, i.e. a straight
    # column median: breakdown scales with B, and the variance reduction of
    # larger buckets only pays off for J far beyond a round cohort's size.
    # Odd keeps the median a true order statistic (an even-count median
    # averages the two middle values, letting one poisoned bucket leak in
    # right at the breakdown margin).
    B = cfg.mom_buckets if cfg.mom_buckets > 0 else (J if J % 2 else J - 1)
    B = min(B, J)
    ids = jnp.arange(J) % B
    sums = jnp.zeros((C.shape[0], B), C.dtype).at[:, ids].add(C)
    cnts = jnp.zeros((B,), C.dtype).at[ids].add(1.0)
    return jnp.median(sums / cnts, axis=1)


def robustify(G: jax.Array, C: jax.Array, w: jax.Array, cfg: RobustConfig
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Robustified ``(G', c', s)`` for a contextual solve.

    ``C`` is either the (K, J) cross matrix (rows: updates, columns:
    per-client gradient reports — the pooling case) or an already-mixed
    (K,) c vector (gradient pre-pass: only clipping applies).  ``w`` are
    the ĝ mixing weights over columns.  The caller must combine with
    ``α_eff = s ⊙ α`` so the applied step uses the clipped updates the
    solve priced; with defenses off this is the exact identity
    ``(G, C @ w, 1)``."""
    s = clip_scales(G, cfg)
    Gr = G * jnp.outer(s, s)
    if C.ndim == 1:
        return Gr, s * C, s
    return Gr, pool_cross(s[:, None] * C, w, cfg), s
