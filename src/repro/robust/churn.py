"""Churn waves: time-scheduled mass-dropout and rejoin on the event runtime.

A :class:`ChurnWave` takes a seeded ``fraction`` of the fleet offline for a
virtual-time window ``[start, end)`` — a regional outage, an OS-update
wave, a diurnal coverage dip.  A :class:`ChurnSchedule` stacks waves and is
plugged into :class:`~repro.edge.events.EventScheduler` (the ``churn=``
constructor argument): any task *dispatched* while its device is inside an
active wave terminates as a DROPOUT.  Availability collapses when a wave
starts and recovers the moment it ends — no persistent state, so rejoining
devices pick up normally on their next dispatch.

Determinism: wave membership is a pure seeded draw; the scheduler consumes
its dropout-coin / duration RNG draws exactly as in the churn-free run and
only *overrides the outcome*, so the full event trace remains a pure
function of (fleet, churn schedule, seed) — the property the PR-8
determinism test pins on both hier engines.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import FrozenSet, Tuple

import numpy as np


@dataclass(frozen=True)
class ChurnWave:
    start: float                 # virtual seconds, inclusive
    end: float                   # virtual seconds, exclusive
    fraction: float              # of the fleet taken offline
    seed: int = 0                # membership draw

    def __post_init__(self):
        if self.end <= self.start:
            raise ValueError(f"wave end must exceed start, got "
                             f"[{self.start}, {self.end})")
        if not (0.0 < self.fraction <= 1.0):
            raise ValueError(f"wave fraction must be in (0, 1], got "
                             f"{self.fraction}")

    def active(self, t: float) -> bool:
        return self.start <= t < self.end


@lru_cache(maxsize=256)
def _wave_members(wave: ChurnWave, num_devices: int) -> FrozenSet[int]:
    m = int(round(wave.fraction * num_devices))
    if m >= num_devices:
        return frozenset(range(num_devices))
    rng = np.random.RandomState(wave.seed)
    return frozenset(int(i) for i in rng.choice(num_devices, m, replace=False))


@lru_cache(maxsize=256)
def _wave_member_mask(wave: ChurnWave, num_devices: int) -> np.ndarray:
    """Boolean lookup of :func:`_wave_members` (vectorized membership)."""
    mask = np.zeros(num_devices, bool)
    mask[list(_wave_members(wave, num_devices))] = True
    return mask


@dataclass(frozen=True)
class ChurnSchedule:
    """Hashable stack of waves over a fleet of ``num_devices``.  The duck
    interface the scheduler consumes is just :meth:`offline`."""
    num_devices: int
    waves: Tuple[ChurnWave, ...] = field(default_factory=tuple)

    def offline(self, device_id: int, t: float) -> bool:
        return any(w.active(t) and device_id in _wave_members(
            w, self.num_devices) for w in self.waves)

    def offline_mask(self, device_ids: np.ndarray,
                     times: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`offline` over parallel (device, dispatch-time)
        arrays — the batch-dispatch path asks one question per cohort instead
        of one per device.  Same membership draws, same answer element-wise
        (tested against the scalar path)."""
        ids = np.asarray(device_ids, np.int64)
        ts = np.asarray(times, np.float64)
        out = np.zeros(ids.shape, bool)
        for w in self.waves:
            active = (ts >= w.start) & (ts < w.end)
            if not active.any():
                continue
            out |= active & _wave_member_mask(w, self.num_devices)[ids]
        return out

    def members(self, wave_idx: int) -> FrozenSet[int]:
        return _wave_members(self.waves[wave_idx], self.num_devices)


def churn_schedule(profile: str, num_devices: int, t_end: float,
                   seed: int = 0) -> ChurnSchedule:
    """Canonical profiles, parameterized by the run's expected virtual span
    ``t_end`` (callers typically measure a clean run first):

      * ``"none"``     — empty schedule,
      * ``"wave"``     — 50% of the fleet offline over the middle fifth,
      * ``"blackout"`` — 90% offline over a short early window (the
        availability-collapse-and-recover stress),
      * ``"rolling"``  — two staggered 40% waves with disjoint seeds.
    """
    if t_end <= 0:
        raise ValueError(f"t_end must be positive, got {t_end}")
    if profile == "none":
        return ChurnSchedule(num_devices, ())
    if profile == "wave":
        return ChurnSchedule(num_devices, (
            ChurnWave(0.4 * t_end, 0.6 * t_end, 0.5, seed),))
    if profile == "blackout":
        return ChurnSchedule(num_devices, (
            ChurnWave(0.2 * t_end, 0.35 * t_end, 0.9, seed),))
    if profile == "rolling":
        return ChurnSchedule(num_devices, (
            ChurnWave(0.25 * t_end, 0.5 * t_end, 0.4, seed),
            ChurnWave(0.45 * t_end, 0.7 * t_end, 0.4, seed + 1)))
    raise KeyError(f"unknown churn profile '{profile}' "
                   "(none|wave|blackout|rolling)")
