from .federated import FederatedDataset, dirichlet_partition, make_federated
from .fleetgen import VirtualFleetDataset, eval_device_ids
from .loader import batch_iterator, epoch_batches
from .synthetic import (make_femnist_like, make_mnist_like, make_synthetic,
                        make_token_stream)

__all__ = [
    "FederatedDataset", "VirtualFleetDataset", "dirichlet_partition",
    "eval_device_ids", "make_federated",
    "batch_iterator", "epoch_batches", "make_femnist_like", "make_mnist_like",
    "make_synthetic", "make_token_stream",
]
