"""Federated dataset container + non-IID partitioners.

``FederatedDataset`` stores equal-size per-device shards as dense arrays
``x (N, m, ...), y (N, m)`` so client local training can be ``vmap``-ed over
the device axis (the paper's eq. (1) assumes equal |D_k|; unequal sizes are
supported through per-device sample masks and p_k weights).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass
class FederatedDataset:
    x: np.ndarray          # (N, m, ...) per-device features
    y: np.ndarray          # (N, m)      per-device labels
    mask: np.ndarray       # (N, m)      1.0 where the sample is real
    test_x: np.ndarray     # (M, ...)    held-out global test set
    test_y: np.ndarray     # (M,)
    num_classes: int

    @property
    def num_devices(self) -> int:
        return self.x.shape[0]

    @property
    def samples_per_device(self) -> int:
        return self.x.shape[1]

    def client_weights(self) -> np.ndarray:
        """p_k = |D_k| / |D| (paper §II-A)."""
        sizes = self.mask.sum(axis=1)
        return (sizes / sizes.sum()).astype(np.float32)


def dirichlet_partition(x: np.ndarray, y: np.ndarray, num_devices: int,
                        concentration: float, num_classes: int,
                        seed: int = 0) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dirichlet(β) label-skew partition (standard non-IID FL benchmark).

    Lower ``concentration`` → more skew. Returns equal-size padded shards
    ``(x_dev, y_dev, mask)``; devices short of the quota are padded by
    resampling their own data (mask marks the real samples)."""
    rng = np.random.RandomState(seed)
    n = len(y)
    idx_by_class = [np.where(y == c)[0] for c in range(num_classes)]
    for ix in idx_by_class:
        rng.shuffle(ix)
    proportions = rng.dirichlet([concentration] * num_devices, num_classes)
    device_indices: list[list[int]] = [[] for _ in range(num_devices)]
    for c in range(num_classes):
        splits = (np.cumsum(proportions[c]) * len(idx_by_class[c])).astype(int)[:-1]
        for dev, part in enumerate(np.split(idx_by_class[c], splits)):
            device_indices[dev].extend(part.tolist())

    m = max(1, int(np.median([len(d) for d in device_indices])))
    xs, ys, masks = [], [], []
    for dev in range(num_devices):
        ids = np.array(device_indices[dev], dtype=np.int64)
        if len(ids) == 0:   # give an empty device one random sample
            ids = rng.randint(0, n, size=1)
        if len(ids) >= m:
            take = ids[:m]
            mask = np.ones(m, np.float32)
        else:
            pad = rng.choice(ids, m - len(ids), replace=True)
            take = np.concatenate([ids, pad])
            mask = np.concatenate([np.ones(len(ids), np.float32),
                                   np.zeros(m - len(ids), np.float32)])
        xs.append(x[take])
        ys.append(y[take])
        masks.append(mask)
    return np.stack(xs), np.stack(ys), np.stack(masks)


def make_federated(x: np.ndarray, y: np.ndarray, num_devices: int,
                   num_classes: int, concentration: Optional[float] = 0.5,
                   test_frac: float = 0.15, seed: int = 0) -> FederatedDataset:
    """Split off a test set, then partition the rest across devices.
    ``concentration=None`` → IID uniform partition."""
    rng = np.random.RandomState(seed)
    order = rng.permutation(len(y))
    x, y = x[order], y[order]
    n_test = int(len(y) * test_frac)
    test_x, test_y = x[:n_test], y[:n_test]
    x, y = x[n_test:], y[n_test:]

    if concentration is None:
        m = len(y) // num_devices
        xs = x[:m * num_devices].reshape(num_devices, m, *x.shape[1:])
        ys = y[:m * num_devices].reshape(num_devices, m)
        mask = np.ones((num_devices, m), np.float32)
    else:
        xs, ys, mask = dirichlet_partition(x, y, num_devices, concentration,
                                           num_classes, seed)
    return FederatedDataset(xs, ys, mask, test_x, test_y, num_classes)
