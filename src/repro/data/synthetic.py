"""Procedural dataset generators (offline container — no downloads).

* ``make_synthetic(alpha, beta)`` — the Synthetic(α,β) construction of
  Shamir et al. / Li et al. used by the paper: per-device softmax-linear
  models ``y = argmax softmax(W_k x + b_k)`` where ``W_k, b_k ~ N(u_k, 1)``,
  ``u_k ~ N(0, α)``, and device inputs ``x_k ~ N(v_k, Σ)`` with
  ``v_k ~ N(B_k, 1), B_k ~ N(0, β)``.  α controls model heterogeneity,
  β controls feature heterogeneity; Synthetic_IID uses a single shared
  (W, b) and shared input distribution.

* ``make_mnist_like`` / ``make_femnist_like`` — class-conditional Gaussian
  mixtures over 784 dims with 10/62 classes, standing in for the real
  MNIST/FEMNIST (documented substitution, DESIGN.md §3).

* ``make_token_stream`` — deterministic synthetic token corpus for the LM
  architectures (Zipf-distributed unigrams with Markov bigram structure so
  models have learnable signal).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def make_synthetic(alpha: float, beta: float, num_devices: int = 30,
                   samples_per_device: int = 200, dim: int = 60,
                   num_classes: int = 10, iid: bool = False,
                   seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Return ``(x, y)`` with shapes ``(num_devices, m, dim)`` and
    ``(num_devices, m)`` following the Synthetic(α,β) recipe."""
    rng = np.random.RandomState(seed)
    # Shared diagonal input covariance Σ_jj = j^{-1.2}
    diag = np.array([(j + 1) ** (-1.2) for j in range(dim)])

    if iid:
        W = rng.normal(0, 1, (dim, num_classes))
        b = rng.normal(0, 1, (num_classes,))

    xs, ys = [], []
    for k in range(num_devices):
        if iid:
            Wk, bk, vk = W, b, np.zeros(dim)
        else:
            uk = rng.normal(0, alpha)
            Wk = rng.normal(uk, 1, (dim, num_classes))
            bk = rng.normal(uk, 1, (num_classes,))
            Bk = rng.normal(0, beta)
            vk = rng.normal(Bk, 1, dim)
        xk = rng.multivariate_normal(vk, np.diag(diag), samples_per_device)
        logits = xk @ Wk + bk
        yk = np.argmax(logits, axis=1)
        xs.append(xk.astype(np.float32))
        ys.append(yk.astype(np.int32))
    return np.stack(xs), np.stack(ys)


def _class_gaussian(num_classes: int, dim: int, rng: np.random.RandomState,
                    sep: float = 3.0) -> np.ndarray:
    """Well-separated class means on a sphere."""
    means = rng.normal(0, 1, (num_classes, dim))
    means /= np.linalg.norm(means, axis=1, keepdims=True)
    return means * sep


def make_mnist_like(num_samples: int = 6000, dim: int = 784,
                    num_classes: int = 10, seed: int = 0
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Class-conditional Gaussian mixture standing in for MNIST."""
    rng = np.random.RandomState(seed)
    means = _class_gaussian(num_classes, dim, rng)
    y = rng.randint(0, num_classes, num_samples).astype(np.int32)
    x = means[y] + rng.normal(0, 1.0, (num_samples, dim))
    return x.astype(np.float32), y


def make_femnist_like(num_samples: int = 8000, dim: int = 784,
                      num_classes: int = 62, seed: int = 0
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """62-class variant standing in for Federated-EMNIST."""
    return make_mnist_like(num_samples, dim, num_classes, seed)


def make_token_stream(num_tokens: int, vocab_size: int, seed: int = 0,
                      zipf_a: float = 1.2) -> np.ndarray:
    """Zipf unigram + bigram-Markov synthetic corpus (learnable structure)."""
    rng = np.random.RandomState(seed)
    base = rng.zipf(zipf_a, num_tokens).astype(np.int64)
    base = (base - 1) % vocab_size
    # Inject bigram determinism: every even position partially predicts the next.
    out = base.copy()
    mask = rng.rand(num_tokens) < 0.5
    shifted = (np.roll(out, 1) * 31 + 7) % vocab_size
    out[mask] = shifted[mask]
    return out.astype(np.int32)
