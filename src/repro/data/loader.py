"""Batching utilities (device-resident numpy -> jnp mini-batches)."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def epoch_batches(x: np.ndarray, y: np.ndarray, batch_size: int,
                  rng: np.random.RandomState) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Shuffled mini-batches over one epoch (drops the ragged tail)."""
    order = rng.permutation(len(y))
    for start in range(0, len(y) - batch_size + 1, batch_size):
        ids = order[start:start + batch_size]
        yield x[ids], y[ids]


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int,
                   num_batches: int, seed: int = 0
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Infinite-style iterator yielding exactly ``num_batches`` batches."""
    rng = np.random.RandomState(seed)
    produced = 0
    while produced < num_batches:
        for bx, by in epoch_batches(x, y, batch_size, rng):
            yield bx, by
            produced += 1
            if produced >= num_batches:
                return
        if len(y) < batch_size:   # tiny dataset: sample with replacement
            ids = rng.randint(0, len(y), batch_size)
            yield x[ids], y[ids]
            produced += 1
