"""On-the-fly per-device data shards for fleet-scale simulation.

A :class:`VirtualFleetDataset` never materializes the ``(N, m, dim)`` host
array a :class:`~repro.data.federated.FederatedDataset` stores — at 10⁶
devices that array alone is tens of GB.  Instead each device's shard is a
pure counter-based function of ``(seed, device_id)``: the client-update jit
boundary folds the device id into a PRNG key and generates the shard
*inside* the compiled cohort pass, so host memory stays O(cohort chunk)
regardless of fleet size.  The recipe mirrors Synthetic(α,β)
(``make_synthetic``): per-device softmax-linear teachers ``W_k, b_k ~
N(u_k, 1)`` with ``u_k ~ N(0, α)``, inputs ``x ~ N(v_k, Σ)`` with diagonal
``Σ_jj = (j+1)^{-1.2}`` and ``v_k ~ N(B_k, 1), B_k ~ N(0, β)`` — drawn with
``jax.random`` instead of the numpy generator, so it is the same *family*
of problems, not bit-identical shards.

Determinism: ``materialize()`` evaluates the identical generation function,
so a materialized copy of device k equals the shard the jit boundary
generates for device k bit-for-bit — the property the fleet-vs-64-device
loss-equivalence test relies on.  The test set comes from held-out virtual
device ids ``[N, N + test_devices)`` so no training shard leaks into eval.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .federated import FederatedDataset


@dataclass(frozen=True, eq=False)
class VirtualFleetDataset:
    """Identity-hashed (``eq=False``) so compiled cohort functions cache per
    dataset object, exactly like the loss-fn keys in the other jit caches."""
    num_devices: int
    samples_per_device: int = 16
    dim: int = 16
    num_classes: int = 4
    alpha: float = 1.0
    beta: float = 1.0
    seed: int = 0
    test_devices: int = 64

    # run_hier_simulation dispatches on this instead of isinstance, so user
    # subclasses / wrappers stay duck-compatible
    virtual: bool = True

    def __post_init__(self):
        if self.num_devices < 1 or self.samples_per_device < 1:
            raise ValueError("need at least one device and one sample")
        if self.test_devices < 1:
            raise ValueError("need at least one held-out test device")

    def shard_fn(self) -> Callable[[jnp.ndarray], Tuple[jnp.ndarray,
                                                        jnp.ndarray,
                                                        jnp.ndarray]]:
        """Pure jax function ``device_id -> (x (m, dim) f32, y (m,) i32,
        mask (m,) f32)`` — traceable, vmappable, shard_map-able."""
        m, dim, C = self.samples_per_device, self.dim, self.num_classes
        alpha, beta = float(self.alpha), float(self.beta)
        base = jax.random.PRNGKey(self.seed)
        sigma = jnp.sqrt(jnp.arange(1, dim + 1, dtype=jnp.float32)
                         ** jnp.float32(-1.2))

        def shard(device_id):
            key = jax.random.fold_in(base, device_id.astype(jnp.uint32))
            k_u, k_w, k_b, k_B, k_v, k_x = jax.random.split(key, 6)
            uk = alpha * jax.random.normal(k_u)
            Wk = uk + jax.random.normal(k_w, (dim, C))
            bk = uk + jax.random.normal(k_b, (C,))
            Bk = beta * jax.random.normal(k_B)
            vk = Bk + jax.random.normal(k_v, (dim,))
            x = vk + sigma * jax.random.normal(k_x, (m, dim))
            y = jnp.argmax(x @ Wk + bk, axis=1).astype(jnp.int32)
            return x.astype(jnp.float32), y, jnp.ones((m,), jnp.float32)

        return shard

    def materialize_arrays(self, device_ids) -> Tuple[np.ndarray, np.ndarray,
                                                      np.ndarray]:
        """Host copies of the given devices' shards — the same bits the jit
        boundary generates (one vmap of :meth:`shard_fn`)."""
        ids = jnp.asarray(np.asarray(device_ids, np.int64))
        x, y, mask = jax.vmap(self.shard_fn())(ids)
        return np.asarray(x), np.asarray(y), np.asarray(mask)

    def test_set(self) -> Tuple[np.ndarray, np.ndarray]:
        ids = np.arange(self.num_devices,
                        self.num_devices + self.test_devices, dtype=np.int64)
        x, y, _ = self.materialize_arrays(ids)
        return (x.reshape(-1, self.dim),
                y.reshape(-1).astype(np.int32))

    @property
    def test_x(self) -> np.ndarray:
        return self.test_set()[0]

    @property
    def test_y(self) -> np.ndarray:
        return self.test_set()[1]

    def materialize(self, device_ids: Optional[np.ndarray] = None
                    ) -> FederatedDataset:
        """A real :class:`FederatedDataset` holding (a subset of) the fleet —
        the equivalence-test bridge between the virtual and materialized
        paths.  Don't call this at 10⁶ devices; that is the point."""
        if device_ids is None:
            device_ids = np.arange(self.num_devices, dtype=np.int64)
        x, y, mask = self.materialize_arrays(device_ids)
        tx, ty = self.test_set()
        return FederatedDataset(x, y, mask, tx, ty, self.num_classes)


def eval_device_ids(num_devices: int, cap: int) -> np.ndarray:
    """Deterministic evenly-strided device subsample for fleet-scale eval:
    full coverage whenever the fleet fits the cap (so small-fleet losses are
    exact), every stride-th device otherwise."""
    if num_devices <= cap:
        return np.arange(num_devices, dtype=np.int64)
    stride = -(-num_devices // cap)          # ceil
    return np.arange(num_devices, dtype=np.int64)[::stride][:cap]
