import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

DOC = """Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) combination this lowers and
COMPILES the production step function against ShapeDtypeStruct stand-ins
(no allocation), then records:

  * memory_analysis()  — proves the sharded program fits,
  * cost_analysis()    — per-device FLOPs / bytes for §Roofline,
  * collective bytes   — parsed from the compiled HLO,
  * lower/compile wall time.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out experiments/dryrun
"""

from typing import Optional

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ASSIGNED, get_config
from ..models.registry import get_model
from . import hlo_analysis
from .mesh import make_production_mesh
from .shapes import INPUT_SHAPES, arch_for_shape, input_specs
from .steps import (build_decode_step, build_prefill_step, build_train_step,
                    cache_sds, params_sds)


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               aggregator: str = "contextual",
               extra: Optional[dict] = None) -> dict:
    """Lower + compile one combination; returns the result record."""
    shape = INPUT_SHAPES[shape_name]
    base_cfg = get_config(arch)
    cfg = arch_for_shape(base_cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "aggregator": aggregator, "status": "skip", "skip_reason": None}
    if cfg is None:
        rec["skip_reason"] = ("long_500k inapplicable (see DESIGN.md §5: "
                              "whisper decoder ctx 448)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    extra = dict(extra or {})
    p_mode = "dp" if extra.get("dp_only") else "tp"
    p_sds = params_sds(cfg, mesh, mode=p_mode)
    rec["variant"] = extra or "baseline"

    with mesh:
        if shape.kind == "train":
            step = build_train_step(cfg, mesh, shape, aggregator=aggregator,
                                    **extra)
            batch = input_specs(cfg, shape, mesh)
            lowered = jax.jit(step).lower(p_sds, batch)
        elif shape.kind == "prefill":
            step = build_prefill_step(cfg, mesh, shape)
            batch = input_specs(cfg, shape, mesh)
            lowered = jax.jit(step).lower(p_sds, batch)
        else:
            step = build_decode_step(cfg, mesh, shape)
            token = input_specs(cfg, shape, mesh)["token"]
            cache = cache_sds(cfg, mesh, shape)
            lowered = jax.jit(step).lower(p_sds, token, cache)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = hlo_analysis.cost_analysis_dict(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_rec = {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                   if hasattr(mem, k)}
    except Exception:
        mem_rec = {}
    text = compiled.as_text()
    coll = hlo_analysis.collective_bytes(text)

    # MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D = batch·1
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens      # forward only
    else:
        model_flops = 2.0 * n_active * shape.global_batch
    chips = 512 if multi_pod else 256
    terms = hlo_analysis.roofline(cost, coll, model_flops, num_chips=chips)

    rec.update({
        "status": "ok",
        "window_variant": cfg.sliding_window,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost": {k: cost[k] for k in ("flops", "bytes accessed")
                 if k in cost},
        "memory": mem_rec,
        "collectives": coll,
        "roofline": terms.to_dict(),
        "hlo_ops": text.count("\n"),
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape)")
    ap.add_argument("--aggregator", default="contextual",
                    choices=["contextual", "fedavg"])
    ap.add_argument("--out", default=None, help="output dir for JSON records")
    ap.add_argument("--dp-only", action="store_true",
                    help="replicate params; all axes as data parallel (§Perf)")
    ap.add_argument("--remat", default=None,
                    choices=["full", "dots", "none"],
                    help="activation-checkpoint policy for train steps")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    args = ap.parse_args()
    extra = {}
    if args.dp_only:
        extra["dp_only"] = True
    if args.remat:
        extra["remat"] = False if args.remat == "none" else args.remat

    archs = ASSIGNED if args.all or args.arch is None else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or args.shape is None \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}|{shape_name}|{'multi' if mp else 'single'}"
                try:
                    rec = dryrun_one(arch, shape_name, mp,
                                     aggregator=args.aggregator, extra=extra)
                except Exception as e:                       # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if mp else "single",
                           "status": "fail", "error": str(e)[:2000],
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                status = rec["status"]
                detail = ""
                if status == "ok":
                    r = rec["roofline"]
                    detail = (f" compute={r['compute_s']:.3e}s "
                              f"memory={r['memory_s']:.3e}s "
                              f"coll={r['collective_s']:.3e}s "
                              f"bottleneck={r['bottleneck']} "
                              f"compile={rec['compile_s']}s")
                elif status == "fail":
                    detail = " " + rec["error"].splitlines()[0][:160]
                print(f"[{status:4s}] {tag}{detail}", flush=True)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = f"{arch}_{shape_name}_{rec['mesh']}{args.tag}.json"
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(rec, f, indent=1)
    if failures:
        raise SystemExit(f"{failures} combination(s) failed")


if __name__ == "__main__":
    main()
