"""Analytic roofline model (per arch × shape × mesh), calibrated against the
compiled artifact.

WHY THIS EXISTS (EXPERIMENTS.md §Roofline, methodology): XLA's
``cost_analysis()`` counts a ``while``-loop body ONCE regardless of trip
count (verified empirically in tests/test_roofline_calibration.py).  Every
production model here scans over layers (and flash-attention scans over
sequence blocks), so raw artifact FLOPs/bytes undercount by ~L.  We therefore
compute the three roofline terms analytically from the architecture config +
the sharding scheme, and CALIBRATE the analytic model against
``cost_analysis`` on single-layer, unscanned configurations where the
artifact is exact.  The compiled artifact remains the source of truth for
(a) the collective schedule (which collectives appear), and (b)
memory_analysis (fits / doesn't fit).

All quantities are PER DEVICE per step.  Formulas are intentionally
first-order (MXU matmul FLOPs + the dominant HBM streams); constants are
documented inline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from ..models.config import ArchConfig
from .hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from .shapes import InputShape

BF16 = 2
F32 = 4


def _layer_flops_per_token(cfg: ArchConfig, ctx: int,
                           window: Optional[int]) -> float:
    """Forward matmul FLOPs per token for ONE layer (no embedding/head)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    gates = 2 if cfg.activation in ("silu", "geglu") else 1

    if cfg.family == "ssm" and cfg.rwkv:
        P = cfg.rwkv_head_dim
        Q = max(cfg.ssm_chunk // 4, 16)
        proj = 2 * d * d * 5 + 2 * d * d          # r,k,v,g,w-lora≈d² + out
        # per token: intra-chunk pair products ≈ 2·Q·P + state update 4·P²
        wkv = cfg.rwkv_num_heads * (2 * Q * P + 4 * P * P)
        ffn = 2 * d * cfg.d_ff * 2 + 2 * d * d    # k² path + receptance
        return proj + wkv + ffn

    if cfg.family in ("ssm", "hybrid") and cfg.ssm_state:
        di, N = cfg.ssm_d_inner, cfg.ssm_state
        Hs, P = cfg.ssm_num_heads, cfg.ssm_head_dim
        Q = cfg.ssm_chunk
        proj = 2 * d * (2 * di + 2 * N + Hs) + 2 * di * d
        conv = 2 * cfg.ssm_conv_width * (di + 2 * N)
        # SSD per token: CB row (2·Q·N), M@x (2·Q·P per head … already per
        # token), state in/out (4·P·N per head)
        ssd = Hs * (2 * Q * N / 1 + 2 * Q * P + 4 * P * N)
        return proj + conv + ssd

    attn_ctx = min(ctx, window) if window else ctx
    attn = (2 * d * (H + 2 * KV) * hd + 2 * H * hd * d        # projections
            + 2 * 2 * attn_ctx * H * hd * 0.5)                # QKᵀ + PV causal
    if cfg.family == "moe":
        ff = (2 * d * cfg.d_ff * (gates + 1)
              * (cfg.experts_per_token + cfg.num_shared_experts)
              + 2 * d * cfg.num_experts)
    else:
        ff = 2 * d * cfg.d_ff * (gates + 1)
    return attn + ff


def _hybrid_layer_mix(cfg: ArchConfig, ctx: int, window):
    """Zamba2: L mamba layers + shared attention block every attn_every."""
    mamba = _layer_flops_per_token(
        cfg.with_overrides(family="ssm", rwkv=False), ctx, None)
    attn_cfg = cfg.with_overrides(family="dense", ssm_state=0)
    attn = _layer_flops_per_token(attn_cfg, ctx, window)
    n_shared = cfg.num_layers // cfg.attn_every
    return cfg.num_layers * mamba + n_shared * attn


def model_forward_flops(cfg: ArchConfig, shape: InputShape,
                        window: Optional[int]) -> float:
    """GLOBAL forward FLOPs for one step of this shape."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        tokens = B
        ctx = S
    else:
        tokens = B * S
        ctx = S
    d, V = cfg.d_model, cfg.vocab_size

    if cfg.family == "hybrid":
        per_tok = _hybrid_layer_mix(cfg, ctx, window) / max(cfg.num_layers, 1)
        layers = _hybrid_layer_mix(cfg, ctx, window)
    elif cfg.family == "audio":
        dec = _layer_flops_per_token(cfg, min(ctx, cfg.max_target_positions
                                              if False else ctx), window)
        cross = 2 * d * 2 * cfg.num_kv_heads * cfg.resolved_head_dim \
            + 2 * 2 * cfg.max_source_positions * cfg.num_heads \
            * cfg.resolved_head_dim
        layers = cfg.num_layers * (dec + cross)
        if shape.kind != "decode":
            enc_tokens = cfg.max_source_positions
            enc = _layer_flops_per_token(
                cfg.with_overrides(family="dense"), enc_tokens, None)
            return (tokens * layers + 2 * tokens * d * V
                    + B * enc_tokens * cfg.encoder_layers * enc)
    else:
        layers = cfg.num_layers * _layer_flops_per_token(cfg, ctx, window)
    head = 2 * d * V
    return tokens * (layers + head)


@dataclass
class AnalyticRoofline:
    flops: float          # per device
    hbm_bytes: float      # per device
    coll_bytes: float     # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float   # MODEL_FLOPS(6·N_active·D) / analytic flops

    def to_dict(self):
        return self.__dict__.copy()


def analytic_roofline(cfg: ArchConfig, shape: InputShape, *,
                      data: int = 16, model: int = 16, pods: int = 1,
                      aggregator: str = "contextual",
                      gram_scope_bytes: Optional[float] = None,
                      remat="full", dp_only: bool = False,
                      ring_kv: bool = False) -> AnalyticRoofline:
    """Three roofline terms per device (DESIGN.md §7 sharding scheme).

    Variant knobs mirror the implementation's §Perf levers:
      * ``remat``   — False | "full" (recompute everything) | "dots"
                      (matmul outputs saved; ~15% of fwd recomputed);
      * ``dp_only`` — params replicated, all axes data-parallel (no TP
                      collectives; combine is a full-size all-reduce);
      * ``ring_kv`` — window-bounded ring KV cache for decode.
    """
    chips = data * model * pods
    window = cfg.sliding_window
    B, S = shape.global_batch, shape.seq_len
    n_params = cfg.param_count_estimate()
    n_active = cfg.active_param_count()
    p_bytes = n_params * BF16
    d = cfg.d_model
    p_shard = 1 if dp_only else chips      # param residency divisor
    model_eff = 1 if dp_only else model

    fwd = model_forward_flops(cfg, shape, window)
    if shape.kind == "train":
        flops_global = 3.0 * fwd                    # fwd + 2×bwd
        if remat in (True, "full"):
            flops_global += fwd                     # full recompute
        elif remat == "dots":
            flops_global += 0.15 * fwd              # elementwise-only recompute
    else:
        flops_global = fwd
    flops_dev = flops_global / chips

    # ---- HBM traffic per device ------------------------------------------
    tokens = B * S if shape.kind != "decode" else B
    dp_ways = chips if dp_only else data * pods
    tok_dev = tokens / dp_ways if tokens >= dp_ways else tokens
    act_bytes_layer = tok_dev * d * BF16
    if shape.kind == "train":
        # params read fwd+bwd (+1 recompute), grads written, updates combined
        hbm = (3 * p_bytes / p_shard) * 2 + 2 * p_bytes / p_shard \
            + cfg.num_layers * act_bytes_layer * (8 if remat else 4) \
            + tok_dev * cfg.vocab_size * F32 / model_eff * 2
    elif shape.kind == "prefill":
        hbm = p_bytes / chips + cfg.num_layers * act_bytes_layer * 6 \
            + 2 * cfg.num_layers * tok_dev * cfg.num_kv_heads \
            * cfg.resolved_head_dim * BF16
    else:
        # decode: read all (sharded) params once + stream the KV cache
        if cfg.family == "ssm":
            state = (cfg.rwkv_num_heads * cfg.rwkv_head_dim ** 2 if cfg.rwkv
                     else cfg.ssm_num_heads * cfg.ssm_head_dim * cfg.ssm_state)
            cache_bytes = cfg.num_layers * B * state * F32
        else:
            eff_S = min(S, window) if (window and ring_kv) else S
            n_caches = (cfg.num_layers // cfg.attn_every
                        if cfg.family == "hybrid" else cfg.num_layers)
            cache_bytes = (n_caches * B * eff_S * cfg.num_kv_heads
                           * cfg.resolved_head_dim * 2 * BF16)
            if cfg.family == "hybrid":
                state = cfg.ssm_num_heads * cfg.ssm_head_dim * cfg.ssm_state
                cache_bytes += cfg.num_layers * B * state * F32
        hbm = p_bytes / chips + cache_bytes / chips

    # ---- collective traffic per device ------------------------------------
    # ring all-reduce of x bytes ≈ 2x on the wire per device.
    coll = 0.0
    if shape.kind == "train":
        if not dp_only:
            # per-layer TP: attn out + mlp out all-reduce (fwd) + same in bwd
            tp_layer = 2 * act_bytes_layer * 2 * 2
            coll += cfg.num_layers * tp_layer
        # cohort combine: α-weighted all-reduce of the update
        coll += 2 * (n_params / model_eff) * BF16
        if aggregator == "contextual":
            scope = gram_scope_bytes if gram_scope_bytes is not None else \
                cfg.vocab_size * d * F32          # lm_head slice (f32)
            C = dp_ways
            coll += (C - 1) / C * scope / model_eff  # all-gather scoped slices
        if n_params >= 7e9 and not dp_only:        # FSDP param all-gathers
            coll += 2 * p_bytes / chips * 2       # fwd + bwd gather
    elif shape.kind == "prefill":
        coll += cfg.num_layers * 2 * act_bytes_layer * 2
    else:
        # decode TP all-reduce of (B_loc, d) per layer ×2 blocks + LSE merge
        bloc = max(B / (data * pods), 1)
        coll += cfg.num_layers * 2 * 2 * bloc * d * BF16
        coll += cfg.num_layers * 2 * bloc * cfg.num_heads \
            * (cfg.resolved_head_dim + 1) * F32    # (o, lse) partial merge

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = coll / (ICI_BW * 4)                   # 4 ICI links per chip
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)

    if shape.kind == "train":
        mf = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        mf = 2.0 * n_active * tokens
    else:
        mf = 2.0 * n_active * B
    return AnalyticRoofline(flops_dev, hbm, coll, compute_s, memory_s,
                            coll_s, bottleneck, mf / chips,
                            (mf / chips) / max(flops_dev, 1e-9))
