"""Compiled-HLO analysis: collective bytes + roofline terms.

``collective_bytes`` parses the post-SPMD compiled module text and sums the
result-shape bytes of every cross-device collective (all-gather, all-reduce,
reduce-scatter, all-to-all, collective-permute).  ``cost_analysis`` has no
collective accounting, so this is the §Roofline collective term's source.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (brief-specified).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalised across jax versions: 0.4.x
    returns a list with one dict per program, newer jax a single dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")[-\w]*\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the compiled module."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        # while-loop bodies appear once; scans therefore count once per HLO —
        # multiply by trip count is not recoverable from text, so we report
        # the static module bytes (documented in EXPERIMENTS.md §Roofline).
        out[kind] += _shape_bytes(shape_str)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective bytes (static module)
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None

    def to_dict(self):
        return asdict(self)


def roofline(cost: Dict, coll: Dict[str, int],
             model_flops_total: Optional[float] = None,
             num_chips: int = 256, ici_links: int = 4) -> RooflineTerms:
    """Build the three §Roofline terms from compiled artifacts.

    ``cost`` = compiled.cost_analysis() (PER-DEVICE program);
    ``model_flops_total`` = 6·N·D for the GLOBAL batch — divided by chips
    here so the useful-ratio compares per-device quantities."""
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.get("total", 0))
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = cb / (ICI_BW * ici_links)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = uratio = None
    if model_flops_total:
        mf = model_flops_total / num_chips
        uratio = mf / flops if flops else None
    return RooflineTerms(flops, hbm, cb, compute_s, memory_s, coll_s,
                         bottleneck, mf, uratio)
