"""Distributed step builders — the production train/serve programs.

``build_train_step`` realises the paper's FL round as a first-class SPMD
program (DESIGN.md §3):

  * the global batch is split into C cohorts (C = |pod|·|data| mesh axes) —
    each cohort = one FL client holding its private shard,
  * every cohort runs ``local_steps`` of SGD from the same global params
    (vmapped; per-cohort gradients are *not* averaged by pjit because the
    cohort axis is explicit),
  * the contextual aggregation computes the Gram/cross terms on the
    paper's last-layer scope, solves the K×K system (replicated — it is
    O(C²)) and applies the α-weighted combine — which lowers to a weighted
    all-reduce over the cohort axis, the same wire bytes as FedAvg,
  * ``aggregator='fedavg'`` gives the paper's baseline (uniform mean).

``build_prefill_step`` / ``build_decode_step`` are the serving programs for
the inference shapes (decode = ONE token against a seq-sharded KV cache).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.solve import solve_alpha_simple
from ..models.config import ArchConfig
from ..models.registry import ModelBundle, get_model
from ..sharding.specs import batch_pspec, cache_pspecs, param_pspecs
from .shapes import InputShape, input_specs

Pytree = Any


def num_cohorts(mesh: Mesh, dp_only: bool = False, batch: int = 1 << 30) -> int:
    c = 1
    names = ("pod", "data", "model") if dp_only else ("pod", "data")
    for a in names:
        c *= mesh.shape.get(a, 1)
    if dp_only and batch % c != 0:       # model axis doesn't divide the batch
        c //= mesh.shape.get("model", 1)
    return c


def cohort_axes(mesh: Mesh, dp_only: bool = False, batch: int = 1 << 30):
    names = ("pod", "data", "model") if dp_only else ("pod", "data")
    axes = tuple(a for a in names if a in mesh.shape)
    if dp_only and batch % num_cohorts(mesh, True, 1 << 30) != 0:
        axes = tuple(a for a in axes if a != "model")
    return axes if len(axes) > 1 else axes[0]


# --------------------------------------------------------------- parameters

def params_sds(cfg: ArchConfig, mesh: Mesh, mode: str = "tp") -> Pytree:
    """ShapeDtypeStructs (with NamedShardings) for the model parameters."""
    bundle = get_model(cfg)
    shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    specs = param_pspecs(cfg, shapes, mesh, mode=mode)
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs)


# --------------------------------------------------------------- train step

def _scoped_matrix(updates: Pytree, scope_paths: Tuple[str, ...],
                   C: int) -> jax.Array:
    """Flatten the gram-scope slice of stacked updates to (C, n_scope) f32."""
    flat = jax.tree_util.tree_flatten_with_path(updates)[0]
    picked = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if any(s in name for s in scope_paths):
            picked.append(leaf.reshape(C, -1).astype(jnp.float32))
    if not picked:   # fallback: everything (small models)
        picked = [l.reshape(C, -1).astype(jnp.float32) for _, l in flat]
    return jnp.concatenate(picked, axis=1)


def build_train_step(cfg: ArchConfig, mesh: Mesh, shape: InputShape, *,
                     aggregator: str = "contextual", lr: float = 0.02,
                     local_steps: int = 1, gram_scope: Tuple[str, ...] =
                     ("lm_head", "final_norm"), ridge: float = 1e-6,
                     remat="full", dp_only: bool = False,
                     server_momentum: float = 0.0) -> Callable:
    """Returns ``train_step(params, batch) -> (params, metrics)`` — or, when
    ``server_momentum > 0``, ``train_step((params, velocity), batch)``.

    ``dp_only=True`` treats every mesh axis as data parallelism (cohorts =
    all devices, replicated params) — the §Perf sharding for sub-2B models.
    ``remat``: False | "full" | "dots" (see models.transformer.remat_wrap).
    ``server_momentum`` (beyond-paper): FedAvgM-style momentum applied to
    the α-combined server update — v ← μv + Σα_kΔ_k; w ← w + v.
    """
    bundle = get_model(cfg)
    C = num_cohorts(mesh, dp_only, shape.global_batch)
    beta = 1.0 / lr                      # paper §III-B: β = 1/l
    caxes = cohort_axes(mesh, dp_only, shape.global_batch)

    if cfg.family == "logreg":
        loss_fn = lambda p, b: bundle.train_loss(p, (b["x"], b["y"], None))[0]
    else:
        loss_fn = lambda p, b: bundle.train_loss(p, b, remat=remat)[0]

    def cohort_update(params, cohort_batch):
        """One client's local optimization; returns (Δ, loss_at_w0)."""
        if local_steps == 1:
            l0, g = jax.value_and_grad(loss_fn)(params, cohort_batch)
            delta = jax.tree_util.tree_map(
                lambda gg: (-lr * gg.astype(jnp.float32)).astype(gg.dtype), g)
            return delta, l0
        def body(p, _):
            l, g = jax.value_and_grad(loss_fn)(p, cohort_batch)
            p = jax.tree_util.tree_map(
                lambda pp, gg: (pp.astype(jnp.float32)
                                - lr * gg.astype(jnp.float32)).astype(pp.dtype),
                p, g)
            return p, l
        pT, losses = jax.lax.scan(body, params, None, length=local_steps)
        delta = jax.tree_util.tree_map(jnp.subtract, pT, params)
        return delta, losses[0]

    def train_step(params_or_state, batch):
        if server_momentum > 0.0:
            params, velocity = params_or_state
        else:
            params, velocity = params_or_state, None
        # split the global batch into C explicit cohorts (clients)
        cb = jax.tree_util.tree_map(
            lambda x: x.reshape((C, x.shape[0] // C) + x.shape[1:]), batch)
        cb = jax.lax.with_sharding_constraint(
            cb, jax.tree_util.tree_map(
                lambda x: NamedSharding(
                    mesh, P(*((caxes,) + (None,) * (x.ndim - 1)))), cb))

        deltas, losses = jax.vmap(cohort_update, in_axes=(None, 0))(params, cb)

        if aggregator == "fedavg":
            alpha = jnp.full((C,), 1.0 / C, jnp.float32)
            info = {}
        else:
            # ∇f estimate, K₂=0 form: mean of local first-step directions
            U = _scoped_matrix(deltas, gram_scope, C)          # (C, n_scope)
            gvec = -jnp.mean(U, axis=0) / (lr * local_steps)
            G = U @ U.T
            c = U @ gvec
            alpha = solve_alpha_simple(G, c, beta, ridge)
            info = {"gram_diag_mean": jnp.mean(jnp.diag(G)),
                    "bound": c @ alpha + 0.5 * beta * alpha @ G @ alpha}

        combined = jax.tree_util.tree_map(
            lambda u: jnp.einsum("k,k...->...", alpha,
                                 u.astype(jnp.float32)), deltas)
        if server_momentum > 0.0:
            velocity = jax.tree_util.tree_map(
                lambda v, c: server_momentum * v.astype(jnp.float32) + c,
                velocity, combined)
            combined = velocity
        new_params = jax.tree_util.tree_map(
            lambda p, c: (p.astype(jnp.float32) + c).astype(p.dtype),
            params, combined)
        metrics = {"loss": jnp.mean(losses), "alpha": alpha, **info}
        if server_momentum > 0.0:
            return (new_params, jax.tree_util.tree_map(
                lambda v: v.astype(jnp.float32), velocity)), metrics
        return new_params, metrics

    return train_step


# --------------------------------------------------------------- serve steps

def build_prefill_step(cfg: ArchConfig, mesh: Mesh, shape: InputShape
                       ) -> Callable:
    bundle = get_model(cfg)

    def prefill_step(params, batch):
        logits, cache = bundle.prefill(params, batch, shape.seq_len)
        return logits, cache

    return prefill_step


def build_decode_step(cfg: ArchConfig, mesh: Mesh, shape: InputShape
                      ) -> Callable:
    bundle = get_model(cfg)

    def decode_step(params, token, cache):
        return bundle.decode(params, token, cache)

    return decode_step


def cache_sds(cfg: ArchConfig, mesh: Mesh, shape: InputShape) -> Pytree:
    """ShapeDtypeStructs (with shardings) for the decode cache."""
    bundle = get_model(cfg)
    B = shape.global_batch
    if bundle.init_cache is not None:
        cache_shape = jax.eval_shape(lambda: bundle.init_cache(B, shape.seq_len))
    else:
        # whisper: cache structure comes from prefill (self KV + cross KV)
        p_sds = params_sds(cfg, mesh)
        prompt = {
            "frames": jax.ShapeDtypeStruct(
                (B, cfg.max_source_positions, cfg.d_model),
                jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32),
            "tokens": jax.ShapeDtypeStruct((B, 8), jnp.int32),
        }
        cache_shape = jax.eval_shape(
            lambda p, b: bundle.prefill(p, b, shape.seq_len)[1], p_sds, prompt)
    specs = cache_pspecs(cfg, cache_shape, mesh, B)
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        cache_shape, specs)
