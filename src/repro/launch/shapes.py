"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ArchConfig
from ..models.registry import get_model
from ..sharding.specs import batch_pspec

Pytree = Any


@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k":   InputShape("long_500k", "decode", 524_288, 1),
}


def arch_for_shape(cfg: ArchConfig, shape: InputShape) -> Optional[ArchConfig]:
    """Shape-specific config adjustments; None → the pair is skipped
    (recorded in DESIGN.md §5).

    * long_500k: whisper skipped (decoder ctx 448); full-attention archs get
      the sliding-window variant (window 8192) per the brief's carve-out.
    * whisper decode_32k runs as a documented stress config (self-attn cache
      32k, cross-attn 1500)."""
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return None
        if cfg.family in ("dense", "moe", "vlm") and cfg.sliding_window is None:
            return cfg.with_overrides(sliding_window=8192)
    return cfg


def input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape —
    weak-type-correct, shardable, no device allocation."""
    bundle = get_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    bspec = batch_pspec(mesh, B)

    if shape.kind in ("train", "prefill"):
        spec = bundle.batch_spec(B, S)
        out = {}
        for name, (shp, dt) in spec.items():
            pspec = P(*(tuple(bspec) + (None,) * (len(shp) - 1)))
            out[name] = jax.ShapeDtypeStruct(
                shp, dt, sharding=NamedSharding(mesh, pspec))
        return out

    # decode: one token per sequence
    tok = jax.ShapeDtypeStruct((B,), jnp.int32,
                               sharding=NamedSharding(mesh, bspec))
    return {"token": tok}
