"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because smoke tests run with 1 CPU
device while the dry-run forces 512 host devices via XLA_FLAGS.
"""
from __future__ import annotations

import jax


def _axis_types_kwargs(num_axes: int) -> dict:
    """``axis_types`` for :func:`jax.make_mesh`, empty on jax 0.4.x.

    ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg) only exist from
    jax 0.5; on 0.4.x every axis is implicitly Auto, so omitting the kwarg is
    semantically identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod slice: 16×16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ('data', 'model') single-pod — FL cohorts live on 'data',
    tensor/expert parallelism on 'model'; multi-pod prepends 'pod'
    (hierarchical FL: contextual aggregation within a pod, second-stage
    combine across pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"production mesh needs {need} devices, have {len(devices)} — "
            "run through launch/dryrun.py (it forces 512 host devices)")
    return jax.make_mesh(shape, axes, devices=devices[:need],
                         **_axis_types_kwargs(len(axes)))


def make_host_mesh():
    """Whatever devices exist, as a 1×N ('data','model') mesh — used by CPU
    integration tests and the quickstart example."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"), **_axis_types_kwargs(2))
