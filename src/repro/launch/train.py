"""End-to-end distributed FL-training driver.

Runs the SPMD train step (cohort-split batch → local steps → contextual /
fedavg aggregation) on whatever mesh is available: the host mesh for CPU
runs, the production mesh under the dry-run device override on TPU.

Example (CPU, reduced arch, synthetic tokens):
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --reduced \
      --steps 30 --batch 8 --seq 128 --aggregator contextual
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import save_checkpoint
from ..configs import get_config
from ..data.synthetic import make_token_stream
from ..models.registry import get_model
from .mesh import make_host_mesh, make_production_mesh
from .shapes import InputShape
from .steps import build_train_step


def make_batches(cfg, bundle, batch: int, seq: int, steps: int, seed=0):
    """Synthetic token batches (Zipf+Markov stream) for every family."""
    stream = make_token_stream(batch * seq * steps + 1, cfg.vocab_size, seed)
    for s in range(steps):
        tok = stream[s * batch * seq:(s + 1) * batch * seq].reshape(batch, seq)
        b = {"tokens": jnp.asarray(tok)}
        spec = bundle.batch_spec(batch, seq)
        if "image_embeds" in spec:
            shape, dt = spec["image_embeds"]
            b["tokens"] = b["tokens"][:, :spec["tokens"][0][1]]
            b["image_embeds"] = jnp.asarray(
                np.random.RandomState(s).normal(0, 1, shape), dt)
        if "frames" in spec:
            shape, dt = spec["frames"]
            b["frames"] = jnp.asarray(
                np.random.RandomState(s).normal(0, 1, shape), dt)
        yield b


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--aggregator", default="contextual",
                    choices=["contextual", "fedavg"])
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs the dry-run device override)")
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    bundle = get_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    shape = InputShape("custom", "train", args.seq, args.batch)

    step = build_train_step(cfg, mesh, shape, aggregator=args.aggregator,
                            lr=args.lr, local_steps=args.local_steps,
                            remat=not args.reduced)
    with mesh:
        params = bundle.init(jax.random.PRNGKey(0))
        step_j = jax.jit(step)
        print(f"arch={cfg.name} params={cfg.param_count_estimate()/1e6:.1f}M "
              f"mesh={dict(mesh.shape)} aggregator={args.aggregator}")
        t_last = time.time()
        for i, batch in enumerate(
                make_batches(cfg, bundle, args.batch, args.seq, args.steps)):
            params, metrics = step_j(params, batch)
            if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
                loss = float(metrics["loss"])
                alpha = np.asarray(metrics["alpha"])
                dt = time.time() - t_last
                t_last = time.time()
                print(f"step {i:4d} loss={loss:.4f} "
                      f"alpha[mean={alpha.mean():+.4f} std={alpha.std():.4f}] "
                      f"dt={dt:.2f}s", flush=True)
        if args.checkpoint_dir:
            path = save_checkpoint(args.checkpoint_dir, args.steps, params,
                                   meta={"arch": cfg.name,
                                         "aggregator": args.aggregator})
            print(f"checkpoint written: {path}")


if __name__ == "__main__":
    main()
