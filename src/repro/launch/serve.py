"""Batched serving driver: prefill once, decode N tokens with the KV cache
(the runtime counterpart of the decode_32k / long_500k dry-run shapes).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --reduced \
      --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.registry import get_model
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    bundle = get_model(cfg)
    mesh = make_host_mesh()
    max_seq = args.prompt_len + args.new_tokens + \
        (cfg.num_image_tokens if cfg.family == "vlm" else 0)

    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    prompt = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        prompt["image_embeds"] = jax.random.normal(
            key, (args.batch, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        prompt["frames"] = jax.random.normal(
            key, (args.batch, cfg.max_source_positions, cfg.d_model))

    with mesh:
        t0 = time.time()
        logits, cache = jax.block_until_ready(
            jax.jit(lambda p, b: bundle.prefill(p, b, max_seq))(params, prompt))
        t_prefill = time.time() - t0
        decode = jax.jit(bundle.decode)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [tok]
        t0 = time.time()
        for _ in range(args.new_tokens - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    total = args.batch * (args.new_tokens - 1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"window={cfg.sliding_window}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_decode:.2f}s "
          f"({total / max(t_decode, 1e-9):.1f} tok/s)")
    seq = np.stack([np.asarray(t) for t in toks], 1)
    print("first sequence:", seq[0][:16].tolist())


if __name__ == "__main__":
    main()
