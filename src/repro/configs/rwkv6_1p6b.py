"""RWKV6-1.6B "Finch" [arXiv:2404.05892] — attention-free, data-dependent decay.

24 layers, d_model 2048, d_ff 7168, vocab 65536.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm", rwkv=True,
    num_layers=24, d_model=2048, d_ff=7168, vocab_size=65_536,
    rwkv_head_dim=64, ssm_chunk=128,
    dtype="bfloat16",
)
