"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone + shared attention block.

38 layers, d_model 2048, 32 heads (GQA kv=32), d_ff 8192, vocab 32000,
ssm_state 64.  The shared transformer block (attention + MLP, single weight
set) is interleaved between Mamba2 groups — here every 6 mamba layers.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    attn_every=6,
    activation="silu", rope_theta=10_000.0, dtype="bfloat16",
)
