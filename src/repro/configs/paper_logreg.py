"""The paper's own model: multinomial logistic regression (§IV-A1).

dim 784 / 10 classes for the MNIST-like dataset; the synthetic datasets use
dim 60 / 10 classes (construct via CONFIG.with_overrides(input_dim=60)).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="paper-logreg", family="logreg",
    input_dim=784, num_classes=10, dtype="float32",
)
