"""OLMoE-1B-7B [arXiv:2409.02060] — 64 experts top-8, no shared experts.

16 layers, d_model 2048, 16 heads (kv=16), per-expert d_ff 1024, vocab 50304.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50_304,
    num_experts=64, experts_per_token=8, num_shared_experts=0,
    qk_norm=True, activation="silu", rope_theta=10_000.0, dtype="bfloat16",
)
