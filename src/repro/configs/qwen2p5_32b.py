"""Qwen2.5-32B [hf:Qwen/Qwen2.5 family card] — dense, GQA kv=8, QKV bias.

64 layers, d_model 5120, 40 heads (kv=8), d_ff 27648, vocab 152064.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=27648, vocab_size=152_064,
    qkv_bias=True, activation="silu", rope_theta=1_000_000.0,
    dtype="bfloat16",
)
