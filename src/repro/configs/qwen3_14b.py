"""Qwen3-14B [hf:Qwen/Qwen3-8B family card] — dense, qk_norm, GQA kv=8.

40 layers, d_model 5120, 40 heads (kv=8), d_ff 17408, vocab 151936.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=17408, vocab_size=151_936,
    qk_norm=True, activation="silu", rope_theta=1_000_000.0,
    dtype="bfloat16",
)
