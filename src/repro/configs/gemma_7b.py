"""Gemma-7B [arXiv:2403.08295] — GeGLU, head_dim 256, GQA kv=16.

28 layers, d_model 3072, 16 heads (kv=16), d_ff 24576, vocab 256000.
Embeddings tied (gemma shares input/output embedding).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    head_dim=256, d_ff=24576, vocab_size=256_000,
    activation="geglu", tie_embeddings=True, rope_theta=10_000.0,
    dtype="bfloat16",
)
