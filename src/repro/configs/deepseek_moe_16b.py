"""DeepSeekMoE-16B [arXiv:2401.06066] — fine-grained MoE.

28 layers, d_model 2048, 16 heads (kv=16), per-expert d_ff 1408,
vocab 102400; 64 routed experts top-6 + 2 shared experts.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102_400,
    num_experts=64, experts_per_token=6, num_shared_experts=2,
    activation="silu", rope_theta=10_000.0, dtype="bfloat16",
)
