"""StarCoder2-15B [arXiv:2402.19173] — dense, GQA kv=4, RoPE.

40 layers, d_model 6144, 48 heads (kv=4), d_ff 24576, vocab 49152.
(The public model uses LN+GELU; we keep the assigned dims with the
framework's RMSNorm/gated-MLP stack — gelu activation preserved.)
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    d_ff=24576, vocab_size=49152,
    activation="gelu", rope_theta=100_000.0, dtype="bfloat16",
    sliding_window=4096,   # starcoder2 trains with 4k sliding window
)
