"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder audio backbone.

32 decoder layers (+32 encoder), d_model 1280, 20 heads (kv=20), d_ff 5120,
vocab 51866.  Conv/mel frontend STUBBED: input_specs supplies 1500 frame
embeddings.  long_500k skipped (decoder ctx 448 — DESIGN.md §5).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio", is_encoder_decoder=True,
    num_layers=32, encoder_layers=32, d_model=1280, num_heads=20,
    num_kv_heads=20, d_ff=5120, vocab_size=51_866,
    max_source_positions=1500, max_target_positions=448,
    activation="gelu", dtype="bfloat16",
)
