"""Assigned-architecture configs (+ the paper's own models).

Every entry cites its source spec.  ``get_config(name)`` returns the FULL
production config; ``get_config(name).reduced()`` is the CPU smoke variant.
"""
from __future__ import annotations

from importlib import import_module
from typing import Dict, List

from ..models.config import ArchConfig

_MODULES = [
    "zamba2_1p2b", "starcoder2_15b", "deepseek_moe_16b", "rwkv6_1p6b",
    "chameleon_34b", "qwen3_14b", "gemma_7b", "whisper_large_v3",
    "qwen2p5_32b", "olmoe_1b_7b", "paper_logreg",
]

_ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "starcoder2-15b": "starcoder2_15b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "chameleon-34b": "chameleon_34b",
    "qwen3-14b": "qwen3_14b",
    "gemma-7b": "gemma_7b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2.5-32b": "qwen2p5_32b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "paper-logreg": "paper_logreg",
}

ASSIGNED = [a for a in _ALIASES if a != "paper-logreg"]


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    mod = import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get_config(name) for name in _ALIASES}
