"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM, VQ image tokens.

48 layers, d_model 8192, 64 heads (GQA kv=8), d_ff 22016, vocab 65536.
Image tokenizer stubbed: input_specs supplies 1024 patch-code embeddings.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65_536, num_image_tokens=1024,
    qk_norm=True,    # chameleon uses qk-norm for stability
    activation="silu", rope_theta=10_000.0, dtype="bfloat16",
)
