"""Chrome trace-event export: one ``.jsonl`` trace → Perfetto-viewable JSON.

Converts the ``kind="span"`` events of a streamed trace
(``repro.obs.spans``) into the Chrome trace-event format that Perfetto
(https://ui.perfetto.dev) and ``chrome://tracing`` open directly, with the
two clocks as two *processes*:

  * pid 1 — **wall clock**: host ``perf_counter`` intervals, rebased so the
    trace starts at t=0.  Nested spans land on one thread track (their
    intervals nest by construction); *flat* spans (the event scheduler's
    overlapping task/transfer lifetimes, ``CommLedger`` link transfers) are
    emitted as async begin/end pairs, which Perfetto stacks without
    corrupting the nesting track.
  * pid 2 — **virtual clock**: the same spans positioned by their simulated
    edge-time interval (only spans that carried virtual stamps appear).
    Wall and virtual tracks scroll side by side, so "the cloud solve is 2%
    of virtual round time but 60% of wall time" is one glance.

Span tags ride in ``args`` (clickable in the UI).  Usage::

    python -m repro.obs.perfetto BENCH_hier.jsonl -o trace.json

then drag ``trace.json`` into Perfetto.  ``export_chrome_trace`` is the
library entry point (streams the input; events list is the output size).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Union

from .jsonl import iter_trace
from .spans import span_fields, span_tags

WALL_PID = 1
VIRTUAL_PID = 2

_META = [
    {"ph": "M", "pid": WALL_PID, "name": "process_name",
     "args": {"name": "wall clock"}},
    {"ph": "M", "pid": WALL_PID, "name": "process_sort_index",
     "args": {"sort_index": 0}},
    {"ph": "M", "pid": VIRTUAL_PID, "name": "process_name",
     "args": {"name": "virtual clock (simulated edge time)"}},
    {"ph": "M", "pid": VIRTUAL_PID, "name": "process_sort_index",
     "args": {"sort_index": 1}},
]


def chrome_trace_events(path: Union[str, Any]) -> List[Dict[str, Any]]:
    """The ``traceEvents`` list for one jsonl trace (metadata included)."""
    spans: List[Dict[str, Any]] = []
    base_wall: Optional[float] = None
    for event in iter_trace(path, kind="span"):
        f = span_fields(event)
        if "t0_wall" not in f:
            continue                     # malformed span event: skip
        if base_wall is None or f["t0_wall"] < base_wall:
            base_wall = f["t0_wall"]
        spans.append(f)
    out: List[Dict[str, Any]] = list(_META)
    next_async_id = 1
    for f in spans:
        name = str(f.get("name", "span"))
        args = {"path": f.get("path", name), **span_tags(f)}
        ts = (f["t0_wall"] - base_wall) * 1e6          # µs since trace start
        dur = f.get("dur_wall_s", 0.0) * 1e6
        if f.get("flat"):
            # overlapping lifetime: async begin/end pair on the wall track
            aid = next_async_id
            next_async_id += 1
            out.append({"ph": "b", "cat": "flat", "id": aid, "name": name,
                        "pid": WALL_PID, "tid": 1, "ts": ts, "args": args})
            out.append({"ph": "e", "cat": "flat", "id": aid, "name": name,
                        "pid": WALL_PID, "tid": 1, "ts": ts + dur})
        else:
            out.append({"ph": "X", "cat": "span", "name": name,
                        "pid": WALL_PID, "tid": 0, "ts": ts, "dur": dur,
                        "args": args})
        if "t0_virtual" in f:
            vts = f["t0_virtual"] * 1e6                # virtual s → µs
            vdur = f.get("dur_virtual_s", 0.0) * 1e6
            if f.get("flat"):
                aid = next_async_id
                next_async_id += 1
                out.append({"ph": "b", "cat": "flat", "id": aid,
                            "name": name, "pid": VIRTUAL_PID, "tid": 1,
                            "ts": vts, "args": args})
                out.append({"ph": "e", "cat": "flat", "id": aid,
                            "name": name, "pid": VIRTUAL_PID, "tid": 1,
                            "ts": vts + vdur})
            else:
                out.append({"ph": "X", "cat": "span", "name": name,
                            "pid": VIRTUAL_PID, "tid": 0, "ts": vts,
                            "dur": vdur, "args": args})
    return out


def export_chrome_trace(trace_path: Union[str, Any], out_path: str) -> int:
    """Write the Chrome trace JSON for ``trace_path``; returns the number
    of source spans exported (0 means the trace carried no spans)."""
    events = chrome_trace_events(trace_path)
    # each source span contributes exactly one wall-track open ("X" or "b")
    n_spans = sum(1 for e in events
                  if e["ph"] in ("X", "b") and e["pid"] == WALL_PID)
    with open(out_path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return n_spans


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Export a repro.obs jsonl trace to Chrome trace-event "
                    "JSON (open in https://ui.perfetto.dev)")
    ap.add_argument("trace", help="input .jsonl trace")
    ap.add_argument("-o", "--out", default="trace.json",
                    help="output Chrome trace JSON (default: trace.json)")
    args = ap.parse_args(argv)
    try:
        n = export_chrome_trace(args.trace, args.out)
    except FileNotFoundError:
        print(f"perfetto: trace not found: {args.trace}", file=sys.stderr)
        return 2
    except (json.JSONDecodeError, KeyError) as exc:
        print(f"perfetto: {args.trace}: truncated or invalid jsonl ({exc})",
              file=sys.stderr)
        return 2
    print(f"wrote {args.out} ({n} spans from {args.trace})", file=sys.stderr)
    if n == 0:
        print("perfetto: warning: trace carried no span events",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
