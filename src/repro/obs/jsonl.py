"""Append-only jsonl event stream — the file-backed tracker.

One JSON object per line, in emission order::

    {"step": 12, "t_wall": 1754700000.123, "kind": "metrics",
     "scope": "hier/run0",
     "metrics": {"hier/run0/train_loss": 0.41, "hier/run0/t_virtual": 88.2}}

``step`` is monotone *per scope*: within one scope explicit steps may
repeat or grow but never go backwards (a regression raises — the stream is
the ground truth for event ordering), while independent scopes — e.g. the
several simulations a bench runs into one trace — each keep their own step
counter.  Events logged without a step inherit their scope's latest one.
``t_wall`` is the host wall-clock at emission, so a live run can be
tailed::

    tail -f BENCH_hier.jsonl | python -m json.tool --json-lines

:func:`iter_trace` parses a stream back into :class:`TrackedEvent`s one at
a time — a generator, so trace tools (``summarize_trace.py``,
``trace_diff.py``, the Perfetto export) never hold a long trace in memory;
:func:`read_trace` is the list-materializing shim for call sites that want
random access.  ``tests/test_obs.py`` pins the write → parse →
same-metrics round trip.  The parser intentionally lives next to the
writer, but the *bench* JSON derivation (records → ``BENCH_*.json``) is
stdlib-only and lives in ``benchmarks/bench_trace.py`` so CI scripts can
run it without jax.
"""
from __future__ import annotations

import json
from typing import IO, Dict, Iterator, List, Optional, Union

import numpy as np

from .tracker import TrackedEvent, Tracker


def _jsonable(obj):
    """numpy scalars/arrays → python; everything else must be JSON-ready."""
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")


class JsonlTracker(Tracker):
    """Streams every event to an append-only ``.jsonl`` file.

    ``path`` may be a filename (truncated unless ``append=True``) or an open
    text handle (left open on ``finish``).  ``flush_every`` batches flushes:
    the default 1 flushes per write — a live, tailable stream — while hot
    benches can raise it to amortize syscalls (``finish()`` always flushes
    whatever is pending, and ``use_tracker`` calls it even when the body
    raises, so no tail of the trace is lost either way).
    """

    def __init__(self, path: Union[str, IO[str]], *, append: bool = False,
                 flush_every: int = 1):
        if flush_every < 1:
            raise ValueError(f"flush_every must be >= 1, got {flush_every}")
        if hasattr(path, "write"):
            self._fh: IO[str] = path          # type: ignore[assignment]
            self._owns = False
        else:
            self._fh = open(path, "a" if append else "w")
            self._owns = True
        self._last_step: Dict[str, int] = {}
        self._flush_every = int(flush_every)
        self._pending = 0

    def _record(self, event: TrackedEvent) -> None:
        last = self._last_step.get(event.scope, 0)
        if event.step is not None:
            if event.step < last:
                raise ValueError(
                    f"non-monotonic step in scope '{event.scope}': "
                    f"{event.step} after {last}")
            last = self._last_step[event.scope] = event.step
        line = {"step": last, "t_wall": event.t_wall, "kind": event.kind,
                "scope": event.scope, "metrics": event.metrics}
        self._fh.write(json.dumps(line, default=_jsonable) + "\n")
        self._pending += 1
        if self._pending >= self._flush_every:
            self._fh.flush()
            self._pending = 0

    def finish(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._pending = 0
            if self._owns:
                self._fh.close()


def _iter_handle(fh: IO[str], kind: Optional[str]
                 ) -> Iterator[TrackedEvent]:
    for line in fh:
        if not line.strip():
            continue
        obj = json.loads(line)
        if kind is not None and obj["kind"] != kind:
            continue
        yield TrackedEvent(kind=obj["kind"], metrics=obj["metrics"],
                           step=obj["step"], t_wall=obj["t_wall"],
                           scope=obj.get("scope", ""))


def iter_trace(path: Union[str, IO[str]],
               kind: Optional[str] = None) -> Iterator[TrackedEvent]:
    """Parse a jsonl trace lazily, one :class:`TrackedEvent` at a time
    (optionally one ``kind`` only) — long traces never materialize."""
    if hasattr(path, "read"):
        yield from _iter_handle(path, kind)
    else:
        with open(path) as f:
            yield from _iter_handle(f, kind)


def read_trace(path: Union[str, IO[str]],
               kind: Optional[str] = None) -> List[TrackedEvent]:
    """List-materializing shim over :func:`iter_trace` for call sites that
    need random access or multiple passes."""
    return list(iter_trace(path, kind))
