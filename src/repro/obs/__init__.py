"""Streaming observability: pluggable trackers for live per-round metrics.

See ``repro.obs.tracker`` for the protocol and the process-wide
:func:`current_tracker` context, ``repro.obs.jsonl`` for the append-only
file stream benches and CI consume.
"""
from .jsonl import JsonlTracker, read_trace
from .tracker import (NOOP, CompositeTracker, InMemoryTracker, NoopTracker,
                      TrackedEvent, Tracker, current_tracker, use_tracker)

__all__ = [
    "NOOP", "CompositeTracker", "InMemoryTracker", "JsonlTracker",
    "NoopTracker", "TrackedEvent", "Tracker", "current_tracker",
    "read_trace", "use_tracker",
]
