"""Streaming observability: pluggable trackers, live metrics, span tracing.

See ``repro.obs.tracker`` for the protocol and the process-wide
:func:`current_tracker` context, ``repro.obs.jsonl`` for the append-only
file stream benches and CI consume, ``repro.obs.spans`` for dual-clock
(wall + virtual) span tracing, and ``repro.obs.perfetto`` for the Chrome
trace-event export viewable in Perfetto / ``chrome://tracing``.
"""
from . import spans
from .jsonl import JsonlTracker, iter_trace, read_trace
from .spans import (begin_span, end_span, record_span, span, span_fields,
                    span_tags, use_virtual_clock, virtual_now)
from .tracker import (NOOP, CompositeTracker, InMemoryTracker, NoopTracker,
                      TrackedEvent, Tracker, current_tracker, use_tracker)

__all__ = [
    "NOOP", "CompositeTracker", "InMemoryTracker", "JsonlTracker",
    "NoopTracker", "TrackedEvent", "Tracker", "begin_span", "current_tracker",
    "end_span", "iter_trace", "read_trace", "record_span", "span",
    "span_fields", "span_tags", "spans", "use_tracker", "use_virtual_clock",
    "virtual_now",
]
