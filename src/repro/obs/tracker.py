"""Streaming telemetry trackers (idiom: levanter's ``levanter.tracker``).

Every runtime in this repo produces live signals — per-round losses, the
``CommLedger``'s per-tier bytes, round-engine wall-clocks, kernel-autotune
decisions — that used to be visible only in end-of-run result dataclasses.
A :class:`Tracker` is the streaming outlet for all of them:

  * ``log(metrics, step=...)``    — one timestamped event of flat metrics;
  * ``log_summary(metrics)``      — run-level facts (configs, final numbers,
    bench records); no step, ordered like everything else;
  * ``jot(**tags)``               — sticky key/value tags (run name, engine);
  * ``scope(prefix)``             — a view whose metric keys are prefixed
    ``"prefix/"`` (hierarchical: ``tracker.scope("gateway/3")``).

The active tracker is process-wide, like levanter's: library code calls
:func:`current_tracker` and logs unconditionally cheap events; callers opt
in with ``with use_tracker(JsonlTracker(path)): ...``.  The default is
:data:`NOOP` — a :class:`NoopTracker` whose ``active`` flag is False so hot
loops can skip building metric dicts entirely::

    tr = current_tracker()
    if tr.active:
        tr.log({"train_loss": loss}, step=t)

Implementations here: :class:`NoopTracker` (default, zero overhead),
:class:`InMemoryTracker` (tests/notebooks), :class:`CompositeTracker`
(fan-out).  The append-only file tracker lives in ``repro.obs.jsonl``.
This module imports nothing from the rest of ``repro`` — the kernel
registry and the hier engines log through it without cycles.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Sequence

Metrics = Dict[str, Any]


@dataclass(frozen=True)
class TrackedEvent:
    """One logged event, as :class:`InMemoryTracker` records it (the jsonl
    tracker serializes the same fields per line).  ``scope`` is the full
    ``a/b`` prefix path the event was logged under ("" at the root) — the
    jsonl tracker enforces step monotonicity per scope, since one trace
    typically interleaves several independent runs."""
    kind: str                     # "metrics" | "summary" | "tags" | "span"
    metrics: Metrics
    step: Optional[int] = None
    t_wall: float = 0.0
    scope: str = ""


class Tracker:
    """Base tracker: the four-method protocol plus scope plumbing.

    Subclasses implement :meth:`_record`; ``log``/``log_summary``/``jot``
    route through it with the event kind.  ``active`` is the hot-loop guard:
    when False (the noop), callers may skip metric construction.
    """

    active: bool = True

    # -- protocol -----------------------------------------------------------

    def log(self, metrics: Metrics, *, step: Optional[int] = None) -> None:
        self._record(TrackedEvent("metrics", dict(metrics), step,
                                  time.time()))

    def log_summary(self, metrics: Metrics) -> None:
        self._record(TrackedEvent("summary", dict(metrics), None,
                                  time.time()))

    def jot(self, **tags: Any) -> None:
        """Sticky tags (run name, engine, platform): one 'tags' event."""
        self._record(TrackedEvent("tags", dict(tags), None, time.time()))

    def log_span(self, metrics: Metrics) -> None:
        """One closed span (``repro.obs.spans``): dual-clock interval plus
        tags, already flattened to JSON-ready fields.  Routed through
        ``_record`` like everything else, so every sink carries spans."""
        self._record(TrackedEvent("span", dict(metrics), None, time.time()))

    def scope(self, prefix: str) -> "Tracker":
        """A view of this tracker whose metric keys are prefixed
        ``"{prefix}/"`` — compose freely: ``tr.scope("hier").scope("gw3")``.
        """
        return _ScopedTracker(self, prefix)

    def finish(self) -> None:
        """Flush/close any underlying sink (no-op by default)."""

    # -- implementation hook ------------------------------------------------

    def _record(self, event: TrackedEvent) -> None:
        raise NotImplementedError


class NoopTracker(Tracker):
    """The default: swallows everything, advertises ``active = False`` so
    instrumented hot paths skip even building the metrics dict."""

    active = False

    def log(self, metrics: Metrics, *, step: Optional[int] = None) -> None:
        pass

    def log_summary(self, metrics: Metrics) -> None:
        pass

    def jot(self, **tags: Any) -> None:
        pass

    def log_span(self, metrics: Metrics) -> None:
        pass

    def scope(self, prefix: str) -> "Tracker":
        return self                 # no per-scope allocation on the noop

    def _record(self, event: TrackedEvent) -> None:
        pass


class _ScopedTracker(Tracker):
    """Key-prefixing view over another tracker (created by ``scope``)."""

    def __init__(self, inner: Tracker, prefix: str):
        self._inner = inner
        self._prefix = prefix.rstrip("/")

    @property
    def active(self) -> bool:       # type: ignore[override]
        return self._inner.active

    def _record(self, event: TrackedEvent) -> None:
        prefixed = {f"{self._prefix}/{k}": v
                    for k, v in event.metrics.items()}
        scope = (f"{self._prefix}/{event.scope}" if event.scope
                 else self._prefix)
        self._inner._record(TrackedEvent(event.kind, prefixed, event.step,
                                         event.t_wall, scope))


class InMemoryTracker(Tracker):
    """Records every event in order — the test/notebook tracker."""

    def __init__(self) -> None:
        self.events: List[TrackedEvent] = []

    def _record(self, event: TrackedEvent) -> None:
        self.events.append(event)

    # -- conveniences for assertions ---------------------------------------

    def metrics_events(self) -> List[TrackedEvent]:
        return [e for e in self.events if e.kind == "metrics"]

    def span_events(self) -> List[TrackedEvent]:
        return [e for e in self.events if e.kind == "span"]

    def series(self, key: str) -> List[Any]:
        """All values logged under ``key`` (any kind), in event order."""
        return [e.metrics[key] for e in self.events if key in e.metrics]


class CompositeTracker(Tracker):
    """Fans every event out to each child (e.g. jsonl file + in-memory)."""

    def __init__(self, trackers: Sequence[Tracker]):
        self.trackers = list(trackers)

    @property
    def active(self) -> bool:       # type: ignore[override]
        return any(t.active for t in self.trackers)

    def _record(self, event: TrackedEvent) -> None:
        for t in self.trackers:
            t._record(event)

    def finish(self) -> None:
        for t in self.trackers:
            t.finish()


NOOP = NoopTracker()

# The active tracker is thread-local so parallel test workers / background
# eval threads cannot interleave scopes; the default everywhere is NOOP.
_STATE = threading.local()


def current_tracker() -> Tracker:
    """The process-wide active tracker (``NOOP`` unless a ``use_tracker``
    context is open on this thread)."""
    return getattr(_STATE, "stack", None)[-1] if getattr(
        _STATE, "stack", None) else NOOP


@contextmanager
def use_tracker(tracker: Tracker, *, finish: bool = True) -> Iterator[Tracker]:
    """Install ``tracker`` as :func:`current_tracker` for the block; nested
    contexts stack.  ``finish=True`` closes the tracker's sink on exit."""
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(tracker)
    try:
        yield tracker
    finally:
        stack.pop()
        if finish:
            tracker.finish()
