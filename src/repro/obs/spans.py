"""Span tracing over dual clocks — where time goes inside a round.

The PR-6 tracker protocol streams *scalar* metrics (losses, bytes,
wall-clocks) but cannot say where a round's milliseconds went: gateway
stage vs cloud solve vs link transfer vs jit compile, in host wall time or
in the virtual edge clock.  A *span* is a named interval recorded on BOTH
clocks at once:

  * **wall** — host ``time.perf_counter()`` at open/close, always present;
  * **virtual** — the simulated edge time, present whenever a virtual
    clock is threaded in (:func:`use_virtual_clock` installs the event
    scheduler's ``lambda: scheduler.now`` for the block) or the caller
    stamps it explicitly (``t_virtual=`` on :func:`begin`/:func:`end`,
    :func:`record_span` for transfers whose duration is known up front).

Three entry points, all free on the noop path (one ``active`` check):

  * ``with span(name, **tags): ...`` — nested lifetimes.  Spans opened
    inside run as children: each carries a ``path`` like
    ``"round/event_loop/gateway"`` built from the thread-local span stack,
    which is what the Perfetto export nests on and ``trace_diff`` aligns
    on.  An exception inside the block still closes the span (tagged
    ``error=<ExcType>``), restores the nesting depth, and re-raises.
  * ``h = begin(name, **tags)`` / ``end(h, **tags)`` — explicit handles
    for the event scheduler's NON-nested lifetimes (a dispatched task and
    the next dispatch overlap arbitrarily).  Flat spans take their path
    from the stack at ``begin`` but never push onto it, so they cannot
    corrupt the nesting of context-managed spans; the export renders them
    as async (overlap-safe) track events.
  * ``record_span(name, t0_virtual=, dur_virtual_s=, **tags)`` — a span
    whose interval is already known (the ``CommLedger``'s link transfers:
    virtual duration computed from bytes/bandwidth at record time).

Every close emits ONE ``kind="span"`` event through the active tracker's
``log_span`` — the jsonl / in-memory / composite sinks of ``repro.obs``
carry spans with no changes, and one ``.jsonl`` trace interleaves spans
with the PR-6 metric stream.  Reserved metric keys: ``name``, ``path``,
``depth``, ``flat``, ``t0_wall``, ``dur_wall_s``, ``t0_virtual``,
``dur_virtual_s``; everything else in the event is a caller tag (Perfetto
``args``).  Like ``repro.obs.tracker`` this module imports nothing from
the rest of ``repro`` — the scheduler, engines and kernel registry all
trace through it without cycles.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

from .tracker import TrackedEvent, Tracker, current_tracker

# span-event keys that are structure, not caller tags
RESERVED_KEYS = ("name", "path", "depth", "flat", "t0_wall", "dur_wall_s",
                 "t0_virtual", "dur_virtual_s")

_STATE = threading.local()      # .stack: List[SpanHandle], .vclock: stack


def _stack() -> List["SpanHandle"]:
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    return stack


# ---------------------------------------------------------------------------
# virtual clock threading
# ---------------------------------------------------------------------------

def virtual_now() -> Optional[float]:
    """Current virtual time, or None when no virtual clock is installed."""
    clocks = getattr(_STATE, "vclock", None)
    return clocks[-1]() if clocks else None


@contextmanager
def use_virtual_clock(clock: Callable[[], float]) -> Iterator[None]:
    """Install ``clock`` (e.g. ``lambda: scheduler.now``) as the virtual
    timestamp source for spans opened in the block; contexts stack."""
    clocks = getattr(_STATE, "vclock", None)
    if clocks is None:
        clocks = _STATE.vclock = []
    clocks.append(clock)
    try:
        yield
    finally:
        clocks.pop()


# ---------------------------------------------------------------------------
# span lifecycle
# ---------------------------------------------------------------------------

@dataclass
class SpanHandle:
    """An open span: identity plus its open-time stamps.  The tracker is
    captured at open so a span closes into the sink it opened under even if
    the active tracker changes mid-flight."""
    name: str
    path: str
    depth: int
    t0_wall: float
    t0_virtual: Optional[float]
    tags: Dict[str, Any]
    tracker: Tracker
    flat: bool = False
    _extra: Dict[str, Any] = field(default_factory=dict)


def _emit(h: SpanHandle, t1_wall: float, t1_virtual: Optional[float]) -> None:
    metrics: Dict[str, Any] = {"name": h.name, "path": h.path,
                               "depth": h.depth,
                               "t0_wall": h.t0_wall,
                               "dur_wall_s": max(t1_wall - h.t0_wall, 0.0)}
    if h.flat:
        metrics["flat"] = True
    if h.t0_virtual is not None:
        metrics["t0_virtual"] = h.t0_virtual
        t1v = t1_virtual if t1_virtual is not None else h.t0_virtual
        metrics["dur_virtual_s"] = max(t1v - h.t0_virtual, 0.0)
    metrics.update(h.tags)
    metrics.update(h._extra)
    h.tracker.log_span(metrics)


def current_path() -> str:
    """The open nested-span path on this thread ("" at top level)."""
    stack = _stack()
    return stack[-1].path if stack else ""


@contextmanager
def span(name: str, *, t_virtual: Optional[float] = None,
         clock: Optional[Callable[[], float]] = None,
         **tags: Any) -> Iterator[Optional[SpanHandle]]:
    """Record a nested span around the block.  Yields the handle (or None
    on the noop path); callers may add tags via ``handle.tags[...] = ...``.
    ``clock`` is a per-span virtual clock (e.g. ``lambda: scheduler.now``)
    for call sites outside a :func:`use_virtual_clock` block.  Exceptions
    close the span with an ``error`` tag and re-raise."""
    tr = current_tracker()
    if not tr.active:
        yield None
        return
    stack = _stack()
    parent = stack[-1].path if stack else ""
    if t_virtual is None:
        t_virtual = clock() if clock is not None else virtual_now()
    h = SpanHandle(name=name,
                   path=f"{parent}/{name}" if parent else name,
                   depth=len(stack), t0_wall=time.perf_counter(),
                   t0_virtual=t_virtual,
                   tags=dict(tags), tracker=tr)
    stack.append(h)
    try:
        yield h
    except BaseException as exc:
        h.tags.setdefault("error", type(exc).__name__)
        raise
    finally:
        stack.pop()
        _emit(h, time.perf_counter(),
              clock() if clock is not None else virtual_now())


def begin(name: str, *, t_virtual: Optional[float] = None,
          **tags: Any) -> Optional[SpanHandle]:
    """Open a *flat* span (non-nested lifetime) and return its handle, or
    None when no tracker is active (``end(None)`` is a no-op, so hot call
    sites need no guard of their own)."""
    tr = current_tracker()
    if not tr.active:
        return None
    stack = _stack()
    parent = stack[-1].path if stack else ""
    return SpanHandle(name=name,
                      path=f"{parent}/{name}" if parent else name,
                      depth=len(stack), t0_wall=time.perf_counter(),
                      t0_virtual=(t_virtual if t_virtual is not None
                                  else virtual_now()),
                      tags=dict(tags), tracker=tr, flat=True)


def end(handle: Optional[SpanHandle], *, t_virtual: Optional[float] = None,
        **tags: Any) -> None:
    """Close a span opened with :func:`begin`; extra ``tags`` are merged
    into the emitted event (e.g. the terminal outcome of a task)."""
    if handle is None:
        return
    handle._extra.update(tags)
    t1v = t_virtual if t_virtual is not None else virtual_now()
    _emit(handle, time.perf_counter(), t1v)


def record_span(name: str, *, t0_virtual: float, dur_virtual_s: float,
                **tags: Any) -> None:
    """Emit a span whose virtual interval is already known (link
    transfers): zero wall duration, stamped at the current wall clock."""
    tr = current_tracker()
    if not tr.active:
        return
    stack = _stack()
    parent = stack[-1].path if stack else ""
    now = time.perf_counter()
    h = SpanHandle(name=name,
                   path=f"{parent}/{name}" if parent else name,
                   depth=len(stack), t0_wall=now, t0_virtual=t0_virtual,
                   tags=dict(tags), tracker=tr, flat=True)
    _emit(h, now, t0_virtual + max(dur_virtual_s, 0.0))


# ---------------------------------------------------------------------------
# reading spans back out of a trace
# ---------------------------------------------------------------------------

def span_fields(event: TrackedEvent) -> Dict[str, Any]:
    """A span event's metrics with any scope prefix stripped — spans are
    normally emitted unscoped (via :func:`current_tracker`), but a span
    logged through a ``tracker.scope(...)`` view arrives with prefixed
    keys; this normalizes both so exporters/diff tools see one layout."""
    m = event.metrics
    if event.scope:
        prefix = event.scope + "/"
        m = {(k[len(prefix):] if k.startswith(prefix) else k): v
             for k, v in m.items()}
    return m


def span_tags(fields: Dict[str, Any]) -> Dict[str, Any]:
    """The caller-tag subset of normalized span fields (Perfetto args)."""
    return {k: v for k, v in fields.items() if k not in RESERVED_KEYS}


# package-level aliases: ``spans.begin``/``spans.end`` read naturally with
# the module prefix, ``begin_span``/``end_span`` without it
begin_span = begin
end_span = end

