"""Sharding-aware pytree checkpointing (npz payload + JSON treedef).

Works for any pytree of arrays (params, optimizer state, FL server state).
Arrays are gathered to host (``jax.device_get``) before writing; on restore
the caller re-shards via ``jax.device_put(tree, shardings)``.

Layout:  <dir>/<step>.ckpt.npz  +  <dir>/<step>.ckpt.json (structure + meta)
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten_with_names(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    meta: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    base = os.path.join(directory, f"{step:08d}.ckpt")
    # numpy's savez cannot serialise ml_dtypes (bfloat16 &c.) — store those
    # upcast to f32; restore casts back via the template dtype.
    storable = [a.astype(np.float32) if a.dtype.name not in
                ("float32", "float64", "int32", "int64", "uint8", "int8",
                 "uint16", "int16", "uint32", "uint64", "bool", "float16")
                else a for a in host]
    np.savez(base + ".npz", **{f"leaf_{i}": a for i, a in enumerate(storable)})
    with open(base + ".json", "w") as f:
        json.dump({"step": step, "names": names,
                   "dtypes": [str(a.dtype) for a in host],
                   "shapes": [list(a.shape) for a in host],
                   "meta": meta or {}}, f)
    return base + ".npz"


def load_checkpoint(directory: str, step: int, like: Pytree
                    ) -> Tuple[Pytree, Dict[str, Any]]:
    base = os.path.join(directory, f"{step:08d}.ckpt")
    with open(base + ".json") as f:
        header = json.load(f)
    payload = np.load(base + ".npz")
    leaves = [payload[f"leaf_{i}"] for i in range(len(header["names"]))]
    names, tmpl_leaves, treedef = _flatten_with_names(like)
    if names != header["names"]:
        raise ValueError("checkpoint structure mismatch: "
                         f"{set(names) ^ set(header['names'])}")
    restored = [np.asarray(a, dtype=t.dtype) for a, t in zip(leaves, tmpl_leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored), header["meta"]


def restore_latest(directory: str, like: Pytree
                   ) -> Optional[Tuple[int, Pytree, Dict[str, Any]]]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(int(m.group(1)) for f in os.listdir(directory)
                   if (m := re.match(r"^(\d+)\.ckpt\.npz$", f)))
    if not steps:
        return None
    tree, meta = load_checkpoint(directory, steps[-1], like)
    return steps[-1], tree, meta
