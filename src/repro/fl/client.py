"""Client-side local optimization (Algorithm 1, line 4).

``client_update`` runs mini-batch SGD (optionally with the FedProx proximal
term) for a *traced* number of steps — computational heterogeneity is
simulated by giving each client a per-round step budget and masking steps
beyond it, so the whole client population can be ``vmap``-ed inside one jit.

Loss functions follow the convention
    ``loss_fn(params, (x, y, sample_weight)) -> scalar``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _sample_batch(key: jax.Array, x: jax.Array, y: jax.Array,
                  mask: jax.Array, batch_size: int):
    """Mask-aware with-replacement mini-batch sampling (jit-friendly)."""
    m = x.shape[0]
    probs = mask / jnp.maximum(mask.sum(), 1.0)
    idx = jax.random.choice(key, m, shape=(batch_size,), p=probs)
    return x[idx], y[idx], jnp.ones((batch_size,), jnp.float32)


def local_gradient(loss_fn: Callable, params: Pytree, x: jax.Array,
                   y: jax.Array, mask: jax.Array) -> Pytree:
    """Full-local-dataset gradient ∇F_k(w) — used for the ∇f(w^t) estimate."""
    return jax.grad(loss_fn)(params, (x, y, mask))


def client_update(loss_fn: Callable, global_params: Pytree, x: jax.Array,
                  y: jax.Array, mask: jax.Array, num_steps: jax.Array,
                  key: jax.Array, *, max_steps: int, batch_size: int,
                  lr: float, mu: float = 0.0
                  ) -> Tuple[Pytree, Pytree]:
    """One client's local optimization.

    Returns ``(delta, first_grad)``: the parameter update
    Δ = w_k^{t+1} − w^t, and the first mini-batch gradient at w^t (the K₂=0
    global-gradient estimate reuses these, §III-B).
    """
    if mu != 0.0:
        def step_loss(p, batch):
            base = loss_fn(p, batch)
            sq = sum(jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2)
                     for a, b in zip(jax.tree_util.tree_leaves(p),
                                     jax.tree_util.tree_leaves(global_params)))
            return base + 0.5 * mu * sq
    else:
        step_loss = loss_fn

    grad_fn = jax.grad(step_loss)

    def body(params, inp):
        step_idx, step_key = inp
        bx, by, bw = _sample_batch(step_key, x, y, mask, batch_size)
        g = grad_fn(params, (bx, by, bw))
        live = (step_idx < num_steps).astype(jnp.float32)
        params = jax.tree_util.tree_map(
            lambda p, gg: (p - lr * live * gg.astype(jnp.float32)).astype(p.dtype),
            params, g)
        return params, None

    keys = jax.random.split(key, max_steps)
    steps = jnp.arange(max_steps)
    final, _ = jax.lax.scan(body, global_params, (steps, keys))

    delta = jax.tree_util.tree_map(jnp.subtract, final, global_params)
    # K₂=0 estimate (§III-B): full-local-dataset gradient at w^t — the same
    # quantity a dedicated K₂ device would report.
    first_grad = jax.grad(loss_fn)(global_params, (x, y, mask))
    return delta, first_grad
