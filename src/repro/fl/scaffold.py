"""SCAFFOLD (Karimireddy et al. 2020 — the paper's ref [10]) and its
contextual hybrid.

SCAFFOLD corrects client drift with control variates: the server keeps a
global variate ``c`` and every client a local ``c_i``; local SGD steps use
``g + c − c_i``, and after a round

    c_i⁺ = c_i − c − Δ_i / (steps_i · lr)          (option II of the paper)
    c   ← c + (K/N) · mean_i (c_i⁺ − c_i)

The paper under reproduction criticises SCAFFOLD's statefulness (§V) —
implementing it lets the benchmarks make that comparison concrete, and
``aggregator='contextual'`` gives the beyond-paper SCAFFOLD(Contextual)
combination (drift-corrected local steps + optimal-bound server combine).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import AggregatorConfig, SolveConfig, aggregate
from .server import ServerConfig

Pytree = Any


class ScaffoldState(NamedTuple):
    params: Pytree
    c_global: Pytree          # control variate
    c_locals: Pytree          # stacked (N, …) per-client variates
    round_idx: jax.Array


def init_scaffold(params: Pytree, num_devices: int) -> ScaffoldState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    c_locals = jax.tree_util.tree_map(
        lambda z: jnp.zeros((num_devices,) + z.shape, jnp.float32), zeros)
    return ScaffoldState(params, zeros, c_locals, jnp.zeros((), jnp.int32))


def _sample_batch(key, x, y, mask, batch_size):
    m = x.shape[0]
    probs = mask / jnp.maximum(mask.sum(), 1.0)
    idx = jax.random.choice(key, m, shape=(batch_size,), p=probs)
    return x[idx], y[idx], jnp.ones((batch_size,), jnp.float32)


def build_scaffold_round_fn(loss_fn: Callable, cfg: ServerConfig,
                            samples_per_device: int) -> Callable:
    """round_fn(state, data, sel, num_steps, key) -> (state, info)."""
    steps_per_epoch = max(samples_per_device // cfg.batch_size, 1)
    max_steps = cfg.max_epochs * steps_per_epoch
    lr = cfg.lr

    agg_cfg = AggregatorConfig(
        name=cfg.aggregator,
        solve=SolveConfig(beta=cfg.smoothness, ridge=cfg.ridge),
        gram_scope=cfg.gram_scope)
    agg_fn = aggregate(cfg.aggregator)

    def client_update(params, c_global, c_i, x, y, mask, num_steps, key):
        grad_fn = jax.grad(loss_fn)

        def body(p, inp):
            step_idx, step_key = inp
            bx, by, bw = _sample_batch(step_key, x, y, mask, cfg.batch_size)
            g = grad_fn(p, (bx, by, bw))
            live = (step_idx < num_steps).astype(jnp.float32)
            p = jax.tree_util.tree_map(
                lambda pp, gg, cg, ci: (pp - lr * live * (
                    gg.astype(jnp.float32) + cg - ci)).astype(pp.dtype),
                p, g, c_global, c_i)
            return p, None

        keys = jax.random.split(key, max_steps)
        final, _ = jax.lax.scan(body, params,
                                (jnp.arange(max_steps), keys))
        delta = jax.tree_util.tree_map(jnp.subtract, final, params)
        denom = jnp.maximum(num_steps.astype(jnp.float32) * lr, 1e-12)
        c_i_new = jax.tree_util.tree_map(
            lambda ci, cg, d: ci - cg - d.astype(jnp.float32) / denom,
            c_i, c_global, delta)
        first_grad = jax.grad(loss_fn)(params, (x, y, mask))
        return delta, c_i_new, first_grad

    @jax.jit
    def round_fn(state: ScaffoldState, data, sel, num_steps, key
                 ) -> Tuple[ScaffoldState, Dict[str, jax.Array]]:
        x, y, mask = data
        cx, cy, cm = x[sel], y[sel], mask[sel]
        c_sel = jax.tree_util.tree_map(lambda z: z[sel], state.c_locals)
        keys = jax.random.split(key, sel.shape[0])

        deltas, c_new, grads = jax.vmap(
            lambda ci, xx, yy, mm, ns, kk: client_update(
                state.params, state.c_global, ci, xx, yy, mm, ns, kk)
        )(c_sel, cx, cy, cm, num_steps, keys)

        grad_est = jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0), grads)
        new_params, info = agg_fn(state.params, deltas, grad_est, agg_cfg)

        # server variate update: c += (K/N)·mean(c_i⁺ − c_i)
        K, N = sel.shape[0], cfg.num_devices
        dc = jax.tree_util.tree_map(
            lambda new, old: jnp.mean(new - old[sel], axis=0),
            c_new, state.c_locals)
        c_global = jax.tree_util.tree_map(
            lambda c, d: c + (K / N) * d, state.c_global, dc)
        c_locals = jax.tree_util.tree_map(
            lambda all_c, new: all_c.at[sel].set(new), state.c_locals, c_new)

        info = dict(info)
        info["c_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(l)) for l in
            jax.tree_util.tree_leaves(c_global)))
        return ScaffoldState(new_params, c_global, c_locals,
                             state.round_idx + 1), info

    return round_fn


def run_scaffold(name: str, loss_fn: Callable, apply_fn: Callable,
                 init_params: Pytree, dataset, cfg: ServerConfig,
                 num_rounds: int, selection_seed: int = 1234):
    """Simulation loop mirroring fl.simulation.run_simulation."""
    from .metrics import evaluate_classifier, global_train_loss
    from .server import sample_round
    from .simulation import SimulationResult
    import time

    round_fn = build_scaffold_round_fn(loss_fn, cfg,
                                       dataset.samples_per_device)
    steps_per_epoch = max(dataset.samples_per_device // cfg.batch_size, 1)
    state = init_scaffold(jax.tree_util.tree_map(jnp.asarray, init_params),
                          cfg.num_devices)
    data = (jnp.asarray(dataset.x), jnp.asarray(dataset.y),
            jnp.asarray(dataset.mask))
    rng = np.random.RandomState(selection_seed)
    key = jax.random.PRNGKey(selection_seed)
    result = SimulationResult(name=name)
    t0 = time.time()
    for _ in range(num_rounds):
        sel, _, num_steps = sample_round(rng, cfg, steps_per_epoch)
        key, rk = jax.random.split(key)
        state, info = round_fn(state, data, jnp.asarray(sel),
                               jnp.asarray(num_steps), rk)
        result.train_loss.append(global_train_loss(
            loss_fn, state.params, *data))
        nll, acc = evaluate_classifier(apply_fn, state.params,
                                       jnp.asarray(dataset.test_x),
                                       jnp.asarray(dataset.test_y))
        result.test_acc.append(acc)
        result.test_nll.append(nll)
    result.wall_time = time.time() - t0
    return result
