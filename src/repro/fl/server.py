"""Server-side round orchestration (Algorithm 1 + Algorithm 2).

``build_round_fn`` compiles ONE jitted function executing a full FL round:

  1. gather the K selected clients' shards from the stacked dataset,
  2. ``vmap`` ``client_update`` over them (heterogeneous step budgets),
  3. estimate ∇f(w^t) from K₂ separately-sampled devices (or K₂=0 → reuse
     the round's own first-step gradients, §III-B),
  4. aggregate with the configured strategy (fedavg / folb / contextual / …).

Device sampling itself stays outside jit (numpy RNG, seeded identically
across algorithms as in the paper's §IV-A3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import AggregatorConfig, SolveConfig, aggregate
from .client import client_update, local_gradient

Pytree = Any


@dataclass(frozen=True)
class ServerConfig:
    aggregator: str = "contextual"
    num_devices: int = 30            # N
    clients_per_round: int = 10      # K
    grad_sample: int = 0             # K₂ (0 → reuse S_t, §III-B)
    lr: float = 0.03                 # client learning rate l
    beta: Optional[float] = None     # None → paper's β = 1/l
    mu: float = 0.0                  # FedProx proximal coefficient
    batch_size: int = 32
    min_epochs: int = 1              # computational heterogeneity:
    max_epochs: int = 20             #   epochs ~ U[min, max] per client/round
    gram_scope: Optional[str] = None # e.g. "last_layer" (§III-B efficiency)
    ridge: float = 1e-6
    expected_pool: Optional[int] = None  # N' for contextual_expected
    # -- adversarial wiring (repro.robust) --------------------------------
    # all three stay hashable (frozen dataclasses / tuple): ServerConfig is
    # an lru_cache key for the compiled round function, and the attack is
    # jit-static so corruption happens inside the compiled round
    attack: Optional[Any] = None         # AttackModel; None → honest run
    malicious: Tuple[int, ...] = ()      # device ids under adversarial control
    robust: Optional[Any] = None         # RobustConfig for robust aggregators

    @property
    def smoothness(self) -> float:
        return self.beta if self.beta is not None else 1.0 / self.lr


class RoundState(NamedTuple):
    params: Pytree
    round_idx: jax.Array


def init_server(params: Pytree) -> RoundState:
    return RoundState(params=params, round_idx=jnp.zeros((), jnp.int32))


def build_round_fn(loss_fn: Callable, cfg: ServerConfig,
                   samples_per_device: int) -> Callable:
    """Return ``round_fn(state, data, sel, grad_sel, num_steps, key)``.

    * ``data``       — ``(x (N,m,...), y (N,m), mask (N,m))`` stacked shards
    * ``sel``        — (K,) int32 selected client ids S_t
    * ``grad_sel``   — (K₂,) int32 ids for the ∇f estimate (ignored if K₂=0)
    * ``num_steps``  — (K,) int32 per-client local step budgets
    """
    steps_per_epoch = max(samples_per_device // cfg.batch_size, 1)
    max_steps = cfg.max_epochs * steps_per_epoch
    beta = cfg.smoothness

    agg_cfg = AggregatorConfig(
        name=cfg.aggregator,
        solve=SolveConfig(beta=beta, ridge=cfg.ridge),
        gram_scope=cfg.gram_scope,
        robust=cfg.robust)
    try:
        agg_fn = aggregate(cfg.aggregator)
    except KeyError:
        # robust variants register on package import; pull them in lazily so
        # core never imports upward and honest runs never pay the import
        from .. import robust  # noqa: F401
        agg_fn = aggregate(cfg.aggregator)
    # robust contextual variants consume the stacked per-client gradient
    # reports (the (K, J) cross matrix their pooling defends) instead of the
    # pre-averaged ĝ
    grad_stack = getattr(agg_fn, "grad_stack", False)

    # update-space attacks corrupt inside the jit (label_flip poisons the
    # dataset in run_simulation instead); the adversary key derives by
    # fold_in so the honest clients' key stream is bit-identical to the
    # clean run — attacked vs clean losses differ only through the attack
    attack = cfg.attack
    if attack is not None and (attack.corrupts_data or not cfg.malicious):
        attack = None
    mal = (np.asarray(sorted(set(cfg.malicious)), np.int32)
           if attack is not None else None)

    upd = partial(client_update, loss_fn, max_steps=max_steps,
                  batch_size=cfg.batch_size, lr=cfg.lr, mu=cfg.mu)

    @jax.jit
    def round_fn(state: RoundState, data, sel, grad_sel, num_steps, key
                 ) -> Tuple[RoundState, Dict[str, jax.Array]]:
        x, y, mask = data
        cx, cy, cm = x[sel], y[sel], mask[sel]
        keys = jax.random.split(key, sel.shape[0])
        deltas, first_grads = jax.vmap(
            lambda xx, yy, mm, ns, kk: upd(state.params, xx, yy, mm, ns, kk)
        )(cx, cy, cm, num_steps, keys)
        if attack is not None:
            from ..robust.attacks import corrupt_stacked
            deltas, first_grads = corrupt_stacked(
                attack, deltas, first_grads,
                jnp.isin(sel, jnp.asarray(mal)),
                jax.random.fold_in(key, 0x0BAD))

        if cfg.grad_sample > 0:
            gx, gy, gm = x[grad_sel], y[grad_sel], mask[grad_sel]
            grads = jax.vmap(lambda xx, yy, mm: local_gradient(
                loss_fn, state.params, xx, yy, mm))(gx, gy, gm)
            if attack is not None:
                from ..robust.attacks import corrupt_stacked
                _, grads = corrupt_stacked(
                    attack, grads, grads,
                    jnp.isin(grad_sel, jnp.asarray(mal)),
                    jax.random.fold_in(key, 0x0BAD ^ 1))
        else:
            grads = first_grads
        grad_est = (grads if grad_stack else jax.tree_util.tree_map(
            lambda g: jnp.mean(g, axis=0), grads))

        if cfg.aggregator == "contextual_expected":
            new_params, info = agg_fn(state.params, deltas, grad_est, agg_cfg,
                                      pool_size=cfg.expected_pool or cfg.num_devices)
        else:
            new_params, info = agg_fn(state.params, deltas, grad_est, agg_cfg)

        update_norms = jax.vmap(
            lambda i: jnp.sqrt(sum(jnp.sum(jnp.square(l[i].astype(jnp.float32)))
                                   for l in jax.tree_util.tree_leaves(deltas)))
        )(jnp.arange(sel.shape[0]))
        info = dict(info)
        info["update_norms"] = update_norms
        return RoundState(new_params, state.round_idx + 1), info

    return round_fn


def sample_round(rng: np.random.RandomState, cfg: ServerConfig,
                 steps_per_epoch: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side per-round randomness: S_t, the K₂ gradient sample, and the
    per-client local step budgets (epochs ~ U[min,max] × steps/epoch).

    Both S_t and the K₂ sample are drawn WITHOUT replacement (a device
    reports one gradient, duplicating it would silently bias the ∇f
    estimate), so both K and K₂ must fit in N."""
    if cfg.clients_per_round > cfg.num_devices:
        raise ValueError(
            f"clients_per_round={cfg.clients_per_round} exceeds "
            f"num_devices={cfg.num_devices}; cannot select a round cohort")
    if cfg.grad_sample > cfg.num_devices:
        raise ValueError(
            f"grad_sample={cfg.grad_sample} exceeds num_devices="
            f"{cfg.num_devices}; the K₂ gradient sample is drawn without "
            "replacement — use grad_sample <= num_devices (or 0 to reuse "
            "the round's own first-step gradients)")
    sel = rng.choice(cfg.num_devices, size=cfg.clients_per_round, replace=False)
    k2 = max(cfg.grad_sample, 1)
    grad_sel = rng.choice(cfg.num_devices, size=k2, replace=False)
    epochs = rng.randint(cfg.min_epochs, cfg.max_epochs + 1,
                         size=cfg.clients_per_round)
    num_steps = (epochs * steps_per_epoch).astype(np.int32)
    return sel.astype(np.int32), grad_sel.astype(np.int32), num_steps
