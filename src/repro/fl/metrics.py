"""Evaluation metrics for the FL experiments (paper §IV-A4)."""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def evaluate_classifier(apply_fn: Callable, params: Pytree, x: jax.Array,
                        y: jax.Array, batch: int = 4096
                        ) -> Tuple[float, float]:
    """Return ``(mean_nll, accuracy)`` on a held-out set."""
    n = x.shape[0]
    total_nll, total_correct = 0.0, 0.0
    for start in range(0, n, batch):
        bx, by = x[start:start + batch], y[start:start + batch]
        logits = apply_fn(params, bx)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, by[:, None], axis=-1)[:, 0]
        total_nll += float(jnp.sum(nll))
        total_correct += float(jnp.sum(jnp.argmax(logits, -1) == by))
    return total_nll / n, total_correct / n


@lru_cache(maxsize=32)
def _global_loss_fn(loss_fn: Callable) -> Callable:
    """One jitted evaluator per loss function, with ``params`` as a traced
    *argument* — the former closure re-defined (and re-jitted) a fresh
    ``per_device`` on every call, paying a full recompile each round
    (``tests/test_fl_system.py`` counts the traces)."""
    @jax.jit
    def run(params, x, y, mask):
        def per_device(cx, cy, cm):
            return (loss_fn(params, (cx, cy, cm))
                    * jnp.maximum(cm.sum(), 1.0), cm.sum())

        losses, counts = jax.vmap(per_device)(x, y, mask)
        return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)

    return run


def global_train_loss(loss_fn: Callable, params: Pytree, x: jax.Array,
                      y: jax.Array, mask: jax.Array) -> float:
    """f(w) = mask-weighted mean loss over ALL devices' data (paper eq. 1)."""
    return float(_global_loss_fn(loss_fn)(params, x, y, mask))
