"""End-to-end FL simulation harness (reproduces the paper's experiments).

Runs T rounds of a configured algorithm on a :class:`FederatedDataset`,
keeping ALL host-side randomness (device selection, epoch heterogeneity)
on a dedicated seed so different algorithms see *identical* selections —
exactly the paper's §IV-A3 protocol.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..data.federated import FederatedDataset
from .metrics import evaluate_classifier, global_train_loss
from .server import RoundState, ServerConfig, build_round_fn, init_server, sample_round

Pytree = Any


@dataclass
class SimulationResult:
    name: str
    train_loss: List[float] = field(default_factory=list)
    test_acc: List[float] = field(default_factory=list)
    test_nll: List[float] = field(default_factory=list)
    alpha_history: List[np.ndarray] = field(default_factory=list)
    wall_time: float = 0.0

    def rounds_to_accuracy(self, level: float) -> Optional[int]:
        """First round index whose test accuracy reaches ``level`` (fig. 6)."""
        for i, acc in enumerate(self.test_acc):
            if acc >= level:
                return i + 1
        return None

    def loss_volatility(self) -> float:
        """Mean |Δ loss| between consecutive rounds after round 5 — the
        robustness metric (paper: 'wide fluctuations, even in consecutive
        rounds')."""
        arr = np.asarray(self.train_loss[5:])
        if len(arr) < 2:
            return 0.0
        return float(np.mean(np.abs(np.diff(arr))))


def run_simulation(name: str, loss_fn: Callable, apply_fn: Callable,
                   init_params: Pytree, dataset: FederatedDataset,
                   cfg: ServerConfig, num_rounds: int,
                   selection_seed: int = 1234, eval_every: int = 1,
                   collect_alpha: bool = False) -> SimulationResult:
    round_fn = build_round_fn(loss_fn, cfg, dataset.samples_per_device)
    steps_per_epoch = max(dataset.samples_per_device // cfg.batch_size, 1)

    state = init_server(jax.tree_util.tree_map(jnp.asarray, init_params))
    data = (jnp.asarray(dataset.x), jnp.asarray(dataset.y),
            jnp.asarray(dataset.mask))
    sel_rng = np.random.RandomState(selection_seed)  # shared across algorithms
    key = jax.random.PRNGKey(selection_seed)

    result = SimulationResult(name=name)
    t0 = time.time()
    for t in range(num_rounds):
        sel, grad_sel, num_steps = sample_round(sel_rng, cfg, steps_per_epoch)
        key, round_key = jax.random.split(key)
        state, info = round_fn(state, data, jnp.asarray(sel),
                               jnp.asarray(grad_sel), jnp.asarray(num_steps),
                               round_key)
        if collect_alpha and "alpha" in info:
            result.alpha_history.append(np.asarray(info["alpha"]))
        if (t + 1) % eval_every == 0 or t == num_rounds - 1:
            loss = global_train_loss(loss_fn, state.params, data[0], data[1],
                                     data[2])
            nll, acc = evaluate_classifier(apply_fn, state.params,
                                           jnp.asarray(dataset.test_x),
                                           jnp.asarray(dataset.test_y))
            result.train_loss.append(loss)
            result.test_acc.append(acc)
            result.test_nll.append(nll)
    result.wall_time = time.time() - t0
    return result
