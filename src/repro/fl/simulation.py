"""End-to-end FL simulation harness (reproduces the paper's experiments).

``run_simulation`` runs T synchronous rounds of a configured algorithm on a
:class:`FederatedDataset`, keeping ALL host-side randomness (device
selection, epoch heterogeneity) on a dedicated seed so different algorithms
see *identical* selections — exactly the paper's §IV-A3 protocol.

``run_async_simulation`` drives the same datasets/metrics through the
``repro.edge`` event-driven runtime: devices train at profile-dependent
speeds, updates arrive asynchronously, and the server aggregates buffered
(possibly stale) updates.  Both paths share the eval/metrics code, and the
async event stream is itself a pure function of (fleet, seed) — aggregation
choices never perturb timing — so algorithms remain comparable.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..data.federated import FederatedDataset
from .client import client_update
from .metrics import evaluate_classifier, global_train_loss
from .server import RoundState, ServerConfig, build_round_fn, init_server, sample_round

Pytree = Any


@dataclass
class SimulationResult:
    name: str
    train_loss: List[float] = field(default_factory=list)
    test_acc: List[float] = field(default_factory=list)
    test_nll: List[float] = field(default_factory=list)
    alpha_history: List[np.ndarray] = field(default_factory=list)
    wall_time: float = 0.0

    def rounds_to_accuracy(self, level: float) -> Optional[int]:
        """First round index whose test accuracy reaches ``level`` (fig. 6)."""
        for i, acc in enumerate(self.test_acc):
            if acc >= level:
                return i + 1
        return None

    def loss_volatility(self) -> float:
        """Mean |Δ loss| between consecutive rounds after round 5 — the
        robustness metric (paper: 'wide fluctuations, even in consecutive
        rounds')."""
        arr = np.asarray(self.train_loss[5:])
        if len(arr) < 2:
            return 0.0
        return float(np.mean(np.abs(np.diff(arr))))


def run_simulation(name: str, loss_fn: Callable, apply_fn: Callable,
                   init_params: Pytree, dataset: FederatedDataset,
                   cfg: ServerConfig, num_rounds: int,
                   selection_seed: int = 1234, eval_every: int = 1,
                   collect_alpha: bool = False) -> SimulationResult:
    round_fn = build_round_fn(loss_fn, cfg, dataset.samples_per_device)
    steps_per_epoch = max(dataset.samples_per_device // cfg.batch_size, 1)

    state = init_server(jax.tree_util.tree_map(jnp.asarray, init_params))
    data = (jnp.asarray(dataset.x), jnp.asarray(dataset.y),
            jnp.asarray(dataset.mask))
    sel_rng = np.random.RandomState(selection_seed)  # shared across algorithms
    key = jax.random.PRNGKey(selection_seed)

    result = SimulationResult(name=name)
    t0 = time.time()
    for t in range(num_rounds):
        sel, grad_sel, num_steps = sample_round(sel_rng, cfg, steps_per_epoch)
        key, round_key = jax.random.split(key)
        state, info = round_fn(state, data, jnp.asarray(sel),
                               jnp.asarray(grad_sel), jnp.asarray(num_steps),
                               round_key)
        if collect_alpha and "alpha" in info:
            result.alpha_history.append(np.asarray(info["alpha"]))
        if (t + 1) % eval_every == 0 or t == num_rounds - 1:
            loss = global_train_loss(loss_fn, state.params, data[0], data[1],
                                     data[2])
            nll, acc = evaluate_classifier(apply_fn, state.params,
                                           jnp.asarray(dataset.test_x),
                                           jnp.asarray(dataset.test_y))
            result.train_loss.append(loss)
            result.test_acc.append(acc)
            result.test_nll.append(nll)
    result.wall_time = time.time() - t0
    return result


@dataclass
class AsyncSimulationResult:
    """Metrics of an async run, indexed by *virtual wall-clock* eval points."""
    name: str
    times: List[float] = field(default_factory=list)       # virtual seconds
    versions: List[int] = field(default_factory=list)      # model version
    train_loss: List[float] = field(default_factory=list)
    test_acc: List[float] = field(default_factory=list)
    test_nll: List[float] = field(default_factory=list)
    staleness_mean: List[float] = field(default_factory=list)  # per flush
    alpha_history: List[np.ndarray] = field(default_factory=list)
    updates_per_device: Optional[np.ndarray] = None   # arrivals aggregated
    dispatched: int = 0
    arrived: int = 0
    dropped: int = 0
    wall_time: float = 0.0                                 # real seconds

    def time_to_accuracy(self, level: float) -> Optional[float]:
        """First virtual time at which test accuracy reaches ``level``."""
        return self.to_curve().time_to_accuracy(level)

    def to_curve(self):
        from ..edge.wallclock import WallclockCurve
        return WallclockCurve(name=self.name, times=list(self.times),
                              test_acc=list(self.test_acc),
                              train_loss=list(self.train_loss))


def run_async_simulation(name: str, loss_fn: Callable, apply_fn: Callable,
                         init_params: Pytree, dataset: FederatedDataset,
                         cfg, fleet, num_aggregations: int,
                         selection_seed: int = 1234, eval_every: int = 1,
                         collect_alpha: bool = False) -> AsyncSimulationResult:
    """Event-driven async FL (``cfg`` is a :class:`repro.edge.AsyncConfig`).

    The server keeps up to ``cfg.concurrency`` tasks in flight (default: one
    per device); devices without a task wait in a FIFO queue, so a
    concurrency cap rotates work across the whole fleet rather than pinning
    it to a fixed subset.  Each ARRIVAL is trained against the params it was
    *dispatched* with, buffered, and the buffer is flushed through the
    configured aggregator (``contextual_async`` / ``fedbuff`` /
    ``fedasync``) once ``cfg.buffer_size`` updates are present.  Dropouts
    lose their work; the freed slot goes to the next waiting device.  Runs
    until ``num_aggregations`` buffer flushes have been applied.
    """
    # Imported lazily: repro.edge imports repro.fl at module scope, so the
    # reverse edge must not exist at import time.
    from ..edge.async_server import AsyncBuffer, BufferedUpdate
    from ..edge.events import EventKind, EventScheduler
    from ..edge.wallclock import model_flops_per_step, model_payload_bytes

    if fleet.num_devices != cfg.num_devices:
        raise ValueError(f"fleet has {fleet.num_devices} devices, config "
                         f"expects {cfg.num_devices}")
    if dataset.num_devices < cfg.num_devices:
        raise ValueError(f"dataset has {dataset.num_devices} device shards, "
                         f"need {cfg.num_devices}")

    steps_per_epoch = max(dataset.samples_per_device // cfg.batch_size, 1)
    max_steps = cfg.max_epochs * steps_per_epoch
    upd = jax.jit(partial(client_update, loss_fn, max_steps=max_steps,
                          batch_size=cfg.batch_size, lr=cfg.lr, mu=cfg.mu))

    params = jax.tree_util.tree_map(jnp.asarray, init_params)
    x = jnp.asarray(dataset.x)
    y = jnp.asarray(dataset.y)
    mask = jnp.asarray(dataset.mask)
    test_x, test_y = jnp.asarray(dataset.test_x), jnp.asarray(dataset.test_y)

    scheduler = EventScheduler(
        fleet, seed=selection_seed,
        flops_per_step=model_flops_per_step(params, cfg.batch_size),
        payload_bytes=model_payload_bytes(params))
    buffer = AsyncBuffer(cfg)
    epoch_rng = np.random.RandomState(selection_seed + 1)
    base_key = jax.random.PRNGKey(selection_seed)

    version = 0
    in_flight: Dict[int, tuple] = {}     # device_id -> (params snapshot, version)
    idle = deque(range(fleet.num_devices))   # devices waiting for a task

    def dispatch_next() -> None:
        device_id = idle.popleft()
        epochs = int(epoch_rng.randint(cfg.min_epochs, cfg.max_epochs + 1))
        scheduler.dispatch(device_id, epochs * steps_per_epoch, version)
        in_flight[device_id] = (params, version)

    concurrency = (fleet.num_devices if cfg.concurrency is None
                   else min(cfg.concurrency, fleet.num_devices))
    for _ in range(concurrency):
        dispatch_next()

    result = AsyncSimulationResult(
        name=name, updates_per_device=np.zeros(fleet.num_devices, np.int64))
    max_events = 1000 + 50 * num_aggregations * cfg.buffer_size
    aggs = 0
    events_processed = 0
    t0 = time.time()
    while aggs < num_aggregations:
        if events_processed >= max_events:
            raise RuntimeError(f"exceeded {max_events} events before reaching "
                               f"{num_aggregations} aggregations")
        events_processed += 1
        evt = scheduler.pop()
        if evt is None:
            raise RuntimeError("event queue exhausted before reaching "
                               f"{num_aggregations} aggregations")
        disp_params, disp_version = in_flight.pop(evt.device_id)
        idle.append(evt.device_id)      # back of the queue either way
        if evt.kind == EventKind.DROPOUT:
            dispatch_next()             # lost work; slot goes to next waiter
            continue
        key = jax.random.fold_in(base_key, evt.seq)
        delta, grad = upd(disp_params, x[evt.device_id], y[evt.device_id],
                          mask[evt.device_id], jnp.int32(evt.num_steps), key)
        buffer.add(BufferedUpdate(delta, grad, disp_version, evt.device_id))
        result.updates_per_device[evt.device_id] += 1
        if buffer.ready():
            params, info = buffer.flush(params, version)
            version += 1
            aggs += 1
            result.staleness_mean.append(float(np.mean(info["staleness"])))
            if collect_alpha and "alpha" in info:
                result.alpha_history.append(np.asarray(info["alpha"]))
            if aggs % eval_every == 0 or aggs == num_aggregations:
                loss = global_train_loss(loss_fn, params, x, y, mask)
                nll, acc = evaluate_classifier(apply_fn, params, test_x, test_y)
                result.times.append(scheduler.now)
                result.versions.append(version)
                result.train_loss.append(loss)
                result.test_acc.append(acc)
                result.test_nll.append(nll)
        dispatch_next()                 # fresh task on the freshest model
    result.wall_time = time.time() - t0
    result.dispatched = scheduler.stats.dispatched
    result.arrived = scheduler.stats.arrived
    result.dropped = scheduler.stats.dropped
    return result
