"""End-to-end FL simulation harness (reproduces the paper's experiments).

``run_simulation`` runs T synchronous rounds of a configured algorithm on a
:class:`FederatedDataset`, keeping ALL host-side randomness (device
selection, epoch heterogeneity) on a dedicated seed so different algorithms
see *identical* selections — exactly the paper's §IV-A3 protocol.

``run_async_simulation`` drives the same datasets/metrics through the
``repro.edge`` event-driven runtime: devices train at profile-dependent
speeds, updates arrive asynchronously, and the server aggregates buffered
(possibly stale) updates.  Both paths share the eval/metrics code, and the
async event stream is itself a pure function of (fleet, seed) — aggregation
choices never perturb timing — so algorithms remain comparable.

``run_hier_simulation`` runs synchronous rounds over a ``repro.hier``
multi-tier topology: the model broadcast flows down the tree, devices train,
each aggregation node waits for its members (timeout model: dropouts still
cost their partial time), summarizes, and ships the summary one hop up —
every hop is an event on the PR-1 scheduler, so round times are true
multi-hop critical paths and the per-tier byte ledger measures the uplink
saving the hierarchy exists for.  The per-round array math runs on the
fused engine (``repro.hier.fused``): flat (P, n) round matrices, one
shape-keyed jit call per tier node, Gram reductions through the
backend-aware kernel registry; ``HierSimulationResult.engine`` reports the
real wall-clock split (first-round compile vs steady-state).  With ``HierConfig.compress`` set (the
``hier_contextual_sketch`` aggregator), every summary uplink instead
carries an error-feedback-compressed payload (``repro.compress``): the
ledger records true serialized sizes, downstream solves consistently use
the decodes, and the cloud's γ stage runs on sketched cross-terms.
"""
from __future__ import annotations

import os
import time
from collections import deque
from dataclasses import dataclass, field, replace as dc_replace
from functools import lru_cache, partial
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..data.federated import FederatedDataset
from ..obs import current_tracker, spans
from .client import client_update
from .metrics import evaluate_classifier, global_train_loss
from .server import RoundState, ServerConfig, build_round_fn, init_server, sample_round

Pytree = Any

# how much per-round α/γ history the result dataclasses retain:
#   True  — unbounded (the pre-tracker behavior; fine for short runs)
#   False — none (the tracker stream carries the per-round values instead)
#   int N — a rolling window of the last N entries (long fleet runs used to
#           OOM the host on P-vectors × thousands of rounds)
RecordHistory = Union[bool, int]


def _history_buffer(record_history: RecordHistory):
    """Backing store for a result's per-round history: a plain list when
    unbounded (or disabled), a ``deque(maxlen=N)`` for a rolling window —
    eviction is O(1) per append instead of the O(n) ``del hist[0]`` a list
    pays, which at fleet-scale round counts dominated history upkeep."""
    if record_history is True or record_history is False \
            or record_history == 0:
        return []
    return deque(maxlen=int(record_history))


def _history_push(hist, item: Any, record_history: RecordHistory) -> None:
    if record_history is False or record_history == 0:
        return
    hist.append(item)      # deque(maxlen) evicts the oldest entry itself
    if (record_history is not True and not isinstance(hist, deque)
            and len(hist) > int(record_history)):
        del hist[0]        # list fallback (caller skipped _history_buffer)


def _vec_stats(prefix: str, v) -> Dict[str, float]:
    """Flat summary stats of a weight vector for one tracker event (the full
    vector stays out of the stream unless the caller opted in)."""
    a = np.asarray(v, np.float64)
    if a.size == 0:
        return {}
    return {f"{prefix}_mean": float(a.mean()), f"{prefix}_std": float(a.std()),
            f"{prefix}_min": float(a.min()), f"{prefix}_max": float(a.max())}


# ---------------------------------------------------------------------------
# process-wide compile caches: repeated simulations with the same client
# hyper-parameters (tests, benchmark sweeps) reuse one compiled function
# instead of re-jitting a fresh closure per run
# ---------------------------------------------------------------------------

@lru_cache(maxsize=32)
def _client_update_fn(loss_fn: Callable, max_steps: int, batch_size: int,
                      lr: float, mu: float) -> Callable:
    """Jitted single-device ``client_update`` (async runtime)."""
    return jax.jit(partial(client_update, loss_fn, max_steps=max_steps,
                           batch_size=batch_size, lr=lr, mu=mu))


@lru_cache(maxsize=32)
def _batched_client_update_fn(loss_fn: Callable, max_steps: int,
                              batch_size: int, lr: float, mu: float,
                              mesh=None) -> Callable:
    """Jitted vmapped cohort ``client_update`` (hierarchical runtime).  A
    mesh with a ``'fleet'`` axis shard_maps the cohort over it (params
    replicated, per-device rows split)."""
    upd = partial(client_update, loss_fn, max_steps=max_steps,
                  batch_size=batch_size, lr=lr, mu=mu)

    def cohort(params, xs, ys, ms, ns, keys):
        return jax.vmap(lambda xx, yy, mm, n, k: upd(params, xx, yy, mm, n, k)
                        )(xs, ys, ms, ns, keys)

    if mesh is not None and "fleet" in mesh.shape:
        from ..sharding.specs import shard_cohort_fn
        return shard_cohort_fn(mesh, cohort, num_stacked_args=5)
    return jax.jit(cohort)


@lru_cache(maxsize=16)
def _batched_virtual_update_fn(loss_fn: Callable, max_steps: int,
                               batch_size: int, lr: float, mu: float,
                               dataset, mesh=None) -> Callable:
    """Jitted vmapped cohort ``client_update`` over a
    :class:`~repro.data.fleetgen.VirtualFleetDataset`: each device's shard is
    generated *inside* the jit boundary from its id (counter-based PRNG
    fold-in), so a fleet-scale cohort never materializes an (N, m, dim) host
    array.  ``dataset`` is identity-hashed (frozen, ``eq=False``).  A mesh
    with a ``'fleet'`` axis shard_maps the cohort over it — shard
    generation *and* training both run device-parallel."""
    shard = dataset.shard_fn()
    upd = partial(client_update, loss_fn, max_steps=max_steps,
                  batch_size=batch_size, lr=lr, mu=mu)

    def cohort(params, dev_ids, ns, keys):
        def one(d, n, k):
            xx, yy, mm = shard(d)
            return upd(params, xx, yy, mm, n, k)
        return jax.vmap(one)(dev_ids, ns, keys)

    if mesh is not None and "fleet" in mesh.shape:
        from ..sharding.specs import shard_cohort_fn
        return shard_cohort_fn(mesh, cohort, num_stacked_args=3)
    return jax.jit(cohort)


@lru_cache(maxsize=32)
def _round_fn_cached(loss_fn: Callable, cfg: ServerConfig,
                     samples_per_device: int) -> Callable:
    """One compiled round function per (loss, config, shard size)."""
    return build_round_fn(loss_fn, cfg, samples_per_device)


@dataclass
class SimulationResult:
    name: str
    train_loss: List[float] = field(default_factory=list)
    test_acc: List[float] = field(default_factory=list)
    test_nll: List[float] = field(default_factory=list)
    alpha_history: List[np.ndarray] = field(default_factory=list)
    wall_time: float = 0.0

    def rounds_to_accuracy(self, level: float) -> Optional[int]:
        """First round index whose test accuracy reaches ``level`` (fig. 6)."""
        for i, acc in enumerate(self.test_acc):
            if acc >= level:
                return i + 1
        return None

    def loss_volatility(self) -> float:
        """Mean |Δ loss| between consecutive rounds after round 5 — the
        robustness metric (paper: 'wide fluctuations, even in consecutive
        rounds')."""
        arr = np.asarray(self.train_loss[5:])
        if len(arr) < 2:
            return 0.0
        return float(np.mean(np.abs(np.diff(arr))))


def run_simulation(name: str, loss_fn: Callable, apply_fn: Callable,
                   init_params: Pytree, dataset: FederatedDataset,
                   cfg: ServerConfig, num_rounds: int,
                   selection_seed: int = 1234, eval_every: int = 1,
                   collect_alpha: bool = False,
                   record_history: RecordHistory = True) -> SimulationResult:
    round_fn = _round_fn_cached(loss_fn, cfg, dataset.samples_per_device)
    steps_per_epoch = max(dataset.samples_per_device // cfg.batch_size, 1)
    if (cfg.attack is not None and cfg.attack.corrupts_data
            and cfg.malicious):
        # label-flip adversaries poison their shards before the run; the
        # update-space attacks corrupt inside the compiled round instead
        from ..robust.attacks import poison_labels
        dataset = poison_labels(dataset, cfg.malicious)

    state = init_server(jax.tree_util.tree_map(jnp.asarray, init_params))
    data = (jnp.asarray(dataset.x), jnp.asarray(dataset.y),
            jnp.asarray(dataset.mask))
    sel_rng = np.random.RandomState(selection_seed)  # shared across algorithms
    key = jax.random.PRNGKey(selection_seed)

    tr = current_tracker().scope(f"sync/{name}")
    if tr.active:
        tr.jot(runtime="sync", run=name, aggregator=cfg.aggregator,
               num_rounds=num_rounds)
    result = SimulationResult(name=name)
    result.alpha_history = _history_buffer(record_history)
    t0 = time.time()
    for t in range(num_rounds):
        with spans.span("round", round=t):
            sel, grad_sel, num_steps = sample_round(sel_rng, cfg, steps_per_epoch)
            key, round_key = jax.random.split(key)
            # one jit call fuses the cohort's client updates with the
            # aggregation solve, so they share a span
            with spans.span("update_aggregate"):
                state, info = round_fn(state, data, jnp.asarray(sel),
                                       jnp.asarray(grad_sel),
                                       jnp.asarray(num_steps), round_key)
            if collect_alpha and "alpha" in info:
                _history_push(result.alpha_history, np.asarray(info["alpha"]),
                              record_history)
            event: Dict[str, Any] = {"round": t} if tr.active else {}
            if tr.active and "alpha" in info:
                event.update(_vec_stats("alpha", info["alpha"]))
            if (t + 1) % eval_every == 0 or t == num_rounds - 1:
                with spans.span("eval"):
                    loss = global_train_loss(loss_fn, state.params, data[0],
                                             data[1], data[2])
                    nll, acc = evaluate_classifier(
                        apply_fn, state.params, jnp.asarray(dataset.test_x),
                        jnp.asarray(dataset.test_y))
                result.train_loss.append(loss)
                result.test_acc.append(acc)
                result.test_nll.append(nll)
                if tr.active:
                    event.update(train_loss=loss, test_acc=acc, test_nll=nll)
            if tr.active:
                tr.log(event, step=t)
    result.wall_time = time.time() - t0
    if tr.active and result.train_loss:
        tr.log_summary({"final_train_loss": result.train_loss[-1],
                        "final_test_acc": result.test_acc[-1],
                        "wall_time_s": result.wall_time})
    return result


@dataclass
class AsyncSimulationResult:
    """Metrics of an async run, indexed by *virtual wall-clock* eval points."""
    name: str
    times: List[float] = field(default_factory=list)       # virtual seconds
    versions: List[int] = field(default_factory=list)      # model version
    train_loss: List[float] = field(default_factory=list)
    test_acc: List[float] = field(default_factory=list)
    test_nll: List[float] = field(default_factory=list)
    staleness_mean: List[float] = field(default_factory=list)  # per flush
    alpha_history: List[np.ndarray] = field(default_factory=list)
    updates_per_device: Optional[np.ndarray] = None   # arrivals aggregated
    dispatched: int = 0
    arrived: int = 0
    dropped: int = 0
    wall_time: float = 0.0                                 # real seconds

    def time_to_accuracy(self, level: float) -> Optional[float]:
        """First virtual time at which test accuracy reaches ``level``."""
        return self.to_curve().time_to_accuracy(level)

    def to_curve(self):
        from ..edge.wallclock import WallclockCurve
        return WallclockCurve(name=self.name, times=list(self.times),
                              test_acc=list(self.test_acc),
                              train_loss=list(self.train_loss))


def run_async_simulation(name: str, loss_fn: Callable, apply_fn: Callable,
                         init_params: Pytree, dataset: FederatedDataset,
                         cfg, fleet, num_aggregations: int,
                         selection_seed: int = 1234, eval_every: int = 1,
                         collect_alpha: bool = False,
                         record_history: RecordHistory = True,
                         attack=None, churn=None
                         ) -> AsyncSimulationResult:
    """Event-driven async FL (``cfg`` is a :class:`repro.edge.AsyncConfig`).

    The server keeps up to ``cfg.concurrency`` tasks in flight (default: one
    per device); devices without a task wait in a FIFO queue, so a
    concurrency cap rotates work across the whole fleet rather than pinning
    it to a fixed subset.  Each ARRIVAL is trained against the params it was
    *dispatched* with, buffered, and the buffer is flushed through the
    configured aggregator (``contextual_async`` / ``fedbuff`` /
    ``fedasync``) once ``cfg.buffer_size`` updates are present.  Dropouts
    lose their work; the freed slot goes to the next waiting device.  Runs
    until ``num_aggregations`` buffer flushes have been applied.

    ``attack`` (a :class:`repro.robust.AttackModel`) corrupts each arrival
    from a device in ``fleet.malicious`` before it enters the buffer
    (label-flip attacks poison the malicious shards up front instead);
    ``churn`` (a :class:`repro.robust.ChurnSchedule`) rides on the event
    scheduler, turning tasks dispatched inside an active wave into
    dropouts.
    """
    # Imported lazily: repro.edge imports repro.fl at module scope, so the
    # reverse edge must not exist at import time.
    from ..edge.async_server import AsyncBuffer, BufferedUpdate
    from ..edge.events import EventKind, EventScheduler
    from ..edge.wallclock import model_flops_per_step, model_payload_bytes

    if fleet.num_devices != cfg.num_devices:
        raise ValueError(f"fleet has {fleet.num_devices} devices, config "
                         f"expects {cfg.num_devices}")
    if dataset.num_devices < cfg.num_devices:
        raise ValueError(f"dataset has {dataset.num_devices} device shards, "
                         f"need {cfg.num_devices}")

    malicious = frozenset(getattr(fleet, "malicious", ()))
    if attack is not None and attack.corrupts_data and malicious:
        from ..robust.attacks import poison_labels
        dataset = poison_labels(dataset, malicious)
    live_attack = (attack if attack is not None
                   and not attack.corrupts_data and malicious else None)

    steps_per_epoch = max(dataset.samples_per_device // cfg.batch_size, 1)
    max_steps = cfg.max_epochs * steps_per_epoch
    upd = _client_update_fn(loss_fn, max_steps, cfg.batch_size, cfg.lr,
                           cfg.mu)

    params = jax.tree_util.tree_map(jnp.asarray, init_params)
    x = jnp.asarray(dataset.x)
    y = jnp.asarray(dataset.y)
    mask = jnp.asarray(dataset.mask)
    test_x, test_y = jnp.asarray(dataset.test_x), jnp.asarray(dataset.test_y)

    scheduler = EventScheduler(
        fleet, seed=selection_seed,
        flops_per_step=model_flops_per_step(params, cfg.batch_size),
        payload_bytes=model_payload_bytes(params), churn=churn)
    buffer = AsyncBuffer(cfg)
    epoch_rng = np.random.RandomState(selection_seed + 1)
    base_key = jax.random.PRNGKey(selection_seed)

    version = 0
    in_flight: Dict[int, tuple] = {}     # device_id -> (params snapshot, version)
    idle = deque(range(fleet.num_devices))   # devices waiting for a task

    def dispatch_next() -> None:
        device_id = idle.popleft()
        epochs = int(epoch_rng.randint(cfg.min_epochs, cfg.max_epochs + 1))
        scheduler.dispatch(device_id, epochs * steps_per_epoch, version)
        in_flight[device_id] = (params, version)

    concurrency = (fleet.num_devices if cfg.concurrency is None
                   else min(cfg.concurrency, fleet.num_devices))
    for _ in range(concurrency):
        dispatch_next()

    tr = current_tracker().scope(f"async/{name}")
    if tr.active:
        tr.jot(runtime="async", run=name, aggregator=cfg.aggregator,
               num_aggregations=num_aggregations,
               buffer_size=cfg.buffer_size)
    result = AsyncSimulationResult(
        name=name, updates_per_device=np.zeros(fleet.num_devices, np.int64))
    result.alpha_history = _history_buffer(record_history)
    max_events = 1000 + 50 * num_aggregations * cfg.buffer_size
    aggs = 0
    events_processed = 0
    t0 = time.time()
    with spans.use_virtual_clock(lambda: scheduler.now):
        while aggs < num_aggregations:
            if events_processed >= max_events:
                raise RuntimeError(f"exceeded {max_events} events before reaching "
                                   f"{num_aggregations} aggregations")
            events_processed += 1
            evt = scheduler.pop()
            if evt is None:
                raise RuntimeError("event queue exhausted before reaching "
                                   f"{num_aggregations} aggregations")
            disp_params, disp_version = in_flight.pop(evt.device_id)
            idle.append(evt.device_id)      # back of the queue either way
            if evt.kind == EventKind.DROPOUT:
                dispatch_next()             # lost work; slot goes to next waiter
                continue
            key = jax.random.fold_in(base_key, evt.seq)
            with spans.span("client_update", device=evt.device_id,
                            staleness=version - disp_version):
                delta, grad = upd(disp_params, x[evt.device_id],
                                  y[evt.device_id], mask[evt.device_id],
                                  jnp.int32(evt.num_steps), key)
            if live_attack is not None and evt.device_id in malicious:
                from ..robust.attacks import corrupt_one_jit
                delta, grad = corrupt_one_jit(
                    live_attack, delta, grad,
                    jax.random.fold_in(key, 0x0BAD))
            buffer.add(BufferedUpdate(delta, grad, disp_version, evt.device_id))
            result.updates_per_device[evt.device_id] += 1
            if buffer.ready():
                with spans.span("aggregate", flush=aggs + 1):
                    params, info = buffer.flush(params, version)
                version += 1
                aggs += 1
                stale = float(np.mean(info["staleness"]))
                result.staleness_mean.append(stale)
                if collect_alpha and "alpha" in info:
                    _history_push(result.alpha_history,
                                  np.asarray(info["alpha"]), record_history)
                event: Dict[str, Any] = {}
                if tr.active:
                    event = {"flush": aggs, "t_virtual": scheduler.now,
                             "version": version, "staleness_mean": stale,
                             "staleness_max": float(np.max(info["staleness"]))}
                    if "alpha" in info:
                        event.update(_vec_stats("alpha", info["alpha"]))
                if aggs % eval_every == 0 or aggs == num_aggregations:
                    with spans.span("eval"):
                        loss = global_train_loss(loss_fn, params, x, y, mask)
                        nll, acc = evaluate_classifier(apply_fn, params,
                                                       test_x, test_y)
                    result.times.append(scheduler.now)
                    result.versions.append(version)
                    result.train_loss.append(loss)
                    result.test_acc.append(acc)
                    result.test_nll.append(nll)
                    if tr.active:
                        event.update(train_loss=loss, test_acc=acc, test_nll=nll)
                if tr.active:
                    tr.log(event, step=aggs)
            dispatch_next()                 # fresh task on the freshest model
    result.wall_time = time.time() - t0
    result.dispatched = scheduler.stats.dispatched
    result.arrived = scheduler.stats.arrived
    result.dropped = scheduler.stats.dropped
    if tr.active:
        tr.log_summary({"dispatched": result.dispatched,
                        "arrived": result.arrived,
                        "dropped": result.dropped,
                        "t_virtual_end": scheduler.now,
                        "wall_time_s": result.wall_time})
    return result


@dataclass
class HierSimulationResult:
    """Metrics of a hierarchical run, indexed by virtual wall-clock."""
    name: str
    times: List[float] = field(default_factory=list)       # round-end seconds
    train_loss: List[float] = field(default_factory=list)
    test_acc: List[float] = field(default_factory=list)
    test_nll: List[float] = field(default_factory=list)
    gamma_history: List[np.ndarray] = field(default_factory=list)
    comm: Dict[str, Dict[str, float]] = field(default_factory=dict)
    cloud_uplink_bytes: float = 0.0
    total_bytes: float = 0.0
    dispatched: int = 0         # device tasks only (backhaul transfers are
    arrived: int = 0            # scheduler events but not counted here, so
    dropped: int = 0            # these match AsyncSimulationResult semantics)
    rounds_skipped: int = 0     # rounds where every participant dropped out
    wall_time: float = 0.0
    # engine stats: engine_name ("fused"|"streamed"), the memory model
    # (round_matrix_peak_bytes for the engine used vs what the dense (P, n)
    # matrices would cost, dense_round_matrix_bytes), and real wall-clock —
    # compile_wall_time_s (first round, pays the jit compiles),
    # steady_wall_time_per_round_s (median of the rest), rounds_wall_time_s
    engine: Dict[str, Any] = field(default_factory=dict)

    def time_to_accuracy(self, level: float) -> Optional[float]:
        return self.to_curve().time_to_accuracy(level)

    def to_curve(self):
        from ..edge.wallclock import WallclockCurve
        return WallclockCurve(name=self.name, times=list(self.times),
                              test_acc=list(self.test_acc),
                              train_loss=list(self.train_loss))


def run_hier_simulation(name: str, loss_fn: Callable, apply_fn: Callable,
                        init_params: Pytree, dataset: FederatedDataset,
                        cfg, topology, num_rounds: int,
                        selection_seed: int = 1234, eval_every: int = 1,
                        collect_gamma: bool = False,
                        engine: str = "auto",
                        stream_chunk: Optional[int] = None,
                        mesh=None,
                        record_history: RecordHistory = True,
                        attack=None, churn=None,
                        scheduler_mode: str = "auto",
                        rng_stream: str = "v1",
                        eval_device_cap: int = 4096,
                        cohort_chunk: Optional[int] = None,
                        publish_fn: Optional[Callable[[int, Pytree], None]]
                        = None) -> HierSimulationResult:
    """Synchronous rounds over a multi-tier topology (``cfg`` is a
    :class:`repro.hier.HierConfig`, ``topology`` a :class:`repro.hier.Topology`).

    Per round: the model broadcast flows down the backhaul links, every
    gateway's (fan-in-sampled) devices train at profile speed, each
    aggregation node completes when its last member's terminal event pops —
    dropouts lose their update but still gate the node (timeout model, as in
    the flat sync path) — then its summary rides the uplink as a scheduled
    multi-hop event.  The round ends when the cloud's last child reports; the
    cloud stage goes through the ``core.aggregation`` registry
    (``hier_contextual`` / ``hier_fedavg`` / ``hier_relay`` /
    ``hier_contextual_sketch``).  With ``cfg.compress`` set, summary uplinks
    carry EF-compressed payloads and the γ stage solves on sketched
    cross-terms (see the module docstring and ``repro.compress``).

    ``engine`` picks the round engine: ``"fused"`` (dense (P, n) round
    matrices, fastest at small width), ``"streamed"`` (chunked column
    passes, O(P·chunk) round-matrix memory — big models), or ``"auto"``
    (default): streamed when the dense footprint 2·P·n·4 bytes would exceed
    ``REPRO_DENSE_ROUND_BYTES`` (default 1 GiB).  Device-uplink compression
    needs the dense matrices and forces the fused engine.  ``stream_chunk``
    / ``mesh`` are forwarded to the streamed engine; a mesh with a
    ``'fleet'`` axis additionally shard_maps the cohort client update over
    it (params replicated, per-device rows split — see
    :func:`repro.sharding.specs.shard_cohort_fn`) and row-shards the
    streamed engine's (P, n) statistics pass
    (:func:`repro.sharding.specs.stream_round_shardings`).

    Fleet scale.  ``topology`` may be a :class:`repro.hier.StackedTopology`
    (array-native, no per-device nodes) and ``dataset`` a
    :class:`repro.data.VirtualFleetDataset` (shards generated inside the jit
    boundary from device ids; ``cohort_chunk`` bounds the in-jit shard
    buffer, ``eval_device_cap`` caps the materialized eval subsample — full
    coverage when the fleet fits the cap).  ``scheduler_mode``:

      * ``"event"``  — the per-device event path above;
      * ``"cohort"`` — no per-device Event objects at all: one vectorized
        batch dispatch, per-gateway completion = max member terminal time,
        gateways processed in completion order, backhaul transfers drained
        as events.  Virtual times and results match the event path exactly
        on two-tier trees (the cloud fires only after every gateway; on
        deeper trees transfer tie-breaking at *exactly* equal times may
        order seq numbers differently).  Incompatible with
        ``CompressConfig(device_uplink=True)`` (per-arrival error feedback
        needs per-device events);
      * ``"auto"``   — cohort from 4096 participants per round, else event.

    ``rng_stream`` picks the scheduler's RNG universe (``"v1"`` legacy
    sequential draws, ``"v2"`` counter-based — see
    :class:`repro.edge.EventScheduler`); both are deterministic, v2 is the
    one whose batch dispatch vectorizes.

    ``publish_fn(round, params)``, when given, is called with each round's
    aggregated params the moment the cloud stage applies them (inside the
    round's virtual-clock scope, so ``spans.virtual_now()`` is the round's
    completion time) — the train→serve hook that feeds
    :class:`repro.serve.ModelBus.publish` without the serving side polling
    the result object.  Skipped rounds (every participant dropped) publish
    nothing.
    """
    # Imported lazily: repro.hier imports repro.edge which imports repro.fl,
    # so the reverse edge must not exist at import time.
    from ..compress import ErrorFeedback, payload_gram
    from ..edge.events import EventKind, EventScheduler
    from ..edge.wallclock import model_flops_per_step, model_payload_bytes
    from ..hier.comm import (CommLedger, compressed_summary_bytes,
                             summary_bytes, update_bytes)
    from ..hier.fused import HierRoundEngine
    from ..hier.gateway import CompressedSummary, GatewaySummary
    from ..hier.hier_server import blockdiag_diagnostics
    from ..hier.streamed import StreamedRoundEngine, dense_round_bytes

    fleet = topology.fleet
    virtual = bool(getattr(dataset, "virtual", False))
    if dataset.num_devices < fleet.num_devices:
        raise ValueError(f"dataset has {dataset.num_devices} device shards, "
                         f"topology needs {fleet.num_devices}")

    # -- adversarial wiring (repro.robust): label_flip poisons shards up
    # front; update-space attacks corrupt the cohort's stacked rows after
    # local training, with a key stream independent of the honest fold_ins
    malicious = np.asarray(sorted(getattr(fleet, "malicious", ())), np.int64)
    if attack is not None and attack.corrupts_data and malicious.size:
        if virtual:
            raise ValueError(
                "data-poisoning attacks need materialized shards; a "
                "VirtualFleetDataset generates data inside the jit boundary "
                "(materialize() a subset, or use an update-space attack)")
        from ..robust.attacks import poison_labels
        dataset = poison_labels(dataset, malicious)
    live_attack = (attack if attack is not None
                   and not attack.corrupts_data and malicious.size else None)

    steps_per_epoch = max(dataset.samples_per_device // cfg.batch_size, 1)
    max_steps = cfg.max_epochs * steps_per_epoch
    params = jax.tree_util.tree_map(jnp.asarray, init_params)
    if virtual:
        batch_update = _batched_virtual_update_fn(
            loss_fn, max_steps, cfg.batch_size, cfg.lr, cfg.mu, dataset,
            mesh)
        # eval over a capped, evenly-strided materialized device subsample:
        # exact global loss whenever the fleet fits the cap (the fleet-vs-64
        # equivalence scenario), an unbiased O(cap) estimate beyond it
        from ..data.fleetgen import eval_device_ids
        ex, ey, em = dataset.materialize_arrays(
            eval_device_ids(fleet.num_devices, eval_device_cap))
        x, y, mask = jnp.asarray(ex), jnp.asarray(ey), jnp.asarray(em)
        tx, ty = dataset.test_set()
        test_x, test_y = jnp.asarray(tx), jnp.asarray(ty)
    else:
        batch_update = _batched_client_update_fn(loss_fn, max_steps,
                                                 cfg.batch_size, cfg.lr,
                                                 cfg.mu, mesh)
        x = jnp.asarray(dataset.x)
        y = jnp.asarray(dataset.y)
        mask = jnp.asarray(dataset.mask)
        test_x, test_y = (jnp.asarray(dataset.test_x),
                          jnp.asarray(dataset.test_y))

    n_model = sum(l.size for l in jax.tree_util.tree_leaves(params))
    mbytes = model_payload_bytes(params)
    scheduler = EventScheduler(
        fleet, seed=selection_seed,
        flops_per_step=model_flops_per_step(params, cfg.batch_size),
        payload_bytes=mbytes, churn=churn, rng_stream=rng_stream)
    tr = current_tracker().scope(f"hier/{name}")
    if tr.active:
        tr.jot(runtime="hier", run=name, aggregator=cfg.aggregator,
               depth=topology.depth, num_rounds=num_rounds)
    # the ledger streams every transfer it records (per-tier up/down bytes
    # stamped with the virtual clock) the moment it is recorded
    ledger = CommLedger(topology.depth, tracker=tr.scope("comm"),
                        clock=lambda: scheduler.now)
    sel_rng = np.random.RandomState(selection_seed)
    base_key = jax.random.PRNGKey(selection_seed)

    gateways = topology.gateways            # tier-1 nodes (the cloud, if star)
    solve_cfg = cfg.solve_config()
    relay = cfg.aggregator == "hier_relay"
    tier_mode = cfg.tier_mode
    cloud_kind = "fedavg" if cfg.aggregator == "hier_fedavg" else "combo"

    # -- round-engine selection (the per-round P is fixed by topology+fan_in)
    P_round = sum(min(cfg.fan_in, len(gw.children)) if cfg.fan_in is not None
                  else len(gw.children) for gw in gateways)
    dense_bytes = dense_round_bytes(P_round, n_model)
    if engine not in ("auto", "fused", "streamed"):
        raise ValueError(f"unknown engine '{engine}' (auto|fused|streamed)")
    device_decodes = cfg.compressing and cfg.compress.device_uplink
    if engine == "streamed" and device_decodes:
        # decoded device rows replace rows of the dense matrices; the
        # streamed statistics cannot absorb per-row substitutions — an
        # explicit request must fail loudly, not silently allocate (P, n)
        raise ValueError("engine='streamed' is incompatible with "
                         "CompressConfig(device_uplink=True): decoded "
                         "device rows need the dense round matrices "
                         "(use engine='fused' or 'auto')")
    if engine == "auto":
        budget = float(os.environ.get("REPRO_DENSE_ROUND_BYTES", 1 << 30))
        engine = ("fused" if device_decodes or dense_bytes <= budget
                  else "streamed")
    if scheduler_mode not in ("auto", "event", "cohort"):
        raise ValueError(f"unknown scheduler_mode '{scheduler_mode}' "
                         "(auto|event|cohort)")
    cohort_mode = (scheduler_mode == "cohort"
                   or (scheduler_mode == "auto" and P_round >= 4096))
    if cohort_mode and device_decodes:
        if scheduler_mode == "cohort":
            raise ValueError("scheduler_mode='cohort' is incompatible with "
                             "CompressConfig(device_uplink=True): per-arrival "
                             "error feedback needs per-device events")
        cohort_mode = False
    robust_cfg = getattr(cfg, "robust", None)
    if engine == "streamed":
        eng = StreamedRoundEngine(params, solve_cfg, tier_mode,
                                  cfg.gram_scope, chunk=stream_chunk,
                                  mesh=mesh, donate_params=True,
                                  robust=robust_cfg)
        # the streamed combine donates its params argument off-CPU, and
        # jnp.asarray above is a no-copy identity on jax arrays: copy once
        # so round 1 never invalidates the caller's init_params buffers
        if jax.default_backend() != "cpu":
            params = jax.tree_util.tree_map(jnp.array, params)
    else:
        # dense engine: summaries carry FLAT f32 vectors for ū/ĝ and every
        # tier stage is one shape-keyed jit call; only the final cloud
        # delta converts back to the parameter tree
        eng = HierRoundEngine(params, solve_cfg, tier_mode, cfg.gram_scope,
                              robust=robust_cfg)

    # Summary compression (repro.compress): every compressing sender keeps
    # per-sender error-feedback residuals that persist ACROSS rounds, and
    # linear sketches share one per-round seed so the cloud's Gram stage can
    # run in sketch space (payload_gram).  In a star topology summaries
    # never exist, so only the optional device-uplink compression applies.
    compressing = cfg.compressing
    if compressing:
        comp_u_c, comp_g_c = cfg.compress.build_pair(n_model)
        ef = ErrorFeedback(enabled=cfg.compress.error_feedback)
        compress_devices = cfg.compress.device_uplink

    # model-broadcast delay & per-link down-bytes from the cloud to each
    # gateway (device-tier downlink is inside DeviceProfile.task_time)
    def broadcast_path(gw):
        path, node = [], gw
        while node.parent is not None:
            path.append(node)
            node = topology.nodes[node.parent]
        return list(reversed(path))         # cloud-side hop first

    result = HierSimulationResult(name=name)
    result.gamma_history = _history_buffer(record_history)
    round_walls: List[float] = []
    t0 = time.time()
    with spans.use_virtual_clock(lambda: scheduler.now):
        for t in range(num_rounds):
            with spans.span("round", round=t):
                round_t0 = time.perf_counter()
                round_start = scheduler.now
                # -- selection (identical-selection protocol: one shared RNG).
                # The cohort is flat arrays: per-gateway contiguous blocks of
                # participant rows (part_dev), O(gateways) Python + vectorized
                # numpy — no per-device tuples/dicts at any fleet size.
                groups: List[np.ndarray] = []
                for gw in gateways:
                    devs = np.asarray(gw.children, np.int64)
                    if cfg.fan_in is not None and cfg.fan_in < len(devs):
                        devs = np.sort(sel_rng.choice(devs, cfg.fan_in,
                                                      replace=False))
                    groups.append(devs)
                gw_sizes = np.asarray([len(g) for g in groups], np.int64)
                gw_start = np.zeros(len(groups), np.int64)
                np.cumsum(gw_sizes[:-1], out=gw_start[1:])
                part_dev = np.concatenate(groups)
                P = int(part_dev.size)
                epochs = sel_rng.randint(cfg.min_epochs, cfg.max_epochs + 1,
                                         size=P)
                num_steps = (epochs * steps_per_epoch).astype(np.int32)

                # -- downlink broadcast, then dispatch at each gateway's model-arrival
                down_delay = np.zeros(len(gateways))
                for gi, gw in enumerate(gateways):
                    delay = 0.0
                    for hop in broadcast_path(gw):
                        dl = hop.uplink.downlink_time(mbytes)
                        ledger.record_down(hop.tier, mbytes, dl)
                        delay += dl
                    down_delay[gi] = delay
                # one batched model-fetch record + one batched dispatch for
                # the whole cohort (same draws/trace as the per-device loop
                # under v1; see EventScheduler.dispatch_batch)
                ledger.record_down(0, mbytes, count=P)
                batch = scheduler.dispatch_batch(
                    part_dev, num_steps, version=t,
                    at=round_start + np.repeat(down_delay, gw_sizes),
                    enqueue=not cohort_mode)

                # -- local training for the whole cohort (vmap, one compile) --------
                keys = jax.vmap(jax.random.fold_in, (None, 0))(
                    base_key, jnp.arange(t * P, (t + 1) * P, dtype=jnp.uint32))
                ns_j = jnp.asarray(num_steps)
                with spans.span("client_update", participants=P):
                    if virtual:
                        dev_j = jnp.asarray(part_dev)
                        if cohort_chunk is None or P <= cohort_chunk:
                            deltas, grads = batch_update(params, dev_j, ns_j,
                                                         keys)
                        else:
                            # chunked: bounds the in-jit generated
                            # (chunk, m, dim) shard buffers at fleet scale
                            # (at most two compiled shapes: chunk, remainder)
                            cc = int(cohort_chunk)
                            parts = [batch_update(params, dev_j[s:s + cc],
                                                  ns_j[s:s + cc],
                                                  keys[s:s + cc])
                                     for s in range(0, P, cc)]
                            deltas = jax.tree_util.tree_map(
                                lambda *c: jnp.concatenate(c),
                                *[p[0] for p in parts])
                            grads = jax.tree_util.tree_map(
                                lambda *c: jnp.concatenate(c),
                                *[p[1] for p in parts])
                    else:
                        sel = jnp.asarray(part_dev)
                        deltas, grads = batch_update(params, x[sel], y[sel],
                                                     mask[sel], ns_j, keys)
                if live_attack is not None:
                    from ..robust.attacks import corrupt_stacked_jit
                    mal_mask = jnp.asarray(np.isin(part_dev, malicious))
                    if bool(np.any(np.asarray(mal_mask))):
                        akey = jax.random.fold_in(
                            jax.random.PRNGKey(selection_seed + 7919), t)
                        deltas, grads = corrupt_stacked_jit(
                            live_attack, deltas, grads, mal_mask, akey)
                # the round context is the engine's view of the cohort: the fused
                # engine flattens to (P, n) f32 matrices (cohort slicing is a single
                # in-jit gather per tier node), the streamed engine runs one chunked
                # column pass and keeps only (P, P) statistics — summaries then
                # carry symbolic row-mix refs instead of full-width vectors
                with spans.span("begin_round", engine=eng.name):
                    ctx = eng.begin_round(deltas, grads)

                # -- event loop: device terminals, then multi-hop transfers ---------
                # Contextual tiers run a gradient pre-pass: each gateway ships its
                # cohort ĝ_g up first (n floats), the cloud assembles the global ĝ
                # and broadcasts it back down, and only then do gateways solve and
                # ship (ū_g, G_g, c_g).  Total uplink is identical to packing ĝ_g
                # inside the summary — the pre-pass just reorders it — but every
                # tier's c-term is now priced against the *global* ∇f estimate; a
                # gateway cohort is a skewed sample of a non-IID fleet, and a solve
                # against the skewed local ĝ misweights the whole cohort in a way
                # the parent's γ rescale cannot repair.
                use_prepass = (topology.depth >= 2 and not relay
                               and tier_mode == "contextual"
                               and cfg.gateway_grad == "global")
                interior = [n for tier in range(2, topology.depth + 1)
                            for n in topology.tier_nodes(tier)]
                out_grad = {n.node_id: len(n.children) for n in interior}
                out_sum = {n.node_id: len(n.children) for n in interior}
                recv_grad: Dict[int, list] = {n.node_id: [] for n in interior}
                recv_sum: Dict[int, list] = {n.node_id: [] for n in interior}
                node_ghat: Dict[int, Pytree] = {}
                gw_idxs: Dict[int, np.ndarray] = {}
                meta: Dict[int, tuple] = {}          # event seq -> (kind, node, payload)
                ghat_global = None
                cloud_done = False
                round_info: Dict[str, Any] = {}
                if not cohort_mode:
                    # device id -> cohort row / gateway index, as flat arrays
                    idx_of = np.full(fleet.num_devices, -1, np.int64)
                    idx_of[part_dev] = np.arange(P)
                    part_gw = np.repeat(np.arange(len(gateways)), gw_sizes)
                    out_dev = {gw.node_id: int(gw_sizes[gi])
                               for gi, gw in enumerate(gateways)}
                    survivors: Dict[int, List[int]] = {
                        gw.node_id: [] for gw in gateways}

                def send_up(kind, node, payload, nbytes):
                    parent = topology.nodes[node.parent]
                    dt = node.uplink.uplink_time(nbytes)
                    ledger.record_up(parent.tier, nbytes, dt)
                    evt = scheduler.schedule(dt, node.node_id, version=t)
                    meta[evt.seq] = (kind, node.node_id, payload)

                def send_ghat_down(child_id, ghat):
                    child = topology.nodes[child_id]
                    nbytes = update_bytes(n_model)
                    dt = child.uplink.downlink_time(nbytes)
                    ledger.record_down(child.tier, nbytes, dt)
                    evt = scheduler.schedule(dt, child_id, version=t)
                    meta[evt.seq] = ("ghat", child_id, ghat)

                def gone_up(nid, out_map, complete_fn):
                    """Subtree has nothing to report: release the parent's count."""
                    pid = topology.nodes[nid].parent
                    out_map[pid] -= 1
                    if out_map[pid] == 0:
                        complete_fn(pid)

                def gateway_done(gid, idxs):
                    node = topology.nodes[gid]
                    idxs = np.sort(np.asarray(idxs, np.int64))  # stable order
                    gw_idxs[gid] = idxs
                    if node.parent is None:          # star: the cloud is the gateway
                        finish_cloud(idxs.tolist() if idxs.size else None)
                        return
                    if not idxs.size:
                        if use_prepass:
                            gone_up(gid, out_grad, on_grad_complete)
                        gone_up(gid, out_sum, on_sum_complete)
                        return
                    if relay:
                        send_up("summary", node, idxs.tolist(),
                                len(idxs) * update_bytes(n_model))
                    elif use_prepass:
                        ghat_g = ctx.mean_grad(idxs)
                        send_up("grad", node, (ghat_g, len(idxs)),
                                update_bytes(n_model))
                    else:   # no pre-pass: solve (or average) against the cohort's
                            # own ĝ_g, which rides up inside the summary
                        s = _gateway_summary(gid, idxs, None)
                        if compressing:
                            send_up("summary", node, *_compress_summary(s, gid))
                        else:
                            send_up("summary", node, s,
                                    summary_bytes(len(idxs), n_model,
                                                  include_grad=True))

                def _gateway_summary(gid, idxs, solve_grad):
                    # §III-C at the gateway tier: a fan-in-sampled cohort prices the
                    # pool it was drawn from, exactly like contextual_expected flat
                    pool = len(topology.nodes[gid].children)
                    pool_scale = ((pool - 1) / max(len(idxs) - 1, 1)
                                  if cfg.fan_in is not None and cfg.fan_in < pool
                                  and tier_mode == "contextual" else 1.0)
                    with spans.span("gateway", node=gid, members=len(idxs)):
                        out = ctx.gateway(idxs, solve_grad=solve_grad,
                                          pool_scale=pool_scale)
                    return GatewaySummary(
                        node_id=gid, num_updates=len(idxs),
                        member_ids=part_dev[np.asarray(idxs, np.int64)],
                        G=out["G"], c=out["c"], alpha=out["alpha"],
                        u_bar=out["u_bar"], grad_est=out["ghat"], info=out["info"])

                def _merge_summaries(nid, kids, solve_grad):
                    """Parent-tier merge over what actually arrived: the children's
                    ū refs become this node's members (mass-conserving Σγ=1 stage,
                    see ``hier.gateway.merge_summaries``); member vectors stack
                    inside the jit boundary (fused) or stay symbolic row-mixes
                    (streamed)."""
                    counts = np.asarray([s.num_updates for s in kids], np.float32)
                    with spans.span("merge", node=nid, children=len(kids)):
                        out = ctx.merge([s.u_bar for s in kids],
                                        [s.grad_est for s in kids], counts,
                                        solve_grad=solve_grad)
                    return GatewaySummary(
                        node_id=nid, num_updates=int(counts.sum()),
                        member_ids=np.asarray([s.node_id for s in kids], np.int64),
                        G=out["G"], c=out["c"], alpha=out["alpha"],
                        u_bar=out["u_bar"], grad_est=out["ghat"], info=out["info"])

                def _compress_summary(s, nid):
                    """EF-compress one summary's (ū, ĝ) for its uplink hop; returns
                    (payload, wire bytes).  The same per-round sketch seed is shared
                    by every node and both vectors, so sketched cross-terms compose
                    at the cloud; residual state is per (vector, node).  Under the
                    streamed engine this is where symbolic refs dense-ify: one
                    chunked combine per vector, right before the encode."""
                    comp_u, u_hat = ef.step(("u", nid), ctx.materialize(s.u_bar),
                                            comp_u_c, seed=t)
                    comp_g, g_hat = ef.step(("g", nid), ctx.materialize(s.grad_est),
                                            comp_g_c, seed=t)
                    decoded = dc_replace(s, u_bar=u_hat, grad_est=g_hat)
                    nbytes = compressed_summary_bytes(comp_u.nbytes + comp_g.nbytes)
                    return CompressedSummary(decoded, comp_u, comp_g), nbytes

                def on_grad_complete(nid):
                    nonlocal ghat_global
                    node = topology.nodes[nid]
                    entries = recv_grad[nid]         # [(sender, ĝ ref, count)]
                    if not entries:
                        if node.parent is not None:
                            gone_up(nid, out_grad, on_grad_complete)
                        return
                    counts = np.asarray([c for _, _, c in entries], np.float64)
                    ghat = ctx.compose_grads([g for _, g, _ in entries], counts)
                    if node.parent is None:          # cloud: broadcast the global ĝ
                        ghat_global = ghat
                        for sender, _, _ in entries:
                            send_ghat_down(sender, ghat)
                    else:
                        send_up("grad", node, (ghat, int(counts.sum())),
                                update_bytes(n_model))

                def on_ghat(nid, ghat):
                    node = topology.nodes[nid]
                    node_ghat[nid] = ghat
                    if node.tier == 1:               # gateway: solve and ship
                        idxs = gw_idxs[nid]
                        send_up("summary", node, _gateway_summary(nid, idxs, ghat),
                                summary_bytes(len(idxs), n_model))
                    else:                            # regional: fan the broadcast out
                        for sender, _, _ in recv_grad[nid]:
                            send_ghat_down(sender, ghat)

                def on_sum_complete(nid):
                    node = topology.nodes[nid]
                    kids = recv_sum[nid]
                    if node.parent is None:
                        if not kids:
                            finish_cloud(None)
                        else:
                            finish_cloud(sum(kids, []) if relay else kids)
                        return
                    if not kids:
                        gone_up(nid, out_sum, on_sum_complete)
                        return
                    if relay:
                        fwd = sum(kids, [])
                        send_up("summary", node, fwd,
                                len(fwd) * update_bytes(n_model))
                    elif compressing:
                        # merge over what actually arrived (the decodes), then
                        # re-compress with this node's own error-feedback state
                        s = _merge_summaries(nid, [p.summary for p in kids],
                                             node_ghat.get(nid))
                        send_up("summary", node, *_compress_summary(s, nid))
                    else:
                        s = _merge_summaries(nid, kids, node_ghat.get(nid))
                        send_up("summary", node, s,
                                summary_bytes(len(kids), n_model,
                                              include_grad=not use_prepass))

                def finish_cloud(payload):
                    nonlocal cloud_done, round_info, params
                    if payload is None:              # every participant dropped out
                        result.rounds_skipped += 1
                    else:
                        with spans.span("cloud"):
                            delta, round_info = _cloud_stage(payload)
                            params = ctx.apply(params, delta)
                        if publish_fn is not None:
                            # train→serve hop: hand the round's aggregated
                            # params to the serving side (e.g. ModelBus)
                            # the moment the cloud stage lands them
                            publish_fn(t, params)
                    cloud_done = True

                def _cloud_stage(payload):
                    if isinstance(payload, list) and isinstance(
                            payload[0], (int, np.integer)):
                        # raw updates (star / relay); a star cloud is the fleet's one
                        # gateway, so fan-in sampling prices its pool here too
                        pool = len(topology.nodes[topology.cloud_id].children)
                        scale = ((pool - 1) / max(len(payload) - 1, 1)
                                 if cfg.fan_in is not None and cfg.fan_in < pool
                                 and not relay and tier_mode == "contextual" else 1.0)
                        kind = ("fedavg" if cfg.aggregator == "hier_fedavg"
                                else "raw")
                        return ctx.cloud_raw(payload, kind, solve_scale=scale)
                    if compressing:                      # compressed child summaries
                        csums = payload
                        summaries = [p.summary for p in csums]
                        counts = [s.num_updates for s in summaries]
                        # the P×P stage runs on the sketched cross-terms, corrected
                        # for sketch distortion inside payload_gram; the combine
                        # applies the decodes, so solve and step stay consistent
                        G2c2 = payload_gram(comp_u_c,
                                            [p.comp_u for p in csums],
                                            [p.comp_g for p in csums],
                                            np.asarray(counts, np.float64))
                        ghat = ctx.compose_grads([s.grad_est for s in summaries],
                                                 counts)
                        # no blockdiag diagnostics: the K_g² Gram blocks stayed at
                        # the gateways — that is where the byte saving comes from
                        return ctx.cloud_combo([s.u_bar for s in summaries], counts,
                                               ghat, kind="combo", override=G2c2)
                    summaries = payload              # top-tier child summaries
                    counts = [s.num_updates for s in summaries]
                    ghat = (ghat_global if ghat_global is not None else
                            ctx.compose_grads([s.grad_est for s in summaries],
                                              counts))
                    delta, info = ctx.cloud_combo([s.u_bar for s in summaries],
                                                  counts, ghat, kind=cloud_kind)
                    info = dict(info)
                    info.update(blockdiag_diagnostics(summaries, info["gamma"],
                                                      cfg.smoothness))
                    return delta, info

                def on_transfer(kind, sender, payload):
                    if kind == "grad":
                        pid = topology.nodes[sender].parent
                        recv_grad[pid].append((sender,) + payload)
                        out_grad[pid] -= 1
                        if out_grad[pid] == 0:
                            on_grad_complete(pid)
                    elif kind == "ghat":
                        on_ghat(sender, payload)
                    else:                        # summary
                        pid = topology.nodes[sender].parent
                        recv_sum[pid].append(payload)
                        out_sum[pid] -= 1
                        if out_sum[pid] == 0:
                            on_sum_complete(pid)

                if cohort_mode:
                    # -- cohort device phase: zero per-device Event objects.
                    # Every gateway completes at its members' max terminal
                    # time (dropouts still gate — the timeout model); walk
                    # gateways in completion order, settle each cohort block
                    # vectorized, then drain the backhaul transfers as
                    # events.  The clock may legitimately rewind while
                    # draining transfers scheduled by earlier gateways.
                    max_events = 8 * len(topology.nodes) + 64
                    with spans.span("event_loop"):
                        t_complete = np.maximum.reduceat(batch.t_end, gw_start)
                        for gi in np.argsort(t_complete, kind="stable"):
                            scheduler.advance_to(float(t_complete[gi]))
                            s = int(gw_start[gi])
                            e = s + int(gw_sizes[gi])
                            alive = s + np.flatnonzero(~batch.dropped[s:e])
                            result.arrived += int(alive.size)
                            result.dropped += e - s - int(alive.size)
                            gid = gateways[int(gi)].node_id
                            ledger.record_up(topology.nodes[gid].tier,
                                             update_bytes(n_model),
                                             count=int(alive.size))
                            gateway_done(gid, alive)
                        scheduler.complete_batch(batch)
                        for _ in range(max_events):
                            if cloud_done:
                                break
                            evt = scheduler.pop()
                            if evt is None or evt.seq not in meta:
                                raise RuntimeError(
                                    f"round {t}: non-transfer event in the "
                                    "cohort drain")
                            on_transfer(*meta.pop(evt.seq))
                else:
                    max_events = 8 * (P + len(topology.nodes)) + 64
                    with spans.span("event_loop"):
                        for _ in range(max_events):
                            if cloud_done:
                                break
                            evt = scheduler.pop()
                            if evt is None:
                                raise RuntimeError(f"round {t}: event queue "
                                                   "exhausted before the "
                                                   "cloud completed")
                            if evt.seq in meta:      # backhaul transfer arrival
                                on_transfer(*meta.pop(evt.seq))
                            else:                    # device terminal event
                                pi = int(idx_of[evt.device_id])
                                gid = gateways[int(part_gw[pi])].node_id
                                if evt.kind == EventKind.ARRIVAL:
                                    survivors[gid].append(pi)
                                    result.arrived += 1
                                    if compressing and compress_devices:
                                        # per-device error feedback: the residual of every
                                        # round a device DID report persists on-device.
                                        # BOTH streams compress — the solves downstream
                                        # consume the gradient too, so an upload that only
                                        # shipped the update would be under-priced.  The
                                        # decoded rows enter the round context as ONE
                                        # gathered array update per cohort (fused engine;
                                        # the streamed engine defers to it for this config).
                                        comp_d, vhat = ef.step(
                                            ("dev", evt.device_id), ctx.D[pi],
                                            comp_u_c, seed=t)
                                        comp_dg, ghat = ef.step(
                                            ("devg", evt.device_id), ctx.GM[pi],
                                            comp_g_c, seed=t)
                                        ctx.add_decoded_row(pi, vhat, ghat)
                                        ledger.record_up(
                                            topology.nodes[gid].tier,
                                            comp_d.nbytes + comp_dg.nbytes)
                                    else:
                                        ledger.record_up(topology.nodes[gid].tier,
                                                         update_bytes(n_model))
                                else:
                                    result.dropped += 1
                                out_dev[gid] -= 1
                                if out_dev[gid] == 0:
                                    gateway_done(gid, survivors[gid])
                if not cloud_done:
                    raise RuntimeError(f"round {t}: exceeded {max_events} events")
                result.dispatched += P
                round_walls.append(time.perf_counter() - round_t0)

                if collect_gamma and "gamma" in round_info:
                    _history_push(result.gamma_history,
                                  np.asarray(round_info["gamma"]), record_history)
                event: Dict[str, Any] = {}
                if tr.active:
                    event = {"round": t, "t_virtual": scheduler.now,
                             "round_virtual_s": scheduler.now - round_start,
                             "round_wall_s": round_walls[-1], "participants": P,
                             "rounds_skipped": result.rounds_skipped}
                    if "gamma" in round_info:
                        event.update(_vec_stats("gamma", round_info["gamma"]))
                if (t + 1) % eval_every == 0 or t == num_rounds - 1:
                    with spans.span("eval"):
                        loss = global_train_loss(loss_fn, params, x, y, mask)
                        nll, acc = evaluate_classifier(apply_fn, params,
                                                       test_x, test_y)
                    result.times.append(scheduler.now)
                    result.train_loss.append(loss)
                    result.test_acc.append(acc)
                    result.test_nll.append(nll)
                    if tr.active:
                        event.update(train_loss=loss, test_acc=acc, test_nll=nll)
                if tr.active:
                    tr.log(event, step=t)
    result.wall_time = time.time() - t0
    result.comm = ledger.report()
    result.cloud_uplink_bytes = ledger.cloud_uplink_bytes
    result.total_bytes = ledger.total_bytes()
    # compressed summary tiers dense-ify above the encode hop: the largest
    # summary-level fan-in bounds the (members, n) stacks the streamed
    # engine's fused-fallback stages hold (0 when uncompressed / fused)
    dense_members = 0
    if compressing and eng.name == "streamed":
        dense_members = max((len(nd.children)
                             for tier in range(2, topology.depth + 1)
                             for nd in topology.tier_nodes(tier)), default=0)
    result.engine = {
        "engine_name": eng.name,
        # deterministic memory model of the engine actually used vs the
        # dense (P, n) footprint — THE acceptance metric for big models
        "round_matrix_peak_bytes": eng.peak_round_bytes(
            P_round, dense_fallback_members=dense_members),
        "dense_round_matrix_bytes": dense_bytes,
    }
    if round_walls:
        steady = round_walls[1:] if len(round_walls) > 1 else round_walls
        result.engine.update({
            "compile_wall_time_s": round_walls[0],
            "steady_wall_time_per_round_s": float(np.median(steady)),
            "rounds_wall_time_s": float(np.sum(round_walls)),
        })
    if tr.active:
        tr.log_summary({**result.engine,
                        "cloud_uplink_bytes": result.cloud_uplink_bytes,
                        "total_bytes": result.total_bytes,
                        "t_virtual_end": scheduler.now,
                        "wall_time_s": result.wall_time})
    return result
