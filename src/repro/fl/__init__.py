from .client import client_update, local_gradient
from .metrics import evaluate_classifier, global_train_loss
from .scaffold import ScaffoldState, build_scaffold_round_fn, run_scaffold
from .server import RoundState, ServerConfig, build_round_fn, init_server
from .simulation import SimulationResult, run_simulation

__all__ = [
    "client_update", "local_gradient", "evaluate_classifier",
    "global_train_loss", "RoundState", "ServerConfig", "build_round_fn",
    "init_server", "SimulationResult", "run_simulation", "ScaffoldState",
    "build_scaffold_round_fn", "run_scaffold",
]
