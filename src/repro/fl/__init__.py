from .client import client_update, local_gradient
from .metrics import evaluate_classifier, global_train_loss
from .scaffold import ScaffoldState, build_scaffold_round_fn, run_scaffold
from .server import RoundState, ServerConfig, build_round_fn, init_server
from .simulation import (AsyncSimulationResult, HierSimulationResult,
                         SimulationResult, run_async_simulation,
                         run_hier_simulation, run_simulation)

__all__ = [
    "client_update", "local_gradient", "evaluate_classifier",
    "global_train_loss", "RoundState", "ServerConfig", "build_round_fn",
    "init_server", "AsyncSimulationResult", "HierSimulationResult",
    "SimulationResult", "run_async_simulation", "run_hier_simulation",
    "run_simulation", "ScaffoldState", "build_scaffold_round_fn",
    "run_scaffold",
]
