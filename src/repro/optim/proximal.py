"""FedProx proximal objective wrapper (Li et al. 2020, paper ref [8]).

FedProx adds ``(μ/2)·‖w − w_global‖²`` to each client's local objective so
local optimization cannot drift arbitrarily far from the round's global
parameters.  The paper's FedProx (Contextual) variant = this local objective
+ the contextual server aggregation.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


def proximal_loss_fn(loss_fn: Callable, mu: float) -> Callable:
    """Wrap ``loss_fn(params, batch) -> scalar`` with the proximal term.

    The returned function has signature ``(params, batch, anchor) -> scalar``
    where ``anchor`` is the round's global parameters w^t.
    """
    if mu == 0.0:
        return lambda params, batch, anchor: loss_fn(params, batch)

    def wrapped(params: Pytree, batch, anchor: Pytree):
        base = loss_fn(params, batch)
        sq = sum(jnp.sum((p.astype(jnp.float32) - a.astype(jnp.float32)) ** 2)
                 for p, a in zip(jax.tree_util.tree_leaves(params),
                                 jax.tree_util.tree_leaves(anchor)))
        return base + 0.5 * mu * sq

    return wrapped
