"""Minimal, pytree-native optimizers (no optax in this container).

All optimizers share the functional interface

    state = <name>_init(params)
    new_params, new_state = <name>_update(params, grads, state, lr, **kw)

``make_optimizer(name, **defaults)`` returns an ``(init, update)`` pair with
the hyper-parameters bound, which is what the FL client loop and the
distributed train step consume.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class SGDState(NamedTuple):
    momentum: Pytree           # zeros-like(params) when momentum == 0 too
    count: jax.Array


class AdamWState(NamedTuple):
    mu: Pytree
    nu: Pytree
    count: jax.Array


OptState = Any


def sgd_init(params: Pytree) -> SGDState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return SGDState(momentum=zeros, count=jnp.zeros((), jnp.int32))


def sgd_update(params: Pytree, grads: Pytree, state: SGDState, lr,
               momentum: float = 0.0, weight_decay: float = 0.0,
               nesterov: bool = False) -> Tuple[Pytree, SGDState]:
    def upd(p, g, m):
        g = g + weight_decay * p if weight_decay else g
        m_new = momentum * m + g
        step = (g + momentum * m_new) if nesterov else (m_new if momentum else g)
        return (p - lr * step).astype(p.dtype), m_new.astype(m.dtype)

    flat = jax.tree_util.tree_map(upd, params, grads, state.momentum)
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mom = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return new_params, SGDState(new_mom, state.count + 1)


def adamw_init(params: Pytree) -> AdamWState:
    zeros32 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(mu=zeros32,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros32),
                      count=jnp.zeros((), jnp.int32))


def adamw_update(params: Pytree, grads: Pytree, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0) -> Tuple[Pytree, AdamWState]:
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu_new = b1 * mu + (1 - b1) * g32
        nu_new = b2 * nu + (1 - b2) * g32 * g32
        step = (mu_new / c1) / (jnp.sqrt(nu_new / c2) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p - lr * step).astype(p.dtype), mu_new, nu_new

    flat = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], flat, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), AdamWState(pick(1), pick(2), count)


def make_optimizer(name: str, **defaults) -> Tuple[Callable, Callable]:
    """Return ``(init_fn, update_fn(params, grads, state, lr))`` with the
    hyper-parameters bound."""
    if name == "sgd":
        def update(params, grads, state, lr):
            return sgd_update(params, grads, state, lr, **defaults)
        return sgd_init, update
    if name == "adamw":
        def update(params, grads, state, lr):
            return adamw_update(params, grads, state, lr, **defaults)
        return adamw_init, update
    raise KeyError(f"unknown optimizer '{name}'")
