from .optimizers import (AdamWState, OptState, SGDState, adamw_init,
                         adamw_update, make_optimizer, sgd_init, sgd_update)
from .proximal import proximal_loss_fn
from .schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = [
    "AdamWState", "OptState", "SGDState", "adamw_init", "adamw_update",
    "make_optimizer", "sgd_init", "sgd_update", "proximal_loss_fn",
    "constant", "cosine_decay", "linear_warmup_cosine",
]
