from .specs import (batch_pspec, cache_pspecs, fleet_mesh, param_pspecs,
                    shard_cohort_fn, spec_for_leaf, stream_column_shardings,
                    stream_round_shardings)

__all__ = ["batch_pspec", "cache_pspecs", "fleet_mesh", "param_pspecs",
           "shard_cohort_fn", "spec_for_leaf", "stream_column_shardings",
           "stream_round_shardings"]
