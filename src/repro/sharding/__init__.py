from .specs import (batch_pspec, cache_pspecs, param_pspecs, spec_for_leaf)

__all__ = ["batch_pspec", "cache_pspecs", "param_pspecs", "spec_for_leaf"]
