"""Partition-spec rules (DESIGN.md §7).

Strategy per tensor class (mesh axes: optional 'pod', 'data', 'model'):

  * large 2-D projection weights — tensor-parallel on the contraction-free
    dim over 'model'; for ≥`fsdp_threshold` params additionally FSDP the
    other dim over 'data' (all-gathered per layer by GSPMD on use);
  * expert tensors (E, d, ff) — expert-parallel: E over 'model';
  * embeddings (V, d) — vocab over 'model' (+ d over 'data' when FSDP);
  * norms / biases / small vectors — replicated;
  * activations: batch over 'data' ('pod','data' when multi-pod);
  * KV caches: batch over 'data', seq over 'model' (flash-decode LSE
    sharding — valid for every arch since seq always divides, unlike
    kv_heads);  long_500k (batch 1): seq over ('data','model').

Every axis assignment is guarded by divisibility; a non-dividing axis is
dropped (replicated) rather than producing an invalid sharding.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ArchConfig

Pytree = Any

FSDP_THRESHOLD = 7_000_000_000   # params; ≥7B also shards over 'data'


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _fits(dim: int, mesh: Mesh, name) -> bool:
    return dim % _axis_size(mesh, name) == 0


def _guard(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis assignments that don't divide the corresponding dim."""
    out = []
    for dim, name in zip(shape, spec):
        out.append(name if name is not None and _fits(dim, mesh, name) else None)
    return P(*out)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def spec_for_leaf(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
                  fsdp: bool) -> P:
    """Rule table: first match wins.  `shape` includes any leading stacked
    layer axis (we detect and skip it)."""
    d_axis = "data" if fsdp else None
    rules = [
        # --- MoE expert tensors (L, E, d, ff) / (E, d, ff)
        (r"moe/w_(up|gate|down)$", lambda s: ("model", d_axis, None)),
        (r"moe/router$", lambda s: (None, None)),
        # --- embeddings / unembeddings
        (r"(^|/)embed$", lambda s: ("model", d_axis)),
        (r"(^|/)lm_head$", lambda s: (d_axis, "model")),
        (r"img_proj$", lambda s: (None, "model")),
        # --- attention projections (column-parallel qkv, row-parallel o)
        (r"attn/w[qkv]$|cross/w[qkv]$", lambda s: (d_axis, "model")),
        (r"attn/wo$|cross/wo$", lambda s: ("model", d_axis)),
        (r"attn/b[qkv]$|cross/b[qkv]$", lambda s: ("model",)),
        # --- dense MLP (column-parallel up/gate, row-parallel down)
        (r"mlp/w_(up|gate)$|shared/w_(up|gate)$", lambda s: (d_axis, "model")),
        (r"mlp/w_down$|shared/w_down$", lambda s: ("model", d_axis)),
        # --- mamba2
        (r"mamba/in_proj$", lambda s: (d_axis, "model")),
        (r"mamba/out_proj$", lambda s: ("model", d_axis)),
        (r"mamba/conv_[wb]$", lambda s: (None,) * len(s)),
        # --- rwkv6
        (r"rwkv/(wr|wk|wv|wg|ffn_k|ffn_r|w_A)$", lambda s: (d_axis, "model")),
        (r"rwkv/(wo|ffn_v|w_B)$", lambda s: ("model", d_axis)),
    ]
    for pat, builder in rules:
        if re.search(pat, path_str):
            spec = builder(shape)
            # leading stacked-layer axes (scan stacks) stay unsharded
            lead = len(shape) - len(spec)
            return _guard((None,) * lead + tuple(spec), shape, mesh)
    return P()   # replicate (norms, scalars, small vectors)


def param_pspecs(cfg: ArchConfig, params_shape: Pytree, mesh: Mesh,
                 mode: str = "tp") -> Pytree:
    """Map a pytree of ShapeDtypeStructs (or arrays) to PartitionSpecs.

    ``mode='tp'`` — tensor/expert-parallel over 'model' (+FSDP ≥7B);
    ``mode='dp'`` — fully replicated params (§Perf iteration 1: small models
    use every mesh axis as data parallelism; the per-layer TP all-reduces
    disappear and the only collective left is the cohort combine)."""
    if mode == "dp":
        flat, treedef = jax.tree_util.tree_flatten(params_shape)
        return jax.tree_util.tree_unflatten(treedef, [P()] * len(flat))
    fsdp = cfg.param_count_estimate() >= FSDP_THRESHOLD
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [spec_for_leaf(_path_str(p), tuple(l.shape), mesh, fsdp)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspec(mesh: Mesh, batch_size: int) -> P:
    """Sharding for the leading batch axis of inputs."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    name = tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)
    if name is None or batch_size % _axis_size(mesh, name) != 0:
        # try data only, else replicate (long_500k batch=1)
        if "data" in mesh.shape and batch_size % mesh.shape["data"] == 0:
            return P("data")
        return P(None)
    return P(name)


def cache_pspecs(cfg: ArchConfig, cache_shape: Pytree, mesh: Mesh,
                 batch_size: int) -> Pytree:
    """KV caches: (L, B, S, KV, hd) → batch@data, seq@model; batch-1 decode
    shards seq over ('data','model').  SSM states: (L, B, H, P[, N]) →
    batch@data, heads@model."""
    bspec = batch_pspec(mesh, batch_size)
    batch_axis = bspec[0] if len(bspec) else None
    seq_axes = ("model",) if batch_axis is not None else \
        tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    seq_axis = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    def leaf_spec(path, leaf):
        shape = tuple(leaf.shape)
        name = _path_str(path)
        if leaf.ndim == 0 or "position" in name:
            return P()
        if leaf.ndim == 5:      # (L, B, S, KV, hd) stacked KV cache
            return _guard((None, batch_axis, seq_axis, None, None), shape, mesh)
        if leaf.ndim == 4 and "wkv" in name:    # rwkv (L?, B, H, P, P)…
            return _guard((None, batch_axis, "model", None), shape, mesh)
        if leaf.ndim == 5 and "ssm" in name:
            return _guard((None, batch_axis, "model", None, None), shape, mesh)
        if leaf.ndim == 4:      # (L, B, W, C) conv state or (B,S,KV,hd)
            return _guard((None, batch_axis, None, None), shape, mesh)
        if leaf.ndim == 3:
            return _guard((None, batch_axis, None), shape, mesh)
        if leaf.ndim == 2:
            return _guard((None, batch_axis), shape, mesh)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = [leaf_spec(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def stream_column_shardings(mesh: Mesh, stacked: Pytree) -> Pytree:
    """Shardings for a *stacked* round pytree (leading P device axis per
    leaf) that partition the streamed engine's chunk axis: the trailing
    (column) dim of every ≥2-D leaf is sharded over every available mesh
    axis, so the ``stream_stats`` scan partitions its column windows across
    devices and GSPMD all-reduces the (P, P) accumulators.  Guarded by
    divisibility like every other rule here — a non-dividing leaf stays
    replicated rather than producing an invalid sharding."""
    axes = [a for a in ("pod", "data", "model") if a in mesh.shape]
    name = tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)

    def leaf_sharding(leaf):
        shape = tuple(leaf.shape)
        if name is None or len(shape) < 2:
            return NamedSharding(mesh, P())
        spec = (None,) * (len(shape) - 1) + (name,)
        return NamedSharding(mesh, _guard(spec, shape, mesh))

    return jax.tree_util.tree_map(leaf_sharding, stacked)


def fleet_mesh(devices=None) -> Mesh:
    """One-axis 'fleet' mesh over the host's accelerators — the device-axis
    sharding entry point for fleet-scale cohorts (compose it with
    'data'/'model' axes by building the Mesh yourself)."""
    devices = jax.devices() if devices is None else devices
    return Mesh(np.asarray(devices), ("fleet",))


def stream_round_shardings(mesh: Mesh, stacked: Pytree) -> Pytree:
    """:func:`stream_column_shardings` plus a leading device-axis partition:
    with a ``'fleet'`` mesh axis the leading P (device) dim of every leaf
    shards over it — each mesh device holds its own row block of the round
    matrices, so the streamed engine's (P, n) statistics pass runs
    row-parallel — composing with the chunk-axis column sharding over the
    remaining axes.  Without a ``'fleet'`` axis this is exactly
    :func:`stream_column_shardings` (back-compat for existing meshes)."""
    if "fleet" not in mesh.shape:
        return stream_column_shardings(mesh, stacked)
    col_axes = [a for a in ("pod", "data", "model") if a in mesh.shape]
    col = tuple(col_axes) if len(col_axes) > 1 else \
        (col_axes[0] if col_axes else None)

    def leaf_sharding(leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        if len(shape) == 1:
            return NamedSharding(mesh, _guard(("fleet",), shape, mesh))
        spec = ("fleet",) + (None,) * (len(shape) - 2) + (col,)
        return NamedSharding(mesh, _guard(spec, shape, mesh))

    return jax.tree_util.tree_map(leaf_sharding, stacked)


def shard_cohort_fn(mesh: Mesh, cohort_fn, num_stacked_args: int):
    """``shard_map`` a cohort function ``(params, *stacked_args) -> pytree``
    over the ``'fleet'`` axis: params replicated, every stacked argument and
    every output leaf partitioned on its leading cohort axis — each mesh
    device trains its own block of the cohort.  Cohorts that don't divide
    the axis are padded (first row repeated) and sliced back, so any P
    works.  Returns a jitted callable."""
    from jax.experimental.shard_map import shard_map
    import jax.numpy as jnp

    axis = mesh.shape["fleet"]
    inner = shard_map(
        cohort_fn, mesh=mesh,
        in_specs=(P(),) + (P("fleet"),) * num_stacked_args,
        out_specs=P("fleet"), check_rep=False)

    @jax.jit
    def wrapped(params, *args):
        B = args[0].shape[0]
        pad = (-B) % axis
        if pad:
            args = tuple(jnp.concatenate(
                [a, jnp.repeat(a[:1], pad, axis=0)]) for a in args)
        out = inner(params, *args)
        if pad:
            out = jax.tree_util.tree_map(lambda a: a[:B], out)
        return out

    return wrapped


def named(mesh: Mesh, tree_of_specs: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))
