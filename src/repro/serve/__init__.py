"""Serving stack: continuous-batching decode with hot-swapped FL models.

Closes the train-to-serve loop: ``run_hier_simulation``'s ``publish_fn``
hook pushes each round's aggregated params onto a :class:`ModelBus`, a
:class:`DecodeEngine` adopts versions at scan-chunk boundaries without
draining in-flight requests, and :mod:`repro.serve.offline` replays request
traces under the virtual clock for staleness-vs-quality accounting.
"""
from .bus import ModelBus, Published
from .engine import Completion, DecodeEngine, Request
from .offline import ScheduledModel, TraceRequest, replay, synthetic_trace

__all__ = [
    "Completion", "DecodeEngine", "ModelBus", "Published", "Request",
    "ScheduledModel", "TraceRequest", "replay", "synthetic_trace",
]
