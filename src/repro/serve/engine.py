"""Slot-based continuous-batching decode engine with hot-swapped models.

One persistent KV cache (``init_lm_cache(..., ring=False)``) holds
``num_slots`` resident requests; each batch row is an independent request at
its own depth, tracked by per-slot ``positions``/``stop_at`` arrays that
feed ``flash_decode``'s length masking.  Token generation runs as a jitted
``lax.scan`` over ``scan_chunk`` steps with the cache and slot arrays
donated — one device dispatch per chunk instead of one per token, which is
where the steady-state throughput over the per-token-jit loop comes from.

Requests are admitted and retired at chunk boundaries.  Prompts prefill in
fixed-size chunks (:func:`repro.models.transformer.prefill_chunk`), one
chunk per engine step, so a long prompt never stalls resident decoders for
more than one chunk.  Rows of a slot at index ≥ its length may hold
retired-request or padded-prefill garbage; they are never attended because
``flash_decode`` masks ``kpos < length`` and decode writes row ``p``
exactly when the slot's position reaches ``p`` (write-before-read).
Inactive slots (``pos >= stop_at`` — retired, fresh, or mid-chunked-
prefill) write nothing: ``decode_slots`` drops their K/V scatter, so a
slot's stale device position can never clobber rows a new request is
being chunk-prefilled into while other slots decode.

Model hot-swap: the engine re-snapshots its :class:`~repro.serve.bus.ModelBus`
at every step boundary.  An in-flight scan chunk runs entirely on one
published tree — a request may span versions, but a single forward pass
never sees a torn/mixed-version tree.  Swap stall (publish→adopt wall
latency) is recorded as a ``serve/model_swap`` span; every completion
carries the model versions it was admitted and finished under.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig
from ..models.transformer import (decode_slots, init_lm_cache, prefill_chunk)
from ..obs import spans
from .bus import ModelBus

Pytree = Any


@dataclass
class Request:
    """A generation request: prompt token ids plus a generation budget."""
    rid: int
    prompt: Sequence[int]
    max_new: int
    t_submit_wall: float = 0.0
    t_submit_virtual: Optional[float] = None


@dataclass
class Completion:
    """A finished request with its provenance across model versions."""
    rid: int
    prompt_len: int
    tokens: List[int]                 # all generated ids (len == max_new)
    admit_version: int
    final_version: int
    t_submit_wall: float
    t_admit_wall: float
    t_finish_wall: float
    t_submit_virtual: Optional[float] = None
    t_finish_virtual: Optional[float] = None


@dataclass
class _Prefill:
    """Progress of the one in-flight chunked prefill."""
    req: Request
    slot: int
    tokens: np.ndarray                # full prompt, int32
    offset: int = 0                   # tokens already written to the cache
    t_admit_wall: float = 0.0


@dataclass
class _SlotInfo:
    """Host-side record for one occupied slot."""
    req: Request
    prompt_len: int
    emitted: List[int] = field(default_factory=list)
    admit_version: int = 0
    t_admit_wall: float = 0.0
    remaining: int = 0                # decode emissions still owed


class DecodeEngine:
    """Continuous-batching decoder over a KV-cache family (dense / moe).

    ``step()`` advances the engine by one scheduling quantum: adopt the
    newest published model, feed at most one prefill chunk, run one jitted
    ``scan_chunk``-step decode chunk, and retire finished requests.
    """

    def __init__(self, cfg: ArchConfig, bus: ModelBus, *, num_slots: int = 4,
                 max_seq: int = 256, scan_chunk: int = 8,
                 prefill_chunk_tokens: int = 32, greedy: bool = True,
                 seed: int = 0, window: Optional[int] = None,
                 prefill_chunks_per_step: Optional[int] = None):
        if cfg.family not in ("dense", "moe"):
            raise ValueError("DecodeEngine needs a KV-cache family "
                             f"(dense/moe), got {cfg.family!r}")
        self.cfg = cfg
        self.bus = bus
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.scan_chunk = int(scan_chunk)
        # a chunk wider than the cache cannot be written in one slice
        self.prefill_chunk_tokens = min(int(prefill_chunk_tokens),
                                        self.max_seq)
        self.greedy = bool(greedy)
        self.window = window if window is not None else cfg.sliding_window
        # admission burst: how many prefill chunks one step may feed (short
        # prompts admit in bursts after a retire wave; a long prompt still
        # gets at most one chunk per step so decoders never stall behind it)
        self.prefill_chunks_per_step = (int(prefill_chunks_per_step)
                                        if prefill_chunks_per_step is not None
                                        else self.num_slots)

        snap = bus.snapshot()
        self._params = snap.params
        self.model_version = snap.version

        self._cache = init_lm_cache(cfg, self.num_slots, self.max_seq,
                                    ring=False)
        zeros = jnp.zeros((self.num_slots,), jnp.int32)
        self._tokens, self._positions, self._stop_at = zeros, zeros, zeros
        self._key = jax.random.PRNGKey(seed)

        # host mirrors — slot scheduling never reads device arrays
        self._pos_host = np.zeros(self.num_slots, np.int64)
        self._stop_host = np.zeros(self.num_slots, np.int64)
        self._slots: Dict[int, _SlotInfo] = {}

        self.pending: List[Request] = []
        self._prefilling: Optional[_Prefill] = None
        self._next_rid = 0

        self.stats: Dict[str, float] = {
            "decode_chunks": 0, "decode_steps": 0, "tokens_emitted": 0,
            "prefill_chunks": 0, "prefill_tokens": 0, "swaps": 0,
            "swap_stall_s_total": 0.0, "swap_stall_s_max": 0.0,
            "occupancy_steps": 0.0,   # sum over decode steps of occupied/B
        }

        self._decode_fn = self._build_decode_fn()
        self._prefill_fn = self._build_prefill_fn()

    # ------------------------------------------------------------- compiled

    def _build_decode_fn(self):
        cfg, window, T = self.cfg, self.window, self.scan_chunk
        greedy = self.greedy

        def chunk(params, cache, tokens, positions, key, stop_at):
            def one(carry, _):
                cache, tok, pos, key = carry
                active = pos < stop_at
                logits, cache = decode_slots(cfg, params, tok, cache, pos,
                                             window=window, active=active)
                key, sub = jax.random.split(key)
                if greedy:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                else:
                    nxt = jax.random.categorical(sub, logits).astype(jnp.int32)
                tok = jnp.where(active, nxt, tok)
                pos = pos + active.astype(jnp.int32)
                return (cache, tok, pos, key), (tok, active)

            (cache, tokens, positions, key), (toks, actives) = jax.lax.scan(
                one, (cache, tokens, positions, key), None, length=T)
            # pack emissions + active mask into ONE (2, T, B) array so the
            # host boundary costs a single device->host transfer per chunk
            emitted = jnp.stack([toks, actives.astype(jnp.int32)], 0)
            return cache, tokens, positions, key, emitted

        return jax.jit(chunk, donate_argnums=(1, 2, 3, 4))

    def _build_prefill_fn(self):
        cfg, window = self.cfg, self.window

        def chunk(params, cache, tokens, slot, start):
            return prefill_chunk(cfg, params, tokens, cache, slot, start,
                                 window=window)

        return jax.jit(chunk, donate_argnums=(1,))

    # ------------------------------------------------------------ admission

    def submit(self, prompt: Sequence[int], max_new: int,
               rid: Optional[int] = None) -> int:
        """Queue a request; returns its rid.  Prompt + generation must fit
        the slot's row space (``prompt_len + max_new <= max_seq``)."""
        plen = len(prompt)
        if plen < 1:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if plen + max_new > self.max_seq:
            raise ValueError(f"prompt_len({plen}) + max_new({max_new}) "
                             f"exceeds max_seq({self.max_seq})")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self.pending.append(Request(
            rid=rid, prompt=list(prompt), max_new=int(max_new),
            t_submit_wall=time.perf_counter(),
            t_submit_virtual=spans.virtual_now()))
        return rid

    def _free_slots(self) -> List[int]:
        busy = set(self._slots)
        if self._prefilling is not None:
            busy.add(self._prefilling.slot)
        return [s for s in range(self.num_slots) if s not in busy]

    def _maybe_adopt_model(self) -> None:
        snap = self.bus.snapshot()
        if snap.version == self.model_version:
            return
        stall = time.perf_counter() - snap.t_publish_wall
        self._params = snap.params
        self.model_version = snap.version
        self.stats["swaps"] += 1
        self.stats["swap_stall_s_total"] += stall
        self.stats["swap_stall_s_max"] = max(self.stats["swap_stall_s_max"],
                                             stall)
        spans.record_span("serve/model_swap",
                          t0_virtual=spans.virtual_now() or 0.0,
                          dur_virtual_s=0.0, version=snap.version,
                          stall_s=stall)

    def _start_prefill_if_ready(self) -> None:
        if self._prefilling is not None or not self.pending:
            return
        free = self._free_slots()
        if not free:
            return
        req = self.pending.pop(0)
        self._prefilling = _Prefill(
            req=req, slot=free[0],
            tokens=np.asarray(req.prompt, np.int32),
            t_admit_wall=time.perf_counter())

    def _prefill_one_chunk(self) -> Optional[Completion]:
        """Feed one chunk of the in-flight prompt; on the last chunk sample
        the first generated token and activate the slot.  Returns the
        completion when the request's whole budget was the prefill token
        (``max_new == 1``)."""
        pf = self._prefilling
        if pf is None:
            return None
        C = self.prefill_chunk_tokens
        plen = len(pf.tokens)
        start, end = pf.offset, min(pf.offset + C, plen)
        chunk = pf.tokens[start:end]
        # last chunk is zero-padded to the static width; the padded rows'
        # garbage K/V sit above the slot's length and decode overwrites row
        # p before any step can attend it (write-before-read invariant)
        padded = np.zeros(C, np.int32)
        padded[:end - start] = chunk
        last = end >= plen
        with spans.span("serve/prefill", slot=pf.slot, rid=pf.req.rid,
                        start=start, tokens=int(end - start), last=last):
            logits, self._cache = self._prefill_fn(
                self._params, self._cache, jnp.asarray(padded),
                jnp.asarray(pf.slot, jnp.int32),
                jnp.asarray(start, jnp.int32))
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_tokens"] += end - start
        pf.offset = end
        if not last:
            return None
        # sample the first generated token from the prompt's final row
        row = logits[plen - 1 - start]
        if self.greedy:
            tok0 = int(jnp.argmax(row))
        else:
            self._key, sub = jax.random.split(self._key)
            tok0 = int(jax.random.categorical(sub, row))
        slot, req = pf.slot, pf.req
        stop = plen + req.max_new - 1   # decode owes max_new - 1 emissions
        self._tokens = self._tokens.at[slot].set(tok0)
        self._positions = self._positions.at[slot].set(plen)
        self._stop_at = self._stop_at.at[slot].set(stop)
        self._pos_host[slot] = plen
        self._stop_host[slot] = stop
        self._slots[slot] = _SlotInfo(
            req=req, prompt_len=plen, emitted=[tok0],
            admit_version=self.model_version,
            t_admit_wall=pf.t_admit_wall, remaining=req.max_new - 1)
        self._prefilling = None
        return self._retire_if_done(slot)   # max_new==1 finishes here

    # -------------------------------------------------------------- decode

    def _decode_chunk(self) -> List[Completion]:
        occupied = [s for s, info in self._slots.items() if info.remaining]
        if not occupied:
            return []
        with spans.span("serve/decode_chunk", steps=self.scan_chunk,
                        occupied=len(occupied), version=self.model_version):
            (self._cache, self._tokens, self._positions, self._key,
             emitted) = self._decode_fn(
                self._params, self._cache, self._tokens, self._positions,
                self._key, self._stop_at)
            emitted = np.asarray(emitted)    # (2, T, B): ids + active mask
            toks, actives = emitted[0], emitted[1].astype(bool)
        self.stats["decode_chunks"] += 1
        self.stats["decode_steps"] += self.scan_chunk
        self.stats["occupancy_steps"] += (
            self.scan_chunk * len(occupied) / self.num_slots)

        done: List[Completion] = []
        for slot in occupied:
            mask = actives[:, slot]
            emitted = toks[mask, slot]
            info = self._slots[slot]
            info.emitted.extend(int(t) for t in emitted)
            info.remaining -= int(mask.sum())
            self._pos_host[slot] += int(mask.sum())
            self.stats["tokens_emitted"] += int(mask.sum())
            c = self._retire_if_done(slot)
            if c is not None:
                done.append(c)
        return done

    def _retire_if_done(self, slot: int) -> Optional[Completion]:
        info = self._slots.get(slot)
        if info is None or info.remaining > 0:
            return None
        req = info.req
        comp = Completion(
            rid=req.rid, prompt_len=info.prompt_len,
            tokens=list(info.emitted),
            admit_version=info.admit_version,
            final_version=self.model_version,
            t_submit_wall=req.t_submit_wall,
            t_admit_wall=info.t_admit_wall,
            t_finish_wall=time.perf_counter(),
            t_submit_virtual=req.t_submit_virtual,
            t_finish_virtual=spans.virtual_now())
        del self._slots[slot]
        self._stop_host[slot] = 0
        self._pos_host[slot] = 0
        return comp

    # ------------------------------------------------------------- driving

    @property
    def idle(self) -> bool:
        return (not self.pending and self._prefilling is None
                and not self._slots)

    def step(self) -> List[Completion]:
        """One scheduling quantum; returns requests completed this step."""
        self._maybe_adopt_model()
        done: List[Completion] = []
        for _ in range(self.prefill_chunks_per_step):
            if self._prefilling is None:
                self._start_prefill_if_ready()
                if self._prefilling is None:
                    break                   # no pending work or no free slot
            c = self._prefill_one_chunk()
            if c is not None:
                done.append(c)
            if self._prefilling is not None:
                break                       # long prompt mid-prefill: one
                                            # chunk per step, decode now
        done.extend(self._decode_chunk())
        return done

    def run(self, max_steps: int = 100_000) -> List[Completion]:
        """Step until drained (or ``max_steps``); returns all completions."""
        out: List[Completion] = []
        steps = 0
        while not self.idle and steps < max_steps:
            out.extend(self.step())
            steps += 1
        return out
