"""Offline serving-eval harness: replay a request trace under hot swaps.

maxtext-``offline_inference``-style driver: a synthetic request trace
(arrival times on the PR-1 virtual clock) is replayed against a
:class:`~repro.serve.engine.DecodeEngine` while a model schedule — e.g. the
per-round aggregated params captured from ``run_hier_simulation``'s
``publish_fn`` hook — publishes versions onto the engine's
:class:`~repro.serve.bus.ModelBus` at their round times.  The replay loop
IS the virtual clock (each engine step costs a fixed virtual quantum), and
``spans.use_virtual_clock`` threads it into every span and completion
stamp, so the report can bin request latency and loss by model staleness
deterministically — no wall-clock noise in CI-gated fields.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..obs import spans
from .bus import ModelBus
from .engine import Completion, DecodeEngine

Pytree = Any


@dataclass
class TraceRequest:
    """One trace entry: arrival on the virtual clock + the request body."""
    rid: int
    arrival_s: float
    prompt: List[int]
    max_new: int


@dataclass
class ScheduledModel:
    """One publication: the round's aggregated params at its virtual time."""
    t_publish_s: float
    params: Pytree
    train_loss: Optional[float] = None
    round: Optional[int] = None


def synthetic_trace(*, num_requests: int, vocab: int, seed: int = 0,
                    mean_interarrival_s: float = 0.5,
                    prompt_len: Sequence[int] = (4, 24),
                    max_new: Sequence[int] = (4, 16)) -> List[TraceRequest]:
    """Deterministic Poisson-ish request trace (numpy Generator, seeded)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out: List[TraceRequest] = []
    for rid in range(num_requests):
        t += float(rng.exponential(mean_interarrival_s))
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        new = int(rng.integers(max_new[0], max_new[1] + 1))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        out.append(TraceRequest(rid=rid, arrival_s=t,
                                prompt=[int(x) for x in prompt],
                                max_new=new))
    return out


def replay(engine: DecodeEngine, trace: Sequence[TraceRequest],
           schedule: Sequence[ScheduledModel] = (), *,
           step_cost_s: float = 0.05,
           max_steps: int = 100_000) -> Dict[str, Any]:
    """Replay ``trace`` against ``engine``, publishing ``schedule`` onto its
    bus as virtual time passes.  Returns the serving report (see keys
    below); completions carry virtual stamps for staleness accounting.
    """
    bus: ModelBus = engine.bus
    trace = sorted(trace, key=lambda r: r.arrival_s)
    schedule = sorted(schedule, key=lambda m: m.t_publish_s)
    clock = {"now": 0.0}
    next_req = 0
    next_pub = 0
    completions: List[Completion] = []
    version_info: Dict[int, ScheduledModel] = {}
    version_times: Dict[int, float] = {bus.version: 0.0}
    occupancy: List[float] = []
    steps = 0

    with spans.use_virtual_clock(lambda: clock["now"]):
        while steps < max_steps:
            now = clock["now"]
            while next_pub < len(schedule) and \
                    schedule[next_pub].t_publish_s <= now:
                m = schedule[next_pub]
                v = bus.publish(m.params, train_loss=m.train_loss,
                                t_virtual=m.t_publish_s, round=m.round)
                version_info[v] = m
                version_times[v] = m.t_publish_s
                next_pub += 1
            while next_req < len(trace) and \
                    trace[next_req].arrival_s <= now:
                r = trace[next_req]
                engine.submit(r.prompt, r.max_new, rid=r.rid)
                next_req += 1
            drained = engine.idle and next_req >= len(trace)
            if drained and next_pub >= len(schedule):
                break
            if drained:
                # nothing to serve until the next publication — jump there
                clock["now"] = schedule[next_pub].t_publish_s
                continue
            if engine.idle:
                # idle until the next arrival — advance straight to it
                clock["now"] = max(now, trace[next_req].arrival_s)
                continue
            completions.extend(engine.step())
            occupancy.append(len(engine._slots) / engine.num_slots)
            clock["now"] = clock["now"] + step_cost_s
            steps += 1

    return _report(engine, completions, version_info, version_times,
                   occupancy, steps, step_cost_s)


def _report(engine: DecodeEngine, completions: List[Completion],
            version_info: Dict[int, ScheduledModel],
            version_times: Dict[int, float], occupancy: List[float],
            steps: int, step_cost_s: float) -> Dict[str, Any]:
    lat = [c.t_finish_virtual - c.t_submit_virtual for c in completions
           if c.t_finish_virtual is not None
           and c.t_submit_virtual is not None]
    toks = sum(len(c.tokens) for c in completions)
    virt_total = steps * step_cost_s

    # staleness: how old (virtual) was the serving model at completion
    by_request = []
    for c in completions:
        t_pub = version_times.get(c.final_version)
        stale = (c.t_finish_virtual - t_pub
                 if t_pub is not None and c.t_finish_virtual is not None
                 else None)
        m = version_info.get(c.final_version)
        by_request.append({
            "rid": c.rid, "prompt_len": c.prompt_len,
            "new_tokens": len(c.tokens),
            "admit_version": c.admit_version,
            "final_version": c.final_version,
            "latency_virtual_s": (c.t_finish_virtual - c.t_submit_virtual
                                  if c.t_finish_virtual is not None
                                  and c.t_submit_virtual is not None
                                  else None),
            "staleness_virtual_s": stale,
            "model_train_loss": None if m is None else m.train_loss,
        })

    stales = [r["staleness_virtual_s"] for r in by_request
              if r["staleness_virtual_s"] is not None]
    losses = [r["model_train_loss"] for r in by_request
              if r["model_train_loss"] is not None]
    stats = engine.stats
    return {
        "num_completed": len(completions),
        "tokens_generated": toks,
        "virtual_time_s": virt_total,
        "tokens_per_virtual_s": toks / virt_total if virt_total else 0.0,
        "latency_virtual_mean_s": float(np.mean(lat)) if lat else 0.0,
        "latency_virtual_p95_s": (float(np.percentile(lat, 95))
                                  if lat else 0.0),
        "slot_occupancy_mean": (float(np.mean(occupancy))
                                if occupancy else 0.0),
        "staleness_virtual_mean_s": (float(np.mean(stales))
                                     if stales else 0.0),
        "staleness_virtual_max_s": (float(np.max(stales))
                                    if stales else 0.0),
        "served_loss_mean": float(np.mean(losses)) if losses else None,
        "num_swaps": int(stats["swaps"]),
        "decode_steps": int(stats["decode_steps"]),
        "prefill_chunks": int(stats["prefill_chunks"]),
        "by_request": by_request,
    }
