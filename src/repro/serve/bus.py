"""Versioned, double-buffered model publication bus (train → serve hop).

The aggregation loop (``run_hier_simulation``'s per-round ``publish_fn``
hook, or any driver) pushes each round's aggregated params here; the decode
engine adopts the newest version at its next scan-chunk boundary.  Nothing
drains: in-flight requests keep decoding on the version they started their
current chunk with, and the next chunk runs entirely on the new tree — a
request can span versions, but a single forward pass never sees a mixed
tree.

Double buffering is what makes the snapshot tear-free without a reader
lock: :meth:`publish` stages the incoming tree into the standby buffer and
then flips one reference (``_live``) — a Python attribute store, atomic
under the GIL — so a concurrent :meth:`snapshot` returns either the old
:class:`Published` or the new one, never a half-written mix.  The writer
lock only serializes concurrent *publishers*.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Optional

from ..obs import spans

Pytree = Any


@dataclass(frozen=True)
class Published:
    """One immutable published model: the tree plus its provenance."""
    version: int
    params: Pytree
    train_loss: Optional[float] = None
    t_publish_wall: float = 0.0
    t_publish_virtual: Optional[float] = None
    round: Optional[int] = None


class ModelBus:
    """Single-writer-friendly versioned params bus with atomic snapshots."""

    def __init__(self, params: Pytree, *, train_loss: Optional[float] = None):
        first = Published(version=0, params=params, train_loss=train_loss,
                         t_publish_wall=time.perf_counter(),
                         t_publish_virtual=spans.virtual_now())
        self._buffers: list = [first, None]
        self._live: int = 0
        self._lock = threading.Lock()
        self._published = 1           # total publish count (incl. seed tree)

    def publish(self, params: Pytree, *, train_loss: Optional[float] = None,
                t_virtual: Optional[float] = None,
                round: Optional[int] = None) -> int:
        """Stage ``params`` into the standby buffer and flip it live.
        Returns the new version number (monotone)."""
        with self._lock:
            cur = self._buffers[self._live]
            standby = 1 - self._live
            pub = Published(
                version=cur.version + 1, params=params, train_loss=train_loss,
                t_publish_wall=time.perf_counter(),
                t_publish_virtual=(t_virtual if t_virtual is not None
                                   else spans.virtual_now()),
                round=round)
            self._buffers[standby] = pub
            self._live = standby      # atomic flip: readers see old xor new
            self._published += 1
        spans.record_span("model_publish",
                          t0_virtual=pub.t_publish_virtual or 0.0,
                          dur_virtual_s=0.0, version=pub.version,
                          train_loss=train_loss)
        return pub.version

    def snapshot(self) -> Published:
        """The newest published model — one attribute read, never torn."""
        return self._buffers[self._live]

    @property
    def version(self) -> int:
        return self.snapshot().version

    @property
    def num_published(self) -> int:
        return self._published
