"""Per-device compute/network profiles and canonical edge fleets.

A :class:`DeviceProfile` captures the three axes of edge heterogeneity the
paper's experiments abstract away (it draws epochs ~ U[min,max] inside a
synchronous round):

  * compute   — effective FLOP/s of the device,
  * network   — uplink/downlink bandwidth in bytes/s,
  * reliability — a per-task dropout probability (device dies / goes out of
    coverage / user kills the app before the update is uploaded).

``task_time`` turns a local-training workload (steps × FLOPs/step, model
payload) into a virtual duration, with optional lognormal jitter drawn from a
caller-provided RNG so the whole simulation stays deterministic under a seed.

Canonical fleets (cf. Wang et al., adaptive FL at the edge):

  * :func:`uniform_fleet`  — homogeneous devices (sanity baseline),
  * :func:`bimodal_fleet`  — phones + gateways: a slow cohort ``slowdown``×
    slower than the fast one, with its own dropout rate,
  * :func:`longtail_fleet` — Pareto-distributed compute, the "one straggler
    dominates the round" regime.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class DeviceProfile:
    device_id: int
    flops: float                 # effective FLOP/s
    up_bw: float                 # uplink bytes/s
    down_bw: float               # downlink bytes/s
    dropout: float = 0.0         # per-task dropout probability in [0, 1)
    jitter: float = 0.0          # lognormal sigma on the compute time

    def __post_init__(self):
        if not (0.0 <= self.dropout < 1.0):
            raise ValueError(
                f"device {self.device_id}: dropout must be in [0, 1), got "
                f"{self.dropout} (1.0 would never complete a task)")

    def compute_time(self, flops_required: float) -> float:
        return flops_required / self.flops

    def comm_time(self, payload_bytes: float) -> float:
        """Model download + update upload for one task."""
        return payload_bytes / self.down_bw + payload_bytes / self.up_bw

    def task_time(self, flops_required: float, payload_bytes: float,
                  rng: Optional[np.random.RandomState] = None) -> float:
        """Virtual duration of one dispatch→arrival task on this device."""
        t = self.compute_time(flops_required)
        if rng is not None and self.jitter > 0.0:
            t *= float(np.exp(rng.normal(0.0, self.jitter)))
        return t + self.comm_time(payload_bytes)


@dataclass(frozen=True)
class Fleet:
    name: str
    profiles: Tuple[DeviceProfile, ...]
    # device ids under adversarial control (repro.robust: seeded assignment
    # via assign_adversaries); empty for honest fleets.  Lives on the fleet
    # so sync/async/hier runs over the same fleet see the same adversaries.
    malicious: Tuple[int, ...] = ()

    def __post_init__(self):
        bad = [i for i in self.malicious
               if not (0 <= i < len(self.profiles))]
        if bad:
            raise ValueError(f"malicious ids out of range for "
                             f"{len(self.profiles)} devices: {bad}")

    @property
    def num_devices(self) -> int:
        return len(self.profiles)

    def is_malicious(self, device_id: int) -> bool:
        return device_id in self.malicious

    def __getitem__(self, device_id: int) -> DeviceProfile:
        return self.profiles[device_id]

    def __iter__(self) -> Iterator[DeviceProfile]:
        return iter(self.profiles)

    def describe(self) -> str:
        f = np.array([p.flops for p in self.profiles])
        d = np.array([p.dropout for p in self.profiles])
        return (f"{self.name}: N={self.num_devices} "
                f"flops[min/med/max]={f.min():.2e}/{np.median(f):.2e}/"
                f"{f.max():.2e} mean_dropout={d.mean():.3f}")


# Reference magnitudes: a mid-range phone sustains ~1 GFLOP/s of useful
# training throughput on ~10 Mbit/s uplink; gateways are ~an order faster.
PHONE_FLOPS = 1e9
PHONE_BW = 1.25e6


@dataclass(frozen=True, eq=False)
class ArrayFleet:
    """Array-backed fleet: one numpy vector per profile field instead of one
    frozen :class:`DeviceProfile` object per device.

    At 10⁵–10⁶ devices the tuple-of-dataclasses representation costs hundreds
    of MB and seconds of host time before a single round runs; this class
    keeps the whole fleet in five float64 vectors and exposes the same duck
    interface the runtimes consume (``num_devices``, ``__getitem__`` →
    a :class:`DeviceProfile` built on demand, ``malicious``, ``describe``).
    The vectorized scheduler path (``EventScheduler.dispatch_batch``) reads
    the arrays directly via :func:`fleet_arrays`."""
    name: str
    flops: np.ndarray
    up_bw: np.ndarray
    down_bw: np.ndarray
    dropout: np.ndarray
    jitter: np.ndarray
    malicious: Tuple[int, ...] = ()

    def __post_init__(self):
        n = len(self.flops)
        for f in ("flops", "up_bw", "down_bw", "dropout", "jitter"):
            arr = np.asarray(getattr(self, f), np.float64)
            if arr.shape != (n,):
                raise ValueError(f"{f} must be shape ({n},), got {arr.shape}")
            object.__setattr__(self, f, arr)
        if np.any((self.dropout < 0.0) | (self.dropout >= 1.0)):
            raise ValueError("dropout must be in [0, 1) for every device")
        bad = [i for i in self.malicious if not (0 <= i < n)]
        if bad:
            raise ValueError(f"malicious ids out of range for {n} devices: "
                             f"{bad}")

    @property
    def num_devices(self) -> int:
        return len(self.flops)

    def is_malicious(self, device_id: int) -> bool:
        return device_id in self.malicious

    def __getitem__(self, device_id: int) -> DeviceProfile:
        i = int(device_id)
        return DeviceProfile(i, float(self.flops[i]), float(self.up_bw[i]),
                             float(self.down_bw[i]), float(self.dropout[i]),
                             float(self.jitter[i]))

    def __iter__(self) -> Iterator[DeviceProfile]:
        return (self[i] for i in range(self.num_devices))

    def describe(self) -> str:
        f = self.flops
        return (f"{self.name}: N={self.num_devices} "
                f"flops[min/med/max]={f.min():.2e}/{np.median(f):.2e}/"
                f"{f.max():.2e} mean_dropout={self.dropout.mean():.3f}")


def fleet_arrays(fleet) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]:
    """Per-device (flops, up_bw, down_bw, dropout, jitter) float64 vectors
    for any fleet — a view for :class:`ArrayFleet`, an O(N) one-time build
    for a tuple-of-profiles :class:`Fleet`."""
    if isinstance(fleet, ArrayFleet):
        return (fleet.flops, fleet.up_bw, fleet.down_bw, fleet.dropout,
                fleet.jitter)
    return tuple(np.asarray([getattr(p, f) for p in fleet], np.float64)
                 for f in ("flops", "up_bw", "down_bw", "dropout", "jitter"))


def as_array_fleet(fleet: Fleet) -> ArrayFleet:
    """Convert a tuple-of-profiles fleet to the array representation (same
    per-device values, same malicious set)."""
    if isinstance(fleet, ArrayFleet):
        return fleet
    fl, up, dn, do, ji = fleet_arrays(fleet)
    return ArrayFleet(fleet.name, fl, up, dn, do, ji,
                      malicious=tuple(fleet.malicious))


def array_uniform_fleet(num_devices: int, flops: float = PHONE_FLOPS,
                        bandwidth: float = PHONE_BW, dropout: float = 0.0,
                        jitter: float = 0.05) -> ArrayFleet:
    """:func:`uniform_fleet` without the per-device objects — identical
    per-device values at any fleet size."""
    full = np.full(num_devices, 1.0)
    return ArrayFleet("uniform", full * flops, full * bandwidth,
                      full * bandwidth, full * dropout, full * jitter)


def array_bimodal_fleet(num_devices: int, slow_frac: float = 0.5,
                        slowdown: float = 10.0,
                        fast_flops: float = 10 * PHONE_FLOPS,
                        bandwidth: float = PHONE_BW,
                        dropout_slow: float = 0.1, dropout_fast: float = 0.0,
                        jitter: float = 0.1, seed: int = 0) -> ArrayFleet:
    """:func:`bimodal_fleet` vectorized: the same seeded slow-cohort draw,
    so the array fleet matches the object fleet device-for-device."""
    rng = np.random.RandomState(seed)
    slow_ids = rng.choice(num_devices, int(round(slow_frac * num_devices)),
                          replace=False)
    slow = np.zeros(num_devices, bool)
    slow[slow_ids] = True
    flops = np.where(slow, fast_flops / slowdown, fast_flops)
    bw = np.where(slow, bandwidth / 2, bandwidth)
    dropout = np.where(slow, dropout_slow, dropout_fast)
    return ArrayFleet(f"bimodal(x{slowdown:g})", flops, bw, bw.copy(),
                      dropout, np.full(num_devices, jitter))


def array_longtail_fleet(num_devices: int, shape: float = 1.5,
                         median_flops: float = PHONE_FLOPS,
                         bandwidth: float = PHONE_BW, dropout: float = 0.05,
                         jitter: float = 0.1, seed: int = 0) -> ArrayFleet:
    """:func:`longtail_fleet` vectorized (same seeded Pareto slowdowns)."""
    rng = np.random.RandomState(seed)
    slowdowns = 1.0 + rng.pareto(shape, size=num_devices)
    slowdowns /= np.median(slowdowns)
    flops = median_flops / np.maximum(slowdowns, 1e-3)
    full = np.full(num_devices, 1.0)
    return ArrayFleet("longtail", flops, full * bandwidth, full * bandwidth,
                      full * dropout, full * jitter)


def get_array_fleet(name: str, num_devices: int, **kw) -> ArrayFleet:
    builders = {"uniform": array_uniform_fleet, "bimodal": array_bimodal_fleet,
                "longtail": array_longtail_fleet}
    if name not in builders:
        raise KeyError(f"unknown fleet '{name}'; have {sorted(builders)}")
    return builders[name](num_devices, **kw)


def uniform_fleet(num_devices: int, flops: float = PHONE_FLOPS,
                  bandwidth: float = PHONE_BW, dropout: float = 0.0,
                  jitter: float = 0.05) -> Fleet:
    """Homogeneous fleet — async should roughly tie sync here."""
    return Fleet("uniform", tuple(
        DeviceProfile(i, flops, bandwidth, bandwidth, dropout, jitter)
        for i in range(num_devices)))


def bimodal_fleet(num_devices: int, slow_frac: float = 0.5,
                  slowdown: float = 10.0, fast_flops: float = 10 * PHONE_FLOPS,
                  bandwidth: float = PHONE_BW, dropout_slow: float = 0.1,
                  dropout_fast: float = 0.0, jitter: float = 0.1,
                  seed: int = 0) -> Fleet:
    """Phones + gateways: a ``slow_frac`` cohort is ``slowdown``× slower and
    flakier.  Which devices are slow is a seeded draw so fleets are
    reproducible but not index-correlated with data heterogeneity."""
    rng = np.random.RandomState(seed)
    slow_ids = set(rng.choice(num_devices, int(round(slow_frac * num_devices)),
                              replace=False).tolist())
    profiles = []
    for i in range(num_devices):
        if i in slow_ids:
            profiles.append(DeviceProfile(i, fast_flops / slowdown,
                                          bandwidth / 2, bandwidth / 2,
                                          dropout_slow, jitter))
        else:
            profiles.append(DeviceProfile(i, fast_flops, bandwidth, bandwidth,
                                          dropout_fast, jitter))
    return Fleet(f"bimodal(x{slowdown:g})", tuple(profiles))


def longtail_fleet(num_devices: int, shape: float = 1.5,
                   median_flops: float = PHONE_FLOPS,
                   bandwidth: float = PHONE_BW, dropout: float = 0.05,
                   jitter: float = 0.1, seed: int = 0) -> Fleet:
    """Pareto(shape)-distributed slowdowns: most devices are fine, a heavy
    tail is arbitrarily slow (the regime where synchronous rounds collapse)."""
    rng = np.random.RandomState(seed)
    slowdowns = 1.0 + rng.pareto(shape, size=num_devices)
    slowdowns /= np.median(slowdowns)  # median device = median_flops
    return Fleet("longtail", tuple(
        DeviceProfile(i, median_flops / max(s, 1e-3), bandwidth, bandwidth,
                      dropout, jitter)
        for i, s in enumerate(slowdowns)))


def get_fleet(name: str, num_devices: int, **kw) -> Fleet:
    builders = {"uniform": uniform_fleet, "bimodal": bimodal_fleet,
                "longtail": longtail_fleet}
    if name not in builders:
        raise KeyError(f"unknown fleet '{name}'; have {sorted(builders)}")
    return builders[name](num_devices, **kw)
