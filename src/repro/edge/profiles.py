"""Per-device compute/network profiles and canonical edge fleets.

A :class:`DeviceProfile` captures the three axes of edge heterogeneity the
paper's experiments abstract away (it draws epochs ~ U[min,max] inside a
synchronous round):

  * compute   — effective FLOP/s of the device,
  * network   — uplink/downlink bandwidth in bytes/s,
  * reliability — a per-task dropout probability (device dies / goes out of
    coverage / user kills the app before the update is uploaded).

``task_time`` turns a local-training workload (steps × FLOPs/step, model
payload) into a virtual duration, with optional lognormal jitter drawn from a
caller-provided RNG so the whole simulation stays deterministic under a seed.

Canonical fleets (cf. Wang et al., adaptive FL at the edge):

  * :func:`uniform_fleet`  — homogeneous devices (sanity baseline),
  * :func:`bimodal_fleet`  — phones + gateways: a slow cohort ``slowdown``×
    slower than the fast one, with its own dropout rate,
  * :func:`longtail_fleet` — Pareto-distributed compute, the "one straggler
    dominates the round" regime.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class DeviceProfile:
    device_id: int
    flops: float                 # effective FLOP/s
    up_bw: float                 # uplink bytes/s
    down_bw: float               # downlink bytes/s
    dropout: float = 0.0         # per-task dropout probability in [0, 1)
    jitter: float = 0.0          # lognormal sigma on the compute time

    def __post_init__(self):
        if not (0.0 <= self.dropout < 1.0):
            raise ValueError(
                f"device {self.device_id}: dropout must be in [0, 1), got "
                f"{self.dropout} (1.0 would never complete a task)")

    def compute_time(self, flops_required: float) -> float:
        return flops_required / self.flops

    def comm_time(self, payload_bytes: float) -> float:
        """Model download + update upload for one task."""
        return payload_bytes / self.down_bw + payload_bytes / self.up_bw

    def task_time(self, flops_required: float, payload_bytes: float,
                  rng: Optional[np.random.RandomState] = None) -> float:
        """Virtual duration of one dispatch→arrival task on this device."""
        t = self.compute_time(flops_required)
        if rng is not None and self.jitter > 0.0:
            t *= float(np.exp(rng.normal(0.0, self.jitter)))
        return t + self.comm_time(payload_bytes)


@dataclass(frozen=True)
class Fleet:
    name: str
    profiles: Tuple[DeviceProfile, ...]
    # device ids under adversarial control (repro.robust: seeded assignment
    # via assign_adversaries); empty for honest fleets.  Lives on the fleet
    # so sync/async/hier runs over the same fleet see the same adversaries.
    malicious: Tuple[int, ...] = ()

    def __post_init__(self):
        bad = [i for i in self.malicious
               if not (0 <= i < len(self.profiles))]
        if bad:
            raise ValueError(f"malicious ids out of range for "
                             f"{len(self.profiles)} devices: {bad}")

    @property
    def num_devices(self) -> int:
        return len(self.profiles)

    def is_malicious(self, device_id: int) -> bool:
        return device_id in self.malicious

    def __getitem__(self, device_id: int) -> DeviceProfile:
        return self.profiles[device_id]

    def __iter__(self) -> Iterator[DeviceProfile]:
        return iter(self.profiles)

    def describe(self) -> str:
        f = np.array([p.flops for p in self.profiles])
        d = np.array([p.dropout for p in self.profiles])
        return (f"{self.name}: N={self.num_devices} "
                f"flops[min/med/max]={f.min():.2e}/{np.median(f):.2e}/"
                f"{f.max():.2e} mean_dropout={d.mean():.3f}")


# Reference magnitudes: a mid-range phone sustains ~1 GFLOP/s of useful
# training throughput on ~10 Mbit/s uplink; gateways are ~an order faster.
PHONE_FLOPS = 1e9
PHONE_BW = 1.25e6


def uniform_fleet(num_devices: int, flops: float = PHONE_FLOPS,
                  bandwidth: float = PHONE_BW, dropout: float = 0.0,
                  jitter: float = 0.05) -> Fleet:
    """Homogeneous fleet — async should roughly tie sync here."""
    return Fleet("uniform", tuple(
        DeviceProfile(i, flops, bandwidth, bandwidth, dropout, jitter)
        for i in range(num_devices)))


def bimodal_fleet(num_devices: int, slow_frac: float = 0.5,
                  slowdown: float = 10.0, fast_flops: float = 10 * PHONE_FLOPS,
                  bandwidth: float = PHONE_BW, dropout_slow: float = 0.1,
                  dropout_fast: float = 0.0, jitter: float = 0.1,
                  seed: int = 0) -> Fleet:
    """Phones + gateways: a ``slow_frac`` cohort is ``slowdown``× slower and
    flakier.  Which devices are slow is a seeded draw so fleets are
    reproducible but not index-correlated with data heterogeneity."""
    rng = np.random.RandomState(seed)
    slow_ids = set(rng.choice(num_devices, int(round(slow_frac * num_devices)),
                              replace=False).tolist())
    profiles = []
    for i in range(num_devices):
        if i in slow_ids:
            profiles.append(DeviceProfile(i, fast_flops / slowdown,
                                          bandwidth / 2, bandwidth / 2,
                                          dropout_slow, jitter))
        else:
            profiles.append(DeviceProfile(i, fast_flops, bandwidth, bandwidth,
                                          dropout_fast, jitter))
    return Fleet(f"bimodal(x{slowdown:g})", tuple(profiles))


def longtail_fleet(num_devices: int, shape: float = 1.5,
                   median_flops: float = PHONE_FLOPS,
                   bandwidth: float = PHONE_BW, dropout: float = 0.05,
                   jitter: float = 0.1, seed: int = 0) -> Fleet:
    """Pareto(shape)-distributed slowdowns: most devices are fine, a heavy
    tail is arbitrarily slow (the regime where synchronous rounds collapse)."""
    rng = np.random.RandomState(seed)
    slowdowns = 1.0 + rng.pareto(shape, size=num_devices)
    slowdowns /= np.median(slowdowns)  # median device = median_flops
    return Fleet("longtail", tuple(
        DeviceProfile(i, median_flops / max(s, 1e-3), bandwidth, bandwidth,
                      dropout, jitter)
        for i, s in enumerate(slowdowns)))


def get_fleet(name: str, num_devices: int, **kw) -> Fleet:
    builders = {"uniform": uniform_fleet, "bimodal": bimodal_fleet,
                "longtail": longtail_fleet}
    if name not in builders:
        raise KeyError(f"unknown fleet '{name}'; have {sorted(builders)}")
    return builders[name](num_devices, **kw)
