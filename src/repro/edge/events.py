"""Deterministic event-driven scheduler for the async edge runtime.

The scheduler owns a virtual clock and a binary heap of pending events.  It
knows nothing about models or aggregation — it turns *dispatches* (server
hands a device a training task at virtual time t) into timed *arrivals*
(the update reaches the server) or *dropouts* (the device dies mid-task),
using each device's :class:`~repro.edge.profiles.DeviceProfile`.

Determinism contract (tested by ``tests/test_edge_runtime.py``):

  * all randomness (duration jitter, dropout coin flips, epoch draws) comes
    from one ``np.random.RandomState(seed)``, consumed in dispatch order;
  * heap ties at equal virtual time break on a monotone sequence number, so
    event order is a pure function of (fleet, seed, dispatch sequence);
  * every dispatch produces exactly one terminal event (ARRIVAL xor DROPOUT):
    updates are never lost or duplicated, only late.

RNG streams.  The legacy ``rng_stream="v1"`` contract above draws a
*variable* number of scalars per dispatch (the jitter normal only when the
profile has jitter, the death fraction only on dropout) from one Mersenne
Twister — bit-faithful vectorization of that stream is impossible, so
:meth:`EventScheduler.dispatch_batch` under v1 replays the per-task scalar
draws in dispatch order (same trace as N ``dispatch()`` calls, still one
heapify).  ``rng_stream="v2"`` is the *documented fleet-scale stream*: every
task's draws are a pure counter-based hash of ``(seed, task seq)`` (murmur3
finalizer, the PR-4 ``rng_sketch`` idiom), so a whole cohort's durations and
dropout coins vectorize into one numpy pass and per-device ``dispatch()``
produces bit-identical traces to ``dispatch_batch`` (both tested).  v1 and
v2 are different (equally valid) random universes; pick per run, never mix.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Dict, List, Optional

import numpy as np

from ..obs import spans
from .profiles import Fleet, fleet_arrays


# -- counter-based draws (rng_stream="v2") ----------------------------------
# murmur3 finalizer over (seed, task seq, field): the same integer mixing the
# rng_sketch kernels use, evaluated in numpy so a million-task cohort is one
# vectorized pass and a scalar dispatch is the B=1 special case of it.

def _mix32(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32, copy=True)
    x ^= x >> np.uint32(16)
    x *= np.uint32(0x85EBCA6B)
    x ^= x >> np.uint32(13)
    x *= np.uint32(0xC2B2AE35)
    x ^= x >> np.uint32(16)
    return x


def _stream_uniform(seed: int, seqs: np.ndarray, fieldno: int) -> np.ndarray:
    """One U(0,1) per task seq for one draw field (0/1: jitter Box-Muller
    pair, 2: dropout coin, 3: death fraction).  (h+0.5)·2⁻³² keeps the
    uniforms strictly inside (0, 1) so log() below is always finite."""
    salt = np.uint32((0x9E3779B9 * (fieldno + 1) + seed) & 0xFFFFFFFF)
    h = _mix32(_mix32(np.asarray(seqs, np.uint32)) ^ salt)
    return (h.astype(np.float64) + 0.5) * 2.0 ** -32


class EventKind(IntEnum):
    DISPATCH = 0   # recorded in the trace when the server hands out a task
    ARRIVAL = 1    # the device's update reaches the server
    DROPOUT = 2    # the device died mid-task; its work is lost


@dataclass(frozen=True)
class Event:
    time: float
    seq: int                 # monotone tie-breaker; also a unique task id
    kind: EventKind
    device_id: int
    # metadata the runtime attached at dispatch (step budget, model version …)
    num_steps: int = 0
    version: int = 0


@dataclass
class SchedulerStats:
    dispatched: int = 0            # device tasks handed out
    arrived: int = 0               # device updates that reached their parent
    dropped: int = 0               # device tasks lost mid-flight
    transfers: int = 0             # backhaul link events scheduled
    transfers_done: int = 0        # backhaul link events delivered


@dataclass(frozen=True)
class BatchDispatch:
    """Vectorized view of one :meth:`EventScheduler.dispatch_batch` cohort:
    parallel per-task arrays in dispatch order.  With ``enqueue=False`` no
    per-task :class:`Event` objects exist at all — the caller consumes these
    arrays (terminal times and outcomes are fully determined at dispatch)
    and settles the cohort with :meth:`EventScheduler.complete_batch`."""
    device_ids: np.ndarray       # (B,) int64
    seqs: np.ndarray             # (B,) int64 — the cohort's task ids
    num_steps: np.ndarray        # (B,) int32
    start: np.ndarray            # (B,) float64 dispatch times
    t_end: np.ndarray            # (B,) float64 terminal times
    dropped: np.ndarray          # (B,) bool — True: DROPOUT, else ARRIVAL
    version: int = 0

    @property
    def size(self) -> int:
        return len(self.device_ids)


class EventScheduler:
    """Heap-of-events virtual-time simulator over a device fleet."""

    def __init__(self, fleet: Fleet, seed: int, flops_per_step: float,
                 payload_bytes: float, churn=None, rng_stream: str = "v1"):
        if rng_stream not in ("v1", "v2"):
            raise ValueError(f"unknown rng_stream '{rng_stream}' (v1|v2)")
        self.fleet = fleet
        self.rng = np.random.RandomState(seed)
        self.rng_stream = rng_stream
        self.flops_per_step = float(flops_per_step)
        self.payload_bytes = float(payload_bytes)
        # optional churn schedule (repro.robust.churn duck interface:
        # ``offline(device_id, t) -> bool``): a task dispatched while its
        # device sits inside an active wave terminates as a DROPOUT
        self.churn = churn
        self.now = 0.0
        self.stats = SchedulerStats()
        self.trace: List[Event] = []      # full event log (tests, debugging)
        self._heap: List[Event] = []
        self._next_seq = 0
        self._seed = int(seed)
        self._profile_arrays = None       # lazy (flops, up, down, drop, jit)
        self._batch_inflight = 0          # non-enqueued cohort tasks pending
        self._transfer_seqs: set = set()  # pending link events (not devices)
        # open span handles per in-flight event (repro.obs.spans): a
        # dispatch/schedule opens a FLAT span at the event's virtual start,
        # pop closes it at the terminal virtual time.  Empty (and free)
        # under the default noop tracker — spans.begin returns None there.
        self._spans: Dict[int, object] = {}

    def _take_seq(self) -> int:
        s = self._next_seq
        self._next_seq += 1
        return s

    def _fleet_arrays(self):
        if self._profile_arrays is None:
            self._profile_arrays = fleet_arrays(self.fleet)
        return self._profile_arrays

    def _v2_outcomes(self, device_ids: np.ndarray, seqs: np.ndarray,
                     num_steps: np.ndarray):
        """Vectorized per-task (duration, drops, death fraction) under the
        counter-based v2 stream — the scalar ``dispatch`` path calls this
        with B=1, so batch and per-device dispatch agree bit-for-bit."""
        fl, up, dn, do, ji = self._fleet_arrays()
        ids = np.asarray(device_ids, np.int64)
        t = np.asarray(num_steps, np.float64) * self.flops_per_step / fl[ids]
        sigma = ji[ids]
        if np.any(sigma > 0.0):
            u0 = _stream_uniform(self._seed, seqs, 0)
            u1 = _stream_uniform(self._seed, seqs, 1)
            z = np.sqrt(-2.0 * np.log(u0)) * np.cos(2.0 * np.pi * u1)
            t = np.where(sigma > 0.0, t * np.exp(sigma * z), t)
        duration = t + self.payload_bytes / dn[ids] + self.payload_bytes / up[ids]
        drops = _stream_uniform(self._seed, seqs, 2) < do[ids]
        death = 0.05 + 0.9 * _stream_uniform(self._seed, seqs, 3)
        return duration, drops, death

    # -- dispatch ----------------------------------------------------------
    def dispatch(self, device_id: int, num_steps: int, version: int,
                 at: Optional[float] = None) -> Event:
        """Hand ``device_id`` a task of ``num_steps`` local steps at the
        current virtual time (or at ``at`` ≥ now — the hierarchical runtime
        delays dispatch until the model broadcast reaches the device's
        gateway); schedules its terminal ARRIVAL/DROPOUT event."""
        start = self.now if at is None else at
        if start < self.now - 1e-12:
            raise ValueError(f"cannot dispatch in the past: at={at} < "
                             f"now={self.now}")
        seq = self._take_seq()
        disp = Event(start, seq, EventKind.DISPATCH, device_id,
                     num_steps=num_steps, version=version)
        self.trace.append(disp)
        self.stats.dispatched += 1

        if self.rng_stream == "v2":
            dur, drp, death = self._v2_outcomes(
                np.asarray([device_id]), np.asarray([seq]),
                np.asarray([num_steps]))
            duration, drops = float(dur[0]), bool(drp[0])
            if self.churn is not None and self.churn.offline(device_id, start):
                drops = True
            if drops:
                duration *= float(death[0])
        else:
            prof = self.fleet[device_id]
            duration = prof.task_time(num_steps * self.flops_per_step,
                                      self.payload_bytes, self.rng)
            drops = self.rng.random_sample() < prof.dropout
            # churn overrides the outcome AFTER the profile coin is consumed,
            # so the RNG stream (and with it every non-churned event) is
            # identical to the churn-free run — the determinism contract
            # above holds per (fleet, seed, churn schedule)
            if self.churn is not None and self.churn.offline(device_id, start):
                drops = True
            if drops:
                # die uniformly somewhere inside the task
                duration *= float(self.rng.uniform(0.05, 0.95))
        kind = EventKind.DROPOUT if drops else EventKind.ARRIVAL
        evt = Event(start + duration, seq, kind, device_id,
                    num_steps=num_steps, version=version)
        heapq.heappush(self._heap, (evt.time, evt.seq, evt))
        h = spans.begin("sched/task", t_virtual=start, device=device_id,
                        num_steps=num_steps, version=version)
        if h is not None:
            self._spans[seq] = h
        return evt

    def dispatch_batch(self, device_ids, num_steps, version: int = 0,
                       at=None, enqueue: bool = True) -> BatchDispatch:
        """Dispatch a whole cohort at once: one vectorized draw of durations
        and dropout coins (under ``rng_stream="v2"``; the v1 compat path
        replays the legacy per-task scalar draws in dispatch order, so its
        trace is bit-identical to N ``dispatch()`` calls) and one heapify
        instead of per-device heap pushes.

        ``at`` is an optional per-task (or scalar) dispatch time ≥ now.  With
        ``enqueue=False`` no per-task :class:`Event` objects are created at
        all — the fleet-scale cohort path consumes the returned arrays
        directly (every terminal time/outcome is already determined here) and
        must settle the cohort once via :meth:`complete_batch`; the trace
        records nothing for such cohorts (a million Event objects is exactly
        the O(fleet) cost this path removes)."""
        ids = np.atleast_1d(np.asarray(device_ids, np.int64))
        B = ids.size
        ns = np.broadcast_to(np.asarray(num_steps, np.int32), (B,))
        if at is None:
            start = np.full(B, self.now)
        else:
            start = np.broadcast_to(np.asarray(at, np.float64), (B,)).copy()
            if B and start.min() < self.now - 1e-12:
                raise ValueError(f"cannot dispatch in the past: "
                                 f"min(at)={start.min()} < now={self.now}")
        seq0 = self._next_seq
        self._next_seq += B
        seqs = np.arange(seq0, seq0 + B, dtype=np.int64)

        if self.rng_stream == "v2":
            duration, drops, death = self._v2_outcomes(ids, seqs, ns)
            drops = drops.copy()
            if self.churn is not None:
                if hasattr(self.churn, "offline_mask"):
                    drops |= self.churn.offline_mask(ids, start)
                else:
                    drops |= np.fromiter(
                        (self.churn.offline(int(d), float(s))
                         for d, s in zip(ids, start)), bool, count=B)
            duration = np.where(drops, duration * death, duration)
        else:
            duration = np.empty(B)
            drops = np.empty(B, bool)
            for i in range(B):
                prof = self.fleet[int(ids[i])]
                duration[i] = prof.task_time(
                    int(ns[i]) * self.flops_per_step, self.payload_bytes,
                    self.rng)
                d = self.rng.random_sample() < prof.dropout
                if self.churn is not None and self.churn.offline(
                        int(ids[i]), float(start[i])):
                    d = True
                if d:
                    duration[i] *= float(self.rng.uniform(0.05, 0.95))
                drops[i] = d

        t_end = start + duration
        self.stats.dispatched += B
        batch = BatchDispatch(ids, seqs, ns, start, t_end, drops,
                              version=version)
        if enqueue:
            kinds = np.where(drops, int(EventKind.DROPOUT),
                             int(EventKind.ARRIVAL))
            events = []
            for i in range(B):
                seq = int(seqs[i])
                self.trace.append(Event(float(start[i]), seq,
                                        EventKind.DISPATCH, int(ids[i]),
                                        num_steps=int(ns[i]), version=version))
                evt = Event(float(t_end[i]), seq, EventKind(int(kinds[i])),
                            int(ids[i]), num_steps=int(ns[i]), version=version)
                events.append((evt.time, evt.seq, evt))
                h = spans.begin("sched/task", t_virtual=float(start[i]),
                                device=int(ids[i]), num_steps=int(ns[i]),
                                version=version)
                if h is not None:
                    self._spans[seq] = h
            self._heap.extend(events)
            heapq.heapify(self._heap)
        else:
            self._batch_inflight += B
        return batch

    def advance_to(self, t: float) -> None:
        """Move the virtual clock forward to ``t`` (cohort-mode device phase:
        the caller walks gateway completions in time order without popping
        per-device events)."""
        if t < self.now - 1e-9:
            raise ValueError(f"cannot advance backwards: t={t} < "
                             f"now={self.now}")
        self.now = max(self.now, t)

    def complete_batch(self, batch: BatchDispatch) -> None:
        """Settle a non-enqueued cohort's terminal outcomes in the stats
        (totals identical to popping every per-device event).  Does not touch
        the clock — the caller interleaves :meth:`advance_to` with its own
        per-gateway completion handling."""
        n_drop = int(np.count_nonzero(batch.dropped))
        self.stats.arrived += batch.size - n_drop
        self.stats.dropped += n_drop
        self._batch_inflight -= batch.size
        if self._batch_inflight < 0:
            raise RuntimeError("complete_batch called for an enqueued or "
                               "already-settled cohort")

    def schedule(self, delay: float, node_id: int,
                 kind: EventKind = EventKind.ARRIVAL,
                 num_steps: int = 0, version: int = 0) -> Event:
        """Schedule an arbitrary terminal event ``delay`` after now — the
        hierarchical runtime's multi-hop link transfers (gateway summary →
        regional → cloud).  ``node_id`` may exceed the fleet size: interior
        tree nodes are not devices and consume no fleet profile or RNG draws,
        so scheduling keeps the device event stream deterministic.  Counted
        in ``stats.transfers``/``transfers_done`` — never in the device-task
        dispatched/arrived/dropped counters."""
        if delay < 0:
            raise ValueError(f"delay must be >= 0, got {delay}")
        seq = self._take_seq()
        self.stats.transfers += 1
        self._transfer_seqs.add(seq)
        evt = Event(self.now + delay, seq, kind, node_id,
                    num_steps=num_steps, version=version)
        heapq.heappush(self._heap, (evt.time, evt.seq, evt))
        h = spans.begin("sched/transfer", t_virtual=self.now, node=node_id,
                        version=version)
        if h is not None:
            self._spans[seq] = h
        return evt

    # -- event loop --------------------------------------------------------
    def pending(self) -> int:
        return len(self._heap)

    def pop(self) -> Optional[Event]:
        """Advance the clock to the next terminal event and return it."""
        if not self._heap:
            return None
        _, _, evt = heapq.heappop(self._heap)
        self.now = evt.time
        self.trace.append(evt)
        if evt.seq in self._transfer_seqs:
            self._transfer_seqs.discard(evt.seq)
            self.stats.transfers_done += 1
            outcome = "delivered"
        elif evt.kind == EventKind.ARRIVAL:
            self.stats.arrived += 1
            outcome = "arrival"
        else:
            self.stats.dropped += 1
            outcome = "dropout"
        h = self._spans.pop(evt.seq, None)
        if h is not None:
            spans.end(h, t_virtual=evt.time, outcome=outcome)
        return evt

    # -- invariants (cheap enough to assert in tests) ----------------------
    def conservation_ok(self) -> bool:
        """Every dispatch/transfer is in-flight xor terminal — nothing
        lost/duplicated."""
        return (self.stats.dispatched + self.stats.transfers
                == self.stats.arrived + self.stats.dropped
                + self.stats.transfers_done + self.pending()
                + self._batch_inflight)

    def trace_signature(self) -> List[tuple]:
        """Hashable rendering of the full trace for determinism tests."""
        return [(round(e.time, 9), e.seq, int(e.kind), e.device_id,
                 e.num_steps, e.version) for e in self.trace]
