"""Deterministic event-driven scheduler for the async edge runtime.

The scheduler owns a virtual clock and a binary heap of pending events.  It
knows nothing about models or aggregation — it turns *dispatches* (server
hands a device a training task at virtual time t) into timed *arrivals*
(the update reaches the server) or *dropouts* (the device dies mid-task),
using each device's :class:`~repro.edge.profiles.DeviceProfile`.

Determinism contract (tested by ``tests/test_edge_runtime.py``):

  * all randomness (duration jitter, dropout coin flips, epoch draws) comes
    from one ``np.random.RandomState(seed)``, consumed in dispatch order;
  * heap ties at equal virtual time break on a monotone sequence number, so
    event order is a pure function of (fleet, seed, dispatch sequence);
  * every dispatch produces exactly one terminal event (ARRIVAL xor DROPOUT):
    updates are never lost or duplicated, only late.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Dict, List, Optional

import numpy as np

from ..obs import spans
from .profiles import Fleet


class EventKind(IntEnum):
    DISPATCH = 0   # recorded in the trace when the server hands out a task
    ARRIVAL = 1    # the device's update reaches the server
    DROPOUT = 2    # the device died mid-task; its work is lost


@dataclass(frozen=True)
class Event:
    time: float
    seq: int                 # monotone tie-breaker; also a unique task id
    kind: EventKind
    device_id: int
    # metadata the runtime attached at dispatch (step budget, model version …)
    num_steps: int = 0
    version: int = 0


@dataclass
class SchedulerStats:
    dispatched: int = 0            # device tasks handed out
    arrived: int = 0               # device updates that reached their parent
    dropped: int = 0               # device tasks lost mid-flight
    transfers: int = 0             # backhaul link events scheduled
    transfers_done: int = 0        # backhaul link events delivered


class EventScheduler:
    """Heap-of-events virtual-time simulator over a device fleet."""

    def __init__(self, fleet: Fleet, seed: int, flops_per_step: float,
                 payload_bytes: float, churn=None):
        self.fleet = fleet
        self.rng = np.random.RandomState(seed)
        self.flops_per_step = float(flops_per_step)
        self.payload_bytes = float(payload_bytes)
        # optional churn schedule (repro.robust.churn duck interface:
        # ``offline(device_id, t) -> bool``): a task dispatched while its
        # device sits inside an active wave terminates as a DROPOUT
        self.churn = churn
        self.now = 0.0
        self.stats = SchedulerStats()
        self.trace: List[Event] = []      # full event log (tests, debugging)
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self._transfer_seqs: set = set()  # pending link events (not devices)
        # open span handles per in-flight event (repro.obs.spans): a
        # dispatch/schedule opens a FLAT span at the event's virtual start,
        # pop closes it at the terminal virtual time.  Empty (and free)
        # under the default noop tracker — spans.begin returns None there.
        self._spans: Dict[int, object] = {}

    # -- dispatch ----------------------------------------------------------
    def dispatch(self, device_id: int, num_steps: int, version: int,
                 at: Optional[float] = None) -> Event:
        """Hand ``device_id`` a task of ``num_steps`` local steps at the
        current virtual time (or at ``at`` ≥ now — the hierarchical runtime
        delays dispatch until the model broadcast reaches the device's
        gateway); schedules its terminal ARRIVAL/DROPOUT event."""
        start = self.now if at is None else at
        if start < self.now - 1e-12:
            raise ValueError(f"cannot dispatch in the past: at={at} < "
                             f"now={self.now}")
        prof = self.fleet[device_id]
        seq = next(self._seq)
        disp = Event(start, seq, EventKind.DISPATCH, device_id,
                     num_steps=num_steps, version=version)
        self.trace.append(disp)
        self.stats.dispatched += 1

        duration = prof.task_time(num_steps * self.flops_per_step,
                                  self.payload_bytes, self.rng)
        drops = self.rng.random_sample() < prof.dropout
        # churn overrides the outcome AFTER the profile coin is consumed, so
        # the RNG stream (and with it every non-churned event) is identical
        # to the churn-free run — the determinism contract above holds per
        # (fleet, seed, churn schedule)
        if self.churn is not None and self.churn.offline(device_id, start):
            drops = True
        if drops:
            # die uniformly somewhere inside the task
            duration *= float(self.rng.uniform(0.05, 0.95))
            kind = EventKind.DROPOUT
        else:
            kind = EventKind.ARRIVAL
        evt = Event(start + duration, seq, kind, device_id,
                    num_steps=num_steps, version=version)
        heapq.heappush(self._heap, (evt.time, evt.seq, evt))
        h = spans.begin("sched/task", t_virtual=start, device=device_id,
                        num_steps=num_steps, version=version)
        if h is not None:
            self._spans[seq] = h
        return evt

    def schedule(self, delay: float, node_id: int,
                 kind: EventKind = EventKind.ARRIVAL,
                 num_steps: int = 0, version: int = 0) -> Event:
        """Schedule an arbitrary terminal event ``delay`` after now — the
        hierarchical runtime's multi-hop link transfers (gateway summary →
        regional → cloud).  ``node_id`` may exceed the fleet size: interior
        tree nodes are not devices and consume no fleet profile or RNG draws,
        so scheduling keeps the device event stream deterministic.  Counted
        in ``stats.transfers``/``transfers_done`` — never in the device-task
        dispatched/arrived/dropped counters."""
        if delay < 0:
            raise ValueError(f"delay must be >= 0, got {delay}")
        seq = next(self._seq)
        self.stats.transfers += 1
        self._transfer_seqs.add(seq)
        evt = Event(self.now + delay, seq, kind, node_id,
                    num_steps=num_steps, version=version)
        heapq.heappush(self._heap, (evt.time, evt.seq, evt))
        h = spans.begin("sched/transfer", t_virtual=self.now, node=node_id,
                        version=version)
        if h is not None:
            self._spans[seq] = h
        return evt

    # -- event loop --------------------------------------------------------
    def pending(self) -> int:
        return len(self._heap)

    def pop(self) -> Optional[Event]:
        """Advance the clock to the next terminal event and return it."""
        if not self._heap:
            return None
        _, _, evt = heapq.heappop(self._heap)
        self.now = evt.time
        self.trace.append(evt)
        if evt.seq in self._transfer_seqs:
            self._transfer_seqs.discard(evt.seq)
            self.stats.transfers_done += 1
            outcome = "delivered"
        elif evt.kind == EventKind.ARRIVAL:
            self.stats.arrived += 1
            outcome = "arrival"
        else:
            self.stats.dropped += 1
            outcome = "dropout"
        h = self._spans.pop(evt.seq, None)
        if h is not None:
            spans.end(h, t_virtual=evt.time, outcome=outcome)
        return evt

    # -- invariants (cheap enough to assert in tests) ----------------------
    def conservation_ok(self) -> bool:
        """Every dispatch/transfer is in-flight xor terminal — nothing
        lost/duplicated."""
        return (self.stats.dispatched + self.stats.transfers
                == self.stats.arrived + self.stats.dropped
                + self.stats.transfers_done + self.pending())

    def trace_signature(self) -> List[tuple]:
        """Hashable rendering of the full trace for determinism tests."""
        return [(round(e.time, 9), e.seq, int(e.kind), e.device_id,
                 e.num_steps, e.version) for e in self.trace]
