"""Buffered asynchronous aggregation with staleness-aware contextual solve.

In the async runtime updates arrive one at a time, each computed against the
model version the device was *dispatched* with.  The server buffers arrivals
and aggregates whenever ``buffer_size`` updates are present.  Staleness
τ_k = (current model version) − (dispatch version) is discounted by a weight
s_k = s(τ_k) ∈ (0, 1]:

  * ``contextual_async`` — the paper's K×K contextual solve over the buffer
    under a shrink-to-noise staleness model: a τ-stale update is treated as
    Δ̃_k with mean s_k·Δ_k and uncorrelated residual energy (1−s_k²)·‖Δ_k‖²
    (total energy preserved).  The *expected* context-dependent bound then
    has staleness-discounted Gram cross-terms

        E⟨Δ̃_j, Δ̃_k⟩ = s_j s_k G_jk (j≠k),   E‖Δ̃_k‖² = G_kk,
        E⟨Δ̃_k, ∇f⟩ = s_k c_k,

    and its stationary α is applied to the raw buffered updates.  Stale
    updates keep full self-energy but lose credited alignment, so their α
    is damped toward 0 as s_k → 0; with s ≡ 1 this is *exactly*
    ``contextual`` (tested) — the sync algorithm is the zero-staleness
    special case.
  * ``fedbuff``  — FedBuff-style baseline: w ← w + (1/M) Σ_k s_k Δ_k
    (the server mixing rate η is folded into s by the runtime).
  * ``fedasync`` — FedAsync is the M=1 special case of the same rule; it is
    registered separately so configs read naturally.

All three are registered in the existing ``core.aggregation`` registry and
share its calling convention, so they also work from the synchronous round
path if given an ``AggregatorConfig.staleness`` vector.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.aggregation import (AggregatorConfig, _num_clients,
                                _stacked_to_matrix, aggregate,
                                register_aggregator)
from ..core.flatten import scope_vector, stacked_weighted_sum, tree_add
from ..core.gram import gram_and_cross, gram_residual
from ..core.solve import SolveConfig, bound_value, solve_alpha, theorem1_reduction

Pytree = Any


# ---------------------------------------------------------------------------
# staleness discounting
# ---------------------------------------------------------------------------

def staleness_weight(tau: float, mode: str = "poly",
                     decay: float = 0.5) -> float:
    """s(τ) ∈ (0, 1]: monotone non-increasing discount of a τ-versions-old
    update.  ``poly``: (1+τ)^(−a) (FedAsync's polynomial family), ``exp``:
    e^(−aτ), ``const``: 1 (no discounting)."""
    tau = max(float(tau), 0.0)
    if mode == "const":
        return 1.0
    if mode == "exp":
        return math.exp(-decay * tau)
    if mode == "poly":
        return (1.0 + tau) ** (-decay)
    raise KeyError(f"unknown staleness mode '{mode}' (poly|exp|const)")


# ---------------------------------------------------------------------------
# aggregators (registered into core.aggregation)
# ---------------------------------------------------------------------------

def _staleness_or_ones(stacked: Pytree, cfg: AggregatorConfig) -> jax.Array:
    K = _num_clients(stacked)
    if cfg.staleness is None:
        return jnp.ones((K,), jnp.float32)
    return jnp.asarray(cfg.staleness, jnp.float32)


def aggregate_contextual_async(params: Pytree, stacked_updates: Pytree,
                               grad_tree: Pytree, cfg: AggregatorConfig
                               ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    """Contextual K×K solve with staleness-discounted Gram cross-terms.

    NB the diagonal must stay at full energy: discounting the whole Gram as
    S·G·S and re-scaling α by s cancels exactly for invertible G (the solve
    absorbs any row/column scaling), i.e. would make staleness a no-op.
    Keeping E‖Δ̃_k‖² = G_kk while crediting only s_k of the alignment is what
    actually shrinks a stale update's α."""
    s = _staleness_or_ones(stacked_updates, cfg)
    U = _stacked_to_matrix(stacked_updates, cfg.gram_scope)
    g = scope_vector(grad_tree, cfg.gram_scope)
    G, c = gram_and_cross(U, g)
    d = jnp.diag(G)
    Gd = G * jnp.outer(s, s) + jnp.diag(d * (1.0 - s * s))
    cd = c * s
    alpha = solve_alpha(Gd, cd, cfg.solve)
    new = tree_add(params, stacked_weighted_sum(stacked_updates, alpha))
    beta = cfg.solve.beta
    info = {
        "alpha": alpha,
        "staleness_weight": s,
        "bound": bound_value(Gd, cd, alpha, beta),
        "theorem1_reduction": theorem1_reduction(Gd, alpha, beta),
        "stationarity_residual": jnp.linalg.norm(
            gram_residual(Gd, cd, alpha, beta)),
        "gram_diag": d,
    }
    return new, info


def aggregate_fedbuff(params: Pytree, stacked_updates: Pytree,
                      grad_tree: Optional[Pytree], cfg: AggregatorConfig
                      ) -> Tuple[Pytree, Dict[str, jax.Array]]:
    """FedBuff: uniform mean of staleness-discounted buffered updates.
    FedAsync is this with a single-update buffer."""
    s = _staleness_or_ones(stacked_updates, cfg)
    w = s / s.shape[0]
    new = tree_add(params, stacked_weighted_sum(stacked_updates, w))
    return new, {"alpha": w, "staleness_weight": s}


register_aggregator("contextual_async", aggregate_contextual_async)
register_aggregator("fedbuff", aggregate_fedbuff)
register_aggregator("fedasync", aggregate_fedbuff)


# ---------------------------------------------------------------------------
# async server config + update buffer
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AsyncConfig:
    """Configuration of the asynchronous edge server (mirrors the sync
    :class:`repro.fl.server.ServerConfig` where the concepts coincide)."""
    aggregator: str = "contextual_async"  # contextual_async | fedbuff | fedasync
    num_devices: int = 30                 # N
    buffer_size: int = 5                  # M updates per aggregation
    concurrency: Optional[int] = None     # in-flight cap (None → all devices)
    lr: float = 0.03                      # client learning rate l
    server_lr: float = 1.0                # η for fedasync/fedbuff mixing
    beta: Optional[float] = None          # None → paper's β = 1/l
    mu: float = 0.0                       # FedProx proximal coefficient
    batch_size: int = 32
    min_epochs: int = 1                   # per-dispatch epoch draw ~ U[min,max]
    max_epochs: int = 20
    gram_scope: Optional[str] = None
    ridge: float = 1e-6
    staleness_mode: str = "poly"          # poly | exp | const
    staleness_decay: float = 0.5

    def __post_init__(self):
        if self.aggregator == "fedasync" and self.buffer_size != 1:
            raise ValueError("fedasync aggregates every arrival; set "
                             f"buffer_size=1 (got {self.buffer_size})")
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {self.buffer_size}")
        if self.concurrency is not None and self.concurrency < 1:
            raise ValueError("concurrency must be >= 1 (or None for one task "
                             f"per device), got {self.concurrency}")

    @property
    def smoothness(self) -> float:
        return self.beta if self.beta is not None else 1.0 / self.lr

    def weight(self, tau: float) -> float:
        return staleness_weight(tau, self.staleness_mode, self.staleness_decay)


@dataclass
class BufferedUpdate:
    delta: Pytree          # w_k(after local steps) − w(dispatch version)
    grad: Pytree           # ∇F_k at the dispatch params (K₂=0-style estimate)
    dispatch_version: int
    device_id: int


class AsyncBuffer:
    """Holds arrived updates and flushes them through the configured
    aggregator once ``cfg.buffer_size`` are present."""

    def __init__(self, cfg: AsyncConfig):
        self.cfg = cfg
        self.items: List[BufferedUpdate] = []
        self.agg_fn = aggregate(cfg.aggregator)
        self.base_cfg = AggregatorConfig(
            name=cfg.aggregator,
            solve=SolveConfig(beta=cfg.smoothness, ridge=cfg.ridge),
            gram_scope=cfg.gram_scope)

    def add(self, update: BufferedUpdate) -> None:
        self.items.append(update)

    def ready(self) -> bool:
        return len(self.items) >= self.cfg.buffer_size

    def flush(self, params: Pytree, current_version: int
              ) -> Tuple[Pytree, Dict[str, Any]]:
        """Aggregate the buffered updates into ``params`` and clear."""
        assert self.items, "flush() on an empty buffer"
        taus = np.array([current_version - u.dispatch_version
                         for u in self.items], np.float32)
        s = np.array([self.cfg.weight(t) for t in taus], np.float32)
        # the server mixing rate η rides along in the aggregator's effective
        # weights (fedbuff/fedasync only); s itself stays the documented
        # s(τ) ∈ (0, 1] in the info dict below
        s_eff = (s * self.cfg.server_lr
                 if self.cfg.aggregator in ("fedbuff", "fedasync") else s)

        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[u.delta for u in self.items])
        # ∇f estimate: staleness-weighted mean of the buffered local gradients
        # (fresher gradients better represent ∇f at the current iterate).
        gw = s / max(float(s.sum()), 1e-12)
        grad_est = jax.tree_util.tree_map(
            lambda *gs: sum(w * g for w, g in zip(gw, gs)),
            *[u.grad for u in self.items])

        agg_cfg = replace(self.base_cfg, staleness=jnp.asarray(s_eff))
        new_params, info = self.agg_fn(params, stacked, grad_est, agg_cfg)
        info = dict(info)
        info["staleness_weight"] = jnp.asarray(s)
        info["staleness"] = taus
        info["device_ids"] = np.array([u.device_id for u in self.items])
        self.items = []
        return new_params, info
