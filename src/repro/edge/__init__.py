"""Async edge runtime: event-driven device simulation + staleness-aware
contextual aggregation.

Submodules:
  * profiles     — per-device compute/network/dropout profiles + canonical
                   fleets (uniform / bimodal phone+gateway / long-tail)
  * events       — deterministic heap-of-events virtual-time scheduler
  * async_server — buffered async aggregation (contextual_async / fedbuff /
                   fedasync, registered in ``core.aggregation``)
  * wallclock    — rounds-to-accuracy → virtual-time-to-accuracy conversion

The entry point is :func:`repro.fl.run_async_simulation`, which drives these
against the same datasets/metrics as the synchronous path.
"""
from .async_server import (AsyncBuffer, AsyncConfig, BufferedUpdate,
                           aggregate_contextual_async, aggregate_fedbuff,
                           staleness_weight)
from .events import (BatchDispatch, Event, EventKind, EventScheduler,
                     SchedulerStats)
from .profiles import (ArrayFleet, DeviceProfile, Fleet, array_bimodal_fleet,
                       array_longtail_fleet, array_uniform_fleet,
                       as_array_fleet, bimodal_fleet, fleet_arrays,
                       get_array_fleet, get_fleet, longtail_fleet,
                       uniform_fleet)
from .wallclock import (WallclockCurve, model_flops_per_step,
                        model_payload_bytes, sync_round_durations,
                        sync_wallclock_curve)

__all__ = [
    "AsyncBuffer", "AsyncConfig", "BufferedUpdate",
    "aggregate_contextual_async", "aggregate_fedbuff", "staleness_weight",
    "BatchDispatch", "Event", "EventKind", "EventScheduler", "SchedulerStats",
    "ArrayFleet", "DeviceProfile", "Fleet", "array_bimodal_fleet",
    "array_longtail_fleet", "array_uniform_fleet", "as_array_fleet",
    "bimodal_fleet", "fleet_arrays", "get_array_fleet", "get_fleet",
    "longtail_fleet", "uniform_fleet", "WallclockCurve", "model_flops_per_step",
    "model_payload_bytes", "sync_round_durations", "sync_wallclock_curve",
]
