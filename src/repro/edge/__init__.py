"""Async edge runtime: event-driven device simulation + staleness-aware
contextual aggregation.

Submodules:
  * profiles     — per-device compute/network/dropout profiles + canonical
                   fleets (uniform / bimodal phone+gateway / long-tail)
  * events       — deterministic heap-of-events virtual-time scheduler
  * async_server — buffered async aggregation (contextual_async / fedbuff /
                   fedasync, registered in ``core.aggregation``)
  * wallclock    — rounds-to-accuracy → virtual-time-to-accuracy conversion

The entry point is :func:`repro.fl.run_async_simulation`, which drives these
against the same datasets/metrics as the synchronous path.
"""
from .async_server import (AsyncBuffer, AsyncConfig, BufferedUpdate,
                           aggregate_contextual_async, aggregate_fedbuff,
                           staleness_weight)
from .events import Event, EventKind, EventScheduler, SchedulerStats
from .profiles import (DeviceProfile, Fleet, bimodal_fleet, get_fleet,
                       longtail_fleet, uniform_fleet)
from .wallclock import (WallclockCurve, model_flops_per_step,
                        model_payload_bytes, sync_round_durations,
                        sync_wallclock_curve)

__all__ = [
    "AsyncBuffer", "AsyncConfig", "BufferedUpdate",
    "aggregate_contextual_async", "aggregate_fedbuff", "staleness_weight",
    "Event", "EventKind", "EventScheduler", "SchedulerStats",
    "DeviceProfile", "Fleet", "bimodal_fleet", "get_fleet", "longtail_fleet",
    "uniform_fleet", "WallclockCurve", "model_flops_per_step",
    "model_payload_bytes", "sync_round_durations", "sync_wallclock_curve",
]
