"""Virtual wall-clock accounting: rounds-to-accuracy → time-to-accuracy.

Synchronous and asynchronous FL are not comparable on a per-round axis (an
async "round" is one buffer flush, a sync round waits for its slowest
client).  The common currency is *virtual wall-clock*: the simulated time at
which the server's model reached each evaluation point.

For the async runtime this is just the event scheduler's clock.  For the
synchronous baseline, :func:`sync_round_durations` replays the simulation's
host-side randomness (``sample_round`` on the same selection seed — the
paper's §IV-A3 protocol makes this exact) and charges each round
``max_k task_time(k)``: the straggler gates the round.  Dropped-out devices
in sync cost the server the full straggler wait as well (we charge the
round's max regardless — the usual timeout model, mildly sync-favouring).

Workload model: a local SGD step on batch B costs ≈ 6·B·|w| FLOPs
(fwd + bwd ≈ 3× the 2·B·|w| forward MACs); one task moves the |w|-float32
model down and the update back up.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

import jax
import numpy as np

from ..core.flatten import tree_size
from ..fl.server import ServerConfig, sample_round
from .profiles import Fleet

Pytree = Any


def model_payload_bytes(params: Pytree) -> float:
    """float32 over-the-wire size of one model/update."""
    return 4.0 * tree_size(params)


def model_flops_per_step(params: Pytree, batch_size: int) -> float:
    """≈ FLOPs of one local mini-batch SGD step (fwd+bwd ≈ 6·B·|w|)."""
    return 6.0 * batch_size * tree_size(params)


@dataclass
class WallclockCurve:
    """A (virtual time → metric) curve; the async/sync comparison axis."""
    name: str
    times: List[float] = field(default_factory=list)      # seconds, increasing
    test_acc: List[float] = field(default_factory=list)
    train_loss: List[float] = field(default_factory=list)

    def time_to_accuracy(self, level: float) -> Optional[float]:
        """First virtual time at which test accuracy reaches ``level``."""
        for t, acc in zip(self.times, self.test_acc):
            if acc >= level:
                return t
        return None

    def accuracy_at(self, time: float) -> Optional[float]:
        """Best accuracy achieved by virtual ``time`` (step-function read)."""
        i = bisect.bisect_right(self.times, time)
        if i == 0:
            return None
        return max(self.test_acc[:i])


def sync_round_durations(fleet: Fleet, cfg: ServerConfig,
                         steps_per_epoch: int, num_rounds: int,
                         flops_per_step: float, payload_bytes: float,
                         selection_seed: int = 1234,
                         timing_seed: int = 0) -> np.ndarray:
    """Per-round durations of a *synchronous* run on ``fleet``.

    Replays ``sample_round`` with the run's own selection seed, so the
    replayed (selection, step-budget) pairs are exactly those the simulation
    executed; each round costs the max task time over its K participants."""
    if fleet.num_devices != cfg.num_devices:
        raise ValueError(f"fleet has {fleet.num_devices} devices, config "
                         f"expects {cfg.num_devices}")
    sel_rng = np.random.RandomState(selection_seed)
    timing_rng = np.random.RandomState(timing_seed)
    durations = np.zeros(num_rounds)
    for t in range(num_rounds):
        sel, _, num_steps = sample_round(sel_rng, cfg, steps_per_epoch)
        durations[t] = max(
            fleet[int(d)].task_time(int(n) * flops_per_step, payload_bytes,
                                    timing_rng)
            for d, n in zip(sel, num_steps))
    return durations


def sync_wallclock_curve(result, fleet: Fleet, cfg: ServerConfig,
                         steps_per_epoch: int, num_rounds: int,
                         eval_every: int, flops_per_step: float,
                         payload_bytes: float, selection_seed: int = 1234,
                         timing_seed: int = 0) -> WallclockCurve:
    """Attach virtual times to a sync :class:`~repro.fl.SimulationResult`'s
    eval points (which ``run_simulation`` records every ``eval_every`` rounds
    plus the final round)."""
    durations = sync_round_durations(fleet, cfg, steps_per_epoch, num_rounds,
                                     flops_per_step, payload_bytes,
                                     selection_seed, timing_seed)
    cumulative = np.cumsum(durations)
    eval_rounds = [t for t in range(num_rounds)
                   if (t + 1) % eval_every == 0 or t == num_rounds - 1]
    if len(eval_rounds) != len(result.test_acc):
        raise ValueError(
            f"eval schedule mismatch: replay expects {len(eval_rounds)} eval "
            f"points, result has {len(result.test_acc)}")
    return WallclockCurve(name=result.name,
                          times=[float(cumulative[t]) for t in eval_rounds],
                          test_acc=list(result.test_acc),
                          train_loss=list(result.train_loss))
