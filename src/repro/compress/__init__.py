"""Sub-O(n) summary compression for the hierarchical uplink.

A family of linear-sketch and selection compressors behind one
:class:`Compressor` protocol, plus the per-sender error-feedback state that
makes lossy uplinks convergent.  The hier runtime
(:func:`repro.fl.run_hier_simulation` with ``HierConfig.compress`` set and
the ``hier_contextual_sketch`` aggregator) ships gateway summaries through
these — the cloud's P×P contextual solve runs on sketched cross-terms via
:func:`payload_gram` and the combine applies the decoded updates, so the
solve stays exactly consistent with what actually crossed the wire.

Submodules:
  * base           — protocol, payloads + wire-size accounting, identity
                     scheme, :class:`CompressConfig` budget resolution
  * sketch         — signed random projection and SRHT (linear, unbiased,
                     sketch-space Gram)
  * topk           — magnitude top-k masking (exact sparse decode)
  * lowrank        — rank-r factored summaries (truncated SVD)
  * error_feedback — per-sender residual state (telescoping-exact)
"""
from . import lowrank, sketch, topk  # noqa: F401  (register schemes)
from .base import (Compressed, CompressConfig, Compressor,
                   IdentityCompressor, available_schemes, payload_gram,
                   register_scheme)
from .error_feedback import ErrorFeedback
from .lowrank import LowRankCompressor
from .sketch import SignSketch, SRHTSketch, fwht
from .topk import TopKCompressor

__all__ = [
    "Compressed", "CompressConfig", "Compressor", "IdentityCompressor",
    "available_schemes", "payload_gram", "register_scheme",
    "ErrorFeedback", "LowRankCompressor", "SignSketch", "SRHTSketch",
    "fwht", "TopKCompressor",
]
