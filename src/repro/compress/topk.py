"""Top-k magnitude masking — the selection compressor.

Keeps the k largest-|v| coordinates and ships (value, index) pairs: 2k wire
words for an n-vector, so ``CompressConfig.ratio`` resolves ``k = n/(2·ratio)``.
The decode is the *exact* sparse vector the receiver applies — the bias
lives entirely in the dropped residual, which per-sender error feedback
(:mod:`repro.compress.error_feedback`) re-injects into the next round's
input, the classic EF construction that restores convergence for any
contraction compressor.  At k = n the scheme is the identity, the exactness
anchor the tests pin against the uncompressed pipeline.

The selection itself streams through ``kernels.ops.topk_select`` (chunked
per-block top-k + candidate merge; Pallas twin in ``kernels.topk``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Compressed, CompressConfig, Compressor, register_scheme


class TopKCompressor(Compressor):
    """Magnitude top-k with exact sparse decode."""

    name = "topk"
    linear = False

    def __init__(self, k: int):
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.k = int(k)

    def encode(self, vec: jax.Array, seed: int = 0) -> Compressed:
        from ..kernels import ops
        n = int(vec.shape[0])
        k = min(self.k, n)
        vals, idx = ops.topk_select(jnp.asarray(vec, jnp.float32), k)
        return Compressed(self.name, n, (vals, idx), seed)

    def decode(self, comp: Compressed) -> jax.Array:
        vals, idx = comp.data
        return jnp.zeros((comp.n,), jnp.float32).at[idx].set(vals)

    def wire_floats(self, n: int) -> int:
        return 2 * min(self.k, n)


def _build(cfg: CompressConfig, n: int) -> TopKCompressor:
    k = cfg.k if cfg.k is not None else max(1, int(n / (2.0 * cfg.ratio)))
    return TopKCompressor(k)


register_scheme("topk", _build)
