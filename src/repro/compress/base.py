"""Compressor protocol for sub-O(n) gateway summaries (``repro.compress``).

The hierarchical pipeline's remaining O(n) term is the gateway summary's
(ū_g, ĝ_g) pair riding the backhaul at full model width.  Every scheme here
is an *encoder/decoder pair over flat f32 vectors*:

    comp  = compressor.encode(v, seed)      # what rides the wire
    v_hat = compressor.decode(comp)         # what the receiver reconstructs

with two structural properties the contextual algebra leans on:

  * **Linear sketches** (``linear = True``: sign random projection, SRHT,
    identity) are a matrix ``S (m, n)`` with ``E[SᵀS] = I`` — the scaling is
    folded into S, so sketch-space inner products ``⟨S u, S v⟩`` are already
    *distortion-corrected* unbiased estimates of ``⟨u, v⟩`` and the cloud's
    P×P Gram stage can run entirely in sketch space
    (:func:`payload_gram`, O(P²·m) instead of O(P²·n)).  Linearity also
    means sketched gradient estimates combine exactly:
    ``S(Σ w_h ĝ_h) = Σ w_h S ĝ_h``.
  * **Non-linear selections** (top-k, low-rank) decode to the exact vector
    the receiver applies, so Gram blocks computed on decodes are *exact* for
    the applied updates (no correction needed — the bias lives in the
    discarded residual, which error feedback re-injects next round,
    see :mod:`repro.compress.error_feedback`).

``CompressConfig.build(n)`` resolves a scheme + byte budget into a concrete
compressor: ``ratio`` is the uplink byte-reduction target for one n-vector,
so every scheme prices its own payload layout (top-k pays 2 words per kept
entry, rank-r pays r·(rows+cols), sketches pay m).
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, replace as _dc_replace
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

WIRE_BYTES = 4.0      # f32 values and i32 indices both ride as 4-byte words


@dataclass
class Compressed:
    """One compressed vector as it rides the wire.

    ``data`` holds the payload arrays (sketch coordinates, top-k values +
    indices, low-rank factors); ``n`` the original length; ``seed`` whatever
    the decoder needs to rebuild shared randomness (linear sketches
    regenerate S from it — the matrix itself never travels)."""
    scheme: str
    n: int
    data: Tuple[jax.Array, ...]
    seed: int = 0

    @property
    def nbytes(self) -> float:
        """Serialized wire size: every payload element is a 4-byte word."""
        return WIRE_BYTES * sum(int(np.prod(d.shape)) for d in self.data)


class Compressor(abc.ABC):
    """One compression scheme (see module docstring for the contract)."""

    name: str = "base"
    linear: bool = False        # True ⇒ encode is v ↦ S v with E[SᵀS] = I

    @abc.abstractmethod
    def encode(self, vec: jax.Array, seed: int = 0) -> Compressed:
        """Compress a flat f32 vector ``(n,)``."""

    @abc.abstractmethod
    def decode(self, comp: Compressed) -> jax.Array:
        """Reconstruct the full-width estimate ``(n,)`` of the encoded vector."""

    @abc.abstractmethod
    def wire_floats(self, n: int) -> int:
        """Payload size (4-byte words) for an ``n``-vector — must equal
        ``encode(v).nbytes / 4`` for any ``v`` of that length (tested)."""

    def dot(self, a: Compressed, b: Compressed) -> jax.Array:
        """Distortion-corrected estimate of ``⟨u, v⟩`` from two payloads.

        Linear sketches take it in sketch space (both operands must share
        the same ``seed`` → same S); selection schemes fall back to the dot
        of decodes, which is *exact* for the vectors the receiver applies.
        """
        if self.linear:
            if a.seed != b.seed:
                raise ValueError(f"sketch-space dot needs a shared sketch: "
                                 f"seeds {a.seed} != {b.seed}")
            return jnp.vdot(a.data[0], b.data[0])
        return jnp.vdot(self.decode(a), self.decode(b))


def payload_gram(compressor: Compressor, u_comps: Sequence[Compressed],
                 g_comps: Sequence[Compressed], weights: np.ndarray
                 ) -> Tuple[jax.Array, jax.Array]:
    """The cloud's sketched cross-terms: ``G₂[g,h] ≈ ⟨ū_g, ū_h⟩`` and
    ``c₂[g] ≈ ⟨ū_g, ĝ⟩`` with ``ĝ = Σ w_h ĝ_h``, computed without ever
    materializing an n-vector when the scheme is linear.

    For linear sketches this is unbiased for the inner products of the
    *encoded targets* (the correction for sketch distortion is folded into
    S's scaling), while the combine applies their MMSE-*shrunk* decodes.
    That is not an inconsistency: every child shrinks by the same factor s
    (linear schemes share one S), so pricing the decodes would scale G₂ and
    c₂ uniformly by s² — and the mass-conserving Σγ=1 KKT stage is exactly
    invariant under that joint rescale (substitute λ → λ/s²; tested).  For
    selection schemes the estimate is exact for the decoded updates
    actually applied.
    """
    w = np.asarray(weights, np.float64)
    w = w / max(float(w.sum()), 1e-12)
    if compressor.linear:
        seeds = {c.seed for c in list(u_comps) + list(g_comps)}
        if len(seeds) != 1:
            raise ValueError(f"sketch-space Gram needs one shared sketch "
                             f"seed, got {sorted(seeds)}")
        S = jnp.stack([c.data[0] for c in u_comps])          # (P, m)
        sg = sum(float(wi) * c.data[0] for wi, c in zip(w, g_comps))
    else:
        S = jnp.stack([compressor.decode(c) for c in u_comps])   # (P, n)
        sg = sum(float(wi) * compressor.decode(c)
                 for wi, c in zip(w, g_comps))
    return S @ S.T, S @ sg


class IdentityCompressor(Compressor):
    """No-op scheme (S = I): the exactness anchor — every pipeline claim
    must collapse to the uncompressed run under it (tested)."""

    name = "identity"
    linear = True

    def encode(self, vec: jax.Array, seed: int = 0) -> Compressed:
        return Compressed("identity", int(vec.shape[0]),
                          (jnp.asarray(vec, jnp.float32),), seed)

    def decode(self, comp: Compressed) -> jax.Array:
        return comp.data[0]

    def wire_floats(self, n: int) -> int:
        return n


_SCHEMES: Dict[str, Callable[["CompressConfig", int], Compressor]] = {}


def register_scheme(name: str, build: Callable[["CompressConfig", int],
                                               Compressor]) -> None:
    if name in _SCHEMES:
        raise KeyError(f"compression scheme '{name}' already registered")
    _SCHEMES[name] = build


def available_schemes() -> Tuple[str, ...]:
    return tuple(sorted(_SCHEMES))


register_scheme("identity", lambda cfg, n: IdentityCompressor())


@dataclass(frozen=True)
class CompressConfig:
    """Scheme + byte budget for summary compression (``HierConfig.compress``).

    ``ratio`` is the per-vector uplink reduction target: an n-float vector
    must ride in ≤ n/ratio 4-byte words, and each scheme solves for its own
    parameter (sketch_dim = n/ratio; top-k pays value+index so k = n/2ratio;
    rank-r pays r·(rows+cols) of the reshaped near-square matrix).  Explicit
    ``sketch_dim`` / ``k`` / ``rank`` override the budget-derived value.
    """
    scheme: str = "topk"           # identity | sign_sketch | srht | topk | lowrank
    ratio: float = 8.0
    sketch_dim: Optional[int] = None
    k: Optional[int] = None
    rank: Optional[int] = None
    u_frac: float = 0.5            # fraction of the per-summary budget spent
                                   # on ū vs ĝ; the update stream carries the
                                   # applied step, so overweighting it (~0.75)
                                   # buys loss at the same wire size.  Linear
                                   # sketches need 0.5: ū and ĝ must share S
                                   # for the sketch-space c-term.
    error_feedback: bool = True
    device_uplink: bool = False    # also EF-compress device→gateway uploads
                                   # — BOTH the update and the gradient
                                   # stream (the tier solve consumes both),
                                   # with per-device residual state
    seed: int = 0

    def __post_init__(self):
        if self.ratio < 1.0:
            raise ValueError(f"ratio must be >= 1, got {self.ratio}")
        for fname in ("sketch_dim", "k", "rank"):
            v = getattr(self, fname)
            if v is not None and v < 1:
                raise ValueError(f"{fname} must be >= 1, got {v}")
        if not (0.0 < self.u_frac < 1.0):
            raise ValueError(f"u_frac must be in (0, 1), got {self.u_frac}")
        if self.u_frac != 0.5 and self.scheme in ("identity", "sign_sketch",
                                                  "srht"):
            raise ValueError(f"u_frac={self.u_frac} needs a selection scheme "
                             "(topk|lowrank): linear sketches must sketch ū "
                             "and ĝ with the same S")

    def _resolve(self, n: int, ratio: float) -> Compressor:
        # imported here so base carries no scheme dependencies
        from . import lowrank, sketch, topk  # noqa: F401  (register schemes)
        if self.scheme not in _SCHEMES:
            raise KeyError(f"unknown compression scheme '{self.scheme}'; "
                           f"have {available_schemes()}")
        cfg = self if ratio == self.ratio else _dc_replace(self, ratio=ratio,
                                                           u_frac=0.5)
        return _SCHEMES[self.scheme](cfg, n)

    def build(self, n: int) -> Compressor:
        """Resolve to a concrete compressor for a single ``n``-float vector
        (budget: n/ratio wire words)."""
        return self._resolve(n, self.ratio)

    def build_pair(self, n: int) -> Tuple[Compressor, Compressor]:
        """Resolve the (ū, ĝ) compressor pair for one summary: the joint
        budget ``2n/ratio`` wire words is split ``u_frac : 1−u_frac``.
        At u_frac = 0.5 both equal :meth:`build`.  A sub-budget larger than
        the vector itself clamps to full width (per-vector ratio ≥ 1) — a
        skewed split of a mild joint ratio cannot overflow n."""
        return (self._resolve(n, max(1.0, self.ratio / (2.0 * self.u_frac))),
                self._resolve(n, max(1.0, self.ratio
                                     / (2.0 * (1.0 - self.u_frac)))))
