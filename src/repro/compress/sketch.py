"""Linear sketch compressors: signed random projection and SRHT.

Both are a matrix ``S (m, n)`` with ``E[SᵀS] = I`` — the distortion
correction is folded into S's scaling, so sketch-space inner products are
unbiased estimates of true inner products and the cloud's P×P stage can run
on the payloads directly (:func:`repro.compress.base.payload_gram`).  The
matrix never rides the wire: every party regenerates it from the shared
per-round ``seed``, and re-drawing S each round decorrelates the
reconstruction noise that error feedback re-injects.

  * :class:`SignSketch` — dense Rademacher projection ``S = R/√m``,
    ``R ∈ {±1}^{m×n}``.  The apply streams through the counter-based RNG
    kernel (``kernels.ops.sign_sketch``): R's entries are a pure hash of
    (row, column, seed) generated on the fly inside the contraction, so the
    O(m·n) sign matrix is **never materialized** — encode and decode both
    touch only one (m, block) tile at a time, on every backend.
  * :class:`SRHTSketch` — structured subsampled randomized Hadamard
    transform ``S = √(N/m)·P·H_N/√N·D``: O(n log n) apply and O(n) state
    (the n sign flips + m sampled rows), no dense matrix at any point.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .base import Compressed, CompressConfig, Compressor, register_scheme


def _key(seed_base: int, seed: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed_base), seed)


def _seed32(seed_base: int, seed: int) -> jax.Array:
    """Fold (seed_base, per-round seed) into the uint32 counter-RNG seed."""
    x = (int(seed_base) * 0x9E3779B1 + int(seed) * 0x85EBCA6B
         + 0x1B873593) & 0xFFFFFFFF
    return jnp.uint32(x)


class SignSketch(Compressor):
    """Signed random projection ``v ↦ R v / √m`` (unbiased: E[SᵀS] = I).

    R is the implicit counter-based sign matrix of
    :mod:`repro.kernels.rng_sketch`: regenerated tile-by-tile inside the
    kernel from (row, column, seed) counters, identical on every backend,
    never resident in memory.

    The decode applies the MMSE shrinkage ``m/(m+n+1)·Sᵀs``: the naive
    adjoint ``Sᵀs = SᵀS v`` inflates norms by ~n/m, which makes the
    round-to-round error operator ``I − SᵀS`` an *expansion* for m < n+1 —
    the applied steps diverge and error feedback cannot save them.  Shrunk,
    ``E‖(I − c·SᵀS)x‖² = (1 − m/(m+n+1))·‖x‖²`` is a contraction, which is
    exactly the condition the EF convergence argument needs (tested: the
    unshrunk decode demonstrably expands, the shrunk one contracts)."""

    name = "sign_sketch"
    linear = True

    def __init__(self, m: int, seed_base: int = 0):
        if m < 1:
            raise ValueError(f"sketch_dim must be >= 1, got {m}")
        self.m = int(m)
        self.seed_base = seed_base

    def sign_matrix(self, n: int, seed: int = 0) -> jax.Array:
        """Materialized ``S = R/√m`` — oracle for tests only; the encode /
        decode paths never build this."""
        from ..kernels.rng_sketch import rng_sign_matrix
        r = rng_sign_matrix(_seed32(self.seed_base, seed), self.m, n)
        return r / jnp.sqrt(jnp.float32(self.m))

    def encode(self, vec: jax.Array, seed: int = 0) -> Compressed:
        from ..kernels import ops
        s = ops.sign_sketch(jnp.asarray(vec, jnp.float32)[None, :],
                            _seed32(self.seed_base, seed), self.m)[0]
        return Compressed(self.name, int(vec.shape[0]), (s,), seed)

    def decode(self, comp: Compressed) -> jax.Array:
        from ..kernels import ops
        shrink = self.m / (self.m + comp.n + 1.0)
        return shrink * ops.sign_sketch_adjoint(
            comp.data[0], _seed32(self.seed_base, comp.seed), comp.n)

    def wire_floats(self, n: int) -> int:
        return self.m


def fwht(x: jax.Array) -> jax.Array:
    """In-order fast Walsh–Hadamard transform of a power-of-2 vector.

    Unnormalized: ``fwht(fwht(x)) = N·x`` — callers divide by √N to get the
    orthonormal ``H_N/√N`` the SRHT analysis assumes.
    """
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError(f"fwht needs a power-of-2 length, got {n}")
    y, h = x, 1
    while h < n:
        y = y.reshape(-1, 2, h)
        y = jnp.stack([y[:, 0, :] + y[:, 1, :],
                       y[:, 0, :] - y[:, 1, :]], axis=1)
        h *= 2
    return y.reshape(-1)


class SRHTSketch(Compressor):
    """Subsampled randomized Hadamard transform (structured, matrix-free).

    ``S = √(N/m) · P · (H_N/√N) · D`` with D a diagonal of Rademacher signs,
    H the N-point Hadamard transform (N = n padded to a power of 2) and P a
    uniform without-replacement row sample.  Unbiased (E[SᵀS] = I).

    Here ``SᵀS = (N/m)·Q`` with Q an orthogonal projection onto a random
    m-dimensional rotated-coordinate subspace, so the decode shrinks by
    ``m/N``: the shrunk reconstruction is exactly ``Q v`` — an orthogonal
    projection, hence ``I − Q`` is non-expansive and error feedback
    converges (the unshrunk adjoint expands by N/m on the kept subspace).
    At m = N the projection is the identity: decode ∘ encode is *exact* —
    the sketch_dim = n anchor the tests pin.
    """

    name = "srht"
    linear = True

    def __init__(self, m: int, seed_base: int = 0):
        if m < 1:
            raise ValueError(f"sketch_dim must be >= 1, got {m}")
        self.m = int(m)
        self.seed_base = seed_base

    def _padded(self, n: int) -> int:
        return 1 << max(int(math.ceil(math.log2(max(n, 1)))), 0)

    def _signs_rows(self, n: int, seed: int):
        N = self._padded(n)
        m = min(self.m, N)
        key = _key(self.seed_base, seed)
        d = jax.random.rademacher(key, (N,), jnp.float32)
        rows = jax.random.choice(jax.random.fold_in(key, 1), N, (m,),
                                 replace=False)
        return d, rows, N, m

    def encode(self, vec: jax.Array, seed: int = 0) -> Compressed:
        n = int(vec.shape[0])
        d, rows, N, m = self._signs_rows(n, seed)
        v = jnp.zeros((N,), jnp.float32).at[:n].set(
            jnp.asarray(vec, jnp.float32))
        t = fwht(d * v) / jnp.sqrt(jnp.float32(N))
        s = t[rows] * jnp.sqrt(jnp.float32(N) / jnp.float32(m))
        return Compressed(self.name, n, (s,), seed)

    def decode(self, comp: Compressed) -> jax.Array:
        d, rows, N, m = self._signs_rows(comp.n, comp.seed)
        z = jnp.zeros((N,), jnp.float32).at[rows].set(
            comp.data[0] * jnp.sqrt(jnp.float32(N) / jnp.float32(m)))
        shrink = m / float(N)                # Sᵀs → Q v (see class docstring)
        return shrink * (d * fwht(z) / jnp.sqrt(jnp.float32(N)))[:comp.n]

    def wire_floats(self, n: int) -> int:
        return min(self.m, self._padded(n))


def _build_sign(cfg: CompressConfig, n: int) -> SignSketch:
    m = cfg.sketch_dim if cfg.sketch_dim is not None else max(
        1, int(n / cfg.ratio))
    return SignSketch(m, seed_base=cfg.seed)


def _build_srht(cfg: CompressConfig, n: int) -> SRHTSketch:
    m = cfg.sketch_dim if cfg.sketch_dim is not None else max(
        1, int(n / cfg.ratio))
    return SRHTSketch(m, seed_base=cfg.seed)


register_scheme("sign_sketch", _build_sign)
register_scheme("srht", _build_srht)
