"""Rank-r factored summaries — the spectral compressor.

Reshapes the flat n-vector into a near-square (rows × cols) matrix (zero
padded; exact, the pad never re-enters) and ships the best rank-r
approximation as two factors: ``r·(rows + cols)`` wire words, so
``CompressConfig.ratio`` resolves ``r ≈ n / (ratio·(rows+cols)) ≈ √n/(2·ratio)``
— the steepest compression curve of the family when the update matrix has
fast-decaying spectrum (which FL updates empirically do: a few shared
directions dominate a round's cohort).  Like top-k this is a projection
(idempotent, non-expansive), so per-sender error feedback makes it
convergent; at full rank it is exact.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .base import Compressed, CompressConfig, Compressor, register_scheme


def _shape_for(n: int):
    rows = int(math.ceil(math.sqrt(n)))
    cols = int(math.ceil(n / rows))
    return rows, cols


class LowRankCompressor(Compressor):
    """Truncated-SVD factorization of the near-square reshape."""

    name = "lowrank"
    linear = False

    def __init__(self, rank: int):
        if rank < 1:
            raise ValueError(f"rank must be >= 1, got {rank}")
        self.rank = int(rank)

    def _rank_for(self, n: int) -> int:
        rows, cols = _shape_for(n)
        return min(self.rank, rows, cols)

    def encode(self, vec: jax.Array, seed: int = 0) -> Compressed:
        n = int(vec.shape[0])
        rows, cols = _shape_for(n)
        r = self._rank_for(n)
        m = jnp.zeros((rows * cols,), jnp.float32).at[:n].set(
            jnp.asarray(vec, jnp.float32)).reshape(rows, cols)
        u, s, vt = jnp.linalg.svd(m, full_matrices=False)
        return Compressed(self.name, n,
                          (u[:, :r] * s[:r], vt[:r, :]), seed)

    def decode(self, comp: Compressed) -> jax.Array:
        a, b = comp.data
        return (a @ b).reshape(-1)[:comp.n]

    def wire_floats(self, n: int) -> int:
        rows, cols = _shape_for(n)
        return self._rank_for(n) * (rows + cols)


def _build(cfg: CompressConfig, n: int) -> LowRankCompressor:
    if cfg.rank is not None:
        return LowRankCompressor(cfg.rank)
    rows, cols = _shape_for(n)
    return LowRankCompressor(max(1, int(n / (cfg.ratio * (rows + cols)))))


register_scheme("lowrank", _build)
