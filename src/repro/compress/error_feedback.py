"""Per-sender error-feedback state — what makes lossy uplinks convergent.

Every compressing sender (a device, a gateway, a regional node) keeps the
residual of its *own* last transmission and folds it into the next one:

    target_t  = v_t + e_{t-1}
    payload_t = encode(target_t)
    e_t       = target_t - decode(payload_t)

The telescoping identity ``Σ_t decode_t = Σ_t v_t − e_T`` holds *exactly*
by construction (tested): nothing is ever lost, only delayed, which is the
standard EF argument that restores SGD-style convergence under any
contraction compressor (top-k, low-rank) and keeps the re-drawn linear
sketches' zero-mean noise from accumulating.

State is keyed by an arbitrary hashable sender id, so one ledger serves
per-device state (``("dev", device_id)``) and per-node summary state
(``("u", node_id)`` / ``("g", node_id)``) side by side; senders that sit
out a round (fan-in sampling, dropouts) simply carry their residual.
"""
from __future__ import annotations

from typing import Dict, Hashable, Tuple

import jax
import jax.numpy as jnp

from .base import Compressed, Compressor


class ErrorFeedback:
    """Residual ledger for one simulation (persists across rounds)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.residual: Dict[Hashable, jax.Array] = {}

    def step(self, sender: Hashable, vec: jax.Array, compressor: Compressor,
             seed: int = 0) -> Tuple[Compressed, jax.Array]:
        """Compress ``vec`` on behalf of ``sender``; returns (payload,
        decoded) and rolls the sender's residual forward."""
        target = jnp.asarray(vec, jnp.float32)
        if self.enabled and sender in self.residual:
            target = target + self.residual[sender]
        comp = compressor.encode(target, seed=seed)
        decoded = compressor.decode(comp)
        if self.enabled:
            self.residual[sender] = target - decoded
        return comp, decoded

    def residual_norm(self, sender: Hashable) -> float:
        r = self.residual.get(sender)
        return 0.0 if r is None else float(jnp.linalg.norm(r))
