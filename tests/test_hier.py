"""Tests for the hierarchical aggregation subsystem (repro.hier): Gram block
composition against the flat reductions on every execution path, topology
validation, summary composability/exactness, the mass-conserving parent-tier
solve, and the multi-hop simulation end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SolveConfig, available_aggregators,
                        blockwise_gram_and_cross, gram_and_cross,
                        gram_and_cross_chunked, gram_block,
                        gram_block_chunked, merge_gram_blocks, solve_alpha)
from repro.core.flatten import tree_to_vector
from repro.edge import bimodal_fleet, uniform_fleet
from repro.fl import run_hier_simulation
from repro.hier import (HierConfig, Link, get_topology,
                        geo_partitioned_topology, merge_summaries,
                        star_topology, summarize_updates, summary_bytes,
                        two_tier_topology, update_bytes)
from repro.kernels import ops
from repro.kernels.gram import gram_block_pallas
from repro.models.logistic import logistic_apply, logistic_loss

import repro.hier.hier_server  # noqa: F401  (registers hier aggregators)


# ---------------------------------------------------------------------------
# Gram block composition (satellite): merged per-gateway blocks == flat
# ---------------------------------------------------------------------------

def _split(U, sizes):
    out, o = [], 0
    for s in sizes:
        out.append(U[o:o + s])
        o += s
    return out


# K = 13 with uneven groups: neither K nor any group is a multiple of the
# 8-sublane pad, exercising the padding paths.
@pytest.mark.parametrize("sizes", [(4, 5, 4), (1, 12), (13,), (3, 3, 3, 4)])
def test_block_merge_equals_flat_jnp(sizes):
    key = jax.random.PRNGKey(sum(sizes))
    U = jax.random.normal(key, (13, 700))
    g = jax.random.normal(jax.random.fold_in(key, 1), (700,))
    Gf, cf = gram_and_cross(U, g)
    Gm, cm = blockwise_gram_and_cross(_split(U, sizes), g)
    np.testing.assert_allclose(np.asarray(Gm), np.asarray(Gf), rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(cm), np.asarray(cf), rtol=1e-5,
                               atol=1e-4)


def test_block_merge_equals_flat_chunked():
    key = jax.random.PRNGKey(7)
    U = jax.random.normal(key, (11, 900))      # n not a chunk multiple
    g = jax.random.normal(jax.random.fold_in(key, 1), (900,))
    Gf, cf = gram_and_cross(U, g)
    Gm, cm = blockwise_gram_and_cross(
        _split(U, (4, 3, 4)), g,
        diag_fn=lambda u, gr: gram_and_cross_chunked(u, gr, chunk=256),
        block_fn=lambda a, b: gram_block_chunked(a, b, chunk=256))
    np.testing.assert_allclose(np.asarray(Gm), np.asarray(Gf), atol=1e-4)
    np.testing.assert_allclose(np.asarray(cm), np.asarray(cf), atol=1e-4)


def test_block_merge_equals_flat_pallas():
    from repro.kernels.gram import gram_pallas
    key = jax.random.PRNGKey(11)
    U = jax.random.normal(key, (13, 500))      # K=13: sublane pad in kernel
    g = jax.random.normal(jax.random.fold_in(key, 1), (500,))
    Gf, cf = gram_and_cross(U, g)
    Gm, cm = blockwise_gram_and_cross(
        _split(U, (5, 4, 4)), g,
        diag_fn=lambda u, gr: gram_pallas(u, gr, block_n=128, interpret=True),
        block_fn=lambda a, b: gram_block_pallas(a, b, g, block_n=128,
                                                interpret=True)[0])
    np.testing.assert_allclose(np.asarray(Gm), np.asarray(Gf), atol=1e-3)
    np.testing.assert_allclose(np.asarray(cm), np.asarray(cf), atol=1e-3)


def test_gram_block_pallas_matches_ref_and_ops_dispatch():
    key = jax.random.PRNGKey(3)
    ua = jax.random.normal(key, (5, 333))
    ub = jax.random.normal(jax.random.fold_in(key, 1), (7, 333))
    g = jax.random.normal(jax.random.fold_in(key, 2), (333,))
    Gp, cp = gram_block_pallas(ua, ub, g, block_n=128, interpret=True)
    np.testing.assert_allclose(np.asarray(Gp), np.asarray(ua @ ub.T),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(cp), np.asarray(ua @ g), atol=1e-4)
    # default dispatch now routes through the registry (compiled XLA off-TPU,
    # not interpret-mode Pallas) — equal up to f32 accumulation order
    Gd, cd = ops.gram_block_and_cross(ua, ub, g, block_n=128)
    np.testing.assert_allclose(np.asarray(Gd), np.asarray(Gp), rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(cd), np.asarray(cp), rtol=1e-5,
                               atol=1e-4)


def test_merge_gram_blocks_validates_segment_count():
    with pytest.raises(ValueError, match="cross-term"):
        merge_gram_blocks([jnp.eye(2)], {}, [])


# ---------------------------------------------------------------------------
# topologies
# ---------------------------------------------------------------------------

def test_topology_builders_shapes_and_helpers():
    fleet = uniform_fleet(12)
    star = star_topology(fleet)
    assert star.depth == 1 and star.gateways[0].node_id == star.cloud_id
    two = two_tier_topology(fleet, 3)
    assert two.depth == 2 and len(two.gateways) == 3
    assert sorted(sum((two.devices_under(g.node_id) for g in two.gateways),
                      [])) == list(range(12))
    geo = geo_partitioned_topology(fleet, 2, 2)
    assert geo.depth == 3 and len(geo.gateways) == 4
    assert len(geo.tier_nodes(2)) == 2
    assert geo.devices_under(geo.cloud_id) == list(range(12))
    assert "depth=3" in geo.describe()


def test_topology_validation_rejects_bad_trees():
    fleet = uniform_fleet(4)
    with pytest.raises(ValueError, match="num_gateways"):
        two_tier_topology(fleet, 9)
    with pytest.raises(ValueError, match="bandwidth"):
        Link(0.0, 1.0)
    with pytest.raises(KeyError):
        get_topology("nope", 8)
    assert get_topology("two_tier_bimodal", 8, num_gateways=2).depth == 2
    assert get_topology("star", 6).num_devices == 6
    assert get_topology("geo", 8).depth == 3


def test_link_transfer_times():
    link = Link(1e6, 2e6, latency=0.5)
    assert link.uplink_time(1e6) == pytest.approx(1.5)
    assert link.downlink_time(1e6) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# summaries: composability + exactness of the two-stage solve
# ---------------------------------------------------------------------------

def _toy(key, K=8, dim=30, classes=3):
    k1, k2 = jax.random.split(key)
    updates = [{"w": jax.random.normal(jax.random.fold_in(k1, i),
                                       (dim, classes)) * 0.1}
               for i in range(K)]
    grads = [{"w": jax.random.normal(jax.random.fold_in(k2, i),
                                     (dim, classes)) * 0.1}
             for i in range(K)]
    return updates, grads


def test_single_gateway_hier_equals_flat_exactly():
    """One gateway holding the whole cohort: the gateway solve IS the flat
    solve, and the mass-conserving cloud stage must return γ = 1 exactly."""
    updates, grads = _toy(jax.random.PRNGKey(0))
    cfg = SolveConfig(beta=4.0, ridge=1e-8)
    s = summarize_updates(100, range(8), updates, grads, [1] * 8, cfg)
    top = merge_summaries(101, [s], cfg)
    np.testing.assert_allclose(np.asarray(top.alpha), [1.0], atol=1e-5)
    # flat solve over the same members
    U = jnp.stack([tree_to_vector(u) for u in updates])
    g = tree_to_vector(jax.tree_util.tree_map(
        lambda *xs: sum(xs) / len(xs), *grads))
    G, c = gram_and_cross(U, g)
    alpha_flat = solve_alpha(G, c, cfg)
    np.testing.assert_allclose(np.asarray(s.alpha), np.asarray(alpha_flat),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(top.u_bar["w"]),
                               np.asarray(s.u_bar["w"]), rtol=1e-5)


def test_summary_composes_recursively_and_conserves_counts():
    updates, grads = _toy(jax.random.PRNGKey(1), K=9)
    cfg = SolveConfig(beta=4.0)
    s1 = summarize_updates(100, range(3), updates[:3], grads[:3], [1] * 3, cfg)
    s2 = summarize_updates(101, range(3, 6), updates[3:6], grads[3:6],
                           [1] * 3, cfg)
    s3 = summarize_updates(102, range(6, 9), updates[6:], grads[6:],
                           [1] * 3, cfg)
    regional = merge_summaries(200, [s1, s2], cfg)
    assert regional.num_updates == 6
    top = merge_summaries(300, [regional, s3], cfg)
    assert top.num_updates == 9
    # parent-tier solves conserve mass
    assert float(jnp.sum(regional.alpha)) == pytest.approx(1.0, abs=1e-5)
    assert float(jnp.sum(top.alpha)) == pytest.approx(1.0, abs=1e-5)
    assert np.isfinite(np.asarray(top.u_bar["w"])).all()


def test_hier_fedavg_tier_rule_composes_to_flat_mean():
    updates, grads = _toy(jax.random.PRNGKey(2), K=6)
    cfg = SolveConfig(beta=4.0)
    s1 = summarize_updates(100, range(4), updates[:4], grads[:4], [1] * 4,
                           cfg, mode="mean")
    s2 = summarize_updates(101, range(4, 6), updates[4:], grads[4:], [1] * 2,
                           cfg, mode="mean")
    top = merge_summaries(200, [s1, s2], cfg, mode="mean")
    flat_mean = np.mean(np.stack([np.asarray(u["w"]) for u in updates]), 0)
    np.testing.assert_allclose(np.asarray(top.u_bar["w"]), flat_mean,
                               rtol=1e-5, atol=1e-7)


def test_summarize_rejects_empty_and_bad_mode():
    cfg = SolveConfig(beta=4.0)
    with pytest.raises(ValueError, match="zero updates"):
        summarize_updates(1, [], [], [], [], cfg)
    updates, grads = _toy(jax.random.PRNGKey(3), K=2)
    with pytest.raises(KeyError, match="tier mode"):
        summarize_updates(1, [0, 1], updates, grads, [1, 1], cfg, mode="bogus")


def test_mass_conserving_solve_beats_any_single_child_on_bound():
    """Σγ=1 keeps every corner e_g feasible, so the constrained cloud bound
    must be ≤ the bound of promoting any single child's combination."""
    from repro.core.solve import bound_value
    key = jax.random.PRNGKey(5)
    Ub = jax.random.normal(key, (4, 50))
    g = jax.random.normal(jax.random.fold_in(key, 1), (50,))
    G2, c2 = gram_and_cross(Ub, g)
    beta = 3.0
    gamma = solve_alpha(G2, c2, SolveConfig(beta=beta, ridge=1e-8,
                                            sum_to=1.0))
    assert float(jnp.sum(gamma)) == pytest.approx(1.0, abs=1e-5)
    b_star = float(bound_value(G2, c2, gamma, beta))
    for gidx in range(4):
        corner = jnp.zeros((4,)).at[gidx].set(1.0)
        assert b_star <= float(bound_value(G2, c2, corner, beta)) + 1e-4


# ---------------------------------------------------------------------------
# registry + comm accounting
# ---------------------------------------------------------------------------

def test_hier_aggregators_registered():
    names = available_aggregators()
    for name in ("hier_contextual", "hier_fedavg", "hier_relay"):
        assert name in names


def test_summary_vs_update_bytes():
    n, k = 10_000, 16
    assert summary_bytes(k, n) < 2 * update_bytes(n)
    assert summary_bytes(k, n, include_grad=True) == pytest.approx(
        summary_bytes(k, n) + update_bytes(n))
    # the whole point: one summary ≪ forwarding k raw updates
    assert summary_bytes(k, n, include_grad=True) < 0.2 * k * update_bytes(n)


def test_hier_config_validation():
    with pytest.raises(ValueError, match="aggregator"):
        HierConfig(aggregator="bogus")
    with pytest.raises(ValueError, match="fan_in"):
        HierConfig(fan_in=0)
    with pytest.raises(ValueError, match="gateway_grad"):
        HierConfig(gateway_grad="bogus")
    assert HierConfig(lr=0.25).smoothness == pytest.approx(4.0)
    assert HierConfig(aggregator="hier_fedavg").tier_mode == "mean"


# ---------------------------------------------------------------------------
# end-to-end simulation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_problem(tiny_edge_problem):
    # shared session-scoped dataset/model (conftest) → one set of compiled
    # functions serves both this module and test_compress
    ds, params, _ = tiny_edge_problem
    return ds, params


def _hier(ds, params, topo, seed=11, rounds=5, **kw):
    base = dict(aggregator="hier_contextual", lr=0.2, batch_size=10,
                min_epochs=1, max_epochs=4)
    base.update(kw)
    return run_hier_simulation("hier", logistic_loss, logistic_apply, params,
                               ds, HierConfig(**base), topo,
                               num_rounds=rounds, selection_seed=seed,
                               eval_every=2)


def test_hier_simulation_runs_and_is_deterministic(tiny_problem):
    ds, params = tiny_problem
    fleet = bimodal_fleet(12, slowdown=4.0, dropout_slow=0.2, seed=0)
    topo = two_tier_topology(fleet, 3)
    r1 = _hier(ds, params, topo)
    r2 = _hier(ds, params, topo)
    assert r1.times == r2.times
    assert r1.train_loss == r2.train_loss
    assert np.isfinite(r1.train_loss).all()
    assert all(b >= a for a, b in zip(r1.times, r1.times[1:]))
    assert r1.arrived + r1.dropped == r1.dispatched - 0  # all rounds drained
    # fused-engine wall-clock stats ride the result (satellite: compile vs
    # steady-state split for bench sweeps)
    assert set(r1.engine) >= {"compile_wall_time_s",
                              "steady_wall_time_per_round_s",
                              "rounds_wall_time_s"}
    assert r1.engine["rounds_wall_time_s"] > 0


def test_hier_simulation_learns_and_saves_uplink(tiny_problem):
    ds, params = tiny_problem
    fleet = bimodal_fleet(12, slowdown=4.0, dropout_slow=0.0, seed=0)
    flat = _hier(ds, params, star_topology(fleet), rounds=6)
    hier = _hier(ds, params, two_tier_topology(fleet, 3), rounds=6)
    assert hier.train_loss[-1] < hier.train_loss[0]
    assert hier.cloud_uplink_bytes < flat.cloud_uplink_bytes
    # per-tier ledger is populated for every tier of the tree
    assert hier.comm["tier_2"]["bytes_up"] == hier.cloud_uplink_bytes
    assert hier.comm["tier_1"]["bytes_up"] > 0
    assert hier.comm["tier_1"]["bytes_down"] > 0


def test_hier_relay_matches_flat_math(tiny_problem):
    """Relay routes raw updates through the tree: same bytes as flat at the
    cloud and the identical contextual result (the events are identical)."""
    ds, params = tiny_problem
    fleet = uniform_fleet(12, dropout=0.0, jitter=0.05)
    flat = _hier(ds, params, star_topology(fleet), rounds=4)
    relay = _hier(ds, params, two_tier_topology(fleet, 3), rounds=4,
                  aggregator="hier_relay")
    np.testing.assert_allclose(flat.train_loss, relay.train_loss, rtol=1e-5)
    assert relay.cloud_uplink_bytes == pytest.approx(flat.cloud_uplink_bytes)


def test_hier_fedavg_gateway_grad_and_fan_in(tiny_problem):
    ds, params = tiny_problem
    fleet = uniform_fleet(12, dropout=0.0)
    topo = two_tier_topology(fleet, 3)
    r = _hier(ds, params, topo, aggregator="hier_fedavg", fan_in=2)
    assert np.isfinite(r.train_loss).all()
    g = _hier(ds, params, topo, gateway_grad="global")
    assert np.isfinite(g.train_loss).all()
    # the pre-pass costs latency, not bytes: same cloud uplink either way
    loc = _hier(ds, params, topo, gateway_grad="local")
    assert g.cloud_uplink_bytes == pytest.approx(loc.cloud_uplink_bytes)
    assert g.times[-1] > loc.times[-1]


def test_hier_three_tier_geo(tiny_problem):
    ds, params = tiny_problem
    topo = geo_partitioned_topology(uniform_fleet(12, dropout=0.1), 2, 2)
    r = _hier(ds, params, topo, rounds=4)
    assert np.isfinite(r.train_loss).all()
    assert r.comm["tier_3"]["bytes_up"] > 0          # regional → cloud
    assert r.comm["tier_2"]["bytes_up"] > 0          # gateway → regional
    assert r.rounds_skipped == 0
    # gradient pre-pass through the regional tier: same bytes, more hops
    g = _hier(ds, params, topo, rounds=4, gateway_grad="global")
    assert np.isfinite(g.train_loss).all()
    assert g.cloud_uplink_bytes == pytest.approx(r.cloud_uplink_bytes)
    assert g.times[-1] > r.times[-1]


def test_hier_simulation_rejects_small_dataset(tiny_problem):
    ds, params = tiny_problem
    topo = star_topology(uniform_fleet(50))
    with pytest.raises(ValueError, match="device shards"):
        _hier(ds, params, topo)
