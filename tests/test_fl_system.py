"""Integration tests: the FL simulation reproduces the paper's qualitative
claims (fast CPU versions of §IV).

Reproduction note (EXPERIMENTS.md §Repro): the contextual advantage
manifests in the paper's own regime — strong statistical heterogeneity
(Synthetic(1,1)-style conflicting local optima) + aggressive local
optimization (up to 20 epochs, larger lr).  In benign regimes FedAvg's
multi-epoch averaged steps win per-round; contextual's trust-region-like
step (−(1/β)·P_U∇f) is the stable choice where FedAvg fluctuates/diverges.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_federated, make_mnist_like, make_synthetic
from repro.data.federated import FederatedDataset
from repro.fl import ServerConfig, run_simulation
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

DIM, CLASSES, N_DEV = 60, 10, 30


@pytest.fixture(scope="module")
def synth11():
    """Synthetic(α=1, β=1) — the paper's high-heterogeneity dataset."""
    xs, ys = make_synthetic(1.0, 1.0, num_devices=N_DEV,
                            samples_per_device=60, dim=DIM, seed=2)
    mask = np.ones(ys.shape, np.float32)
    tx, ty = xs.reshape(-1, DIM)[:400], ys.reshape(-1)[:400]
    return FederatedDataset(xs, ys, mask, tx, ty, CLASSES)


def _run(name, agg, ds, rounds=60, lr=0.2, **kw):
    cfg = ArchConfig(name="lr", family="logreg", input_dim=DIM,
                     num_classes=CLASSES)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    base = dict(num_devices=N_DEV, clients_per_round=10, lr=lr,
                batch_size=10, min_epochs=1, max_epochs=20)
    base.update(kw)
    return run_simulation(name, logistic_loss, logistic_apply, params, ds,
                          ServerConfig(aggregator=agg, **base),
                          num_rounds=rounds, selection_seed=42,
                          eval_every=3, collect_alpha=True)


def test_contextual_beats_fedavg_under_heterogeneity(synth11):
    """Paper fig. 4/5: with strong heterogeneity + aggressive local steps,
    the contextual version reaches lower loss and higher accuracy."""
    r_ctx = _run("ctx", "contextual", synth11)
    r_avg = _run("avg", "fedavg", synth11)
    assert r_ctx.train_loss[-1] < r_avg.train_loss[-1]
    assert r_ctx.test_acc[-1] >= r_avg.test_acc[-1] - 0.02


def test_contextual_is_more_robust(synth11):
    """Paper's robustness claim: smaller round-to-round fluctuations."""
    r_ctx = _run("ctx", "contextual", synth11, rounds=45)
    r_avg = _run("avg", "fedavg", synth11, rounds=45)
    assert r_ctx.loss_volatility() < r_avg.loss_volatility()
    arr = np.asarray(r_ctx.train_loss)
    big_jumps = np.sum(np.diff(arr) > 0.05)
    assert big_jumps <= 2          # near-monotone descent (Theorem 1)


def test_k2_variants_all_converge_and_k2_0_suffices(synth11):
    """Paper fig. 2/3's practical claim: the cheap K₂=0 variant performs at
    least as well as estimating ∇f from all N devices — no dedicated
    gradient-sampling round is needed.  (In our reproduction K₂=0 is in fact
    the FASTEST variant: the estimate is correlated with S_t's own updates,
    so more of it lies in span{Δ_k}; see EXPERIMENTS.md §Repro.)"""
    finals = {}
    for k2 in (0, 10, N_DEV):
        r = _run(f"k2={k2}", "contextual", synth11, rounds=30, grad_sample=k2)
        assert np.isfinite(r.train_loss).all()
        assert r.train_loss[-1] < r.train_loss[0] * 0.8   # all converge
        finals[k2] = r.train_loss[-1]
    assert finals[0] <= finals[N_DEV] + 0.1, finals


def test_fedprox_contextual_and_folb_run(synth11):
    r_prox = _run("prox-ctx", "contextual", synth11, rounds=10, mu=0.1)
    r_folb = _run("folb", "folb", synth11, rounds=10)
    assert np.isfinite(r_prox.train_loss).all()
    assert np.isfinite(r_folb.train_loss).all()
    assert r_prox.train_loss[-1] < r_prox.train_loss[0]


def test_expected_variant_runs(synth11):
    r = _run("ctx-exp", "contextual_expected", synth11, rounds=10,
             expected_pool=N_DEV)
    assert np.isfinite(r.train_loss).all()
    assert r.train_loss[-1] < r.train_loss[0]


def test_alpha_varies_across_stages(synth11):
    """Paper fig. 7: aggregation variables vary between rounds and stages,
    unlike FedAvg's constant 1/K."""
    r = _run("ctx", "contextual", synth11, rounds=20)
    early, late = r.alpha_history[0], r.alpha_history[-1]
    assert early.shape == late.shape == (10,)
    assert not np.allclose(early, late, atol=1e-3)
    assert np.std(early) > 1e-4


def test_last_layer_scope_tracks_full_gram(synth11):
    """§III-B efficiency note: last-layer-scoped α ≈ full-scope α for models
    whose gradient variation concentrates in the head (logreg: head IS the
    model, so they coincide; the MLP test in test_core_math covers scoping)."""
    r_full = _run("full", "contextual", synth11, rounds=10)
    assert np.isfinite(r_full.train_loss).all()


def test_computational_heterogeneity_consistent_selection():
    """Same selection seed → identical per-round device choices and step
    budgets across algorithms (§IV-A3 protocol)."""
    from repro.fl.server import sample_round
    cfg = ServerConfig(num_devices=30, clients_per_round=10)
    r1 = np.random.RandomState(7)
    r2 = np.random.RandomState(7)
    for _ in range(5):
        s1 = sample_round(r1, cfg, steps_per_epoch=4)
        s2 = sample_round(r2, cfg, steps_per_epoch=4)
        for a, b in zip(s1, s2):
            np.testing.assert_array_equal(a, b)


def test_synthetic_noniid_dataset_properties():
    x, y = make_synthetic(alpha=1.0, beta=1.0, num_devices=10,
                          samples_per_device=50, dim=20, seed=1)
    assert x.shape == (10, 50, 20) and y.shape == (10, 50)
    hists = np.stack([np.bincount(y[d], minlength=10) for d in range(10)])
    assert np.std(hists.astype(float), axis=0).sum() > 0


def test_dirichlet_partition_skew():
    from repro.data import dirichlet_partition
    x, y = make_mnist_like(2000, dim=16, num_classes=10, seed=3)
    xs, ys, mask = dirichlet_partition(x, y, num_devices=20,
                                       concentration=0.1, num_classes=10)
    assert xs.shape[0] == 20 and mask.min() >= 0
    fracs = []
    for d in range(20):
        valid = ys[d][mask[d] > 0]
        if len(valid):
            fracs.append(np.max(np.bincount(valid, minlength=10)) / len(valid))
    assert np.mean(fracs) > 0.3


def test_global_train_loss_traces_once_across_rounds():
    """Regression: ``global_train_loss`` used to close a fresh ``@jax.jit``
    over ``params`` on every call, recompiling each round.  The hoisted
    evaluator takes params as a traced argument — repeated same-shape calls
    must not re-trace (the trace counter is a python side effect, so it
    ticks exactly once per compilation)."""
    from repro.fl.metrics import global_train_loss

    traces = {"n": 0}

    def counting_loss(params, batch):
        traces["n"] += 1
        cx, cy, cm = batch
        logits = cx @ params["w"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, cy[:, None], axis=-1)[:, 0]
        return jnp.sum(nll * cm) / jnp.maximum(cm.sum(), 1.0)

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 30, 16)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=(4, 30)))
    mask = jnp.ones((4, 30), jnp.float32)
    p1 = {"w": jnp.asarray(rng.normal(size=(16, 10)).astype(np.float32))}
    p2 = {"w": p1["w"] * 3.0}       # rescaled logits: loss must move

    l1 = global_train_loss(counting_loss, p1, x, y, mask)
    assert traces["n"] == 1
    for params in (p1, p2, p1):         # new values, same shapes: no retrace
        global_train_loss(counting_loss, params, x, y, mask)
    assert traces["n"] == 1
    assert np.isfinite(l1)
    assert global_train_loss(counting_loss, p2, x, y, mask) != pytest.approx(
        l1)                             # params actually flow through


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"m": jnp.ones((3, 4), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 5, tree, meta={"note": "t"})
    back, meta = load_checkpoint(str(tmp_path), 5, tree)
    assert meta["note"] == "t"
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(tree["w"]))
