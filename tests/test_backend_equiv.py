"""Backend equivalence for the kernel registry (PR-4 tentpole).

Every registry op must produce the same numbers on every backend —
compiled-XLA, interpret-mode Pallas, and the eager jnp reference — within
f32 accumulation-order tolerance, including the counter-based RNG sign
sketch against its materialized-R oracle at fixed seed.  Also covers the
registry mechanics (autotune cache, forcing, back-compat ``use_pallas``)
and the fused hier round stages against the pytree reference functions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, registry
from repro.kernels.rng_sketch import (rng_sign_matrix, rng_sketch_pallas,
                                      rng_sketch_xla, rng_sketch_adjoint_xla)

TOL = dict(rtol=1e-5, atol=1e-3)


def _data(K=7, n=333, m=11, seed=0):
    key = jax.random.PRNGKey(seed)
    U = jax.random.normal(key, (K, n), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    R = jax.random.normal(jax.random.fold_in(key, 2), (m, n), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 3), (n,), jnp.float32)
    a = jax.random.normal(jax.random.fold_in(key, 4), (K,), jnp.float32)
    return U, g, R, w, a


def _allclose(x, y):
    jax.tree_util.tree_map(
        lambda p, q: np.testing.assert_allclose(
            np.asarray(p, np.float32), np.asarray(q, np.float32), **TOL),
        list(x) if isinstance(x, tuple) else x,
        list(y) if isinstance(y, tuple) else y)


# ---------------------------------------------------------------- per-op

CALLS = {
    "gram": lambda d, be: ops.gram_and_cross(d[0], d[1], backend=be,
                                             block_n=128),
    "gram_block": lambda d, be: ops.gram_block_and_cross(
        d[0], d[0][:3], d[1], backend=be, block_n=128),
    "sketch": lambda d, be: ops.sketch_apply(d[0], d[2], backend=be,
                                             block_n=128),
    "topk": lambda d, be: ops.topk_select(d[1], 17, backend=be, block_n=128),
    "combine": lambda d, be: ops.weighted_combine(d[3], d[0], d[4],
                                                  backend=be, block_n=128),
    "sign_sketch": lambda d, be: ops.sign_sketch(d[0], 1234, 11, backend=be,
                                                 block_n=128),
}


@pytest.mark.parametrize("op", sorted(CALLS))
def test_every_backend_matches_ref(op):
    d = _data()
    want = CALLS[op](d, "ref")
    for be in ops.backends(op):
        got = CALLS[op](d, be)
        if op == "topk":
            # compare as dense sparse-reconstructions (tie ordering differs)
            n = d[1].shape[0]
            dv, dr = np.zeros(n), np.zeros(n)
            dv[np.asarray(got[1])] = np.asarray(got[0])
            dr[np.asarray(want[1])] = np.asarray(want[0])
            np.testing.assert_allclose(dv, dr, atol=1e-5)
        else:
            _allclose(got, want)


def test_every_op_has_all_three_backends():
    for op in ("gram", "gram_block", "sketch", "topk", "combine",
               "sign_sketch", "flash_decode"):
        assert {"pallas", "xla", "ref"} <= set(ops.backends(op)), op
    assert {"xla", "ref"} <= set(ops.backends("sign_sketch_adjoint"))


# ------------------------------------------------------- decode attention

def _attn_data(B=3, S=64, KV=2, G=2, hd=16, seed=2):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, KV, G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd),
                         jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd),
                          jnp.float32)
    lengths = jnp.asarray([1, S // 2, S], jnp.int32)
    return q, k, v, lengths


def test_flash_decode_every_backend_matches_ref():
    """The serving hot path rides the registry like the aggregation ops
    (PR-10 satellite): all three backends agree, including masked tails,
    sliding window, and logit softcap."""
    q, k, v, lengths = _attn_data()
    want = ref.flash_decode_ref(q, k, v, lengths)
    want_w = ref.flash_decode_ref(q, k, v, lengths, window=16, softcap=5.0)
    for be in ops.backends("flash_decode"):
        _allclose(ops.flash_decode(q, k, v, lengths, backend=be), want)
        _allclose(ops.flash_decode(q, k, v, lengths, window=16,
                                   softcap=5.0, backend=be), want_w)


def test_flash_decode_autotune_streams_registry_event():
    from repro.obs import InMemoryTracker, use_tracker

    registry.clear_autotune_cache()
    q, k, v, lengths = _attn_data()
    mem = InMemoryTracker()
    with use_tracker(mem):
        ops.flash_decode(q, k, v, lengths)
        ops.flash_decode(q, k, v, lengths)        # same bucket: cached
    picks = [e.metrics for e in mem.metrics_events()
             if "kernels/autotune/op" in e.metrics]
    assert len(picks) == 1
    assert picks[0]["kernels/autotune/op"] == "flash_decode"
    assert picks[0]["kernels/autotune/backend"] in \
        ops.backends("flash_decode")
    rec = next(r for r in registry.autotune_records()
               if r["op"] == "flash_decode")
    assert rec["num_backends"] == 3
    if not ops.on_tpu():
        # interpret-mode pallas must never be timed as a candidate
        assert "us_per_call_pallas" not in rec


def test_backend_equiv_property_sweep():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(K=st.integers(1, 12), n=st.integers(8, 2000),
           seed=st.integers(0, 2 ** 16))
    def check(K, n, seed):
        key = jax.random.PRNGKey(seed)
        U = jax.random.normal(key, (K, n), jnp.float32)
        g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
        want = ref.gram_ref(U, g)
        for be in ("pallas", "xla"):
            _allclose(ops.gram_and_cross(U, g, backend=be, block_n=128),
                      want)

    check()


# ------------------------------------------------- counter-based RNG sketch

def test_rng_sketch_streaming_matches_materialized_oracle():
    """The tentpole invariant: every streaming path (XLA scan, Pallas
    in-kernel generation, any chunk size) reproduces the materialized-R
    oracle exactly up to f32 accumulation order, at fixed seed."""
    U, _, _, _, _ = _data(K=5, n=700)
    seed = jnp.uint32(99)
    m = 13
    R = rng_sign_matrix(seed, m, 700)
    want = (U @ R.T) / jnp.sqrt(jnp.float32(m))
    for block in (128, 256, 1024):
        _allclose(rng_sketch_xla(U, seed, m=m, block_n=block), want)
        _allclose(rng_sketch_pallas(U, seed, m=m, block_n=block,
                                    interpret=True), want)
    # adjoint against the same R
    s = want[0]
    _allclose(rng_sketch_adjoint_xla(s, seed, n=700, block_n=256),
              (R.T @ s) / jnp.sqrt(jnp.float32(m)))


def test_rng_sketch_chunking_invariance_and_determinism():
    U, _, _, _, _ = _data(K=3, n=513)     # n prime-ish: pad path
    a = ops.sign_sketch(U, 7, 9, block_n=128)
    b = ops.sign_sketch(U, 7, 9, block_n=512)
    _allclose(a, b)
    _allclose(a, ops.sign_sketch(U, 7, 9, block_n=128))   # deterministic
    c = ops.sign_sketch(U, 8, 9, block_n=128)             # seed changes R
    assert float(jnp.max(jnp.abs(a - c))) > 1e-3


def test_rng_sign_matrix_statistics():
    """R behaves like iid ±1: zero mean, near-orthogonal rows."""
    R = rng_sign_matrix(jnp.uint32(3), 32, 8192)
    assert set(np.unique(np.asarray(R))) == {-1.0, 1.0}
    assert abs(float(R.mean())) < 0.02
    cross = np.asarray(R @ R.T / 8192) - np.eye(32)
    assert np.abs(cross).max() < 0.06                     # ~4/√n


def test_sign_sketch_compressor_never_materializes_but_matches_matrix():
    """compress.SignSketch == explicit S v with the materialized oracle."""
    from repro.compress import SignSketch
    v = jax.random.normal(jax.random.PRNGKey(5), (610,))
    c = SignSketch(m=64, seed_base=9)
    comp = c.encode(v, seed=4)
    S = c.sign_matrix(610, seed=4)
    _allclose(comp.data[0], S @ v)
    shrink = 64 / (64 + 610 + 1.0)
    _allclose(c.decode(comp), shrink * (S.T @ comp.data[0]))


# ------------------------------------------------------- registry mechanics

def test_autotune_caches_and_reports():
    registry.clear_autotune_cache()
    d = _data(K=4, n=256)
    ops.gram_and_cross(d[0], d[1])
    recs = registry.autotune_records()
    assert any(r["op"] == "gram" for r in recs)
    rec = next(r for r in recs if r["op"] == "gram")
    assert rec["backend_selected"] in ops.backends("gram")
    assert rec["num_backends"] == 3
    # off-TPU, interpret-mode pallas must never be an autotune candidate
    if not ops.on_tpu():
        assert "us_per_call_pallas" not in rec
    before = len(registry.autotune_records())
    ops.gram_and_cross(d[0], d[1])            # same bucket: no re-tune
    assert len(registry.autotune_records()) == before


def test_autotune_decisions_stream_through_tracker():
    """Every resolved dispatch — autotuned or forced — announces itself once
    per (op, bucket, backend) on the active tracker (satellite: registry
    telemetry)."""
    from repro.obs import InMemoryTracker, use_tracker

    registry.clear_autotune_cache()
    d = _data(K=4, n=256)
    mem = InMemoryTracker()
    with use_tracker(mem):
        ops.gram_and_cross(d[0], d[1])        # autotuned pick
        ops.gram_and_cross(d[0], d[1])        # cached: no second event
        with registry.force_backend("xla"):
            ops.gram_and_cross(d[0], d[1])    # forced pick, same bucket
    picks = [e.metrics for e in mem.metrics_events()
             if "kernels/autotune/op" in e.metrics]
    tuned = [m for m in picks if not m["kernels/autotune/forced"]]
    assert len(tuned) == 1
    assert tuned[0]["kernels/autotune/op"] == "gram"
    assert tuned[0]["kernels/autotune/backend"] in ops.backends("gram")
    assert any(k.startswith("kernels/autotune/us_per_call_")
               for k in tuned[0])
    forced = [m for m in picks if m["kernels/autotune/forced"]]
    assert len(forced) == 1
    assert forced[0]["kernels/autotune/op"] == "gram"
    assert forced[0]["kernels/autotune/backend"] == "xla"


def test_force_backend_scoped_and_use_pallas_compat():
    d = _data(K=4, n=256)
    want = ref.gram_ref(d[0], d[1])
    with registry.force_backend("ref"):
        _allclose(ops.gram_and_cross(d[0], d[1]), want)
    with registry.force_backend("ref", op="gram"):
        _allclose(ops.gram_and_cross(d[0], d[1]), want)
    # use_pallas=False now means the reference oracle on EVERY op (the PR-3
    # wrappers disagreed: gram ran interpret-mode Pallas off-TPU)
    _allclose(ops.gram_and_cross(d[0], d[1], use_pallas=False), want)
    _allclose(ops.gram_and_cross(d[0], d[1], use_pallas=True, block_n=128),
              want)


def test_forced_backend_is_preference_explicit_backend_is_requirement():
    """force_backend/env forcing falls back when supports() rejects the
    shapes; an explicit backend= arg is a hard requirement and raises."""
    v = jax.random.normal(jax.random.PRNGKey(0), (6000,))
    with registry.force_backend("pallas"):
        vals, _ = ops.topk_select(v, 3000, block_n=128)   # k > block_n
        assert vals.shape == (3000,)                      # fell back
    with pytest.raises(ValueError, match="exceeds block_n"):
        ops.topk_select(v, 3000, backend="pallas", block_n=128)


def test_fused_stage_cache_rebinds_under_forced_backend():
    """The stage cache keys on the selected gram backend, so forcing a
    backend compiles a fresh stage instead of silently reusing the old."""
    from repro.core.solve import SolveConfig
    from repro.hier import fused
    cfg = SolveConfig(beta=4.0)
    U = jax.random.normal(jax.random.PRNGKey(1), (4, 200), jnp.float32)
    GR = jax.random.normal(jax.random.PRNGKey(2), (4, 200), jnp.float32)
    ones = jnp.ones((4,), jnp.float32)
    s1 = fused.summary_stage(4, 200, cfg, "contextual")
    with registry.force_backend("ref"):
        s2 = fused.summary_stage(4, 200, cfg, "contextual")
    assert s2 is not s1
    _allclose(s1(U, GR, ones)["alpha"], s2(U, GR, ones)["alpha"])
    assert fused.summary_stage(4, 200, cfg, "contextual") is s1


def test_registry_rejects_unknown():
    with pytest.raises(KeyError, match="unknown kernel op"):
        registry.dispatch("bogus_op", jnp.zeros((2, 2)))
    with pytest.raises(KeyError, match="not registered"):
        ops.gram_and_cross(jnp.zeros((2, 8)), jnp.zeros((8,)),
                           backend="bogus")


def test_dispatch_under_jit_uses_static_preference():
    """dispatch() inside a jit trace cannot time; it must still resolve."""
    d = _data(K=3, n=128)

    @jax.jit
    def f(U, g):
        return ops.gram_and_cross(U, g)

    _allclose(f(d[0], d[1]), ref.gram_ref(d[0], d[1]))


# ----------------------------------------------------- fused hier stages

def test_fused_summary_stage_matches_reference_summarize():
    """The fused gateway stage == gateway.summarize_updates on the same
    members (flat vectors as single-leaf pytrees)."""
    from repro.core.solve import SolveConfig
    from repro.hier.fused import summary_stage
    from repro.hier.gateway import summarize_updates
    key = jax.random.PRNGKey(2)
    K, n = 6, 210
    U = jax.random.normal(key, (K, n), jnp.float32)
    GR = jax.random.normal(jax.random.fold_in(key, 1), (K, n), jnp.float32)
    cfg = SolveConfig(beta=4.0, ridge=1e-8)
    for mode in ("contextual", "mean"):
        stage = summary_stage(K, n, cfg, mode)
        out = stage(U, GR, jnp.ones((K,), jnp.float32))
        s = summarize_updates(0, range(K), list(U), list(GR), [1] * K, cfg,
                              mode=mode)
        _allclose(out["alpha"], s.alpha)
        _allclose(out["u_bar"], s.u_bar)
        _allclose(out["ghat"], s.grad_est)
        _allclose(out["G"], s.G)
        _allclose(out["c"], s.c)


def test_fused_cloud_stage_matches_reference_merge():
    """The fused Σγ=1 cloud stage == merge_summaries' solve over the same
    child combinations."""
    from repro.core.solve import SolveConfig
    from repro.hier.fused import cloud_stage, summary_stage
    from repro.hier.gateway import merge_summaries, summarize_updates
    key = jax.random.PRNGKey(3)
    n = 150
    cfg = SolveConfig(beta=5.0, ridge=1e-8)
    kids = []
    for i in range(3):
        k1 = jax.random.fold_in(key, i)
        U = jax.random.normal(k1, (4, n), jnp.float32) * 0.3
        GR = jax.random.normal(jax.random.fold_in(k1, 9), (4, n),
                               jnp.float32)
        kids.append(summarize_updates(i, range(4), list(U), list(GR),
                                      [1] * 4, cfg))
    top = merge_summaries(100, kids, cfg)
    Ubar = jnp.stack([s.u_bar for s in kids])
    Ghat = jnp.stack([s.grad_est for s in kids])
    counts = jnp.asarray([s.num_updates for s in kids], jnp.float32)
    merged = summary_stage(3, n, cfg, "contextual", sum_to=1.0)(
        Ubar, Ghat, counts)
    _allclose(merged["alpha"], top.alpha)
    _allclose(merged["u_bar"], top.u_bar)
    delta, info = cloud_stage(3, n, cfg, "combo")(
        Ubar, merged["ghat"], counts)
    _allclose(info["gamma"], top.alpha)
    _allclose(delta, top.u_bar)
