import os
import sys

import pytest

# Tests run on the single real CPU device (the 512-device override is
# reserved for launch/dryrun.py). Keep compile caches warm across tests.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def tiny_edge_problem():
    """Shared 12-device logreg problem for the hier/compress e2e tests:
    one dataset + model init per SESSION, so every module reuses the same
    shapes and — via the process-wide compile caches in ``repro.fl`` and
    ``repro.hier.fused`` — the same compiled client-update and tier-stage
    functions.  Returns (dataset, params, n_model)."""
    import jax
    import numpy as np
    from repro.data import make_synthetic
    from repro.data.federated import FederatedDataset
    from repro.models import get_model
    from repro.models.config import ArchConfig

    dim, n_dev = 20, 12
    xs, ys = make_synthetic(1.0, 1.0, num_devices=n_dev,
                            samples_per_device=30, dim=dim, seed=5)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, dim)[:150], ys.reshape(-1)[:150], 10)
    model = get_model(ArchConfig(name="lr", family="logreg", input_dim=dim,
                                 num_classes=10))
    return ds, model.init(jax.random.PRNGKey(0)), dim * 10 + 10
