"""Roofline methodology tests (EXPERIMENTS.md §Roofline).

1. Documents WHY the analytic model exists: XLA cost_analysis counts a
   while-loop (lax.scan) body once, independent of trip count.
2. Calibrates the analytic FLOP model against cost_analysis on
   configurations where the artifact is exact (single-layer stacks, short
   sequences below the flash threshold, chunk-length sequences for SSM).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.configs import get_config
from repro.launch.analytic import model_forward_flops
from repro.launch.hlo_analysis import cost_analysis_dict
from repro.launch.shapes import InputShape
from repro.models import get_model


def test_cost_analysis_is_scan_trip_invariant():
    """The calibration premise: scan body FLOPs are counted once."""
    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = lax.scan(body, x, w)
        return h.sum()

    costs = {}
    for L in (1, 4):
        w = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 128), jnp.float32)
        costs[L] = cost_analysis_dict(
            jax.jit(f).lower(w, x).compile())["flops"]
    assert costs[1] == pytest.approx(costs[4], rel=0.01), costs


def _artifact_flops(cfg, B, S):
    """Compile a train-loss forward for an L=1 unscanned-regime config and
    return cost_analysis FLOPs (exact: scan trip counts are 1)."""
    bundle = get_model(cfg)
    params = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    spec = bundle.batch_spec(B, S)
    batch = {k: jax.ShapeDtypeStruct(shp, dt) for k, (shp, dt) in spec.items()}

    def fwd_loss(p, b):
        return bundle.train_loss(p, b)[0]

    compiled = jax.jit(fwd_loss).lower(params, batch).compile()
    return float(cost_analysis_dict(compiled)["flops"])


CAL_CASES = [
    # (arch, B, S, rel_tolerance) — S below flash threshold; SSM at one chunk
    ("qwen3-14b", 2, 512, 0.5),
    ("gemma-7b", 2, 512, 0.5),
    ("olmoe-1b-7b", 2, 512, 0.6),
    ("rwkv6-1.6b", 2, 64, 0.8),
]


@pytest.mark.parametrize("arch,B,S,tol", CAL_CASES)
def test_analytic_flops_calibrated_against_artifact(arch, B, S, tol):
    cfg = get_config(arch).with_overrides(num_layers=1, dtype="float32")
    if cfg.ssm_chunk:
        cfg = cfg.with_overrides(ssm_chunk=S)
    art = _artifact_flops(cfg, B, S)
    shape = InputShape("cal", "train", S, B)
    ana = model_forward_flops(cfg, shape, cfg.sliding_window)
    # artifact counts the forward only (train_loss fwd); analytic fwd too.
    ratio = ana / art
    assert (1 - tol) < ratio < (1 + tol) * 2.2, (arch, art, ana, ratio)
