"""Serving stack (PR-10 tentpole): continuous-batching DecodeEngine,
hot-swapping ModelBus, and the offline replay harness.

The load-bearing invariants:

  * continuous batching is a pure scheduling optimization — each request's
    token stream is bit-identical to serving it alone on an engine of the
    same width (slots never contaminate each other, garbage rows beyond a
    slot's length are never attended);
  * model hot-swaps happen only at step boundaries, versions are adopted
    monotonically, and every completion records the admit/final versions
    it actually ran under;
  * admit/retire slot accounting balances at every step and drains clean;
  * the bus snapshot is never torn, even with a concurrent publisher.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve import (DecodeEngine, ModelBus, ScheduledModel,
                         TraceRequest, replay, synthetic_trace)

CFG = get_config("qwen3-14b").reduced(num_layers=1, d_model=32,
                                      vocab_size=64, dtype="float32")


@pytest.fixture(scope="module")
def params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


def _prompts(n, plen=6, seed=3):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(0, CFG.vocab_size, plen)]
            for _ in range(n)]


# -------------------------------------------------- batching equivalence

def test_continuous_batching_bit_identical_to_solo_decode(params):
    """Three staggered requests on one engine produce exactly the token
    streams each request gets served alone (same engine width)."""
    prompts = _prompts(3)
    max_new = (7, 4, 9)

    eng = DecodeEngine(CFG, ModelBus(params), num_slots=3, max_seq=32,
                       scan_chunk=4, prefill_chunk_tokens=8)
    eng.submit(prompts[0], max_new[0], rid=0)
    done = eng.step()                        # r0 resident before r1/r2 land
    eng.submit(prompts[1], max_new[1], rid=1)
    eng.submit(prompts[2], max_new[2], rid=2)
    done += eng.run()
    batched = {c.rid: c.tokens for c in done}
    assert sorted(batched) == [0, 1, 2]

    for rid in range(3):
        solo = DecodeEngine(CFG, ModelBus(params), num_slots=3, max_seq=32,
                            scan_chunk=4, prefill_chunk_tokens=8)
        solo.submit(prompts[rid], max_new[rid], rid=rid)
        (c,) = solo.run()
        assert c.tokens == batched[rid], f"rid={rid} diverged"


def test_multichunk_prefill_into_reused_slot_while_decoding(params):
    """A prompt longer than ``prefill_chunk_tokens`` chunk-prefilled into a
    *reused* slot, while another slot decodes, must produce the same tokens
    as serving it alone.  Regression: inactive slots carry stale device
    positions (a retired request's stop index, or 0 for fresh slots) and an
    unmasked decode scatter would rewrite an already-prefilled row with
    garbage K/V that later chunks and every decode step then attend."""
    pA, pB, pD = _prompts(3, plen=4, seed=11)
    pC = _prompts(1, plen=12, seed=13)[0]        # 12 > chunk 4 → 3 chunks

    eng = DecodeEngine(CFG, ModelBus(params), num_slots=2, max_seq=32,
                       scan_chunk=2, prefill_chunk_tokens=4)
    # rA/rB admit and retire in one step, leaving both slots free with
    # stale device positions at their stop indices
    eng.submit(pA, 1, rid=0)
    eng.submit(pB, 1, rid=1)
    done = eng.step()
    # rD reuses slot 0 and keeps decoding across rC's whole prefill
    eng.submit(pD, 20, rid=2)
    done += eng.step()
    # rC's 3-chunk prefill reuses slot 1 (stale position 4) while rD decodes
    eng.submit(pC, 4, rid=3)
    done += eng.run()
    batched = {c.rid: c.tokens for c in done}
    assert sorted(batched) == [0, 1, 2, 3]

    for rid, (prompt, max_new) in enumerate([(pA, 1), (pB, 1), (pD, 20),
                                             (pC, 4)]):
        solo = DecodeEngine(CFG, ModelBus(params), num_slots=2, max_seq=32,
                            scan_chunk=2, prefill_chunk_tokens=4)
        solo.submit(prompt, max_new, rid=rid)
        (c,) = solo.run()
        assert c.tokens == batched[rid], f"rid={rid} diverged"


def test_chunked_prefill_matches_wide_prefill_first_token(params):
    """Feeding a prompt in small chunks samples the same first token as
    one chunk covering the whole prompt."""
    prompt = _prompts(1, plen=12)[0]
    tokens = {}
    for chunk_w in (4, 16):
        eng = DecodeEngine(CFG, ModelBus(params), num_slots=1, max_seq=16,
                           scan_chunk=2, prefill_chunk_tokens=chunk_w)
        eng.submit(prompt, 1)
        (c,) = eng.run()
        tokens[chunk_w] = c.tokens
    assert tokens[4] == tokens[16]


# ------------------------------------------------------------- hot swap

def test_hot_swap_version_monotone_and_recorded(params):
    bus = ModelBus(params)
    eng = DecodeEngine(CFG, bus, num_slots=2, max_seq=32, scan_chunk=2,
                       prefill_chunk_tokens=8)
    for p in _prompts(4):
        eng.submit(p, 8)
    done, seen = [], []
    v = 0
    while not eng.idle:
        done += eng.step()
        seen.append(eng.model_version)
        if len(seen) % 2 == 0 and v < 3:     # publish mid-flight
            v = bus.publish(jax.tree_util.tree_map(
                lambda a: a * (1.0 + 0.01), params))
    assert seen == sorted(seen), "adopted versions must be monotone"
    assert eng.stats["swaps"] == eng.model_version == bus.version == v
    for c in done:
        assert 0 <= c.admit_version <= c.final_version <= bus.version
    # a request admitted after the last publish finishes on that version
    eng.submit(_prompts(1)[0], 2)
    (c,) = eng.run()
    assert c.admit_version == c.final_version == v


def test_completions_change_with_published_params(params):
    """Adopting a new version actually changes the weights used."""
    prompts = _prompts(2, plen=8, seed=9)
    outs = []
    for scale in (1.0, 1.5):
        bus = ModelBus(jax.tree_util.tree_map(lambda a: a * scale, params))
        eng = DecodeEngine(CFG, bus, num_slots=2, max_seq=32, scan_chunk=4)
        for p in prompts:
            eng.submit(p, 8)
        outs.append([c.tokens for c in eng.run()])
    assert outs[0] != outs[1]


# ------------------------------------------------------- slot accounting

def test_slot_accounting_balances_every_step(params):
    eng = DecodeEngine(CFG, ModelBus(params), num_slots=2, max_seq=32,
                       scan_chunk=4, prefill_chunk_tokens=8)
    lens = [1, 5, 2, 7, 3]
    for p, mn in zip(_prompts(5), lens):
        eng.submit(p, mn)
    done, steps = [], 0
    while not eng.idle:
        assert len(eng._free_slots()) + len(eng._slots) == eng.num_slots
        done += eng.step()
        steps += 1
        assert steps < 200
    assert len(eng._free_slots()) == eng.num_slots and not eng._slots
    assert not eng.pending and eng._prefilling is None
    assert sorted(len(c.tokens) for c in done) == sorted(lens)
    # the first token of each request is sampled by prefill, the rest by
    # the decode scan
    assert eng.stats["tokens_emitted"] == sum(mn - 1 for mn in lens)
    assert {c.rid for c in done} == set(range(5))


def test_submit_validates_budget(params):
    eng = DecodeEngine(CFG, ModelBus(params), num_slots=1, max_seq=16)
    with pytest.raises(ValueError):
        eng.submit(list(range(12)), 8)       # 12 + 8 > 16
    with pytest.raises(ValueError):
        eng.submit([], 4)
    with pytest.raises(ValueError):
        eng.submit([1, 2], 0)


# ---------------------------------------------------------------- bus

def test_bus_snapshot_never_torn_under_concurrent_publisher():
    """Readers always see a matching (version, params, loss) triple."""
    bus = ModelBus({"w": jnp.zeros((4,))}, train_loss=0.0)
    stop = threading.Event()

    def publisher():
        v = 0
        while not stop.is_set():
            v += 1
            bus.publish({"w": jnp.full((4,), float(v))},
                        train_loss=float(v))
    th = threading.Thread(target=publisher, daemon=True)
    th.start()
    try:
        last = -1
        for _ in range(300):
            snap = bus.snapshot()
            assert snap.version >= last
            last = snap.version
            if snap.version > 0:
                assert float(snap.params["w"][0]) == snap.version
                assert snap.train_loss == snap.version
    finally:
        stop.set()
        th.join(timeout=5)


# ----------------------------------------------------- offline harness

def test_replay_deterministic_under_virtual_clock(params):
    trace = synthetic_trace(num_requests=5, vocab=CFG.vocab_size, seed=7,
                            mean_interarrival_s=0.2, prompt_len=(4, 8),
                            max_new=(2, 6))
    assert all(isinstance(r, TraceRequest) for r in trace)
    sched = [ScheduledModel(t_publish_s=0.3,
                            params=jax.tree_util.tree_map(
                                lambda a: a * 1.01, params),
                            train_loss=0.5, round=0)]
    reports = []
    for _ in range(2):
        eng = DecodeEngine(CFG, ModelBus(params), num_slots=2, max_seq=32,
                           scan_chunk=2, prefill_chunk_tokens=8)
        reports.append(replay(eng, trace, sched, step_cost_s=0.05))
    a, b = reports
    for key in ("num_completed", "tokens_generated", "virtual_time_s",
                "tokens_per_virtual_s", "latency_virtual_mean_s",
                "staleness_virtual_mean_s", "served_loss_mean",
                "num_swaps", "by_request"):
        assert a[key] == b[key], key
    assert a["num_completed"] == 5
    assert a["num_swaps"] == 1
    stale = [r["staleness_virtual_s"] for r in a["by_request"]
             if r["final_version"] == 1]
    assert stale and all(s >= 0.0 for s in stale)
