"""Launcher-layer unit tests: sharding rules, cohort mapping, ring placement,
server momentum, analytic-roofline variant consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.analytic import analytic_roofline
from repro.launch.mesh import make_host_mesh
from repro.launch.shapes import INPUT_SHAPES, InputShape, arch_for_shape
from repro.launch.steps import build_train_step, cohort_axes, num_cohorts
from repro.models import get_model
from repro.models.attention import ring_place
from repro.sharding.specs import param_pspecs, spec_for_leaf


def test_ring_place_short_and_long():
    k = jnp.arange(2 * 10 * 1 * 4, dtype=jnp.float32).reshape(2, 10, 1, 4)
    # short prompt: identity placement + zero pad
    out = ring_place(k, 16)
    np.testing.assert_allclose(np.asarray(out[:, :10]), np.asarray(k))
    assert float(jnp.abs(out[:, 10:]).sum()) == 0.0
    # long prompt: last W rows at position-mod-W slots
    out = ring_place(k, 4)
    for pos in range(6, 10):
        np.testing.assert_allclose(np.asarray(out[:, pos % 4]),
                                   np.asarray(k[:, pos]))


def test_cohort_counting_modes():
    mesh = make_host_mesh()          # (1, n_dev)
    assert num_cohorts(mesh) == 1
    assert num_cohorts(mesh, dp_only=True, batch=len(jax.devices())) == \
        len(jax.devices())


def test_spec_rules_divisibility_guard():
    mesh = make_host_mesh()
    # vocab not divisible by device count → replicated instead of invalid
    spec = spec_for_leaf("embed", (51866, 1280), mesh, fsdp=False)
    for axis, dim in zip(tuple(spec) + (None,) * 2, (51866, 1280)):
        if axis is not None:
            assert dim % mesh.shape[axis] == 0


def test_param_pspecs_dp_mode_replicates():
    cfg = get_config("rwkv6-1.6b").reduced()
    bundle = get_model(cfg)
    shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    specs = param_pspecs(cfg, shapes, mesh, mode="dp")
    for s in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: hasattr(x, "index")):
        pass  # PartitionSpec flattens to nothing; check via tree_map instead
    flat = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: x is not None and not isinstance(x, dict))[0]
    assert all(len(tuple(sp)) == 0 for sp in flat)


def test_server_momentum_accumulates():
    cfg = get_config("qwen3-14b").reduced().with_overrides(
        num_layers=1, d_model=32, d_ff=64, vocab_size=64, num_heads=2,
        num_kv_heads=2, head_dim=16)
    bundle = get_model(cfg)
    mesh = make_host_mesh()
    shape = InputShape("t", "train", 16, 4)
    step = build_train_step(cfg, mesh, shape, lr=0.05, remat=False,
                            server_momentum=0.9)
    params = bundle.init(jax.random.PRNGKey(0))
    vel = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    with mesh:
        (p1, v1), m1 = jax.jit(step)((params, vel), {"tokens": tokens})
        (p2, v2), m2 = jax.jit(step)((p1, v1), {"tokens": tokens})
    v1n = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(v1))
    v2n = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(v2))
    assert v1n > 0 and v2n > 0
    assert float(m2["loss"]) < float(m1["loss"]) + 1.0   # sane training


def test_fedavg_vs_contextual_train_step_same_interface():
    cfg = get_config("olmoe-1b-7b").reduced().with_overrides(
        num_layers=1, d_model=32, d_ff=32, vocab_size=64, num_heads=2,
        num_kv_heads=2, num_experts=4, experts_per_token=2)
    bundle = get_model(cfg)
    mesh = make_host_mesh()
    shape = InputShape("t", "train", 16, 4)
    params = bundle.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    with mesh:
        for agg in ("fedavg", "contextual"):
            step = build_train_step(cfg, mesh, shape, aggregator=agg,
                                    lr=0.05, remat=False)
            new_p, metrics = jax.jit(step)(params, {"tokens": tokens})
            assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_analytic_roofline_all_pairs_finite(shape_name):
    from repro.configs import ASSIGNED
    shape = INPUT_SHAPES[shape_name]
    for arch in ASSIGNED:
        cfg = arch_for_shape(get_config(arch), shape)
        if cfg is None:
            continue
        r = analytic_roofline(cfg, shape)
        assert r.compute_s > 0 and r.memory_s > 0 and r.coll_bytes >= 0
        assert 0 < r.useful_ratio <= 1.2, (arch, shape_name, r.useful_ratio)


def test_analytic_variants_directionality():
    """dp_only must cut collectives; ring must cut decode memory; dots must
    cut train compute — the §Perf lever signs."""
    sh_t = INPUT_SHAPES["train_4k"]
    cfg = get_config("zamba2-1.2b")
    base = analytic_roofline(cfg, sh_t)
    dp = analytic_roofline(cfg, sh_t, dp_only=True)
    assert dp.collective_s < 0.2 * base.collective_s

    sh_d = INPUT_SHAPES["long_500k"]
    cham = arch_for_shape(get_config("chameleon-34b"), sh_d)
    full = analytic_roofline(cham, sh_d, ring_kv=False)
    ring = analytic_roofline(cham, sh_d, ring_kv=True)
    assert ring.memory_s < 0.6 * full.memory_s

    q = get_config("qwen2.5-32b")
    fullr = analytic_roofline(q, sh_t, remat="full")
    dots = analytic_roofline(q, sh_t, remat="dots")
    assert dots.compute_s < fullr.compute_s
