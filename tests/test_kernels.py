"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
with hypothesis shape/dtype sweeps (brief deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.combine import combine_pallas
from repro.kernels.decode_attn import flash_decode_pallas
from repro.kernels.gram import gram_pallas


# ----------------------------------------------------------------- gram

@settings(max_examples=15, deadline=None)
@given(K=st.integers(1, 20), n=st.integers(1, 5000),
       block=st.sampled_from([128, 512, 2048]),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 2**16))
def test_gram_kernel_sweep(K, n, block, dtype, seed):
    key = jax.random.PRNGKey(seed)
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    U = (jax.random.normal(key, (K, n)) * 0.5).astype(dt)
    g = (jax.random.normal(jax.random.fold_in(key, 1), (n,))).astype(dt)
    G, c = gram_pallas(U, g, block_n=block, interpret=True)
    Gr, cr = ref.gram_ref(U, g)
    tol = 2e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(np.asarray(G), np.asarray(Gr), rtol=tol,
                               atol=tol * max(1.0, float(jnp.abs(Gr).max())))
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), rtol=tol,
                               atol=tol * max(1.0, float(jnp.abs(cr).max())))


def test_gram_kernel_zero_padding_exact():
    """Padding columns with zeros must not change the result."""
    U = jnp.ones((3, 130))          # forces padding at block 128
    g = jnp.ones((130,))
    G, c = gram_pallas(U, g, block_n=128, interpret=True)
    np.testing.assert_allclose(np.asarray(G), np.full((3, 3), 130.0))
    np.testing.assert_allclose(np.asarray(c), np.full((3,), 130.0))


# --------------------------------------------------------------- combine

@settings(max_examples=15, deadline=None)
@given(K=st.integers(1, 16), n=st.integers(1, 4000),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 2**16))
def test_combine_kernel_sweep(K, n, dtype, seed):
    key = jax.random.PRNGKey(seed)
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    U = (jax.random.normal(key, (K, n)) * 0.3).astype(dt)
    w = jax.random.normal(jax.random.fold_in(key, 1), (n,)).astype(dt)
    a = jax.random.normal(jax.random.fold_in(key, 2), (K,)).astype(jnp.float32)
    out = combine_pallas(w, U, a, block_n=512, interpret=True)
    outr = ref.combine_ref(w, U, a)
    tol = 3e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(outr, np.float32), rtol=tol, atol=tol)


def test_combine_zero_alpha_identity():
    w = jnp.arange(300, dtype=jnp.float32)
    U = jnp.ones((4, 300))
    out = combine_pallas(w, U, jnp.zeros((4,)), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w))


# ------------------------------------------------------------ decode_attn

@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 3), S=st.integers(8, 600),
       KV=st.sampled_from([1, 2, 4]), G=st.sampled_from([1, 3, 8]),
       block=st.sampled_from([128, 256]),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 2**16))
def test_flash_decode_sweep(B, S, KV, G, block, dtype, seed):
    key = jax.random.PRNGKey(seed)
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    hd = 64
    q = jax.random.normal(key, (B, KV, G, hd)).astype(dt)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd)).astype(dt)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd)).astype(dt)
    lengths = jax.random.randint(jax.random.fold_in(key, 3), (B,), 1, S + 1)
    o, lse = flash_decode_pallas(q, k, v, lengths, block_s=block,
                                 interpret=True)
    orf, lser = ref.flash_decode_ref(q, k, v, lengths)
    tol = 3e-2 if dtype == "bfloat16" else 2e-4
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lser), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("window", [16, 64, 250])
def test_flash_decode_window(window):
    key = jax.random.PRNGKey(0)
    B, S, KV, G, hd = 2, 300, 2, 4, 64
    q = jax.random.normal(key, (B, KV, G, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    lengths = jnp.array([200, 300], jnp.int32)
    o, lse = flash_decode_pallas(q, k, v, lengths, window=window,
                                 block_s=128, interpret=True)
    orf, lser = ref.flash_decode_ref(q, k, v, lengths, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(parts=st.integers(2, 5), seed=st.integers(0, 2**16))
def test_lse_merge_property(parts, seed):
    """Sharded flash-decode + LSE merge == unsharded attention, for any
    number of seq shards (the §Perf collective optimization's invariant)."""
    key = jax.random.PRNGKey(seed)
    B, KV, G, hd = 2, 2, 3, 32
    S = 128 * parts
    q = jax.random.normal(key, (B, KV, G, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    lengths = jnp.array([S - 37, S], jnp.int32)

    os_, ls_ = [], []
    shard = S // parts
    for pidx in range(parts):
        lo = pidx * shard
        local_len = jnp.clip(lengths - lo, 0, shard)
        o_p, l_p = ref.flash_decode_ref(q, k[:, lo:lo + shard],
                                        v[:, lo:lo + shard], local_len)
        os_.append(o_p)
        ls_.append(l_p)
    om, lm = ref.lse_merge_ref(jnp.stack(os_), jnp.stack(ls_))
    ofull, lfull = ref.flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(om), np.asarray(ofull), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(lfull), rtol=1e-4,
                               atol=1e-4)
