"""SCAFFOLD (paper ref [10]) + SCAFFOLD(Contextual) hybrid tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.fl import ServerConfig, run_scaffold
from repro.fl.scaffold import init_scaffold
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss


@pytest.fixture(scope="module")
def ds():
    xs, ys = make_synthetic(1.0, 1.0, num_devices=20, samples_per_device=40,
                            dim=30, seed=5)
    return FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                            xs.reshape(-1, 30)[:200], ys.reshape(-1)[:200], 10)


def _params():
    cfg = ArchConfig(name="lr", family="logreg", input_dim=30, num_classes=10)
    return get_model(cfg).init(jax.random.PRNGKey(0))


def test_scaffold_state_shapes(ds):
    st = init_scaffold(_params(), 20)
    for c, p in zip(jax.tree_util.tree_leaves(st.c_locals),
                    jax.tree_util.tree_leaves(st.params)):
        assert c.shape == (20,) + p.shape


def test_scaffold_converges(ds):
    cfg = ServerConfig(aggregator="fedavg", num_devices=20,
                       clients_per_round=8, lr=0.1, batch_size=10,
                       min_epochs=1, max_epochs=5)
    r = run_scaffold("scaffold", logistic_loss, logistic_apply, _params(),
                     ds, cfg, num_rounds=12)
    assert np.isfinite(r.train_loss).all()
    assert r.train_loss[-1] < r.train_loss[0]


def test_scaffold_contextual_more_robust_than_vanilla(ds):
    """The beyond-paper hybrid: contextual aggregation stabilises SCAFFOLD
    under aggressive local budgets (EXPERIMENTS.md beyond-paper table)."""
    results = {}
    for agg in ("fedavg", "contextual"):
        cfg = ServerConfig(aggregator=agg, num_devices=20,
                           clients_per_round=8, lr=0.2, batch_size=10,
                           min_epochs=1, max_epochs=20)
        results[agg] = run_scaffold(agg, logistic_loss, logistic_apply,
                                    _params(), ds, cfg, num_rounds=18)
    assert (results["contextual"].loss_volatility()
            < results["fedavg"].loss_volatility())
