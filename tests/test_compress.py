"""Tests for the summary-compression subsystem (repro.compress): round-trip
and exactness anchors per scheme, EF telescoping, contraction of the shrunk
sketch decodes, sketch-space Gram correctness, the Pallas sketch/top-k
kernels against their oracles, ledger byte accounting == serialized payload
sizes, the §III-C gateway-tier pool correction, and the compressed
hierarchical simulation end to end (including exact recovery at k = n)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import (CompressConfig, ErrorFeedback,
                            IdentityCompressor, SignSketch, SRHTSketch,
                            TopKCompressor, available_schemes, fwht,
                            payload_gram)
from repro.core import SolveConfig, available_aggregators, solve_alpha
from repro.core.gram import gram_and_cross
from repro.edge import uniform_fleet
from repro.fl import run_hier_simulation
from repro.hier import (HierConfig, compressed_summary_bytes, star_topology,
                        summarize_updates, two_tier_topology)
from repro.kernels import ops
from repro.kernels.sketch import sketch_apply_pallas
from repro.kernels.topk import topk_select_pallas

import repro.hier.hier_server  # noqa: F401  (registers hier aggregators)

N = 610


def _vec(seed, n=N):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,))


# ---------------------------------------------------------------------------
# scheme round trips, wire sizes, exactness anchors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["identity", "sign_sketch", "srht",
                                    "topk", "lowrank"])
def test_roundtrip_shapes_and_wire_size(scheme):
    c = CompressConfig(scheme=scheme, ratio=8.0).build(N)
    v = _vec(0)
    comp = c.encode(v, seed=3)
    dec = c.decode(comp)
    assert dec.shape == (N,)
    # serialized size is exactly what wire_floats promises — the ledger
    # property tests below lean on this
    assert comp.nbytes == pytest.approx(4.0 * c.wire_floats(N))
    if scheme != "identity":
        assert comp.nbytes < 0.3 * 4 * N            # actually compressed


def test_exactness_anchors():
    v = _vec(1)
    # top-k at k = n is the identity
    c = CompressConfig(scheme="topk", k=N).build(N)
    np.testing.assert_allclose(np.asarray(c.decode(c.encode(v))),
                               np.asarray(v), atol=1e-6)
    # SRHT at m = N (the padded power of 2) is an orthonormal transform
    c = CompressConfig(scheme="srht", sketch_dim=1024).build(N)
    np.testing.assert_allclose(np.asarray(c.decode(c.encode(v, seed=5))),
                               np.asarray(v), atol=1e-4)
    # identity is... the identity
    c = IdentityCompressor()
    np.testing.assert_allclose(np.asarray(c.decode(c.encode(v))),
                               np.asarray(v))


def test_fwht_involution_and_orthogonality():
    x = _vec(2, 128)
    y = fwht(x)
    np.testing.assert_allclose(np.asarray(fwht(y) / 128), np.asarray(x),
                               atol=1e-4)
    # H/sqrt(N) preserves norms
    assert float(jnp.linalg.norm(y) / jnp.sqrt(128.0)) == pytest.approx(
        float(jnp.linalg.norm(x)), rel=1e-5)
    with pytest.raises(ValueError, match="power-of-2"):
        fwht(jnp.zeros((100,)))


def test_sketch_decode_is_contraction():
    """The EF convergence condition: ‖x − decode(encode(x))‖ < ‖x‖ on
    average.  The *unshrunk* adjoint violates this for m ≪ n (norms inflate
    by ~n/m), which is exactly why the decodes shrink."""
    for c in (SignSketch(m=64), SRHTSketch(m=64)):
        ratios = []
        for s in range(20):
            v = _vec(100 + s)
            err = v - c.decode(c.encode(v, seed=s))
            ratios.append(float(jnp.linalg.norm(err) / jnp.linalg.norm(v)))
        assert np.mean(ratios) < 1.0, (c.name, np.mean(ratios))


def test_sign_sketch_dot_unbiased():
    """Sketch-space inner products estimate true inner products without the
    n/m distortion of decoded dots (correlated pair so signal ≫ noise)."""
    v = _vec(3)
    w = v + 0.1 * _vec(4)
    c = SignSketch(m=128)
    dots = [float(c.dot(c.encode(v, seed=s), c.encode(w, seed=s)))
            for s in range(60)]
    true = float(jnp.vdot(v, w))
    assert np.mean(dots) == pytest.approx(true, rel=0.15)
    with pytest.raises(ValueError, match="shared sketch"):
        c.dot(c.encode(v, seed=0), c.encode(w, seed=1))


def test_payload_gram_identity_matches_exact_and_srht_estimates():
    v, w, g = _vec(5), _vec(6), _vec(7)
    U = jnp.stack([v, w])
    ident = IdentityCompressor()
    G, c2 = payload_gram(ident, [ident.encode(v), ident.encode(w)],
                         [ident.encode(g), ident.encode(g)],
                         np.array([1.0, 1.0]))
    Gf, cf = gram_and_cross(U, g)
    np.testing.assert_allclose(np.asarray(G), np.asarray(Gf), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(cf), rtol=1e-4,
                               atol=1e-3)
    # srht at m = padded N is exact too (orthonormal rows)
    sk = SRHTSketch(m=1024)
    G, c2 = payload_gram(sk, [sk.encode(v, 9), sk.encode(w, 9)],
                         [sk.encode(g, 9), sk.encode(g, 9)],
                         np.array([1.0, 1.0]))
    np.testing.assert_allclose(np.asarray(G), np.asarray(Gf), rtol=1e-3)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(cf), rtol=1e-3,
                               atol=1e-2)
    with pytest.raises(ValueError, match="shared sketch"):
        payload_gram(sk, [sk.encode(v, 0), sk.encode(w, 1)],
                     [sk.encode(g, 0), sk.encode(g, 0)], np.ones(2))


def test_mass_conserving_gamma_invariant_to_uniform_gram_rescale():
    """Why sketch-space cross-terms may price unshrunk targets while the
    combine applies shrunk decodes: scaling (G₂, c₂) jointly by s² leaves
    the Σγ=1 KKT solution exactly unchanged."""
    key = jax.random.PRNGKey(8)
    U = jax.random.normal(key, (4, 60))
    g = jax.random.normal(jax.random.fold_in(key, 1), (60,))
    G, c = gram_and_cross(U, g)
    cfg = SolveConfig(beta=3.0, ridge=1e-8, sum_to=1.0)
    gamma = solve_alpha(G, c, cfg)
    for s2 in (0.01, 0.3, 9.0):
        gamma_s = solve_alpha(s2 * G, s2 * c, cfg)
        np.testing.assert_allclose(np.asarray(gamma_s), np.asarray(gamma),
                                   rtol=1e-4, atol=1e-6)


def test_compress_config_validation_and_budget():
    with pytest.raises(KeyError, match="unknown compression scheme"):
        CompressConfig(scheme="bogus").build(100)
    with pytest.raises(ValueError, match="ratio"):
        CompressConfig(ratio=0.5)
    with pytest.raises(ValueError, match="k must be"):
        CompressConfig(k=0)
    with pytest.raises(ValueError, match="u_frac"):
        CompressConfig(u_frac=1.5)
    with pytest.raises(ValueError, match="selection scheme"):
        CompressConfig(scheme="srht", u_frac=0.75)
    assert set(available_schemes()) >= {"identity", "sign_sketch", "srht",
                                        "topk", "lowrank"}
    # every scheme meets its byte budget: <= n/ratio wire words per vector
    for scheme in ("sign_sketch", "srht", "topk", "lowrank"):
        c = CompressConfig(scheme=scheme, ratio=8.0).build(N)
        assert c.wire_floats(N) <= N / 8.0 + 1
    # the (u, g) pair splits a 2n/ratio budget by u_frac
    cu, cg = CompressConfig(scheme="topk", ratio=4.0,
                            u_frac=0.75).build_pair(N)
    assert cu.wire_floats(N) + cg.wire_floats(N) <= 2 * N / 4.0 + 2
    assert cu.wire_floats(N) > 2.5 * cg.wire_floats(N)
    # u_frac = 0.5 degenerates to two copies of build()
    cu, cg = CompressConfig(scheme="srht", ratio=4.0).build_pair(N)
    assert cu.wire_floats(N) == cg.wire_floats(N) \
        == CompressConfig(scheme="srht", ratio=4.0).build(N).wire_floats(N)
    # a skewed split of a mild joint ratio clamps at full width instead of
    # crashing on a sub-ratio < 1 the user never set
    cu, cg = CompressConfig(scheme="topk", ratio=1.2,
                            u_frac=0.75).build_pair(N)
    assert cu.wire_floats(N) <= 2 * N and cg.wire_floats(N) <= 2 * N


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

def test_error_feedback_telescopes_exactly():
    """Σ_t decode_t = Σ_t v_t − e_T — nothing is lost, only delayed."""
    ef = ErrorFeedback()
    c = TopKCompressor(k=40)
    total_in = jnp.zeros(N)
    total_out = jnp.zeros(N)
    for t in range(6):
        v = _vec(20 + t)
        _, dec = ef.step("gw", v, c, seed=t)
        total_in += v
        total_out += dec
    np.testing.assert_allclose(np.asarray(total_out + ef.residual["gw"]),
                               np.asarray(total_in), atol=1e-4)
    assert ef.residual_norm("gw") > 0
    assert ef.residual_norm("never-sent") == 0.0


def test_error_feedback_disabled_keeps_no_state():
    ef = ErrorFeedback(enabled=False)
    c = TopKCompressor(k=40)
    v = _vec(30)
    comp, dec = ef.step("gw", v, c)
    assert ef.residual == {}
    np.testing.assert_allclose(np.asarray(dec), np.asarray(c.decode(comp)))


def test_error_feedback_repeated_constant_input_converges():
    """Under a constant signal the EF-compressed stream's running mean
    approaches the signal (the classic EF sanity check)."""
    ef = ErrorFeedback()
    c = TopKCompressor(k=60)
    v = _vec(31)
    acc = jnp.zeros(N)
    T = 40
    for t in range(T):
        _, dec = ef.step("gw", v, c, seed=t)
        acc += dec
    # steady-state residual is O(1) while the mean integrates T sends, so
    # the relative error decays ~‖e_ss‖/(T·‖v‖)
    rel = float(jnp.linalg.norm(acc / T - v) / jnp.linalg.norm(v))
    assert rel < 0.15


# ---------------------------------------------------------------------------
# kernels vs oracles + ops dispatch
# ---------------------------------------------------------------------------

def test_sketch_kernel_matches_ref():
    key = jax.random.PRNGKey(0)
    U = jax.random.normal(key, (5, 333))         # K=5, m=11: both sublane-pad
    R = jax.random.normal(jax.random.fold_in(key, 1), (11, 333))
    out = sketch_apply_pallas(U, R, block_n=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(U @ R.T),
                               rtol=1e-4, atol=1e-4)
    d = ops.sketch_apply(U, R, use_pallas=True, block_n=128)
    np.testing.assert_allclose(np.asarray(d), np.asarray(out), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ops.sketch_apply(U, R)),
                               np.asarray(U @ R.T), rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError, match="disagree on n"):
        sketch_apply_pallas(U, R[:, :100], interpret=True)


@pytest.mark.parametrize("n,k,block_n", [(333, 7, 128), (500, 40, 128),
                                         (128, 128, 128), (1000, 3, 256)])
def test_topk_kernel_matches_ref(n, k, block_n):
    v = jax.random.normal(jax.random.PRNGKey(n + k), (n,))
    vals_p, idx_p = topk_select_pallas(v, k, block_n=block_n, interpret=True)
    vals_r, idx_r = ops.topk_select(v, k, use_pallas=False)
    # compare as reconstructed sparse vectors (robust to tie ordering)
    dense_p = np.zeros(n); dense_p[np.asarray(idx_p)] = np.asarray(vals_p)
    dense_r = np.zeros(n); dense_r[np.asarray(idx_r)] = np.asarray(vals_r)
    np.testing.assert_allclose(dense_p, dense_r, atol=1e-6)
    assert idx_p.dtype == jnp.int32 and int(idx_p.max()) < n
    # padded chunks never leak pad indices
    assert len(set(np.asarray(idx_p).tolist())) == k


def test_topk_kernel_rejects_oversized_k_and_ops_falls_back():
    v = jax.random.normal(jax.random.PRNGKey(0), (600,))
    with pytest.raises(ValueError, match="exceeds block_n"):
        topk_select_pallas(v, 300, block_n=128, interpret=True)
    vals, idx = ops.topk_select(v, 300, use_pallas=True, block_n=128)
    assert vals.shape == (300,)                  # silently used the oracle


# ---------------------------------------------------------------------------
# §III-C pool pricing at the gateway tier
# ---------------------------------------------------------------------------

def test_gateway_pool_size_scales_solve():
    key = jax.random.PRNGKey(0)
    K, pool = 4, 12
    updates = [{"w": jax.random.normal(jax.random.fold_in(key, i), (30,))}
               for i in range(K)]
    grads = [{"w": jax.random.normal(jax.random.fold_in(key, 10 + i), (30,))}
             for i in range(K)]
    cfg = SolveConfig(beta=4.0, ridge=1e-8)
    s_plain = summarize_updates(1, range(K), updates, grads, [1] * K, cfg)
    s_pool = summarize_updates(1, range(K), updates, grads, [1] * K, cfg,
                               pool_size=pool)
    scale = (pool - 1) / (K - 1)
    np.testing.assert_allclose(np.asarray(s_pool.alpha),
                               scale * np.asarray(s_plain.alpha), rtol=1e-5)
    # "mean" tier rule is untouched (selection-unbiased already)
    m_plain = summarize_updates(1, range(K), updates, grads, [1] * K, cfg,
                                mode="mean")
    m_pool = summarize_updates(1, range(K), updates, grads, [1] * K, cfg,
                               mode="mean", pool_size=pool)
    np.testing.assert_allclose(np.asarray(m_pool.alpha),
                               np.asarray(m_plain.alpha))
    with pytest.raises(ValueError, match="pool_size"):
        summarize_updates(1, range(K), updates, grads, [1] * K, cfg,
                          pool_size=2)


# ---------------------------------------------------------------------------
# config + registry plumbing
# ---------------------------------------------------------------------------

def test_hier_sketch_config_and_registry():
    assert "hier_contextual_sketch" in available_aggregators()
    cfg = HierConfig(aggregator="hier_contextual_sketch")
    assert cfg.compress is not None              # defaulted
    assert cfg.compressing and cfg.tier_mode == "contextual"
    with pytest.raises(ValueError, match="hier_contextual_sketch"):
        HierConfig(aggregator="hier_contextual",
                   compress=CompressConfig())
    with pytest.raises(ValueError, match="gateway_grad"):
        HierConfig(aggregator="hier_contextual_sketch",
                   compress=CompressConfig(), gateway_grad="global")


# ---------------------------------------------------------------------------
# compressed hierarchical simulation end to end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_problem(tiny_edge_problem):
    # shared session-scoped dataset/model (conftest) → one set of compiled
    # functions serves both this module and test_hier
    return tiny_edge_problem


def _hier(ds, params, topo, rounds=5, **kw):
    from repro.models.logistic import logistic_apply, logistic_loss
    base = dict(aggregator="hier_contextual", lr=0.2, batch_size=10,
                min_epochs=1, max_epochs=4)
    base.update(kw)
    return run_hier_simulation("t", logistic_loss, logistic_apply, params,
                               ds, HierConfig(**base), topo,
                               num_rounds=rounds, selection_seed=11,
                               eval_every=2)


def test_compressed_sim_exact_at_full_budget(tiny_problem):
    """topk at k = n decodes exactly, so the whole compressed pipeline must
    reproduce the uncompressed hierarchical run bit-for-bit-ish."""
    ds, params, n_model = tiny_problem
    fleet = uniform_fleet(12, dropout=0.0)
    topo = two_tier_topology(fleet, 3)
    plain = _hier(ds, params, topo)
    exact = _hier(ds, params, topo, aggregator="hier_contextual_sketch",
                  compress=CompressConfig(scheme="topk", k=n_model))
    np.testing.assert_allclose(exact.train_loss, plain.train_loss, rtol=1e-4)
    # identity scheme: same losses AND strictly fewer bytes (2n+2 words vs
    # the raw summary's 2n+K²+2K+2 — the G block stays home)
    ident = _hier(ds, params, topo, aggregator="hier_contextual_sketch",
                  compress=CompressConfig(scheme="identity"))
    np.testing.assert_allclose(ident.train_loss, plain.train_loss, rtol=1e-4)
    assert ident.cloud_uplink_bytes < plain.cloud_uplink_bytes


def test_compressed_sim_learns_and_slashes_uplink(tiny_problem):
    ds, params, _ = tiny_problem
    fleet = uniform_fleet(12, dropout=0.0)
    topo = two_tier_topology(fleet, 3)
    plain = _hier(ds, params, topo, rounds=6)
    for scheme in ("topk", "srht"):
        r = _hier(ds, params, topo, rounds=6,
                  aggregator="hier_contextual_sketch",
                  compress=CompressConfig(scheme=scheme, ratio=4.0))
        assert np.isfinite(r.train_loss).all()
        assert r.train_loss[-1] < r.train_loss[0]
        assert r.cloud_uplink_bytes < 0.5 * plain.cloud_uplink_bytes


def test_ledger_matches_serialized_payload_sizes(tiny_problem):
    """CommLedger cloud-tier bytes == rounds × Σ_g serialized compressed
    summary size, computed independently from the compressor's wire format."""
    ds, params, n_model = tiny_problem
    fleet = uniform_fleet(12, dropout=0.0)      # no dropouts: cohorts fixed
    topo = two_tier_topology(fleet, 3)
    rounds = 4
    ccfg = CompressConfig(scheme="topk", ratio=4.0, u_frac=0.75)
    r = _hier(ds, params, topo, rounds=rounds,
              aggregator="hier_contextual_sketch", compress=ccfg)
    cu, cg = ccfg.build_pair(n_model)
    per_summary = compressed_summary_bytes(
        4.0 * (cu.wire_floats(n_model) + cg.wire_floats(n_model)))
    assert r.cloud_uplink_bytes == pytest.approx(rounds * 3 * per_summary)
    # uncompressed comparator: the raw summary formula still governs
    plain = _hier(ds, params, topo, rounds=rounds)
    from repro.hier import summary_bytes
    assert plain.cloud_uplink_bytes == pytest.approx(
        rounds * 3 * summary_bytes(4, n_model, include_grad=True))


def test_device_uplink_compression_star(tiny_problem):
    """Star topology with device-level EF compression: per-device residual
    state, compressed device→cloud ledger pricing BOTH streams the solve
    consumes (update and gradient), finite learning."""
    ds, params, n_model = tiny_problem
    fleet = uniform_fleet(12, dropout=0.0)
    topo = star_topology(fleet)
    ccfg = CompressConfig(scheme="topk", ratio=4.0, device_uplink=True)
    r = _hier(ds, params, topo, rounds=4,
              aggregator="hier_contextual_sketch", compress=ccfg)
    assert np.isfinite(r.train_loss).all()
    plain = _hier(ds, params, topo, rounds=4)
    assert r.cloud_uplink_bytes < 0.6 * plain.cloud_uplink_bytes
    cu, cg = ccfg.build_pair(n_model)
    per_dev = 4.0 * (cu.wire_floats(n_model) + cg.wire_floats(n_model))
    assert r.cloud_uplink_bytes == pytest.approx(4 * 12 * per_dev)


def test_compressed_sim_three_tier_geo(tiny_problem):
    from repro.hier import geo_partitioned_topology
    ds, params, _ = tiny_problem
    topo = geo_partitioned_topology(uniform_fleet(12, dropout=0.1), 2, 2)
    r = _hier(ds, params, topo, rounds=4,
              aggregator="hier_contextual_sketch",
              compress=CompressConfig(scheme="topk", ratio=4.0))
    assert np.isfinite(r.train_loss).all()
    assert r.comm["tier_3"]["bytes_up"] > 0
    assert r.comm["tier_2"]["bytes_up"] > 0


def test_compressed_sim_deterministic(tiny_problem):
    ds, params, _ = tiny_problem
    fleet = uniform_fleet(12, dropout=0.1)
    topo = two_tier_topology(fleet, 3)
    kw = dict(aggregator="hier_contextual_sketch",
              compress=CompressConfig(scheme="sign_sketch", ratio=4.0))
    r1 = _hier(ds, params, topo, **kw)
    r2 = _hier(ds, params, topo, **kw)
    assert r1.train_loss == r2.train_loss
    assert r1.cloud_uplink_bytes == r2.cloud_uplink_bytes


def test_fan_in_pool_correction_runs_in_sim(tiny_problem):
    ds, params, _ = tiny_problem
    fleet = uniform_fleet(12, dropout=0.0)
    topo = two_tier_topology(fleet, 3)
    r = _hier(ds, params, topo, fan_in=2)
    assert np.isfinite(r.train_loss).all()
    star = _hier(ds, params, star_topology(fleet), fan_in=4)
    assert np.isfinite(star.train_loss).all()


# ---------------------------------------------------------------------------
# hypothesis property tests (skipped when hypothesis is unavailable)
# ---------------------------------------------------------------------------

def test_wire_floats_matches_serialization_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(8, 400), seed=st.integers(0, 2 ** 16),
           scheme=st.sampled_from(["sign_sketch", "srht", "topk", "lowrank",
                                   "identity"]),
           ratio=st.sampled_from([2.0, 4.0, 8.0]))
    def check(n, seed, scheme, ratio):
        c = CompressConfig(scheme=scheme, ratio=ratio).build(n)
        v = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        comp = c.encode(v, seed=seed)
        assert comp.nbytes == pytest.approx(4.0 * c.wire_floats(n))
        assert c.decode(comp).shape == (n,)

    check()


def test_topk_kernel_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(10, 700), k=st.integers(1, 64),
           seed=st.integers(0, 999))
    def check(n, k, seed):
        k = min(k, n)
        v = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        vals_p, idx_p = topk_select_pallas(v, k, block_n=128, interpret=True)
        vals_r, idx_r = ops.topk_select(v, k, use_pallas=False)
        np.testing.assert_allclose(
            np.sort(np.abs(np.asarray(vals_p))),
            np.sort(np.abs(np.asarray(vals_r))), atol=1e-6)
        assert int(idx_p.max()) < n

    check()
