"""Numerical property tests for the model substrate: chunked/parallel forms
vs step-by-step recurrences, flash vs naive attention, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.models.attention import flash_attention, naive_attention
from repro.models.config import ArchConfig
from repro.models.moe import init_moe, moe_forward
from repro.models.rwkv import RWKVState, init_rwkv6, rwkv6_decode, rwkv6_forward
from repro.models.ssd import (SSMState, init_mamba2, init_ssm_state,
                              mamba2_decode, mamba2_forward)


# ----------------------------------------------------- flash vs naive attn

@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 2), S=st.integers(4, 160),
       KV=st.sampled_from([1, 2]), G=st.sampled_from([1, 4]),
       mode=st.sampled_from(["causal", "bidir", "window"]),
       seed=st.integers(0, 2**16))
def test_flash_attention_matches_naive(B, S, KV, G, mode, seed):
    key = jax.random.PRNGKey(seed)
    hd = 32
    H = KV * G
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    pos = jnp.arange(S)
    window = 7 if mode == "window" else None
    kwargs = dict(q_positions=pos, k_positions=pos, mode=mode, window=window)
    out_f = flash_attention(q, k, v, block_q=16, block_k=32, **kwargs)
    out_n = naive_attention(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_n),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_softcap():
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 1, 96, 2, 32
    q = jax.random.normal(key, (B, S, H, hd)) * 3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd)) * 3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    pos = jnp.arange(S)
    a = flash_attention(q, k, v, q_positions=pos, k_positions=pos,
                        logit_softcap=30.0, block_q=32, block_k=32)
    b = naive_attention(q, k, v, q_positions=pos, k_positions=pos,
                        logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


# -------------------------------------------- SSD chunked vs recurrence

def _ssm_cfg(chunk):
    return ArchConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      d_ff=64, vocab_size=64, ssm_state=8, ssm_head_dim=16,
                      ssm_chunk=chunk, dtype="float32")


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_stepwise_decode(chunk):
    """Full-sequence chunked SSD == token-by-token recurrent decode."""
    cfg = _ssm_cfg(chunk)
    key = jax.random.PRNGKey(0)
    params = init_mamba2(cfg, key, jnp.float32)
    B, S = 2, 20
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))

    y_full, final_state = mamba2_forward(cfg, params, x)

    state = init_ssm_state(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y_t, state = mamba2_decode(cfg, params, x[:, t:t + 1], state)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final_state.ssm),
                               np.asarray(state.ssm), rtol=2e-3, atol=2e-3)


def test_ssd_state_carry_across_segments():
    """forward(x[:10]) then forward(x[10:], state) == forward(x) — the
    prefill-then-continue invariant."""
    cfg = _ssm_cfg(8)
    key = jax.random.PRNGKey(3)
    params = init_mamba2(cfg, key, jnp.float32)
    B, S = 1, 24
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    y_all, _ = mamba2_forward(cfg, params, x)
    y1, st = mamba2_forward(cfg, params, x[:, :10])
    y2, _ = mamba2_forward(cfg, params, x[:, 10:], init_state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=2e-3, atol=2e-3)


# -------------------------------------------- RWKV6 chunked vs recurrence

def _rwkv_cfg():
    return ArchConfig(name="t", family="ssm", rwkv=True, num_layers=1,
                      d_model=32, d_ff=64, vocab_size=64, rwkv_head_dim=16,
                      ssm_chunk=64, dtype="float32")  # wkv chunk = 16


def test_rwkv6_chunked_matches_stepwise_decode():
    cfg = _rwkv_cfg()
    key = jax.random.PRNGKey(0)
    params = init_rwkv6(cfg, key, jnp.float32)
    B, S = 2, 21
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))

    y_full, final_state = rwkv6_forward(cfg, params, x)

    from repro.models.rwkv import init_rwkv_state
    state = init_rwkv_state(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y_t, state = rwkv6_decode(cfg, params, x[:, t:t + 1], state)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final_state.wkv),
                               np.asarray(state.wkv), rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------- MoE dispatch

def _moe_cfg(E=8, k=2, shared=1):
    return ArchConfig(name="t", family="moe", num_layers=1, d_model=16,
                      d_ff=32, vocab_size=64, num_heads=2, num_kv_heads=2,
                      num_experts=E, experts_per_token=k,
                      num_shared_experts=shared, dtype="float32")


def test_moe_no_drop_matches_dense_reference():
    """In the drop-free regime the sort-based dispatch must equal the dense
    (all-experts, gate-weighted) computation."""
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(0)
    params = init_moe(cfg, key, jnp.float32)
    B, S = 2, 12              # T=24 ≤ 256 → drop-free capacity
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    out, aux = moe_forward(cfg, params, x)

    # dense reference: every token through every expert, weighted by the
    # renormalised top-k gate
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    gates = jnp.zeros_like(probs)
    gates = gates.at[jnp.arange(xt.shape[0])[:, None], top_e].set(top_p)
    up = jnp.einsum("td,edf->tef", xt, params["w_up"])
    gate = jnp.einsum("td,edf->tef", xt, params["w_gate"])
    h = jax.nn.silu(gate) * up
    eo = jnp.einsum("tef,efd->ted", h, params["w_down"])
    ref = jnp.einsum("te,ted->td", gates, eo)
    from repro.models.mlp import mlp_forward
    ref = ref + mlp_forward(cfg, params["shared"], xt)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


@settings(max_examples=10, deadline=None)
@given(E=st.sampled_from([4, 8]), k=st.integers(1, 3),
       T=st.integers(2, 40), seed=st.integers(0, 2**16))
def test_moe_property_output_finite_and_balanced_aux(E, k, T, seed):
    cfg = _moe_cfg(E=E, k=min(k, E), shared=0)
    key = jax.random.PRNGKey(seed)
    params = init_moe(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, T, cfg.d_model))
    out, aux = moe_forward(cfg, params, x)
    assert np.isfinite(np.asarray(out)).all()
    # Switch aux loss is ≥ 1 at uniform routing and small near init
    assert 0.5 < float(aux) < 4.0
