"""Per-architecture smoke tests (deliverable f).

For each assigned architecture: instantiate the REDUCED same-family variant
(≤2 layers, d_model ≤ 512, ≤4 experts) and run one forward + one train step
on CPU, asserting output shapes and no NaNs.  Decode consistency (cache vs
full forward) is asserted for every family with a serve path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import get_model

KEY = jax.random.PRNGKey(0)


def _make_batch(cfg, bundle, B, S):
    batch = {}
    for k, (shape, dt) in bundle.batch_spec(B, S).items():
        if dt == jnp.int32:
            batch[k] = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
        else:
            batch[k] = jax.random.normal(KEY, shape).astype(dt)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    bundle = get_model(cfg)
    params = bundle.init(KEY)
    B, S = 2, 32
    batch = _make_batch(cfg, bundle, B, S)

    logits = bundle.forward(params, batch)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    # one SGD train step decreases nothing catastrophic and yields finite grads
    loss_fn = lambda p: bundle.train_loss(p, batch)[0]
    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), arch
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
    new_params = jax.tree_util.tree_map(
        lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    loss2 = bundle.train_loss(new_params, batch)[0]
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    bundle = get_model(cfg)
    params = bundle.init(KEY)
    B, S = 2, 24
    batch = _make_batch(cfg, bundle, B, S)

    full = bundle.forward(params, batch)
    prompt = dict(batch)
    T = batch["tokens"].shape[1]           # text length (≤ S for VLM)
    prompt["tokens"] = batch["tokens"][:, :T - 1]
    # cache must cover the fused stream (image prefix + text for VLM)
    off = cfg.num_image_tokens if cfg.family == "vlm" else 0
    lp, cache = bundle.prefill(params, prompt, off + T + 8)
    ld, cache2 = bundle.decode(params, batch["tokens"][:, T - 1], cache)

    # positions of the prompt's last / decoded token in the full logits
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(full[:, off + T - 2], np.float32),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(ld, np.float32),
                               np.asarray(full[:, off + T - 1], np.float32),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ["qwen3-14b", "starcoder2-15b",
                                  "deepseek-moe-16b"])
def test_sliding_window_variant_runs(arch):
    """The long_500k carve-out: window-limited attention trains & decodes."""
    cfg = get_config(arch).reduced().with_overrides(sliding_window=16)
    bundle = get_model(cfg)
    params = bundle.init(KEY)
    batch = _make_batch(cfg, bundle, 2, 48)
    loss, _ = bundle.train_loss(params, batch)
    assert np.isfinite(float(loss))
    lp, cache = bundle.prefill(params, {"tokens": batch["tokens"][:, :47]}, 64)
    ld, _ = bundle.decode(params, batch["tokens"][:, 47], cache)
    assert np.isfinite(np.asarray(ld, np.float32)).all()


def test_moe_router_load_balance_loss_positive():
    cfg = get_config("olmoe-1b-7b").reduced()
    bundle = get_model(cfg)
    params = bundle.init(KEY)
    batch = _make_batch(cfg, bundle, 2, 64)
    loss, aux = bundle.train_loss(params, batch)
    assert float(aux) >= 0.9  # ≈1 for a balanced/uniform router at init


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyper-parameters."""
    spec = {
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, num_heads=32,
                            num_kv_heads=32, d_ff=8192, vocab_size=32000,
                            ssm_state=64),
        "starcoder2-15b": dict(num_layers=40, d_model=6144, num_heads=48,
                               num_kv_heads=4, d_ff=24576, vocab_size=49152),
        "deepseek-moe-16b": dict(num_layers=28, d_model=2048, num_heads=16,
                                 num_kv_heads=16, d_ff=1408,
                                 vocab_size=102400, num_experts=64,
                                 experts_per_token=6, num_shared_experts=2),
        "rwkv6-1.6b": dict(num_layers=24, d_model=2048, d_ff=7168,
                           vocab_size=65536, rwkv=True),
        "chameleon-34b": dict(num_layers=48, d_model=8192, num_heads=64,
                              num_kv_heads=8, d_ff=22016, vocab_size=65536),
        "qwen3-14b": dict(num_layers=40, d_model=5120, num_heads=40,
                          num_kv_heads=8, d_ff=17408, vocab_size=151936,
                          qk_norm=True),
        "gemma-7b": dict(num_layers=28, d_model=3072, num_heads=16,
                         num_kv_heads=16, d_ff=24576, vocab_size=256000,
                         head_dim=256, activation="geglu"),
        "whisper-large-v3": dict(num_layers=32, d_model=1280, num_heads=20,
                                 num_kv_heads=20, d_ff=5120,
                                 vocab_size=51866, is_encoder_decoder=True),
        "qwen2.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                            num_kv_heads=8, d_ff=27648, vocab_size=152064,
                            qkv_bias=True),
        "olmoe-1b-7b": dict(num_layers=16, d_model=2048, num_heads=16,
                            num_kv_heads=16, d_ff=1024, vocab_size=50304,
                            num_experts=64, experts_per_token=8),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for f, v in fields.items():
            assert getattr(cfg, f) == v, (arch, f, getattr(cfg, f), v)
