"""Streamed big-model round engine (PR-5 tentpole).

The streamed engine must reproduce the fused engine and the pytree
reference functions exactly (up to f32 accumulation order) on every stage,
every topology shape, and every chunk boundary — while never materializing
the (P, n) round matrices.  Covers: the ``stream_stats`` kernel op across
backends and chunk sizes (n % chunk != 0, single-chunk degenerate case),
the leaf-aligned ``ChunkedFlatView``, per-stage equivalence including the
sketch/EF compressed composition, scope × chunk interaction, the
peak-bytes estimator, and engine auto-selection.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flatten import ChunkedFlatView, tree_to_vector
from repro.core.solve import SolveConfig
from repro.hier import fused, streamed
from repro.hier.streamed import RowMix, StreamedRoundEngine, dense_round_bytes
from repro.kernels import ops, ref

TOL = dict(rtol=1e-5, atol=1e-4)


def _allclose(x, y):
    np.testing.assert_allclose(np.asarray(x, np.float32),
                               np.asarray(y, np.float32), **TOL)


def _stacked(P=8, seed=0, leaves=((3, 5), (7,), (4, 6), (1,))):
    """A stacked multi-leaf pytree (leading P axis) + its gradient twin."""
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i, shape in enumerate(leaves):
        key, k = jax.random.split(key)
        tree[f"leaf{i}"] = jax.random.normal(k, (P,) + shape, jnp.float32)
    key, k = jax.random.split(key)
    grads = jax.tree_util.tree_map(
        lambda l: jax.random.normal(jax.random.fold_in(k, l.size), l.shape),
        tree)
    return tree, grads


def _template(stacked):
    return jax.tree_util.tree_map(lambda l: l[0], stacked)


# ------------------------------------------------------------- kernel op

@pytest.mark.parametrize("P,n,bn", [(4, 333, 64), (1, 7, 64), (6, 64, 64),
                                    (5, 100, 1 << 16), (3, 129, 128)])
def test_stream_stats_backends_match_ref(P, n, bn):
    """Every backend, including the re-anchored remainder window (n % bn
    != 0) and the single-chunk degenerate case (bn >= n)."""
    key = jax.random.PRNGKey(1)
    D = jax.random.normal(key, (P, n), jnp.float32)
    GM = jax.random.normal(jax.random.fold_in(key, 1), (P, n), jnp.float32)
    want = ref.stream_stats_ref(D, GM)
    for be in ops.backends("stream_stats"):
        G, C = ops.stream_stats(D, GM, backend=be, block_n=bn)
        _allclose(G, want[0])
        _allclose(C, want[1])


def test_stream_stats_chunk_invariance():
    key = jax.random.PRNGKey(2)
    D = jax.random.normal(key, (5, 1000), jnp.float32)
    GM = jax.random.normal(jax.random.fold_in(key, 3), (5, 1000))
    base = ops.stream_stats(D, GM, backend="xla", block_n=1000)
    for bn in (64, 128, 333, 1 << 20):
        got = ops.stream_stats(D, GM, backend="xla", block_n=bn)
        _allclose(got[0], base[0])
        _allclose(got[1], base[1])


def test_stream_stats_chunk_property_sweep():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(P=st.integers(1, 9), n=st.integers(1, 500),
           bn=st.integers(1, 600), seed=st.integers(0, 999))
    def check(P, n, bn, seed):
        key = jax.random.PRNGKey(seed)
        D = jax.random.normal(key, (P, n), jnp.float32)
        GM = jax.random.normal(jax.random.fold_in(key, 1), (P, n))
        want = ref.stream_stats_ref(D, GM)
        got = ops.stream_stats(D, GM, backend="xla", block_n=bn)
        _allclose(got[0], want[0])
        _allclose(got[1], want[1])

    check()


def test_stream_stats_bf16_inputs_accumulate_f32():
    D = jnp.ones((3, 300), jnp.bfloat16)
    G, C = ops.stream_stats(D, D, backend="xla", block_n=64)
    assert G.dtype == jnp.float32
    _allclose(G, np.full((3, 3), 300.0))


# --------------------------------------------------------- chunked view

def test_chunked_flat_view_matches_dense_flatten():
    stacked, _ = _stacked(P=6)
    view = ChunkedFlatView(stacked)
    dense = fused.flatten_stacked(stacked)
    assert view.n == dense.shape[1] and view.K == 6
    _allclose(view.materialize(), dense)
    # chunk reassembly is exact and leaf-aligned for every chunk size
    for chunk in (1, 4, 7, 1000):
        got = np.zeros(dense.shape, np.float32)
        widths = []
        for off, _, mat in view.chunks(chunk):
            got[:, off:off + mat.shape[1]] = np.asarray(mat)
            widths.append(mat.shape[1])
        _allclose(got, dense)
        assert max(widths) <= chunk
    boundaries = {s.offset for s in view.slabs}
    offs = {off for off, _, _ in view.chunks(4)}
    assert boundaries <= offs           # leaf starts are chunk starts


def test_chunked_flat_view_scope_matches_scope_indices():
    stacked, _ = _stacked(P=4)
    tmpl = _template(stacked)
    view = ChunkedFlatView(stacked, scope="last_layer")
    idx = fused.scope_indices(tmpl, "last_layer")
    assert idx.dtype == np.int32        # satellite: no silent x64 downcast
    scoped_cols = sorted(
        c for s in view.scoped_slabs for c in range(s.offset,
                                                    s.offset + s.width))
    assert scoped_cols == sorted(int(i) for i in idx)


# ------------------------------------------------- per-stage equivalence

def _round_ctxs(P=8, seed=0, scope=None, chunk=None, beta=4.0):
    stacked, grads = _stacked(P=P, seed=seed)
    cfg = SolveConfig(beta=beta, ridge=1e-8)
    tmpl = _template(stacked)
    feng = fused.HierRoundEngine(tmpl, cfg, "contextual", scope)
    seng = StreamedRoundEngine(tmpl, cfg, "contextual", scope, chunk=chunk)
    return (feng.begin_round(stacked, grads),
            seng.begin_round(stacked, grads), stacked, grads, cfg)


@pytest.mark.parametrize("scope,chunk", [(None, None), (None, 7),
                                         ("leaf2", 5)])
def test_gateway_stage_matches_fused_and_reference(scope, chunk):
    from repro.hier.gateway import summarize_updates
    fctx, sctx, stacked, grads, cfg = _round_ctxs(scope=scope, chunk=chunk)
    idxs = [1, 3, 4, 6]
    fo = fctx.gateway(idxs)
    so = sctx.gateway(idxs)
    for k in ("G", "c", "alpha"):
        _allclose(so[k], fo[k])
    _allclose(sctx.materialize(so["u_bar"]), fo["u_bar"])
    _allclose(sctx.materialize(so["ghat"]), fo["ghat"])
    # and against the pytree reference
    rows = lambda tree, i: jax.tree_util.tree_map(lambda l: l[i], tree)
    s = summarize_updates(0, idxs, [rows(stacked, i) for i in idxs],
                          [rows(grads, i) for i in idxs], [1] * len(idxs),
                          cfg, gram_scope=scope)
    _allclose(so["alpha"], s.alpha)
    _allclose(sctx.materialize(so["u_bar"]), tree_to_vector(s.u_bar))


def test_merge_and_cloud_stages_match_fused():
    fctx, sctx, *_ = _round_ctxs(P=9, seed=3)
    cohorts = [[0, 1, 2], [3, 4, 5], [6, 7, 8]]
    fsums = [fctx.gateway(c) for c in cohorts]
    ssums = [sctx.gateway(c) for c in cohorts]
    counts = [3.0, 3.0, 3.0]
    fm = fctx.merge([s["u_bar"] for s in fsums[:2]],
                    [s["ghat"] for s in fsums[:2]], counts[:2])
    sm = sctx.merge([s["u_bar"] for s in ssums[:2]],
                    [s["ghat"] for s in ssums[:2]], counts[:2])
    for k in ("G", "c", "alpha"):
        _allclose(sm[k], fm[k])
    _allclose(sctx.materialize(sm["u_bar"]), fm["u_bar"])
    # cloud combo over [merged, gateway-3]
    ghat_f = fctx.compose_grads([fm["ghat"], fsums[2]["ghat"]], [6.0, 3.0])
    ghat_s = sctx.compose_grads([sm["ghat"], ssums[2]["ghat"]], [6.0, 3.0])
    fd, fi = fctx.cloud_combo([fm["u_bar"], fsums[2]["u_bar"]], [6.0, 3.0],
                              ghat_f)
    sd, si = sctx.cloud_combo([sm["u_bar"], ssums[2]["u_bar"]], [6.0, 3.0],
                              ghat_s)
    _allclose(si["gamma"], fi["gamma"])
    _allclose(si["gram_diag"], fi["gram_diag"])
    _allclose(sctx.materialize(sd), fd)


def test_cloud_raw_and_fedavg_match_fused():
    for mode, kind in (("contextual", "raw"), ("mean", "fedavg")):
        stacked, grads = _stacked(P=7, seed=4)
        cfg = SolveConfig(beta=3.0, ridge=1e-8)
        tmpl = _template(stacked)
        fctx = fused.HierRoundEngine(tmpl, cfg, mode).begin_round(stacked,
                                                                  grads)
        sctx = StreamedRoundEngine(tmpl, cfg, mode).begin_round(stacked,
                                                                grads)
        idxs = [0, 2, 3, 5, 6]
        fd, fi = fctx.cloud_raw(idxs, kind)
        sd, si = sctx.cloud_raw(idxs, kind)
        _allclose(si["gamma"], fi["gamma"])
        _allclose(sctx.materialize(sd), fd)


def test_streamed_apply_matches_dense_apply():
    fctx, sctx, stacked, _, _ = _round_ctxs(P=8, seed=5)
    w = jax.random.normal(jax.random.PRNGKey(9), (8,), jnp.float32)
    tmpl = _template(stacked)
    fres = fctx.apply(tmpl, w @ fctx.D)
    sres = sctx.apply(tmpl, RowMix(w, "delta"))
    jax.tree_util.tree_map(lambda a, b: _allclose(a, b), fres, sres)


def test_sketch_ef_composition_matches_fused():
    """Materialized refs feed the SAME EF/encode pipeline the dense engine
    runs: identical payloads, decodes and residuals at fixed seed."""
    from repro.compress import CompressConfig, ErrorFeedback
    fctx, sctx, *_ = _round_ctxs(P=8, seed=6)
    comp = CompressConfig(scheme="sign_sketch", ratio=4.0).build(fctx.D.shape[1])
    ef_f, ef_s = ErrorFeedback(), ErrorFeedback()
    for rnd in range(3):                 # residuals telescope across rounds
        fo = fctx.gateway([1, 2, 5])
        so = sctx.gateway([1, 2, 5])
        cf, df = ef_f.step(("u", 0), fo["u_bar"], comp, seed=rnd)
        cs, ds = ef_s.step(("u", 0), sctx.materialize(so["u_bar"]), comp,
                           seed=rnd)
        _allclose(cs.data[0], cf.data[0])
        _allclose(ds, df)
        _allclose(ef_s.residual[("u", 0)], ef_f.residual[("u", 0)])
    # decoded (dense) refs re-enter the streamed tiers via the fused
    # stack-stages — mixed-ref merge must still match
    fo2 = fctx.gateway([0, 4])
    so2 = sctx.gateway([0, 4])
    fm = fctx.merge([df, fo2["u_bar"]], [fo2["ghat"], fo2["ghat"]],
                    [3.0, 2.0])
    sm = sctx.merge([ds, so2["u_bar"]], [so2["ghat"], so2["ghat"]],
                    [3.0, 2.0])
    _allclose(sm["alpha"], fm["alpha"])
    _allclose(sctx.materialize(sm["u_bar"]), fm["u_bar"])


# ------------------------------------------------------ e2e + selection

def _run(ds, params, cfg, topo, engine, rounds=4, **kw):
    from repro.fl import run_hier_simulation
    from repro.models.logistic import logistic_apply, logistic_loss
    return run_hier_simulation("t", logistic_loss, logistic_apply, params,
                               ds, cfg, topo, num_rounds=rounds,
                               selection_seed=11, eval_every=rounds,
                               engine=engine, **kw)


def test_e2e_streamed_matches_fused(tiny_edge_problem):
    from repro.compress import CompressConfig
    from repro.edge import bimodal_fleet
    from repro.hier import HierConfig, two_tier_topology
    ds, params, _ = tiny_edge_problem
    fleet = bimodal_fleet(ds.num_devices, slowdown=5.0, dropout_slow=0.1,
                          seed=0)
    topo = two_tier_topology(fleet, 3)
    base = dict(lr=0.2, batch_size=10, min_epochs=1, max_epochs=3)
    for cfg in (HierConfig(aggregator="hier_contextual", **base),
                HierConfig(aggregator="hier_contextual",
                           gateway_grad="global", **base),
                HierConfig(aggregator="hier_contextual_sketch",
                           compress=CompressConfig(scheme="sign_sketch",
                                                   ratio=4.0), **base)):
        rf = _run(ds, params, cfg, topo, "fused")
        rs = _run(ds, params, cfg, topo, "streamed", stream_chunk=37)
        _allclose(rs.train_loss[-1], rf.train_loss[-1])
        assert rs.cloud_uplink_bytes == rf.cloud_uplink_bytes
        assert rs.total_bytes == rf.total_bytes
        assert rf.engine["engine_name"] == "fused"
        assert rs.engine["engine_name"] == "streamed"


def test_engine_auto_selection_budget(tiny_edge_problem, monkeypatch):
    from repro.edge import bimodal_fleet
    from repro.hier import HierConfig, two_tier_topology
    ds, params, _ = tiny_edge_problem
    fleet = bimodal_fleet(ds.num_devices, slowdown=5.0, dropout_slow=0.0,
                          seed=0)
    topo = two_tier_topology(fleet, 3)
    cfg = HierConfig(aggregator="hier_contextual", lr=0.2, batch_size=10,
                     min_epochs=1, max_epochs=2)
    r = _run(ds, params, cfg, topo, "auto", rounds=1)
    assert r.engine["engine_name"] == "fused"     # tiny model under budget
    monkeypatch.setenv("REPRO_DENSE_ROUND_BYTES", "10")
    r2 = _run(ds, params, cfg, topo, "auto", rounds=1)
    assert r2.engine["engine_name"] == "streamed"
    _allclose(r2.train_loss[-1], r.train_loss[-1])
    with pytest.raises(ValueError, match="unknown engine"):
        _run(ds, params, cfg, topo, "bogus", rounds=1)
    # explicit streamed + device-uplink decode rows must fail loudly (auto
    # quietly picks the fused engine instead)
    from repro.compress import CompressConfig
    dcfg = HierConfig(aggregator="hier_contextual_sketch",
                      compress=CompressConfig(scheme="topk", ratio=4.0,
                                              u_frac=0.75,
                                              device_uplink=True),
                      lr=0.2, batch_size=10, min_epochs=1, max_epochs=2)
    with pytest.raises(ValueError, match="device_uplink"):
        _run(ds, params, dcfg, topo, "streamed", rounds=1)
    r3 = _run(ds, params, dcfg, topo, "auto", rounds=1)
    assert r3.engine["engine_name"] == "fused"


def test_mesh_sharded_chunk_axis_single_device(tiny_edge_problem):
    from jax.sharding import Mesh
    from repro.edge import bimodal_fleet
    from repro.hier import HierConfig, two_tier_topology
    ds, params, _ = tiny_edge_problem
    fleet = bimodal_fleet(ds.num_devices, slowdown=5.0, dropout_slow=0.0,
                          seed=0)
    topo = two_tier_topology(fleet, 3)
    cfg = HierConfig(aggregator="hier_contextual", lr=0.2, batch_size=10,
                     min_epochs=1, max_epochs=2)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    r0 = _run(ds, params, cfg, topo, "streamed", rounds=2)
    r1 = _run(ds, params, cfg, topo, "streamed", rounds=2, mesh=mesh)
    _allclose(r1.train_loss[-1], r0.train_loss[-1])


# ------------------------------------------------------------ estimator

def test_peak_bytes_estimator_sanity():
    cfg = SolveConfig(beta=4.0)
    tmpl = {"w": jnp.zeros((1000, 100)), "b": jnp.zeros((100,))}
    n = 1000 * 100 + 100
    P, chunk = 16, 1 << 10
    seng = StreamedRoundEngine(tmpl, cfg, "contextual", chunk=chunk)
    feng = fused.HierRoundEngine(tmpl, cfg, "contextual")
    assert feng.peak_round_bytes(P) == dense_round_bytes(P, n)
    want = 2 * P * chunk * 4 + 2 * P * P * 4
    assert seng.peak_round_bytes(P) == want
    # the acceptance regime: big model, small chunk → way under 25% dense
    assert seng.peak_round_bytes(P) <= 0.25 * feng.peak_round_bytes(P)
    # degenerate: chunk wider than the model clamps to n (never overstates)
    tiny = StreamedRoundEngine(tmpl, cfg, "contextual", chunk=1 << 30)
    assert tiny.peak_round_bytes(P) == 2 * P * n * 4 + 2 * P * P * 4
    # compressed pipelines dense-ify above the encode hop: the estimator
    # must charge the fused-fallback (members, n) stacks
    assert (seng.peak_round_bytes(P, dense_fallback_members=4)
            == want + 2 * 4 * n * 4)
    with pytest.raises(ValueError, match="chunk"):
        StreamedRoundEngine(tmpl, cfg, "contextual", chunk=0)


def test_compressed_run_reports_dense_fallback_peak(tiny_edge_problem):
    from repro.compress import CompressConfig
    from repro.edge import bimodal_fleet
    from repro.hier import HierConfig, two_tier_topology
    ds, params, n_model = tiny_edge_problem
    fleet = bimodal_fleet(ds.num_devices, slowdown=5.0, dropout_slow=0.0,
                          seed=0)
    topo = two_tier_topology(fleet, 3)
    base = dict(lr=0.2, batch_size=10, min_epochs=1, max_epochs=2)
    plain = _run(ds, params, HierConfig(aggregator="hier_contextual",
                                        **base), topo, "streamed", rounds=1)
    comp = _run(ds, params,
                HierConfig(aggregator="hier_contextual_sketch",
                           compress=CompressConfig(scheme="sign_sketch",
                                                   ratio=4.0), **base),
                topo, "streamed", rounds=1)
    # 3 gateways report dense decodes to the cloud: 2 stacks of (3, n) f32
    assert (comp.engine["round_matrix_peak_bytes"]
            == plain.engine["round_matrix_peak_bytes"] + 2 * 3 * n_model * 4)


def test_apply_does_not_donate_by_default():
    """A caller that reuses its params across apply() calls must be safe:
    donation is an explicit engine opt-in (run_hier_simulation sets it and
    copies the caller's params first)."""
    _, sctx, stacked, _, _ = _round_ctxs(P=8, seed=7)
    assert sctx.engine.donate_params is False
    tmpl = _template(stacked)
    w = RowMix(jnp.ones((8,), jnp.float32) / 8, "delta")
    a = sctx.apply(tmpl, w)
    b = sctx.apply(tmpl, w)          # second use of tmpl must not crash
    jax.tree_util.tree_map(lambda x, y: _allclose(x, y), a, b)


def test_autotune_cap_preserves_alignment_residue(monkeypatch):
    """The timing cap must not lie to alignment-based supports() checks:
    the capped spec keeps the true width's residue mod chunk, so e.g. the
    Pallas tile kernel is only eligible when the REAL slab is aligned."""
    monkeypatch.setattr(streamed, "AUTOTUNE_CAP_COLS", 16)
    chunk = 8
    stacked, grads = _stacked(P=4, seed=9, leaves=((37,), (5, 8)))
    seen = []
    orig = streamed.select_impl_for

    def spy(op, *specs, **kw):
        seen.append(specs[0].shape)
        return orig(op, *specs, **kw)

    monkeypatch.setattr(streamed, "select_impl_for", spy)
    eng = StreamedRoundEngine(_template(stacked), SolveConfig(beta=2.0),
                              "contextual", chunk=chunk)
    eng.begin_round(stacked, grads)
    widths = {37: None, 40: None}
    for shape in seen:
        for true_w in widths:
            if shape[1] <= true_w and shape[1] % chunk == true_w % chunk:
                widths[true_w] = shape[1]
    assert all(v is not None for v in widths.values()), (seen, widths)
    from repro.kernels.ops import _stream_pallas_ok

    class Spec:
        def __init__(self, shape):
            self.shape, self.ndim = shape, len(shape)
    # unaligned true width stays ineligible for the padded pallas path
    assert not _stream_pallas_ok(Spec((8, 37)), Spec((8, 37)), block_n=8)
    assert _stream_pallas_ok(Spec((8, 40)), Spec((8, 40)), block_n=8)


def test_streamed_never_builds_dense_round_matrix():
    """The engine's accumulate path must call the stream_stats op on
    leaf-slab shapes, never on a concatenated (P, n) matrix."""
    from repro.kernels import registry
    stacked, grads = _stacked(P=5, seed=8)
    tmpl = _template(stacked)
    seen = []
    orig = streamed.select_impl_for

    def spy(op, *specs, **kw):
        seen.extend(s.shape for s in specs)
        return orig(op, *specs, **kw)

    streamed.select_impl_for = spy
    try:
        StreamedRoundEngine(tmpl, SolveConfig(beta=2.0), "contextual",
                            chunk=8).begin_round(stacked, grads)
    finally:
        streamed.select_impl_for = orig
    n = sum(l.size for l in jax.tree_util.tree_leaves(tmpl))
    assert seen and all(shape[1] < n for shape in seen)
