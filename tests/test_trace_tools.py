"""Trace tooling: Perfetto export, trace_diff triage, summarize_trace CLI.

These tools consume the span-bearing ``.jsonl`` traces (``repro.obs``) —
the Perfetto exporter from the package, the stdlib-only diff/summarize
CLIs from ``benchmarks/``.  Tests synthesize small traces through the real
span API, then check the exported Chrome trace structure, the per-path
diff alignment, and the hard-error contract on missing/empty/truncated
traces.
"""
import json
import os
import sys

import pytest

from repro.obs import JsonlTracker, spans, use_tracker, use_virtual_clock
from repro.obs.perfetto import (VIRTUAL_PID, WALL_PID, export_chrome_trace,
                                main as perfetto_main)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import summarize_trace  # noqa: E402
import trace_diff  # noqa: E402


def _write_trace(path, round_wall=0.0, extra_round=False):
    """One tiny dual-clock trace: a round with a solve child, two flat
    scheduler tasks, a link transfer — the full span menagerie."""
    vt = [0.0]
    with use_tracker(JsonlTracker(str(path))) as tr:
        tr.jot(run="toy")
        with use_virtual_clock(lambda: vt[0]):
            rounds = 2 if extra_round else 1
            for t in range(rounds):
                with spans.span("round", round=t):
                    h = spans.begin("sched/task", device=3)
                    with spans.span("solve", K=4):
                        vt[0] += 5.0
                        if round_wall:
                            import time
                            time.sleep(round_wall)
                    spans.end(h, outcome="arrival")
                    spans.record_span("link/up", t0_virtual=vt[0],
                                      dur_virtual_s=0.5, tier=1,
                                      bytes=256.0)
        tr.log_summary({"_bench_meta": {"benchmark": "toy", "rounds": rounds}})


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------

def test_perfetto_export_dual_track_structure(tmp_path):
    trace = tmp_path / "BENCH_toy.jsonl"
    out = tmp_path / "trace.json"
    _write_trace(trace)
    n = export_chrome_trace(str(trace), str(out))
    assert n == 4                       # round, solve, sched/task, link/up
    payload = json.loads(out.read_text())
    evs = payload["traceEvents"]
    # both clock tracks are named processes
    names = {(e["pid"], e["args"]["name"]) for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert (WALL_PID, "wall clock") in names
    assert any(pid == VIRTUAL_PID for pid, _ in names)
    # nested spans are complete events on both tracks, flat ones async pairs
    X = [e for e in evs if e["ph"] == "X"]
    assert {e["pid"] for e in X} == {WALL_PID, VIRTUAL_PID}
    assert {e["name"] for e in X if e["pid"] == WALL_PID} == \
        {"round", "solve"}
    b, e_ = [e for e in evs if e["ph"] == "b"], \
        [e for e in evs if e["ph"] == "e"]
    assert len(b) == len(e_) and {e["name"] for e in b} == \
        {"sched/task", "link/up"}
    assert {e["id"] for e in b} == {e["id"] for e in e_}
    # wall timestamps are rebased to the trace start; virtual ones are the
    # simulated seconds verbatim (µs)
    assert min(e["ts"] for e in evs if e.get("pid") == WALL_PID
               and e["ph"] == "X") == pytest.approx(0.0, abs=1e-3)
    vround = [e for e in X if e["pid"] == VIRTUAL_PID
              and e["name"] == "round"]
    assert vround[0]["dur"] == pytest.approx(5.0 * 1e6)
    # tags ride in args
    solve = [e for e in X if e["name"] == "solve"][0]
    assert solve["args"]["K"] == 4 and solve["args"]["path"] == "round/solve"


def test_perfetto_cli_error_and_empty_paths(tmp_path, capsys):
    assert perfetto_main([str(tmp_path / "nope.jsonl")]) == 2
    assert "not found" in capsys.readouterr().err
    # a trace with no spans exports fine but warns
    empty = tmp_path / "nospans.jsonl"
    with use_tracker(JsonlTracker(str(empty))) as tr:
        tr.log({"x": 1}, step=0)
    out = tmp_path / "o.json"
    assert perfetto_main([str(empty), "-o", str(out)]) == 0
    assert "no span events" in capsys.readouterr().err
    assert json.loads(out.read_text())["traceEvents"]    # metadata only


# ---------------------------------------------------------------------------
# trace_diff
# ---------------------------------------------------------------------------

def test_trace_diff_aligns_paths_and_reports_deltas(tmp_path, capsys):
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write_trace(a)
    _write_trace(b, round_wall=0.05, extra_round=True)
    assert trace_diff.main([str(a), str(b)]) == 0
    out = capsys.readouterr().out
    assert "`round/solve`" in out and "`round`" in out
    assert "1→2" in out                  # count alignment: one extra round
    assert "total span wall" in out
    # per-path aggregation: the slowed solve dominates the wall delta
    base, new = trace_diff.collect(str(a)), trace_diff.collect(str(b))
    assert new["round/solve"].wall_s - base["round/solve"].wall_s > 0.04
    assert base["round/solve"].count == 1 and new["round/solve"].count == 2
    # flat spans contribute virtual time but never wall time
    assert base["round/sched/task"].wall_s == 0.0
    assert base["round/sched/task"].virtual_s == pytest.approx(5.0)
    assert base["round/link/up"].virtual_s == pytest.approx(0.5)


def test_trace_diff_error_paths(tmp_path, capsys):
    good = tmp_path / "g.jsonl"
    _write_trace(good)
    assert trace_diff.main([str(tmp_path / "nope.jsonl"), str(good)]) == 2
    assert "no such trace" in capsys.readouterr().err
    # spanless traces: nothing to diff, non-zero with a clear line
    nospan = tmp_path / "n.jsonl"
    with use_tracker(JsonlTracker(str(nospan))) as tr:
        tr.log({"x": 1}, step=0)
    assert trace_diff.main([str(nospan), str(nospan)]) == 1
    assert "no spans" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# summarize_trace hard-error contract
# ---------------------------------------------------------------------------

def test_summarize_trace_renders_spans_and_payload(tmp_path, capsys):
    trace = tmp_path / "BENCH_toy.jsonl"
    _write_trace(trace)
    assert summarize_trace.main([str(trace)]) == 0
    out = capsys.readouterr().out
    assert "### toy" in out and "rounds=3" not in out
    assert "Slowest spans" in out and "`round/solve`" in out
    # flat spans stay out of the wall-sorted triage table
    assert "sched/task" not in out.split("Slowest spans")[1]


def test_summarize_trace_fails_on_missing_empty_truncated(tmp_path, capsys):
    missing = str(tmp_path / "nope.jsonl")
    assert summarize_trace.main([missing]) == 1
    assert "no such trace" in capsys.readouterr().err

    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert summarize_trace.main([str(empty)]) == 1
    assert "empty" in capsys.readouterr().err

    good = tmp_path / "good.jsonl"
    _write_trace(good)
    truncated = tmp_path / "trunc.jsonl"
    truncated.write_text(good.read_text()[:80])
    assert summarize_trace.main([str(truncated)]) == 1
    err = capsys.readouterr().err
    assert "truncated or corrupt" in err and "line 1" in err

    # one bad trace fails the whole invocation, good ones still render
    assert summarize_trace.main([str(good), str(truncated)]) == 1
