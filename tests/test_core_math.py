"""Unit + property tests for the contextual aggregation math (paper §III)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import (AggregatorConfig, SolveConfig, aggregate,
                        bound_value, gram_and_cross, gram_and_cross_chunked,
                        gram_residual, solve_alpha, solve_alpha_simple,
                        theorem1_reduction, tree_to_vector, vector_to_tree)

jax.config.update("jax_enable_x64", False)


def _quadratic(key, n):
    """Random β-smooth quadratic f(w) = ½wᵀAw − bᵀw with known β = λmax(A)."""
    k1, k2 = jax.random.split(key)
    A = jax.random.normal(k1, (n, n))
    A = A @ A.T / n + jnp.eye(n)
    b = jax.random.normal(k2, (n,))
    beta = float(jnp.linalg.eigvalsh(A)[-1])
    f = lambda w: 0.5 * w @ A @ w - b @ w
    return f, beta


@pytest.mark.parametrize("K,n", [(4, 64), (10, 200), (16, 300)])
def test_stationarity_paper_eq10(K, n):
    """α* satisfies the paper's optimality identity ⟨Δ_k, ∇f + βΣα_jΔ_j⟩ = 0."""
    key = jax.random.PRNGKey(K * n)
    f, beta = _quadratic(key, n)
    w = jax.random.normal(jax.random.PRNGKey(1), (n,))
    g = jax.grad(f)(w)
    U = -0.05 * (g[None] + 0.5 * jax.random.normal(jax.random.PRNGKey(2), (K, n)))
    G, c = gram_and_cross(U, g)
    alpha = solve_alpha(G, c, SolveConfig(beta=beta, ridge=1e-10))
    res = gram_residual(G, c, alpha, beta)
    assert float(jnp.linalg.norm(res)) < 1e-3 * float(jnp.linalg.norm(c) + 1)


@pytest.mark.parametrize("seed", range(5))
def test_theorem1_definite_loss_reduction(seed):
    """f(w^t) − f(w^{t+1}) ≥ (β/2)‖Σα_kΔ_k‖² on β-smooth quadratics."""
    key = jax.random.PRNGKey(seed)
    f, beta = _quadratic(key, 120)
    w = jax.random.normal(jax.random.fold_in(key, 1), (120,))
    g = jax.grad(f)(w)
    U = -0.03 * (g[None] + jax.random.normal(jax.random.fold_in(key, 2), (8, 120)))
    G, c = gram_and_cross(U, g)
    alpha = solve_alpha(G, c, SolveConfig(beta=beta))
    reduction = f(w) - f(w + U.T @ alpha)
    promised = theorem1_reduction(G, alpha, beta)
    assert reduction >= promised - 1e-4 * abs(promised)
    assert promised > 0


def test_contextual_beats_fedavg_on_bound():
    """α* minimises g(α): no other aggregation (incl. uniform) has a lower
    context-dependent bound."""
    key = jax.random.PRNGKey(7)
    f, beta = _quadratic(key, 150)
    w = jax.random.normal(jax.random.fold_in(key, 1), (150,))
    g = jax.grad(f)(w)
    U = -0.05 * (g[None] + 0.7 * jax.random.normal(jax.random.fold_in(key, 2),
                                                   (10, 150)))
    G, c = gram_and_cross(U, g)
    alpha = solve_alpha(G, c, SolveConfig(beta=beta, ridge=1e-10))
    g_opt = bound_value(G, c, alpha, beta)
    uniform = jnp.full((10,), 0.1)
    assert g_opt <= bound_value(G, c, uniform, beta) + 1e-5
    for s in range(5):
        rand = jax.random.normal(jax.random.PRNGKey(s), (10,)) * 0.2
        assert g_opt <= bound_value(G, c, rand, beta) + 1e-5


def test_projection_interpretation():
    """Σα_kΔ_k = −(1/β)·P_U∇f — the DESIGN.md §2 projected-gradient identity."""
    key = jax.random.PRNGKey(3)
    K, n, beta = 6, 80, 12.0
    U = jax.random.normal(key, (K, n))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    G, c = gram_and_cross(U, g)
    alpha = solve_alpha(G, c, SolveConfig(beta=beta, ridge=1e-12))
    step = U.T @ alpha
    # projector onto rowspace(U)
    P = U.T @ jnp.linalg.solve(U @ U.T, U)
    np.testing.assert_allclose(np.asarray(step), np.asarray(-P @ g / beta),
                               rtol=1e-3, atol=1e-4)


def test_expected_bound_scaling():
    """§III-C variant = contextual scaled by (N−1)/(K−1)."""
    key = jax.random.PRNGKey(11)
    K, n, N = 5, 40, 30
    U = jax.random.normal(key, (K, n))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    G, c = gram_and_cross(U, g)
    base = solve_alpha(G, c, SolveConfig(beta=8.0))
    scaled = solve_alpha(G, c, SolveConfig(beta=8.0,
                                           expectation_scale=(N - 1) / (K - 1)))
    np.testing.assert_allclose(np.asarray(scaled),
                               np.asarray(base) * (N - 1) / (K - 1), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(K=st.integers(2, 12), n=st.integers(16, 96),
       chunk=st.sampled_from([16, 64, 128]), seed=st.integers(0, 2**16))
def test_property_chunked_gram_equals_dense(K, n, chunk, seed):
    """Streaming (chunked) gram == dense gram for any shape/chunking."""
    key = jax.random.PRNGKey(seed)
    U = jax.random.normal(key, (K, n))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    G1, c1 = gram_and_cross(U, g)
    G2, c2 = gram_and_cross_chunked(U, g, chunk=chunk)
    np.testing.assert_allclose(np.asarray(G1), np.asarray(G2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(K=st.integers(2, 10), seed=st.integers(0, 2**16),
       beta=st.floats(0.5, 50.0))
def test_property_solve_minimises_bound(K, seed, beta):
    """g(α*) ≤ g(α* + ε) for random perturbations — true minimiser."""
    key = jax.random.PRNGKey(seed)
    U = jax.random.normal(key, (K, 64))
    g = jax.random.normal(jax.random.fold_in(key, 1), (64,))
    G, c = gram_and_cross(U, g)
    alpha = solve_alpha(G, c, SolveConfig(beta=beta, ridge=1e-9))
    g_star = float(bound_value(G, c, alpha, beta))
    for s in range(4):
        eps = jax.random.normal(jax.random.PRNGKey(s), (K,)) * 0.05
        assert g_star <= float(bound_value(G, c, alpha + eps, beta)) + 1e-4


def test_flatten_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    vec = tree_to_vector(tree)
    back = vector_to_tree(vec, tree)
    assert jax.tree_util.tree_structure(back) == jax.tree_util.tree_structure(tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32))


def test_aggregate_fedavg_equals_mean():
    K = 4
    params = {"w": jnp.zeros((3,))}
    ups = {"w": jnp.arange(12, dtype=jnp.float32).reshape(K, 3)}
    new, info = aggregate("fedavg")(params, ups, None, AggregatorConfig("fedavg"))
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.asarray(ups["w"].mean(0)))


def test_aggregate_contextual_last_layer_scope():
    """Gram scoped to the head, combine applied to the full update."""
    key = jax.random.PRNGKey(0)
    K = 6
    params = {"hidden": {"w": jnp.zeros((8, 8))}, "head": {"w": jnp.zeros((8, 4))}}
    ups = jax.tree_util.tree_map(
        lambda p: jax.random.normal(key, (K,) + p.shape) * 0.1, params)
    grad = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 1), p.shape), params)
    cfg = AggregatorConfig("contextual", solve=SolveConfig(beta=10.0),
                           gram_scope="last_layer")
    new, info = aggregate("contextual")(params, ups, grad, cfg)
    assert info["alpha"].shape == (K,)
    # hidden layer moved too (combine is full-scope)
    assert float(jnp.abs(new["hidden"]["w"]).sum()) > 0
