"""SPMD integration tests — run in a SUBPROCESS with 8 forced host devices
(the main test process must keep the default single device).

Marked ``slow``: each subprocess compiles a full sharded train step on an
emulated pod mesh (~8 min apiece on this CPU container — they dominated the
old ~26-min tier-1 wall-clock).  The default run skips them; CI's full
-coverage leg (and any local ``pytest -m ""``) still runs everything."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_contextual_combine_matches_reference():
    """shard_map gram/solve/combine on a (2,2,2) pod mesh == local math."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.distributed import contextual_combine_sharded
        from repro.core import gram_and_cross, solve_alpha_simple

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        K, n, beta = 4, 64, 8.0
        key = jax.random.PRNGKey(0)
        U = jax.random.normal(key, (K, n), jnp.float32)
        g = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)

        def body(u, gs):
            comb, alpha = contextual_combine_sharded(u[0], gs, beta, 1e-6)
            return comb[None], alpha[None]

        comb, alpha = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("data", "model"), P("model")),
            out_specs=(P("data", "model"), P("data", None))))(U, g)

        G, c = gram_and_cross(U, g)
        alpha_ref = solve_alpha_simple(G, c, beta, 1e-6)
        comb_ref = U.T @ alpha_ref

        ok_alpha = bool(np.allclose(np.asarray(alpha[0]), np.asarray(alpha_ref),
                                    rtol=1e-4, atol=1e-4))
        ok_comb = bool(np.allclose(np.asarray(comb[0]), np.asarray(comb_ref),
                                   rtol=1e-4, atol=1e-4))
        print(json.dumps({"ok_alpha": ok_alpha, "ok_comb": ok_comb}))
    """)
    res = _run_subprocess(code)
    assert res["ok_alpha"] and res["ok_comb"], res


def test_spmd_train_step_contextual_vs_singlehost():
    """The pjit FL train step on a (4,2) mesh computes the same new params
    as an equivalent single-device cohort loop (paper semantics preserved
    under sharding)."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.shapes import InputShape
        from repro.launch.steps import build_train_step
        from repro.models import get_model

        cfg = get_config("qwen3-14b").reduced().with_overrides(
            num_layers=1, d_model=64, d_ff=128, vocab_size=128,
            num_heads=2, num_kv_heads=2, head_dim=32)
        bundle = get_model(cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shape = InputShape("t", "train", 16, 8)
        step = build_train_step(cfg, mesh, shape, aggregator="contextual",
                                lr=0.05, remat=False)
        params = bundle.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128)
        with mesh:
            new_params, metrics = jax.jit(step)(params, {"tokens": tokens})

        # single-host reference: 4 cohorts of batch 2
        C = 4
        loss = lambda p, b: bundle.train_loss(p, b)[0]
        cb = tokens.reshape(C, 2, 16)
        grads = jax.vmap(lambda b: jax.grad(loss)(params, {"tokens": b}))(cb)
        deltas = jax.tree_util.tree_map(lambda g: -0.05 * g, grads)
        flat = [l.reshape(C, -1) for p, l in
                jax.tree_util.tree_flatten_with_path(deltas)[0]
                if "lm_head" in str(p) or "final_norm" in str(p)]
        U = jnp.concatenate(flat, axis=1).astype(jnp.float32)
        gvec = -jnp.mean(U, 0) / 0.05
        from repro.core import solve_alpha_simple
        alpha = solve_alpha_simple(U @ U.T, U @ gvec, 1.0 / 0.05, 1e-6)
        ref = jax.tree_util.tree_map(
            lambda p, u: p + jnp.einsum("k,k...->...", alpha, u), params, deltas)

        errs = [float(np.max(np.abs(np.asarray(a, np.float32) -
                                    np.asarray(b, np.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(new_params),
                                jax.tree_util.tree_leaves(ref))]
        ok_alpha = bool(np.allclose(np.asarray(metrics["alpha"]),
                                    np.asarray(alpha), rtol=1e-3, atol=1e-4))
        print(json.dumps({"max_err": max(errs), "ok_alpha": ok_alpha}))
    """)
    res = _run_subprocess(code)
    assert res["ok_alpha"], res
    assert res["max_err"] < 5e-4, res


def test_dryrun_entrypoint_one_combo():
    """The dry-run CLI itself (512 devices, 16×16 mesh) works end to end."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "rwkv6-1.6b",
         "--shape", "decode_32k", "--mesh", "single"],
        env=env, capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[ok  ] rwkv6-1.6b|decode_32k|single" in out.stdout
