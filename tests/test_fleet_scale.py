"""Fleet-scale tests: vectorized batch dispatch parity with the per-device
scheduler (both RNG streams, churn included), batched comm-ledger
equivalence, array fleets vs object fleets, stacked topologies, virtual
datasets, cohort-vs-event simulation equality, bounded history windows,
and the device-axis shard_map parity (multi-device CPU subprocess)."""
import json
import os
import subprocess
import sys
import textwrap
from collections import deque

import jax
import numpy as np
import pytest

from repro.data import VirtualFleetDataset, eval_device_ids
from repro.edge import (EventScheduler, array_bimodal_fleet,
                        array_longtail_fleet, array_uniform_fleet,
                        bimodal_fleet, fleet_arrays, longtail_fleet,
                        uniform_fleet)
from repro.fl import run_hier_simulation
from repro.fl.simulation import _history_buffer, _history_push
from repro.hier import (CommLedger, HierConfig, StackedTopology,
                        stacked_two_tier, two_tier_topology)
from repro.hier.topology import TopoNode
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss
from repro.robust import ChurnSchedule, ChurnWave
from repro.robust.attacks import ByzantineGauss, assign_adversaries


# ---------------------------------------------------------------------------
# scheduler: batch dispatch vs per-device dispatch
# ---------------------------------------------------------------------------

def _drain(sched):
    while sched.pop() is not None:
        pass


def _trace_pair(fleet, rng_stream, churn=None):
    """Same cohort through dispatch_batch vs N dispatch() calls."""
    ids = np.arange(fleet.num_devices)
    steps = 5 + (ids % 7)
    batch_sched = EventScheduler(fleet, seed=9, flops_per_step=1e7,
                                 payload_bytes=1e5, churn=churn,
                                 rng_stream=rng_stream)
    batch_sched.dispatch_batch(ids, steps, version=0)
    _drain(batch_sched)
    seq_sched = EventScheduler(fleet, seed=9, flops_per_step=1e7,
                               payload_bytes=1e5, churn=churn,
                               rng_stream=rng_stream)
    for d in ids:
        seq_sched.dispatch(int(d), int(steps[d]), version=0)
    _drain(seq_sched)
    return batch_sched.trace_signature(), seq_sched.trace_signature()


@pytest.mark.parametrize("rng_stream", ["v1", "v2"])
@pytest.mark.parametrize("kind", ["uniform", "bimodal"])
def test_batch_dispatch_matches_per_device(rng_stream, kind):
    fleet = (uniform_fleet(64, dropout=0.1, jitter=0.2) if kind == "uniform"
             else bimodal_fleet(64, slowdown=10.0, dropout_slow=0.1, seed=0))
    batch, seq = _trace_pair(fleet, rng_stream)
    assert batch == seq


@pytest.mark.parametrize("rng_stream", ["v1", "v2"])
def test_batch_dispatch_matches_under_churn(rng_stream):
    fleet = bimodal_fleet(64, slowdown=10.0, dropout_slow=0.1, seed=0)
    churn = ChurnSchedule(64, (ChurnWave(0.0, 1e9, 0.3, seed=4),))
    batch, seq = _trace_pair(fleet, rng_stream, churn=churn)
    assert batch == seq
    # the wave actually bites: some device must have dropped
    kinds = {t[2] for t in batch}
    assert 2 in kinds            # EventKind.DROPOUT


def test_cohort_mode_conservation():
    fleet = uniform_fleet(32, dropout=0.2, jitter=0.1)
    sched = EventScheduler(fleet, seed=3, flops_per_step=1e7,
                           payload_bytes=1e5, rng_stream="v2")
    batch = sched.dispatch_batch(np.arange(32), 6, version=0, enqueue=False)
    assert batch.size == 32
    assert sched.conservation_ok()          # in-flight via _batch_inflight
    sched.advance_to(float(batch.t_end.max()))
    sched.complete_batch(batch)
    assert sched.conservation_ok()
    assert sched.stats.arrived + sched.stats.dropped == 32
    with pytest.raises(RuntimeError):
        sched.complete_batch(batch)          # double settle


def test_v2_scalar_dispatch_is_batch_special_case():
    fleet = bimodal_fleet(16, seed=0)
    a = EventScheduler(fleet, seed=5, flops_per_step=1e7, payload_bytes=1e5,
                       rng_stream="v2")
    b = EventScheduler(fleet, seed=5, flops_per_step=1e7, payload_bytes=1e5,
                       rng_stream="v2")
    for d in range(16):
        a.dispatch(d, 4, version=0)
    b.dispatch_batch(np.arange(16), 4, version=0)
    _drain(a), _drain(b)
    assert a.trace_signature() == b.trace_signature()


# ---------------------------------------------------------------------------
# comm ledger: batched record_* equivalence
# ---------------------------------------------------------------------------

def test_ledger_count_batching_matches_loop():
    loop, batched = CommLedger(depth=2), CommLedger(depth=2)
    for _ in range(37):
        loop.record_down(0, 1234.0, seconds=0.5)
        loop.record_up(1, 99.0, seconds=0.25)
    batched.record_down(0, 1234.0, seconds=0.5, count=37)
    batched.record_up(1, 99.0, seconds=0.25, count=37)
    batched.record_up(1, 5.0, count=0)       # no-op
    assert loop.report() == batched.report()


# ---------------------------------------------------------------------------
# array fleets / stacked topology / virtual dataset
# ---------------------------------------------------------------------------

def test_array_fleets_match_object_fleets():
    pairs = [
        (uniform_fleet(48, dropout=0.1, jitter=0.2),
         array_uniform_fleet(48, dropout=0.1, jitter=0.2)),
        (bimodal_fleet(48, slowdown=10.0, dropout_slow=0.05, seed=3),
         array_bimodal_fleet(48, slowdown=10.0, dropout_slow=0.05, seed=3)),
        (longtail_fleet(48, seed=3), array_longtail_fleet(48, seed=3)),
    ]
    for obj, arr in pairs:
        oa, aa = fleet_arrays(obj), fleet_arrays(arr)
        for a, b in zip(oa, aa):
            np.testing.assert_array_equal(a, b)
        assert arr[5].flops == obj[5].flops    # per-device profile view


def test_stacked_topology_validation():
    fleet = array_uniform_fleet(16)
    topo = stacked_two_tier(fleet, 4)
    assert isinstance(topo, StackedTopology)
    assert topo.num_devices == 16 and topo.depth == 2
    assert len(topo.gateways) == 4
    assert sum(len(g.children) for g in topo.gateways) == 16
    # a gateway that misses a device must be rejected
    nodes = {}
    truncated = False
    for nid, n in topo.nodes.items():
        if n.tier == 1 and not truncated:
            nodes[nid] = TopoNode(n.node_id, n.tier, n.parent,
                                  np.asarray(n.children[:-1], np.int32),
                                  n.uplink)
            truncated = True
        else:
            nodes[nid] = n
    with pytest.raises(ValueError):
        StackedTopology(topo.name, fleet, nodes, topo.cloud_id)


def test_virtual_dataset_shards_and_eval_ids():
    ds = VirtualFleetDataset(num_devices=32, samples_per_device=8, dim=6,
                             num_classes=3, seed=7)
    ids = np.array([0, 5, 31])
    x, y, m = ds.materialize_arrays(ids)
    assert x.shape == (3, 8, 6) and y.shape == (3, 8)
    # jit-boundary shard == materialized shard, bit for bit
    x5, y5, _ = jax.vmap(ds.shard_fn())(np.array([5]))
    np.testing.assert_array_equal(np.asarray(x5[0]), x[1])
    np.testing.assert_array_equal(np.asarray(y5[0]), y[1])
    # held-out test ids never overlap training ids
    fed = ds.materialize()
    assert fed.x.shape == (32, 8, 6)
    assert ds.test_set()[0].shape[0] == ds.test_devices * 8
    # strided eval subsample: full coverage under the cap, capped above
    np.testing.assert_array_equal(eval_device_ids(10, 64), np.arange(10))
    sub = eval_device_ids(1000, 64)
    assert sub.size <= 64 and sub[0] == 0 and np.all(np.diff(sub) > 0)


def test_churn_offline_mask_matches_scalar():
    sched = ChurnSchedule(100, (ChurnWave(1.0, 2.0, 0.4, seed=2),
                                ChurnWave(1.5, 3.0, 0.3, seed=3)))
    ids = np.arange(100)
    for t in (0.5, 1.2, 1.7, 2.5, 3.5):
        mask = sched.offline_mask(ids, np.full(100, t))
        scalar = np.array([sched.offline(int(d), t) for d in ids])
        np.testing.assert_array_equal(mask, scalar)


# ---------------------------------------------------------------------------
# bounded history windows
# ---------------------------------------------------------------------------

def test_history_buffer_window():
    full = _history_buffer(True)
    assert isinstance(full, list)
    for i in range(10):
        _history_push(full, i, True)
    assert full == list(range(10))

    window = _history_buffer(3)
    assert isinstance(window, deque) and window.maxlen == 3
    for i in range(10):
        _history_push(window, i, 3)
    assert list(window) == [7, 8, 9]

    off = _history_buffer(False)
    _history_push(off, 1, False)
    assert list(off) == []


# ---------------------------------------------------------------------------
# end-to-end: cohort mode vs event mode, virtual vs materialized
# ---------------------------------------------------------------------------

def _hier_kw(rounds=3):
    return dict(num_rounds=rounds, selection_seed=42, eval_every=1,
                rng_stream="v2")


def _cfg(**kw):
    base = dict(aggregator="hier_contextual", lr=0.1, mu=0.0, batch_size=8,
                min_epochs=1, max_epochs=2)
    base.update(kw)
    return HierConfig(**base)


def _params(dim=10, classes=3):
    return get_model(ArchConfig(name="lr", family="logreg", input_dim=dim,
                                num_classes=classes)
                     ).init(jax.random.PRNGKey(0))


def _run_pair(attack=None, frac=0.0, churn=None):
    ds = VirtualFleetDataset(num_devices=64, samples_per_device=16, dim=10,
                             num_classes=3, seed=3)
    params = _params()
    obj_fleet = bimodal_fleet(64, slowdown=10.0, dropout_slow=0.05, seed=0)
    arr_fleet = array_bimodal_fleet(64, slowdown=10.0, dropout_slow=0.05,
                                    seed=0)
    if frac:
        obj_fleet = assign_adversaries(obj_fleet, frac, seed=5)
        arr_fleet = assign_adversaries(arr_fleet, frac, seed=5)
    kw = _hier_kw()
    ev = run_hier_simulation(
        "ev", logistic_loss, logistic_apply, params, ds.materialize(),
        _cfg(), two_tier_topology(obj_fleet, 4), scheduler_mode="event",
        attack=attack, churn=churn, **kw)
    co = run_hier_simulation(
        "co", logistic_loss, logistic_apply, params, ds,
        _cfg(), stacked_two_tier(arr_fleet, 4), scheduler_mode="cohort",
        attack=attack, churn=churn, **kw)
    return ev, co


def _assert_equivalent(ev, co, tol=1e-5):
    assert co.times == ev.times                  # virtual clock, exactly
    assert co.cloud_uplink_bytes == ev.cloud_uplink_bytes
    assert co.total_bytes == ev.total_bytes
    assert (co.arrived, co.dropped) == (ev.arrived, ev.dropped)
    assert max(abs(a - b) for a, b in
               zip(ev.train_loss, co.train_loss)) < tol
    assert max(abs(a - b) for a, b in zip(ev.test_acc, co.test_acc)) <= tol


def test_cohort_mode_matches_event_mode():
    _assert_equivalent(*_run_pair())


def test_cohort_mode_matches_under_attack_and_churn():
    churn = ChurnSchedule(64, (ChurnWave(0.0, 1e9, 0.2, seed=4),))
    ev, co = _run_pair(attack=ByzantineGauss(scale=10.0), frac=0.25,
                       churn=churn)
    _assert_equivalent(ev, co)
    assert ev.dropped > 0                        # the wave actually bit


def test_cohort_mode_rejects_device_uplink_compression():
    from repro.compress import CompressConfig
    ds = VirtualFleetDataset(num_devices=16, samples_per_device=16, dim=10,
                             num_classes=3, seed=3)
    topo = stacked_two_tier(array_uniform_fleet(16), 4)
    cfg = _cfg(aggregator="hier_contextual_sketch",
               compress=CompressConfig(scheme="signsketch", ratio=4,
                                       device_uplink=True))
    with pytest.raises(ValueError):
        run_hier_simulation("c", logistic_loss, logistic_apply, _params(),
                            ds, cfg, topo, scheduler_mode="cohort",
                            **_hier_kw(rounds=1))


def test_virtual_dataset_rejects_data_poisoning():
    from repro.robust.attacks import LabelFlip
    ds = VirtualFleetDataset(num_devices=16, samples_per_device=16, dim=10,
                             num_classes=3, seed=3)
    fleet = assign_adversaries(array_uniform_fleet(16), 0.25, seed=1)
    with pytest.raises(ValueError):
        run_hier_simulation("p", logistic_loss, logistic_apply, _params(),
                            ds, _cfg(), stacked_two_tier(fleet, 4),
                            attack=LabelFlip(), **_hier_kw(rounds=1))


def test_cohort_chunking_matches_unchunked():
    ds = VirtualFleetDataset(num_devices=48, samples_per_device=16, dim=10,
                             num_classes=3, seed=3)
    params = _params()
    topo = stacked_two_tier(array_uniform_fleet(48), 4)
    a = run_hier_simulation("a", logistic_loss, logistic_apply, params, ds,
                            _cfg(), topo, scheduler_mode="cohort",
                            **_hier_kw())
    b = run_hier_simulation("b", logistic_loss, logistic_apply, params, ds,
                            _cfg(), topo, scheduler_mode="cohort",
                            cohort_chunk=16, **_hier_kw())
    assert a.times == b.times
    assert max(abs(x - y) for x, y in
               zip(a.train_loss, b.train_loss)) < 1e-5


# ---------------------------------------------------------------------------
# device-axis sharding (multi-device CPU subprocess)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.data.fleetgen import VirtualFleetDataset
    from repro.fl.simulation import _batched_virtual_update_fn
    from repro.models import get_model
    from repro.models.config import ArchConfig
    from repro.models.logistic import logistic_loss
    from repro.sharding.specs import fleet_mesh, stream_round_shardings

    assert jax.device_count() == 8
    ds = VirtualFleetDataset(num_devices=64, samples_per_device=16, dim=8,
                             num_classes=3, seed=3)
    params = get_model(ArchConfig(name="lr", family="logreg", input_dim=8,
                                  num_classes=3)).init(jax.random.PRNGKey(0))
    mesh = fleet_mesh()
    B = 20                                   # 20 % 8 != 0: exercises padding
    ids = jnp.arange(B)
    ns = jnp.full((B,), 4, jnp.int32)
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(7), i)
                    )(jnp.arange(B, dtype=jnp.uint32))
    plain = _batched_virtual_update_fn(logistic_loss, 4, 8, 0.1, 0.0, ds)
    shard = _batched_virtual_update_fn(logistic_loss, 4, 8, 0.1, 0.0, ds,
                                       mesh)
    o1, o2 = plain(params, ids, ns, keys), shard(params, ids, ns, keys)
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree_util.tree_leaves(o1),
                   jax.tree_util.tree_leaves(o2)))
    sh = stream_round_shardings(mesh, {"m": jnp.zeros((16, 32)),
                                       "v": jnp.zeros((16,))})
    print(json.dumps({"diff": diff,
                      "m_spec": str(sh["m"].spec),
                      "v_spec": str(sh["v"].spec)}))
""")


def test_fleet_axis_shard_map_parity():
    # JAX_PLATFORMS=cpu pinned explicitly: a parent jax import exports
    # TPU_LIBRARY_PATH into os.environ, and a child that merely unsets
    # JAX_PLATFORMS hangs probing the TPU plugin on TPU-less hosts
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["diff"] < 1e-5
    assert result["m_spec"] == "PartitionSpec('fleet', None)"
    assert result["v_spec"] == "PartitionSpec('fleet',)"


def test_stream_round_shardings_backcompat_without_fleet_axis():
    from jax.sharding import Mesh
    from repro.sharding.specs import (stream_column_shardings,
                                      stream_round_shardings)
    import jax.numpy as jnp
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    stacked = {"m": jnp.zeros((4, 8)), "v": jnp.zeros((4,))}
    a = stream_column_shardings(mesh, stacked)
    b = stream_round_shardings(mesh, stacked)
    assert {k: s.spec for k, s in a.items()} == \
        {k: s.spec for k, s in b.items()}
