"""Streaming telemetry: tracker protocol, jsonl stream, sim instrumentation.

Covers the observability layer end to end: unit behavior of the tracker
implementations (scoping, fan-out, per-scope monotone steps, jsonl round
trip), the bench trace → BENCH JSON derivation, and the live events the
three simulation loops emit — including ordering under the async/hier
virtual clock and the guarantee that instrumentation never perturbs
results.
"""
import io
import json
import sys

import jax
import numpy as np
import pytest

from repro.edge import AsyncConfig, bimodal_fleet
from repro.fl import (ServerConfig, run_async_simulation, run_hier_simulation,
                      run_simulation)
from repro.hier import HierConfig, two_tier_topology
from repro.models.logistic import logistic_apply, logistic_loss
from repro.obs import (NOOP, CompositeTracker, InMemoryTracker, JsonlTracker,
                       NoopTracker, current_tracker, iter_trace, read_trace,
                       spans, use_tracker, use_virtual_clock)
from repro.obs.spans import span_fields, span_tags

import repro.edge.async_server  # noqa: F401  (registers async aggregators)
import repro.hier.hier_server  # noqa: F401  (registers hier aggregators)


# ---------------------------------------------------------------------------
# tracker protocol units
# ---------------------------------------------------------------------------

def test_default_tracker_is_inactive_noop():
    assert current_tracker() is NOOP
    assert not NOOP.active
    assert NOOP.scope("a").scope("b") is NOOP      # no per-scope allocation
    NOOP.log({"x": 1}, step=3)                      # all swallowed
    NOOP.log_summary({"x": 1})
    NOOP.jot(run="r")


def test_use_tracker_stacks_and_restores():
    t1, t2 = InMemoryTracker(), InMemoryTracker()
    with use_tracker(t1):
        assert current_tracker() is t1
        with use_tracker(t2):
            assert current_tracker() is t2
        assert current_tracker() is t1
        current_tracker().log({"a": 1})
    assert current_tracker() is NOOP
    assert t1.series("a") == [1]
    assert t2.events == []


def test_scope_prefixes_keys_and_threads_scope_path():
    tr = InMemoryTracker()
    tr.scope("hier").scope("gw3").log({"bytes": 7}, step=2)
    (e,) = tr.events
    assert e.metrics == {"hier/gw3/bytes": 7}
    assert e.scope == "hier/gw3"
    assert e.step == 2 and e.kind == "metrics" and e.t_wall > 0
    scoped = tr.scope("x")
    assert scoped.active == tr.active


def test_composite_fans_out_every_event():
    a, b = InMemoryTracker(), InMemoryTracker()
    comp = CompositeTracker([a, b])
    assert comp.active
    comp.scope("s").log({"v": 1}, step=0)
    comp.log_summary({"done": True})
    comp.jot(name="run")
    for t in (a, b):
        assert [e.kind for e in t.events] == ["metrics", "summary", "tags"]
        assert t.series("s/v") == [1]
    assert not CompositeTracker([NoopTracker()]).active


# ---------------------------------------------------------------------------
# jsonl stream
# ---------------------------------------------------------------------------

def test_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    with use_tracker(JsonlTracker(path)) as tr:
        tr.scope("run").log({"loss": 0.5, "vec": np.arange(3)}, step=0)
        tr.scope("run").log({"loss": np.float32(0.25)}, step=1)
        tr.log_summary({"final": 0.25})
    events = read_trace(path)
    assert [e.kind for e in events] == ["metrics", "metrics", "summary"]
    assert events[0].metrics == {"run/loss": 0.5, "run/vec": [0, 1, 2]}
    assert events[1].metrics["run/loss"] == pytest.approx(0.25)
    assert events[0].scope == "run" and events[2].scope == ""
    # stepless events inherit their own scope's latest step (root is at 0)
    assert [e.step for e in events] == [0, 1, 0]
    assert read_trace(path, kind="summary")[0].metrics == {"final": 0.25}
    # every line is valid json with the stream fields (tailable live)
    for line in open(path):
        obj = json.loads(line)
        assert set(obj) == {"step", "t_wall", "kind", "scope", "metrics"}


def test_jsonl_step_monotone_per_scope():
    tr = JsonlTracker(io.StringIO())
    a, b = tr.scope("runA"), tr.scope("runB")
    a.log({"x": 1}, step=5)
    b.log({"x": 1}, step=0)         # independent scope restarts at 0: fine
    a.log({"x": 1}, step=5)         # repeat is fine
    with pytest.raises(ValueError, match="non-monotonic step"):
        a.log({"x": 1}, step=4)
    b.log({"x": 1}, step=1)         # runB unaffected by runA's violation


def test_jsonl_rejects_unserializable():
    tr = JsonlTracker(io.StringIO())
    with pytest.raises(TypeError, match="not JSON-serializable"):
        tr.log({"fn": lambda: None})


def test_jsonl_flush_every_batches_and_finish_flushes(tmp_path):
    path = str(tmp_path / "batched.jsonl")
    tr = JsonlTracker(path, flush_every=100)
    for i in range(3):
        tr.log({"x": i}, step=i)
    # nothing reached disk yet: flushes are batched
    assert open(path).read() == ""
    tr.finish()
    assert [e.metrics["x"] for e in read_trace(path)] == [0, 1, 2]
    with pytest.raises(ValueError, match="flush_every"):
        JsonlTracker(str(tmp_path / "bad.jsonl"), flush_every=0)


def test_use_tracker_finishes_jsonl_when_body_raises(tmp_path):
    path = str(tmp_path / "crash.jsonl")
    with pytest.raises(RuntimeError, match="boom"):
        with use_tracker(JsonlTracker(path, flush_every=1000)) as tr:
            tr.log({"x": 1}, step=0)
            raise RuntimeError("boom")
    # finish() ran on the way out: the pending tail reached disk
    assert [e.metrics["x"] for e in read_trace(path)] == [1]


def test_iter_trace_is_lazy_read_trace_materializes(tmp_path):
    path = str(tmp_path / "lazy.jsonl")
    with use_tracker(JsonlTracker(path)) as tr:
        tr.log({"x": 1}, step=0)
        tr.log_summary({"done": True})
    it = iter_trace(path)
    assert iter(it) is it                       # generator, not a list
    assert next(it).metrics == {"x": 1}
    assert [e.kind for e in read_trace(path)] == ["metrics", "summary"]
    assert len(read_trace(path, kind="summary")) == 1


# ---------------------------------------------------------------------------
# spans: dual-clock intervals through the tracker protocol
# ---------------------------------------------------------------------------

def test_span_nesting_paths_and_dual_clock():
    mem = InMemoryTracker()
    vt = [10.0]
    with use_tracker(mem, finish=False), use_virtual_clock(lambda: vt[0]):
        with spans.span("round", round=3):
            with spans.span("solve", K=4) as h:
                h.tags["extra"] = "yes"
                vt[0] = 12.5
    fields = [span_fields(e) for e in mem.span_events()]
    assert [f["path"] for f in fields] == ["round/solve", "round"]
    solve, rnd = fields
    assert solve["depth"] == 1 and rnd["depth"] == 0
    assert rnd["t0_virtual"] == 10.0
    assert rnd["dur_virtual_s"] == pytest.approx(2.5)
    assert solve["dur_wall_s"] >= 0
    assert span_tags(solve) == {"K": 4, "extra": "yes"}
    assert span_tags(rnd) == {"round": 3}


def test_span_error_path_closes_and_restores_depth():
    mem = InMemoryTracker()
    with use_tracker(mem, finish=False):
        with pytest.raises(RuntimeError):
            with spans.span("outer"):
                with spans.span("inner"):
                    raise RuntimeError("bang")
        # depth restored: a fresh span is top-level again
        with spans.span("after"):
            pass
    fields = [span_fields(e) for e in mem.span_events()]
    assert [f["path"] for f in fields] == ["outer/inner", "outer", "after"]
    assert fields[0]["error"] == "RuntimeError"
    assert fields[1]["error"] == "RuntimeError"
    assert "error" not in fields[2]
    assert fields[2]["depth"] == 0


def test_flat_spans_do_not_corrupt_nesting():
    mem = InMemoryTracker()
    with use_tracker(mem, finish=False):
        with spans.span("round"):
            h1 = spans.begin("task", t_virtual=1.0, device=7)
            h2 = spans.begin("task", t_virtual=2.0, device=8)
            with spans.span("solve"):       # nests under round, not task
                pass
            spans.end(h2, t_virtual=6.0, outcome="arrival")
            spans.end(h1, t_virtual=9.0, outcome="dropout")
    fields = [span_fields(e) for e in mem.span_events()]
    by_path = [f["path"] for f in fields]
    assert by_path == ["round/solve", "round/task", "round/task", "round"]
    tasks = [f for f in fields if f["name"] == "task"]
    assert all(f["flat"] for f in tasks)
    assert {f["outcome"] for f in tasks} == {"arrival", "dropout"}
    assert sorted(f["dur_virtual_s"] for f in tasks) == [4.0, 8.0]


def test_spans_are_free_on_the_noop_path():
    assert current_tracker() is NOOP
    with spans.span("x") as h:
        assert h is None
    assert spans.begin("y") is None
    spans.end(None, outcome="ignored")          # no-op, no error
    spans.record_span("z", t0_virtual=0.0, dur_virtual_s=1.0)
    assert spans.current_path() == ""


def test_record_span_emits_known_virtual_interval():
    mem = InMemoryTracker()
    with use_tracker(mem, finish=False):
        spans.record_span("link/up", t0_virtual=5.0, dur_virtual_s=0.25,
                          tier=2, bytes=1024.0)
    (f,) = [span_fields(e) for e in mem.span_events()]
    assert f["t0_virtual"] == 5.0 and f["dur_virtual_s"] == 0.25
    assert f["dur_wall_s"] == 0.0 and f["flat"]
    assert span_tags(f) == {"tier": 2, "bytes": 1024.0}


def test_span_reserved_keys_match_stdlib_mirror():
    sys.path.insert(0, "benchmarks")
    try:
        import bench_trace
    finally:
        sys.path.pop(0)
    assert tuple(bench_trace.SPAN_RESERVED) == tuple(spans.RESERVED_KEYS)


# ---------------------------------------------------------------------------
# bench trace → BENCH_*.json derivation
# ---------------------------------------------------------------------------

def test_publish_bench_derives_identical_json(tmp_path):
    sys.path.insert(0, "benchmarks")
    try:
        from bench_trace import derive_bench_json
        from common import publish_bench
    finally:
        sys.path.pop(0)
    results = {"benchmark": "toy", "rounds": 3,
               "records": [{"method": "a", "final_loss": 0.5},
                           {"method": "b", "final_loss": 0.25}],
               "acceptance": {"meets_target": True},
               "autotune": [{"op": "gram"}, {"op": "colsum"}]}
    path = str(tmp_path / "BENCH_toy.jsonl")
    with use_tracker(JsonlTracker(path)) as tr:
        # live telemetry interleaves with the published results
        tr.scope("sim").log({"train_loss": 1.0}, step=0)
        publish_bench(results)
    assert derive_bench_json(path) == results


# ---------------------------------------------------------------------------
# simulation instrumentation (shared tiny problem from conftest)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny(tiny_edge_problem):
    ds, params, _ = tiny_edge_problem
    return ds, params


def _sync(ds, params, **kw):
    cfg = ServerConfig(aggregator="contextual", num_devices=ds.num_devices,
                       clients_per_round=6, lr=0.2, batch_size=10,
                       min_epochs=1, max_epochs=4)
    base = dict(num_rounds=4, selection_seed=11, eval_every=2,
                collect_alpha=True)
    base.update(kw)
    return run_simulation("t", logistic_loss, logistic_apply, params, ds,
                          cfg, **base)


def _hier(ds, params, **kw):
    fleet = bimodal_fleet(ds.num_devices, slowdown=4.0, dropout_slow=0.2,
                          seed=0)
    topo = two_tier_topology(fleet, 3)
    cfg = HierConfig(aggregator="hier_contextual", lr=0.2, batch_size=10,
                     min_epochs=1, max_epochs=4)
    base = dict(num_rounds=4, selection_seed=11, eval_every=2)
    base.update(kw)
    return run_hier_simulation("t", logistic_loss, logistic_apply, params,
                               ds, cfg, topo, **base)


def test_sync_sim_streams_rounds_and_summary(tiny):
    ds, params = tiny
    mem = InMemoryTracker()
    with use_tracker(mem):
        r = _sync(ds, params)
    rounds = [e for e in mem.metrics_events() if "sync/t/round" in e.metrics]
    assert [e.metrics["sync/t/round"] for e in rounds] == [0, 1, 2, 3]
    assert [e.step for e in rounds] == [0, 1, 2, 3]
    assert mem.series("sync/t/alpha_mean")      # α stage weights streamed
    losses = mem.series("sync/t/train_loss")
    assert losses == pytest.approx(r.train_loss)
    (summary,) = [e for e in mem.events if e.kind == "summary"]
    assert summary.metrics["sync/t/final_train_loss"] == \
        pytest.approx(r.train_loss[-1])
    tags = [e for e in mem.events if e.kind == "tags"]
    assert tags and tags[0].metrics["sync/t/runtime"] == "sync"


def test_async_sim_event_order_under_virtual_clock(tiny):
    ds, params = tiny
    cfg = AsyncConfig(aggregator="contextual_async",
                      num_devices=ds.num_devices, buffer_size=3, lr=0.2,
                      batch_size=10, min_epochs=1, max_epochs=4)
    fleet = bimodal_fleet(ds.num_devices, slowdown=8.0, dropout_slow=0.2,
                          seed=0)
    mem = InMemoryTracker()
    with use_tracker(mem):
        r = run_async_simulation("t", logistic_loss, logistic_apply, params,
                                 ds, cfg, fleet, num_aggregations=6,
                                 selection_seed=11, eval_every=2)
    flushes = [e for e in mem.metrics_events()
               if "async/t/flush" in e.metrics]
    assert [e.metrics["async/t/flush"] for e in flushes] == [1, 2, 3, 4, 5, 6]
    tv = [e.metrics["async/t/t_virtual"] for e in flushes]
    assert all(b >= a for a, b in zip(tv, tv[1:]))   # virtual clock monotone
    assert all(e.metrics["async/t/staleness_mean"] >= 0 for e in flushes)
    (summary,) = [e for e in mem.events if e.kind == "summary"]
    assert summary.metrics["async/t/dispatched"] == r.dispatched
    assert summary.metrics["async/t/t_virtual_end"] >= tv[-1]


def test_hier_sim_streams_comm_ledger_and_engine(tiny):
    ds, params = tiny
    mem = InMemoryTracker()
    with use_tracker(mem):
        r = _hier(ds, params)
    rounds = [e for e in mem.metrics_events() if "hier/t/round" in e.metrics]
    assert [e.metrics["hier/t/round"] for e in rounds] == [0, 1, 2, 3]
    tv = [e.metrics["hier/t/t_virtual"] for e in rounds]
    assert all(b >= a for a, b in zip(tv, tv[1:]))
    # CommLedger transfers streamed as recorded, virtual-clock stamped and
    # ordered within the round structure
    comm = [e for e in mem.metrics_events()
            if "hier/t/comm/bytes" in e.metrics]
    assert comm
    ctv = [e.metrics["hier/t/comm/t_virtual"] for e in comm]
    assert all(b >= a for a, b in zip(ctv, ctv[1:]))
    assert sum(e.metrics["hier/t/comm/bytes"] for e in comm) == \
        pytest.approx(r.total_bytes)
    assert {e.metrics["hier/t/comm/tier"] for e in comm} <= {0, 1, 2}
    # fused engine stage builds announced on cache miss
    (summary,) = [e for e in mem.events if e.kind == "summary"]
    assert summary.metrics["hier/t/engine_name"] == "fused"
    assert summary.metrics["hier/t/cloud_uplink_bytes"] == \
        pytest.approx(r.cloud_uplink_bytes)


def test_hier_sim_emits_nested_and_flat_spans(tiny):
    ds, params = tiny
    mem = InMemoryTracker()
    with use_tracker(mem):
        r = _hier(ds, params)
    fields = [span_fields(e) for e in mem.span_events()]
    paths = {f["path"] for f in fields}
    # the whole round path shows up, nested
    assert {"round", "round/client_update", "round/begin_round",
            "round/event_loop"} <= paths
    assert any(p.startswith("round/event_loop/gateway") for p in paths)
    assert any(p.startswith("round/event_loop/cloud") for p in paths)
    # round spans carry both clocks; virtual duration matches the scheduler
    rounds = [f for f in fields if f["path"] == "round"]
    assert len(rounds) == 4
    assert [f["round"] for f in rounds] == [0, 1, 2, 3]
    assert all(f["dur_virtual_s"] > 0 and f["dur_wall_s"] > 0
               for f in rounds)
    assert sum(f["dur_virtual_s"] for f in rounds) == \
        pytest.approx(r.times[-1])
    # engine stages trace under their tier node (the compile-vs-steady
    # naming itself is unit-tested below — this process's stage cache may
    # already be warm from earlier tests)
    names = {f["name"] for f in fields}
    assert any(n.startswith("stage_") for n in names)
    # scheduler task lifetimes: flat, virtual-stamped, outcome-tagged
    tasks = [f for f in fields if f["name"] == "sched/task"]
    assert len(tasks) == r.dispatched
    assert all(f["flat"] and f["t0_virtual"] >= 0 for f in tasks)
    outcomes = {f["outcome"] for f in tasks}
    assert outcomes <= {"arrival", "dropout"} and "arrival" in outcomes
    # link transfers land as virtual-time spans with byte tags
    links = [f for f in fields if f["name"].startswith("link/")]
    assert links and all(f["dur_virtual_s"] > 0 and f["dur_wall_s"] == 0.0
                         for f in links)
    assert {f["name"] for f in links} == {"link/up", "link/down"}


def test_async_sim_emits_spans_under_virtual_clock(tiny):
    ds, params = tiny
    cfg = AsyncConfig(aggregator="contextual_async",
                      num_devices=ds.num_devices, buffer_size=3, lr=0.2,
                      batch_size=10, min_epochs=1, max_epochs=4)
    fleet = bimodal_fleet(ds.num_devices, slowdown=8.0, dropout_slow=0.2,
                          seed=0)
    mem = InMemoryTracker()
    with use_tracker(mem):
        run_async_simulation("t", logistic_loss, logistic_apply, params,
                             ds, cfg, fleet, num_aggregations=4,
                             selection_seed=11, eval_every=2)
    fields = [span_fields(e) for e in mem.span_events()]
    aggs = [f for f in fields if f["name"] == "aggregate"]
    assert [f["flush"] for f in aggs] == [1, 2, 3, 4]
    tv = [f["t0_virtual"] for f in aggs]
    assert all(b >= a for a, b in zip(tv, tv[1:]))
    assert all(f["name"] in ("client_update", "aggregate", "eval",
                             "sched/task") for f in fields)
    assert any(f["name"] == "client_update" and "staleness" in f
               for f in fields)


def test_traced_stage_splits_compile_from_steady_state():
    from repro.hier.fused import _traced_stage
    calls = []
    stage = _traced_stage("summary", K=4, n=100, backend="xla",
                          stage=lambda v: calls.append(v) or v * 2)
    mem = InMemoryTracker()
    with use_tracker(mem, finish=False):
        assert stage(1) == 2 and stage(2) == 4 and stage(3) == 6
    names = [span_fields(e)["name"] for e in mem.span_events()]
    assert names == ["stage_summary_compile", "stage_summary",
                     "stage_summary"]
    assert calls == [1, 2, 3]
    # an untracked first call still consumes the compile slot silently
    stage2 = _traced_stage("cloud", K=2, n=10, backend="xla",
                           stage=lambda v: v)
    stage2(0)                                   # no tracker: no span, no cost
    with use_tracker(mem, finish=False):
        stage2(0)
    assert span_fields(mem.span_events()[-1])["name"] == "stage_cloud"


def test_instrumentation_does_not_perturb_results(tiny):
    """Same seeds with and without a live tracker → identical trajectories
    (the telemetry layer only observes)."""
    ds, params = tiny
    r_silent = _hier(ds, params)
    with use_tracker(InMemoryTracker()):
        r_traced = _hier(ds, params)
    assert r_traced.train_loss == r_silent.train_loss
    assert r_traced.times == r_silent.times
    assert r_traced.total_bytes == r_silent.total_bytes


def test_record_history_caps_alpha_history(tiny):
    ds, params = tiny
    full = _sync(ds, params)
    assert len(full.alpha_history) == 4            # True: unbounded (default)
    capped = _sync(ds, params, record_history=2)
    assert len(capped.alpha_history) == 2          # rolling last-2 window
    np.testing.assert_allclose(capped.alpha_history[-1],
                               full.alpha_history[-1])
    off = _sync(ds, params, record_history=False)
    assert off.alpha_history == []
    assert off.train_loss == full.train_loss       # knob only affects history


def test_record_history_caps_gamma_history(tiny):
    ds, params = tiny
    capped = _hier(ds, params, collect_gamma=True, record_history=1)
    assert len(capped.gamma_history) == 1
    off = _hier(ds, params, collect_gamma=True, record_history=0)
    assert off.gamma_history == []
