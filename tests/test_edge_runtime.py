"""Tests for the async edge runtime (repro.edge): scheduler determinism,
update conservation under dropout, staleness-weight bounds, aggregator
equivalences, and the async simulation entry point."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AggregatorConfig, SolveConfig, aggregate
from repro.data.federated import FederatedDataset
from repro.edge import (AsyncConfig, EventKind, EventScheduler, bimodal_fleet,
                        get_fleet, longtail_fleet, staleness_weight,
                        uniform_fleet)
from repro.edge.wallclock import (model_flops_per_step, model_payload_bytes,
                                  sync_round_durations)
from repro.fl import ServerConfig, run_async_simulation
from repro.fl.server import sample_round
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

import repro.edge.async_server  # noqa: F401  (registers async aggregators)


# ---------------------------------------------------------------------------
# fleets
# ---------------------------------------------------------------------------

def test_fleet_builders():
    for fleet in (uniform_fleet(12), bimodal_fleet(12, seed=3),
                  longtail_fleet(12, seed=3)):
        assert fleet.num_devices == 12
        for p in fleet:
            assert p.flops > 0 and 0.0 <= p.dropout < 1.0
            assert p.task_time(1e9, 1e6) > 0
        assert "N=12" in fleet.describe()
    assert get_fleet("bimodal", 8, seed=1).num_devices == 8
    with pytest.raises(KeyError):
        get_fleet("nope", 8)
    with pytest.raises(ValueError):
        uniform_fleet(4, dropout=1.0)   # would never complete a task


# ---------------------------------------------------------------------------
# event scheduler
# ---------------------------------------------------------------------------

def _drive(seed: int, num_events: int = 200, dropout: float = 0.3):
    fleet = uniform_fleet(10, dropout=dropout, jitter=0.2)
    sched = EventScheduler(fleet, seed=seed, flops_per_step=1e7,
                           payload_bytes=1e5)
    for dev in range(fleet.num_devices):
        sched.dispatch(dev, num_steps=10 + dev, version=0)
    arrivals = []
    for i in range(num_events):
        evt = sched.pop()
        assert evt is not None
        if evt.kind == EventKind.ARRIVAL:
            arrivals.append(evt.seq)
        sched.dispatch(evt.device_id, num_steps=10 + (i % 7), version=i)
    return sched, arrivals


def test_scheduler_determinism_under_fixed_seed():
    s1, a1 = _drive(seed=7)
    s2, a2 = _drive(seed=7)
    assert s1.trace_signature() == s2.trace_signature()
    assert a1 == a2
    s3, _ = _drive(seed=8)
    assert s1.trace_signature() != s3.trace_signature()


def test_scheduler_clock_is_monotone():
    sched, _ = _drive(seed=1)
    times = [e.time for e in sched.trace]
    assert all(b >= a for a, b in zip(times, times[1:]))
    assert sched.now > 0.0


def test_no_lost_or_duplicated_updates_under_dropout():
    sched, arrivals = _drive(seed=3, dropout=0.4)
    # conservation: every dispatch is in-flight xor terminal
    assert sched.conservation_ok()
    assert sched.stats.dropped > 0 and sched.stats.arrived > 0
    # no duplicated arrivals: each task id (seq) arrives at most once
    assert len(arrivals) == len(set(arrivals))
    # every terminal event's seq matches exactly one dispatch in the trace
    dispatched = {e.seq for e in sched.trace if e.kind == EventKind.DISPATCH}
    terminal = [e.seq for e in sched.trace if e.kind != EventKind.DISPATCH]
    assert len(terminal) == len(set(terminal))
    assert set(terminal) <= dispatched


# ---------------------------------------------------------------------------
# staleness weights
# ---------------------------------------------------------------------------

def test_staleness_weights_in_unit_interval_and_monotone():
    taus = np.arange(0, 50)
    for mode in ("poly", "exp", "const"):
        for decay in (0.1, 0.5, 2.0):
            w = np.array([staleness_weight(t, mode, decay) for t in taus])
            assert np.all(w > 0.0) and np.all(w <= 1.0)
            assert np.all(np.diff(w) <= 1e-12)           # non-increasing
            assert w[0] == pytest.approx(1.0)
    with pytest.raises(KeyError):
        staleness_weight(1.0, "bogus")


# ---------------------------------------------------------------------------
# async aggregators
# ---------------------------------------------------------------------------

def _toy_updates(key, K=6, dim=40):
    k1, k2, k3 = jax.random.split(key, 3)
    stacked = {"w": jax.random.normal(k1, (K, dim, 3)) * 0.1,
               "b": jax.random.normal(k2, (K, 3)) * 0.1}
    grad = {"w": jax.random.normal(k3, (dim, 3)) * 0.1,
            "b": jnp.zeros((3,))}
    params = {"w": jnp.zeros((dim, 3)), "b": jnp.zeros((3,))}
    return params, stacked, grad


def test_contextual_async_with_unit_staleness_equals_contextual():
    params, stacked, grad = _toy_updates(jax.random.PRNGKey(0))
    cfg = AggregatorConfig(name="x", solve=SolveConfig(beta=5.0))
    new_a, info_a = aggregate("contextual_async")(params, stacked, grad, cfg)
    new_c, info_c = aggregate("contextual")(params, stacked, grad, cfg)
    np.testing.assert_allclose(np.asarray(new_a["w"]), np.asarray(new_c["w"]),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(info_a["alpha"]),
                               np.asarray(info_c["alpha"]), rtol=1e-5,
                               atol=1e-7)


def test_contextual_async_staleness_damps_stale_updates():
    params, stacked, grad = _toy_updates(jax.random.PRNGKey(1))
    s = jnp.array([1.0, 1.0, 1.0, 0.01, 0.01, 0.01])
    base = AggregatorConfig(name="x", solve=SolveConfig(beta=5.0))
    _, info_fresh = aggregate("contextual_async")(params, stacked, grad, base)
    from dataclasses import replace
    _, info_stale = aggregate("contextual_async")(
        params, stacked, grad, replace(base, staleness=s))
    a_fresh = np.abs(np.asarray(info_fresh["alpha"]))
    a_stale = np.abs(np.asarray(info_stale["alpha"]))
    # heavily-discounted updates lose nearly all their weight vs the
    # staleness-free solve; fresh updates keep comparable magnitude
    assert np.all(a_stale[3:] < 0.1 * a_fresh[3:] + 1e-6)
    assert a_stale[:3].mean() > 0.2 * a_fresh[:3].mean()


def test_fedbuff_is_staleness_weighted_mean():
    params, stacked, grad = _toy_updates(jax.random.PRNGKey(2))
    s = jnp.array([1.0, 0.5, 0.25, 1.0, 0.5, 0.25])
    cfg = AggregatorConfig(name="x", solve=SolveConfig(beta=5.0), staleness=s)
    new, info = aggregate("fedbuff")(params, stacked, grad, cfg)
    expect = np.einsum("k,kij->ij", np.asarray(s) / 6.0,
                       np.asarray(stacked["w"]))
    np.testing.assert_allclose(np.asarray(new["w"]), expect, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(info["staleness_weight"]),
                               np.asarray(s))


def test_expected_variant_with_pool_K_equals_contextual():
    """(N−1)/(K−1) = 1 when the pool is the round itself — the expected-bound
    solve must coincide with the contextual one (also exercises the
    dataclasses.replace propagation of every solve field)."""
    params, stacked, grad = _toy_updates(jax.random.PRNGKey(3))
    cfg = AggregatorConfig(name="x", solve=SolveConfig(beta=5.0, ridge=1e-5),
                           staleness=None)
    new_e, _ = aggregate("contextual_expected")(params, stacked, grad, cfg,
                                                pool_size=6)
    new_c, _ = aggregate("contextual")(params, stacked, grad, cfg)
    np.testing.assert_allclose(np.asarray(new_e["w"]), np.asarray(new_c["w"]),
                               rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# sample_round validation (satellite)
# ---------------------------------------------------------------------------

def test_sample_round_rejects_oversized_cohorts():
    rng = np.random.RandomState(0)
    with pytest.raises(ValueError, match="clients_per_round"):
        sample_round(rng, ServerConfig(num_devices=5, clients_per_round=6), 4)
    with pytest.raises(ValueError, match="grad_sample"):
        sample_round(rng, ServerConfig(num_devices=5, clients_per_round=3,
                                       grad_sample=9), 4)


def test_sample_round_gradient_sample_has_no_duplicates():
    rng = np.random.RandomState(0)
    cfg = ServerConfig(num_devices=8, clients_per_round=4, grad_sample=8)
    for _ in range(10):
        _, grad_sel, _ = sample_round(rng, cfg, 4)
        assert len(set(grad_sel.tolist())) == len(grad_sel) == 8


# ---------------------------------------------------------------------------
# async simulation end to end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_problem():
    from repro.data import make_synthetic
    dim, n_dev = 20, 10
    xs, ys = make_synthetic(1.0, 1.0, num_devices=n_dev, samples_per_device=30,
                            dim=dim, seed=5)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, dim)[:150], ys.reshape(-1)[:150], 10)
    model = get_model(ArchConfig(name="lr", family="logreg", input_dim=dim,
                                 num_classes=10))
    return ds, model.init(jax.random.PRNGKey(0))


def _async(ds, params, seed=11, **kw):
    base = dict(aggregator="contextual_async", num_devices=ds.num_devices,
                buffer_size=3, lr=0.2, batch_size=10, min_epochs=1,
                max_epochs=4)
    base.update(kw)
    fleet = bimodal_fleet(ds.num_devices, slowdown=8.0, dropout_slow=0.2,
                          seed=0)
    return run_async_simulation("async", logistic_loss, logistic_apply,
                                params, ds, AsyncConfig(**base), fleet,
                                num_aggregations=8, selection_seed=seed,
                                eval_every=2)


def test_async_simulation_runs_and_is_deterministic(tiny_problem):
    ds, params = tiny_problem
    r1 = _async(ds, params)
    r2 = _async(ds, params)
    assert r1.times == r2.times
    assert r1.train_loss == r2.train_loss
    assert np.isfinite(r1.train_loss).all()
    assert all(b >= a for a, b in zip(r1.times, r1.times[1:]))
    # conservation surfaced in the result: nothing lost besides dropouts
    assert r1.arrived + r1.dropped <= r1.dispatched
    assert r1.arrived >= 8 * 3          # at least buffer_size per aggregation
    assert r1.versions[-1] == 8


def test_async_simulation_learns(tiny_problem):
    ds, params = tiny_problem
    r = _async(ds, params, seed=13)
    assert r.train_loss[-1] < r.train_loss[0]


def test_concurrency_cap_rotates_across_whole_fleet(tiny_problem):
    """A concurrency cap limits in-flight tasks, not which devices may ever
    participate: the FIFO idle queue must rotate work across the fleet."""
    ds, params = tiny_problem
    r = _async(ds, params, concurrency=3)
    assert r.updates_per_device.sum() == r.arrived
    assert (r.updates_per_device > 0).sum() >= ds.num_devices - 2


def test_async_fedbuff_baseline_runs(tiny_problem):
    ds, params = tiny_problem
    r = _async(ds, params, aggregator="fedbuff", server_lr=0.5)
    assert np.isfinite(r.train_loss).all()


def test_async_config_validation(tiny_problem):
    with pytest.raises(ValueError, match="fedasync"):
        AsyncConfig(aggregator="fedasync", buffer_size=4)
    with pytest.raises(ValueError, match="concurrency"):
        AsyncConfig(concurrency=0)
    ds, params = tiny_problem
    with pytest.raises(ValueError, match="fleet"):
        run_async_simulation("x", logistic_loss, logistic_apply, params, ds,
                             AsyncConfig(num_devices=ds.num_devices),
                             uniform_fleet(3), num_aggregations=1)


# ---------------------------------------------------------------------------
# wallclock conversion
# ---------------------------------------------------------------------------

def test_sync_round_durations_deterministic_and_straggler_gated(tiny_problem):
    ds, params = tiny_problem
    cfg = ServerConfig(num_devices=10, clients_per_round=4, batch_size=10,
                       min_epochs=1, max_epochs=4)
    fast = uniform_fleet(10, jitter=0.0)
    slow = bimodal_fleet(10, slow_frac=0.5, slowdown=50.0, jitter=0.0, seed=0)
    fps = model_flops_per_step(params, cfg.batch_size)
    pb = model_payload_bytes(params)
    d1 = sync_round_durations(fast, cfg, 3, 12, fps, pb, selection_seed=9)
    d2 = sync_round_durations(fast, cfg, 3, 12, fps, pb, selection_seed=9)
    np.testing.assert_array_equal(d1, d2)
    d3 = sync_round_durations(slow, cfg, 3, 12, fps, pb, selection_seed=9)
    # a 50× straggler cohort must dominate the round time
    assert np.median(d3) > 2.0 * np.median(d1)
