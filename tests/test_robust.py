"""Tests for the adversarial & churn robustness suite (repro.robust):
robust (G, c) statistics and their breakdown properties, attack models and
stacked corruption, adversary placement / label poisoning, churn schedules
layered on the event scheduler, the flat robust aggregators, and the
end-to-end bounded-loss-inflation / determinism contracts across engines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (AggregatorConfig, aggregate,
                                    available_aggregators)
from repro.core.solve import SolveConfig
from repro.edge import uniform_fleet
from repro.edge.events import EventScheduler
from repro.fl import run_hier_simulation, run_simulation
from repro.fl.server import ServerConfig
from repro.hier import HierConfig, star_topology, two_tier_topology
from repro.models.logistic import logistic_apply, logistic_loss
from repro.robust import (ByzantineGauss, ChurnSchedule, ChurnWave,
                          LabelFlip, RobustConfig, assign_adversaries,
                          available_attacks, churn_schedule, clip_scales,
                          corrupt_stacked, get_attack, poison_labels,
                          pool_cross, robustify)


# ---------------------------------------------------------------------------
# robust (G, c) statistics
# ---------------------------------------------------------------------------

def test_robust_config_validation():
    with pytest.raises(ValueError, match="pool"):
        RobustConfig(pool="bogus")
    with pytest.raises(ValueError, match="clip"):
        RobustConfig(clip=0.0)
    with pytest.raises(ValueError, match="trim_frac"):
        RobustConfig(trim_frac=0.5)
    with pytest.raises(ValueError, match="mom_buckets"):
        RobustConfig(mom_buckets=-1)
    assert RobustConfig(clip=2.0, pool="mom").enabled
    assert RobustConfig(clip=None, pool="trimmed").enabled
    assert not RobustConfig(clip=None, pool="mean").enabled


def test_robustify_identity_when_disabled():
    """Breakdown-point anchor: defenses off → exact identity on (G, c)."""
    key = jax.random.PRNGKey(0)
    U = jax.random.normal(key, (6, 40))
    Gm = jax.random.normal(jax.random.fold_in(key, 1), (6, 40))
    G, C = U @ U.T, U @ Gm.T
    w = jnp.full((6,), 1.0 / 6)
    off = RobustConfig(clip=None, pool="mean")
    Gr, cr, s = robustify(G, C, w, off)
    np.testing.assert_array_equal(np.asarray(Gr), np.asarray(G))
    np.testing.assert_array_equal(np.asarray(cr), np.asarray(C @ w))
    np.testing.assert_array_equal(np.asarray(s), np.ones(6))
    # premixed c vector (gradient pre-pass shape): clip-only path, same deal
    Gr2, cr2, s2 = robustify(G, C @ w, w, off)
    np.testing.assert_array_equal(np.asarray(cr2), np.asarray(C @ w))


def test_clip_scales_damp_oversized_rows():
    U = jnp.concatenate([jnp.ones((6, 10)),            # honest: norm sqrt(10)
                         10.0 * jnp.ones((2, 10))])    # 10x rows
    G = U @ U.T
    s = np.asarray(clip_scales(G, RobustConfig(clip=2.0)))
    np.testing.assert_allclose(s[:6], 1.0, atol=1e-6)
    np.testing.assert_allclose(s[6:], 0.2, atol=1e-3)  # 2*median/10x
    ones = clip_scales(G, RobustConfig(clip=None, pool="mom"))
    np.testing.assert_array_equal(np.asarray(ones), np.ones(8))


@pytest.mark.parametrize("pool", ["mom", "trimmed"])
def test_pool_cross_resists_poisoned_columns(pool):
    """f = 2/9 poisoned gradient columns: the plain mean is dragged far off,
    the robust pools stay at the honest value (breakdown property)."""
    K, J = 5, 9
    C = jnp.ones((K, J)) * 3.0
    C = C.at[:, 2].set(1e4).at[:, 6].set(4e3)         # poisoned columns
    w = jnp.full((J,), 1.0 / J)
    cfg = RobustConfig(clip=None, pool=pool)
    est = np.asarray(pool_cross(C, w, cfg))
    np.testing.assert_allclose(est, 3.0, atol=1e-3)
    mean = np.asarray(C @ w)
    assert np.all(np.abs(mean - 3.0) > 100.0)


def test_pool_cross_small_j_falls_back_to_mean():
    C = jnp.asarray([[1.0, 5.0]])
    w = jnp.asarray([0.5, 0.5])
    out = pool_cross(C, w, RobustConfig(pool="mom"))
    np.testing.assert_allclose(np.asarray(out), [3.0])
    # degenerate trim (would leave no columns) falls back too
    out2 = pool_cross(jnp.ones((2, 3)), jnp.full((3,), 1 / 3),
                      RobustConfig(pool="trimmed", trim_frac=0.4))
    np.testing.assert_allclose(np.asarray(out2), 1.0)


# ---------------------------------------------------------------------------
# flat robust aggregators
# ---------------------------------------------------------------------------

def _agg_problem(key, K=8, n=30, poisoned=()):
    U = jax.random.normal(key, (K, n)) * 0.1
    Gm = jax.random.normal(jax.random.fold_in(key, 1), (K, n)) * 0.1
    for i in poisoned:
        U = U.at[i].set(jax.random.normal(jax.random.fold_in(key, 10 + i),
                                          (n,)) * 2.0)
    params = {"w": jnp.zeros((n,))}
    return params, {"w": U}, {"w": Gm}, U


def test_robust_aggregators_registered():
    names = available_aggregators()
    for n in ("contextual_clipped", "contextual_mom", "krum",
              "coordinate_median"):
        assert n in names


def test_krum_zeroes_outlier_updates():
    params, stacked, grads, U = _agg_problem(jax.random.PRNGKey(3),
                                             poisoned=(0, 5))
    cfg = AggregatorConfig(name="krum", solve=SolveConfig(beta=5.0),
                           robust=RobustConfig(krum_f=2))
    _, info = aggregate("krum")(params, stacked, grads, cfg)
    alpha = np.asarray(info["alpha"])
    assert alpha[0] == 0.0 and alpha[5] == 0.0
    np.testing.assert_allclose(alpha.sum(), 1.0, rtol=1e-6)


def test_coordinate_median_matches_numpy():
    params, stacked, grads, U = _agg_problem(jax.random.PRNGKey(4))
    cfg = AggregatorConfig(name="coordinate_median",
                           solve=SolveConfig(beta=5.0))
    new, _ = aggregate("coordinate_median")(params, stacked, grads, cfg)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.median(np.asarray(U), axis=0),
                               rtol=1e-5, atol=1e-6)


def test_contextual_mom_reports_clip_scales():
    params, stacked, grads, _ = _agg_problem(jax.random.PRNGKey(5),
                                             poisoned=(1,))
    cfg = AggregatorConfig(name="contextual_mom", solve=SolveConfig(beta=5.0),
                           robust=RobustConfig(clip=2.0, pool="mom"))
    _, info = aggregate("contextual_mom")(params, stacked, grads, cfg)
    s = np.asarray(info["clip_scale"])
    assert s[1] < 0.5 and np.all(s <= 1.0 + 1e-6)
    assert aggregate("contextual_mom").grad_stack is True


# ---------------------------------------------------------------------------
# attack models & stacked corruption
# ---------------------------------------------------------------------------

def test_attack_registry():
    assert available_attacks() == ("byzantine_gauss", "label_flip",
                                   "scaled_update", "sign_flip")
    with pytest.raises(KeyError, match="unknown attack"):
        get_attack("bogus")
    assert get_attack("byzantine_gauss", scale=3.0).scale == 3.0
    # label_flip is data poisoning: the update path is the identity
    lf = LabelFlip()
    d, g = {"w": jnp.ones(3)}, {"w": jnp.ones(3)}
    d2, g2 = lf.corrupt(d, g, jax.random.PRNGKey(0))
    assert d2 is d and g2 is g


def test_corrupt_stacked_honest_rows_bit_identical():
    key = jax.random.PRNGKey(7)
    deltas = {"w": jax.random.normal(key, (6, 12))}
    grads = {"w": jax.random.normal(jax.random.fold_in(key, 1), (6, 12))}
    mask = jnp.asarray([False, True, False, False, True, False])
    for name in ("byzantine_gauss", "sign_flip", "scaled_update"):
        atk = get_attack(name)
        cd, cg = corrupt_stacked(atk, deltas, grads, mask,
                                 jax.random.PRNGKey(9))
        for orig, new in ((deltas, cd), (grads, cg)):
            o, nw = np.asarray(orig["w"]), np.asarray(new["w"])
            np.testing.assert_array_equal(nw[~np.asarray(mask)],
                                          o[~np.asarray(mask)])
        assert not np.allclose(np.asarray(cd["w"])[1],
                               np.asarray(deltas["w"])[1])
    # scaled_update leaves the gradient report honest even on attacked rows
    cd, cg = corrupt_stacked(get_attack("scaled_update", factor=5.0),
                             deltas, grads, mask, jax.random.PRNGKey(9))
    np.testing.assert_array_equal(np.asarray(cg["w"]),
                                  np.asarray(grads["w"]))
    np.testing.assert_allclose(np.asarray(cd["w"])[1],
                               5.0 * np.asarray(deltas["w"])[1], rtol=1e-5)


def test_assign_adversaries_and_poison_labels():
    fleet = uniform_fleet(20)
    f1 = assign_adversaries(fleet, 0.25, seed=5)
    f2 = assign_adversaries(fleet, 0.25, seed=5)
    assert f1.malicious == f2.malicious and len(f1.malicious) == 5
    assert f1.malicious != assign_adversaries(fleet, 0.25, seed=6).malicious
    assert assign_adversaries(fleet, 0.0).malicious == ()
    with pytest.raises(ValueError, match="fraction"):
        assign_adversaries(fleet, 1.0)
    assert f1.is_malicious(f1.malicious[0])
    with pytest.raises(ValueError, match="malicious"):
        dataclasses.replace(fleet, malicious=(99,))

    y = np.random.RandomState(0).randint(0, 10, size=(20, 6))
    ds = type("D", (), {})()
    from repro.data.federated import FederatedDataset
    ds = FederatedDataset(np.zeros((20, 6, 3), np.float32), y,
                          np.ones((20, 6), np.float32),
                          np.zeros((4, 3), np.float32),
                          np.arange(4) % 10, 10)
    pd = poison_labels(ds, f1.malicious)
    mal = np.asarray(f1.malicious)
    np.testing.assert_array_equal(pd.y[mal], 9 - y[mal])
    hon = np.setdiff1d(np.arange(20), mal)
    np.testing.assert_array_equal(pd.y[hon], y[hon])
    np.testing.assert_array_equal(pd.test_y, ds.test_y)   # test set clean
    assert poison_labels(ds, ()) is ds


# ---------------------------------------------------------------------------
# churn schedules on the event scheduler
# ---------------------------------------------------------------------------

def test_churn_wave_validation_and_membership():
    with pytest.raises(ValueError, match="fraction"):
        ChurnWave(0.0, 1.0, 1.5)
    with pytest.raises(ValueError, match="end"):
        ChurnWave(2.0, 1.0, 0.5)
    w = ChurnWave(10.0, 20.0, 0.5, seed=3)
    assert w.active(10.0) and w.active(19.9)
    assert not w.active(9.9) and not w.active(20.0)
    sched = ChurnSchedule(10, (w,))
    members = sched.members(0)
    assert len(members) == 5
    assert sched.members(0) == ChurnSchedule(10, (w,)).members(0)
    for d in range(10):
        assert sched.offline(d, 15.0) == (d in members)
        assert not sched.offline(d, 25.0)                 # rejoined


def test_churn_schedule_profiles():
    for profile, frac in (("wave", 0.5), ("blackout", 0.9)):
        sched = churn_schedule(profile, 20, 100.0, seed=1)
        mid = sum(1 for t in np.linspace(0, 100, 201)
                  if any(wv.active(t) for wv in sched.waves))
        assert mid > 0
        assert len(sched.members(0)) == int(round(frac * 20))
    none = churn_schedule("none", 20, 100.0)
    assert none.waves == ()
    rolling = churn_schedule("rolling", 20, 100.0, seed=2)
    assert len(rolling.waves) == 2
    with pytest.raises(KeyError, match="churn profile"):
        churn_schedule("bogus", 20, 100.0)


def test_scheduler_churn_preserves_rng_stream():
    """An empty schedule leaves the event trace bit-identical to churn=None
    (the override only ever flips an outcome); an active wave forces
    dropouts inside its window but leaves every dispatch *before* the wave
    untouched, and the churned trace itself is deterministic."""
    fleet = uniform_fleet(8, dropout=0.1)
    kw = dict(flops_per_step=1e6, payload_bytes=1e4)

    def trace(churn):
        sch = EventScheduler(fleet, seed=3, churn=churn, **kw)
        for t in range(6):
            for d in range(8):
                sch.dispatch(d, 5, version=t, at=float(t) * 10.0)
            while sch.pop() is not None:
                pass
        return sch.trace_signature()

    base = trace(None)
    assert trace(churn_schedule("none", 8, 60.0)) == base
    # blackout window is [12, 21): dispatches at t=0 and t=10 (seqs 0..15)
    # consume RNG before any churn-affected dispatch — identical outcomes
    black = churn_schedule("blackout", 8, 60.0, seed=1)
    churned = trace(black)
    assert churned != base
    assert churned == trace(black)                    # deterministic
    pre = [e for e in base if e[1] < 16]
    pre_c = [e for e in churned if e[1] < 16]
    assert pre == pre_c
    # inside the window the wave's members all drop
    members = black.members(0)
    in_window = [e for e in churned
                 if e[2] == 0 and 12.0 <= e[0] < 21.0 and e[3] in members]
    assert in_window, "expected dispatches inside the blackout window"
    terminal = {e[1]: e[2] for e in churned if e[2] != 0}
    assert all(terminal[e[1]] == 2 for e in in_window)  # all DROPOUT


# ---------------------------------------------------------------------------
# end-to-end: bounded loss inflation & determinism
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def robust_problem(tiny_edge_problem):
    ds, params, _ = tiny_edge_problem
    fleet = assign_adversaries(uniform_fleet(12), 0.17, seed=3)
    return ds, params, fleet


def _flat(ds, params, fleet, agg, attack=None, robust=None, rounds=8):
    cfg = ServerConfig(aggregator=agg, num_devices=12, clients_per_round=8,
                       lr=0.2, batch_size=10, max_epochs=4, attack=attack,
                       malicious=fleet.malicious if attack else (),
                       robust=robust)
    r = run_simulation(agg, logistic_loss, logistic_apply, params, ds, cfg,
                       num_rounds=rounds, eval_every=rounds)
    return r.train_loss[-1]


def test_flat_robust_matches_plain_when_disabled(robust_problem):
    """f = 0 anchor: with defenses off the robust aggregator reproduces the
    plain contextual trajectory (same math, different accumulation order)."""
    ds, params, fleet = robust_problem
    off = RobustConfig(clip=None, pool="mean")
    plain = _flat(ds, params, fleet, "contextual", rounds=4)
    rob = _flat(ds, params, fleet, "contextual_mom", robust=off, rounds=4)
    np.testing.assert_allclose(rob, plain, rtol=1e-4)


def test_flat_bounded_inflation_under_byzantine(robust_problem):
    """Breakdown property end to end at f <= 20%: the robust contextual
    solve's loss inflation stays bounded while FedAvg degrades markedly."""
    ds, params, fleet = robust_problem
    atk = ByzantineGauss(scale=10.0)
    rob = RobustConfig(clip=2.0, pool="mom")
    mom_clean = _flat(ds, params, fleet, "contextual_mom", robust=rob)
    mom_atk = _flat(ds, params, fleet, "contextual_mom", atk, robust=rob)
    fa_clean = _flat(ds, params, fleet, "fedavg")
    fa_atk = _flat(ds, params, fleet, "fedavg", atk)
    assert np.isfinite(mom_atk)
    assert mom_atk <= 1.45 * mom_clean          # bounded inflation
    assert fa_atk >= 1.8 * fa_clean             # undefended: marked damage
    # non-contextual robust baselines also survive the same attack
    for agg in ("krum", "coordinate_median"):
        assert _flat(ds, params, fleet, agg, atk, rounds=4) < fa_atk


def test_flat_label_flip_poisons_dataset_only(robust_problem):
    ds, params, fleet = robust_problem
    atk = get_attack("label_flip")
    loss = _flat(ds, params, fleet, "contextual_mom", atk,
                 robust=RobustConfig(clip=2.0, pool="mom"), rounds=3)
    assert np.isfinite(loss)


def _hier(ds, params, fleet, topo, engine, attack=None, churn=None,
          robust=None, rounds=4, seed=11):
    cfg = HierConfig(aggregator="hier_contextual", lr=0.2, batch_size=10,
                     min_epochs=1, max_epochs=4, robust=robust)
    return run_hier_simulation(f"rob-{engine}", logistic_loss, logistic_apply,
                               params, ds, cfg, topo, num_rounds=rounds,
                               selection_seed=seed, eval_every=2,
                               engine=engine, attack=attack, churn=churn)


def test_hier_robust_engine_parity_under_attack(robust_problem):
    """Fused and streamed engines run the SAME robust tier math: identical
    event traces and near-identical losses under attack + churn."""
    ds, params, fleet = robust_problem
    atk = ByzantineGauss(scale=10.0)
    churn = churn_schedule("wave", 12, 40.0, seed=1)
    rob = RobustConfig(clip=2.0, pool="mom")
    topo = star_topology(fleet)
    rf = _hier(ds, params, fleet, topo, "fused", atk, churn, rob)
    rs = _hier(ds, params, fleet, topo, "streamed", atk, churn, rob)
    assert rf.times == rs.times
    np.testing.assert_allclose(rf.train_loss, rs.train_loss,
                               rtol=5e-4, atol=5e-4)
    assert np.isfinite(rf.train_loss).all()


def test_hier_two_tier_robust_runs(robust_problem):
    ds, params, fleet = robust_problem
    atk = ByzantineGauss(scale=10.0)
    topo = two_tier_topology(fleet, 3)
    r = _hier(ds, params, fleet, topo, "fused", atk,
              robust=RobustConfig(clip=2.0, pool="mom"), rounds=3)
    assert np.isfinite(r.train_loss).all()


def test_hier_config_robust_validation():
    rob = RobustConfig(clip=2.0, pool="mom")
    with pytest.raises(TypeError, match="RobustConfig"):
        HierConfig(robust="clip")
    with pytest.raises(ValueError, match="hier_contextual"):
        HierConfig(aggregator="hier_fedavg", robust=rob)
    with pytest.raises(ValueError, match="gateway_grad"):
        HierConfig(gateway_grad="global", robust=rob)
    assert HierConfig(robust=rob).robust is rob


@pytest.mark.parametrize("engine", ["fused", "streamed"])
def test_seeded_determinism_attack_churn(robust_problem, engine):
    """Satellite: identical (fleet, attack, churn schedule, seed) reproduces
    byte-identical event traces and final losses across two runs, on both
    engines."""
    ds, params, fleet = robust_problem
    atk = ByzantineGauss(scale=10.0)
    churn = churn_schedule("rolling", 12, 40.0, seed=2)
    rob = RobustConfig(clip=2.0, pool="mom")
    topo = star_topology(fleet)
    r1 = _hier(ds, params, fleet, topo, engine, atk, churn, rob, rounds=3)
    r2 = _hier(ds, params, fleet, topo, engine, atk, churn, rob, rounds=3)
    assert r1.times == r2.times                       # byte-identical events
    assert r1.train_loss == r2.train_loss             # bitwise-equal losses
    assert (r1.dispatched, r1.arrived, r1.dropped) == \
        (r2.dispatched, r2.arrived, r2.dropped)


def test_attack_does_not_perturb_honest_rng(robust_problem):
    """The adversary key derives by fold_in, so the clean and attacked runs
    differ ONLY through the corrupted rows: with zero malicious devices an
    attack config is inert and bit-identical to the clean run."""
    ds, params, fleet = robust_problem
    clean_fleet = assign_adversaries(uniform_fleet(12), 0.0)
    atk = ByzantineGauss(scale=10.0)
    a = _flat(ds, params, clean_fleet, "contextual", rounds=3)
    b = _flat(ds, params, clean_fleet, "contextual", atk, rounds=3)
    assert a == b
