"""Async vs sync under increasing straggler severity (repro.edge workload).

Sweeps the bimodal fleet's slowdown factor (how much slower the phone cohort
is than the gateways) and reports virtual wall-clock to reach a target test
accuracy for: sync FedAvg, sync contextual, async FedBuff, and async
staleness-aware contextual.  The interesting trend: sync degrades linearly
with the slowdown (the straggler gates every round) while async degrades
only with the *average* device speed.

Emits ``name,us_per_call,derived`` rows like every other benchmark module;
``collect()`` returns a JSON-ready dict for ``run.py --json``.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from repro.edge import AsyncConfig, bimodal_fleet
from repro.edge.wallclock import (model_flops_per_step, model_payload_bytes,
                                  sync_wallclock_curve)
from repro.fl import ServerConfig, run_async_simulation, run_simulation
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

from .common import dataset, emit

TARGET_ACC = 0.5
SLOWDOWNS = (1.0, 4.0, 16.0)
SEED = 42


def _setup():
    ds = dataset("synthetic_1_1")
    params = get_model(ArchConfig(name="lr", family="logreg",
                                  input_dim=ds.x.shape[-1],
                                  num_classes=ds.num_classes)
                       ).init(jax.random.PRNGKey(0))
    return ds, params


def _curves(ds, params, slowdown: float, rounds: int, aggs: int,
            eval_every: int = 2) -> Dict[str, object]:
    n = ds.num_devices
    fleet = bimodal_fleet(n, slowdown=slowdown, dropout_slow=0.1, seed=0)
    fps = model_flops_per_step(params, 10)
    pb = model_payload_bytes(params)
    spe = max(ds.samples_per_device // 10, 1)

    # run names carry the sweep point: each simulation gets its own tracker
    # scope in the streamed trace (scopes key step monotonicity)
    tag = f"x{slowdown:g}"
    out = {}
    for agg in ("fedavg", "contextual"):
        cfg = ServerConfig(aggregator=agg, num_devices=n, clients_per_round=10,
                           lr=0.2, batch_size=10, min_epochs=1, max_epochs=20)
        r = run_simulation(f"{agg}-sync-{tag}", logistic_loss, logistic_apply,
                           params, ds, cfg, num_rounds=rounds,
                           selection_seed=SEED, eval_every=eval_every)
        out[f"{agg}-sync"] = sync_wallclock_curve(
            r, fleet, cfg, spe, rounds, eval_every, fps, pb,
            selection_seed=SEED)

    async_common = dict(num_devices=n, buffer_size=5, concurrency=10, lr=0.2,
                        batch_size=10, min_epochs=1, max_epochs=20,
                        staleness_mode="poly", staleness_decay=0.5)
    for name, cfg in (
            ("contextual-async", AsyncConfig(aggregator="contextual_async",
                                             **async_common)),
            ("fedbuff-async", AsyncConfig(aggregator="fedbuff", server_lr=0.5,
                                          **async_common))):
        r = run_async_simulation(f"{name}-{tag}", logistic_loss,
                                 logistic_apply, params, ds, cfg, fleet,
                                 num_aggregations=aggs,
                                 selection_seed=SEED, eval_every=eval_every)
        out[name] = r.to_curve()
    return out


def collect(rounds: int = 30, aggs: int = 30) -> Dict[str, List[dict]]:
    """Run the sweep and return JSON-ready records (also used by --json)."""
    ds, params = _setup()
    records = []
    for slowdown in SLOWDOWNS:
        curves = _curves(ds, params, slowdown, rounds, aggs)
        for name, c in curves.items():
            t2a = c.time_to_accuracy(TARGET_ACC)
            records.append({
                "fleet_slowdown": slowdown,
                "method": name,
                "target_acc": TARGET_ACC,
                "virtual_time_to_target_s": t2a,
                "virtual_time_end_s": c.times[-1],
                "best_acc": float(max(c.test_acc)),
                "final_loss": float(c.train_loss[-1]),
            })
    return {"benchmark": "async_vs_sync", "target_acc": TARGET_ACC,
            "records": records}


def run(rounds: int = 30, aggs: int = 30) -> Dict[str, List[dict]]:
    results = collect(rounds, aggs)
    for rec in results["records"]:
        t2a = rec["virtual_time_to_target_s"]
        derived = (f"slowdown=x{rec['fleet_slowdown']:g};"
                   f"t2a{int(TARGET_ACC * 100)}="
                   f"{'%.4fs' % t2a if t2a is not None else 'never'};"
                   f"best_acc={rec['best_acc']:.3f}")
        emit(f"async_vs_sync/x{rec['fleet_slowdown']:g}/{rec['method']}",
             (t2a or rec["virtual_time_end_s"]) * 1e6, derived)
    return results
