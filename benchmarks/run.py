"""Benchmark harness — one module per paper table/figure (deliverable d).

  fig2_3  — contextual K₂/μ variants (paper Figs. 2-3)
  fig4_5  — algorithm comparison: FedAvg/FedProx/FOLB vs contextual (Figs. 4-5)
  fig6    — rounds-to-accuracy across the four datasets (Fig. 6)
  fig7    — aggregation-variable (α) statistics per stage (Fig. 7)
  async   — async edge runtime vs sync under straggler severity sweep
  hier    — hierarchical vs flat contextual: fan-in / tier-depth sweep
  fleet   — fleet-scale rounds: 10³→10⁶ devices via cohort scheduling
  bigmodel— streamed big-model round engine: memory model + equivalence
  robust  — adversarial & churn sweep: robust contextual vs plain vs FedAvg
  kernels — Pallas hot-spot micro-benchmarks
  roofline— per-(arch × shape × mesh) roofline terms from the dry-run

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks round counts.

Every bench runs under a ``JsonlTracker`` streaming live per-round events to
``BENCH_<name>.jsonl`` (tail it to watch a run).  ``--json`` additionally
writes each JSON-capable bench (one whose ``run`` returns a records dict) to
``BENCH_<name>.json`` — *derived from the trace* via
``bench_trace.derive_bench_json``, so the jsonl stream is the single source
of truth for the committed snapshots.
"""
import argparse
import json
import sys

from .bench_trace import derive_bench_json


def _registry():
    """name -> (module, kwargs_fn(quick) -> run kwargs, emits_json)."""
    from . import (async_vs_sync, bigmodel_round, compress_sweep,
                   fig2_3_k2_variants, fig4_5_algorithms,
                   fig6_rounds_to_accuracy, fig7_alpha_stages, fleet_scale,
                   hier_vs_flat, kernel_bench, robust_suite, roofline_report,
                   serve_bench)
    return {
        "fig2_3": (fig2_3_k2_variants,
                   lambda q: dict(rounds=10 if q else 25), False),
        "fig4_5": (fig4_5_algorithms,
                   lambda q: dict(rounds=12 if q else 40), False),
        "fig6": (fig6_rounds_to_accuracy,
                 lambda q: dict(rounds=15 if q else 50), False),
        "fig7": (fig7_alpha_stages,
                 lambda q: dict(rounds=10 if q else 30), False),
        "async": (async_vs_sync,
                  lambda q: dict(rounds=12 if q else 30,
                                 aggs=12 if q else 30), True),
        "hier": (hier_vs_flat, lambda q: dict(rounds=8 if q else 20), True),
        "fleet": (fleet_scale, lambda q: dict(rounds=3, quick=q), True),
        "bigmodel": (bigmodel_round,
                     lambda q: dict(rounds=8 if q else 16, quick=q), True),
        "compress": (compress_sweep,
                     lambda q: dict(rounds=8 if q else 16), True),
        "robust": (robust_suite,
                   lambda q: dict(rounds=10 if q else 20), True),
        "serve": (serve_bench, lambda q: dict(quick=q), True),
        "kernels": (kernel_bench, lambda q: dict(quick=q), True),
        "roofline": (roofline_report, lambda q: {}, False),
    }


def main() -> None:
    registry = _registry()
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: " + ",".join(registry))
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<name>.json for each JSON-capable "
                         "bench in the selection")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(registry)
        if unknown:
            ap.error(f"unknown bench(es) {sorted(unknown)}; "
                     f"have {sorted(registry)}")

    from repro.obs import JsonlTracker, use_tracker

    from .common import publish_bench

    print("name,us_per_call,derived")
    wrote_json = False
    for name, (module, kwargs_fn, emits_json) in registry.items():
        if only is not None and name not in only:
            continue
        trace_path = f"BENCH_{name}.jsonl"
        with use_tracker(JsonlTracker(trace_path)):
            results = module.run(**kwargs_fn(args.quick))
            if emits_json:
                publish_bench(results)
        print(f"streamed {trace_path}", file=sys.stderr)
        if args.json and emits_json:
            path = f"BENCH_{name}.json"
            with open(path, "w") as f:
                json.dump(derive_bench_json(trace_path), f, indent=2)
            print(f"wrote {path} (derived from {trace_path})",
                  file=sys.stderr)
            wrote_json = True
    if args.json and not wrote_json:
        print("--json: no JSON-capable bench in the selection; "
              "no file written", file=sys.stderr)


if __name__ == "__main__":
    main()
