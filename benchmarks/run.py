"""Benchmark harness — one module per paper table/figure (deliverable d).

  fig2_3  — contextual K₂/μ variants (paper Figs. 2-3)
  fig4_5  — algorithm comparison: FedAvg/FedProx/FOLB vs contextual (Figs. 4-5)
  fig6    — rounds-to-accuracy across the four datasets (Fig. 6)
  fig7    — aggregation-variable (α) statistics per stage (Fig. 7)
  async   — async edge runtime vs sync under straggler severity sweep
  kernels — Pallas hot-spot micro-benchmarks
  roofline— per-(arch × shape × mesh) roofline terms from the dry-run

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks round counts.
``--json`` additionally writes the async sweep to ``BENCH_async.json`` so the
perf trajectory accumulates across PRs.
"""
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig2_3,fig4_5,fig6,fig7,"
                         "async,kernels,roofline")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write machine-readable results (BENCH_async.json)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (async_vs_sync, fig2_3_k2_variants, fig4_5_algorithms,
                   fig6_rounds_to_accuracy, fig7_alpha_stages, kernel_bench,
                   roofline_report)

    print("name,us_per_call,derived")
    if only is None or "fig2_3" in only:
        fig2_3_k2_variants.run(rounds=10 if args.quick else 25)
    if only is None or "fig4_5" in only:
        fig4_5_algorithms.run(rounds=12 if args.quick else 40)
    if only is None or "fig6" in only:
        fig6_rounds_to_accuracy.run(rounds=15 if args.quick else 50)
    if only is None or "fig7" in only:
        fig7_alpha_stages.run(rounds=10 if args.quick else 30)
    if only is None or "async" in only:
        async_results = async_vs_sync.run(rounds=12 if args.quick else 30,
                                          aggs=12 if args.quick else 30)
        if args.json:
            with open("BENCH_async.json", "w") as f:
                json.dump(async_results, f, indent=2)
            print("wrote BENCH_async.json", file=sys.stderr)
    elif args.json:
        print("--json currently only records the 'async' section, which "
              "--only excluded; no file written", file=sys.stderr)
    if only is None or "kernels" in only:
        kernel_bench.run()
    if only is None or "roofline" in only:
        roofline_report.run()


if __name__ == "__main__":
    main()
