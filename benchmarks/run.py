"""Benchmark harness — one module per paper table/figure (deliverable d).

  fig2_3  — contextual K₂/μ variants (paper Figs. 2-3)
  fig4_5  — algorithm comparison: FedAvg/FedProx/FOLB vs contextual (Figs. 4-5)
  fig6    — rounds-to-accuracy across the four datasets (Fig. 6)
  fig7    — aggregation-variable (α) statistics per stage (Fig. 7)
  kernels — Pallas hot-spot micro-benchmarks
  roofline— per-(arch × shape × mesh) roofline terms from the dry-run

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks round counts.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig2_3,fig4_5,fig6,fig7,"
                         "kernels,roofline")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (fig2_3_k2_variants, fig4_5_algorithms,
                   fig6_rounds_to_accuracy, fig7_alpha_stages, kernel_bench,
                   roofline_report)

    print("name,us_per_call,derived")
    if only is None or "fig2_3" in only:
        fig2_3_k2_variants.run(rounds=10 if args.quick else 25)
    if only is None or "fig4_5" in only:
        fig4_5_algorithms.run(rounds=12 if args.quick else 40)
    if only is None or "fig6" in only:
        fig6_rounds_to_accuracy.run(rounds=15 if args.quick else 50)
    if only is None or "fig7" in only:
        fig7_alpha_stages.run(rounds=10 if args.quick else 30)
    if only is None or "kernels" in only:
        kernel_bench.run()
    if only is None or "roofline" in only:
        roofline_report.run()


if __name__ == "__main__":
    main()
