"""Summary-compression sweep: scheme / budget vs. final loss and uplink.

Runs the acceptance fleet (64 bimodal devices behind 4 gateways) through the
hierarchical runtime with every compression scheme at a range of byte
budgets, against two anchors: the flat star contextual run (the O(K·n)
baseline every hierarchy is judged by) and the uncompressed PR-2 hier run
(the O(P·n) baseline this PR compresses).  Reported per configuration:
final loss / accuracy, measured cloud-uplink bytes, savings vs. *both*
anchors, and the loss gap vs. the uncompressed hier run.

The JSON (→ ``BENCH_compress.json`` via ``run.py --json``) carries an
``acceptance`` block — the best configuration at ≥4× uplink reduction over
uncompressed hier — which the bench-regression CI gate checks stays ≥4× at
<3% loss gap.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from repro.compress import CompressConfig
from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.edge import bimodal_fleet
from repro.fl import run_hier_simulation
from repro.hier import HierConfig, star_topology, two_tier_topology
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

from .common import emit

SEED = 42
DIM, N_DEV, N_GW = 60, 64, 4
SWEEP = (        # (scheme, ratio over the 2n summary floats, ū budget frac)
    ("topk", 3.4, 0.75),        # headline: ≥4× vs hier at <3% loss gap
    ("topk", 4.0, 0.5),
    ("topk", 8.0, 0.75),
    ("srht", 4.0, 0.5),
    ("sign_sketch", 8.0, 0.5),
    ("lowrank", 8.0, 0.75),
)


def _setup():
    xs, ys = make_synthetic(1.0, 1.0, num_devices=N_DEV,
                            samples_per_device=60, dim=DIM, seed=2)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, DIM)[:400], ys.reshape(-1)[:400], 10)
    params = get_model(ArchConfig(name="lr", family="logreg", input_dim=DIM,
                                  num_classes=10)).init(jax.random.PRNGKey(0))
    return ds, params


def _run(name, ds, params, cfg, topo, rounds):
    return run_hier_simulation(name, logistic_loss, logistic_apply, params,
                               ds, cfg, topo, num_rounds=rounds,
                               selection_seed=SEED, eval_every=rounds)


def collect(rounds: int = 16) -> Dict[str, List[dict]]:
    ds, params = _setup()
    fleet = bimodal_fleet(N_DEV, slowdown=10.0, dropout_slow=0.05, seed=0)
    hier_topo = two_tier_topology(fleet, N_GW)
    base = dict(lr=0.2, batch_size=10, min_epochs=1, max_epochs=10)

    flat = _run("flat", ds, params,
                HierConfig(aggregator="hier_contextual", **base),
                star_topology(fleet), rounds)
    hier = _run("hier", ds, params,
                HierConfig(aggregator="hier_contextual", **base),
                hier_topo, rounds)

    def rec(name, scheme, ratio, u_frac, r):
        gap = (abs(r.train_loss[-1] - hier.train_loss[-1])
               / hier.train_loss[-1])
        return {
            "method": name, "scheme": scheme, "ratio": ratio,
            "u_frac": u_frac,
            "final_loss": r.train_loss[-1], "final_acc": r.test_acc[-1],
            "cloud_uplink_bytes": r.cloud_uplink_bytes,
            "savings_vs_flat": flat.cloud_uplink_bytes / r.cloud_uplink_bytes,
            "savings_vs_hier": hier.cloud_uplink_bytes / r.cloud_uplink_bytes,
            "loss_gap_vs_hier": gap,
        }

    records = [
        rec("flat-contextual", "none", 1.0, 0.5, flat),
        rec("hier-contextual", "none", 1.0, 0.5, hier),
    ]
    for scheme, ratio, u_frac in SWEEP:
        cfg = HierConfig(aggregator="hier_contextual_sketch",
                         compress=CompressConfig(scheme=scheme, ratio=ratio,
                                                 u_frac=u_frac),
                         **base)
        name = f"hier-{scheme}-r{ratio:g}-u{int(u_frac * 100)}"
        r = _run(name, ds, params, cfg, hier_topo, rounds)
        records.append(rec(name, scheme, ratio, u_frac, r))

    # acceptance: the HEADLINE config (SWEEP[0]) judged against the 4×/3%
    # bar.  Deliberately not an argmin over loss gaps: gaps drift a few
    # percent across jax/BLAS versions, and a selection that can flip on
    # benign drift would make the CI gate's exact string/bool comparison
    # flaky.  The headline's savings are deterministic byte accounting, and
    # its gap carries ~17% headroom under the 3% bar.
    best = records[2]                       # first sweep entry
    acceptance = {
        "method": best["method"],
        "savings_vs_hier": best["savings_vs_hier"],
        "loss_gap_vs_hier": best["loss_gap_vs_hier"],
        "meets_4x_at_3pct": bool(best["savings_vs_hier"] >= 4.0
                                 and best["loss_gap_vs_hier"] < 0.03),
    }
    return {"benchmark": "compress_sweep", "num_devices": N_DEV,
            "gateways": N_GW, "rounds": rounds, "records": records,
            "acceptance": acceptance}


def run(rounds: int = 16) -> Dict[str, List[dict]]:
    results = collect(rounds)
    for r in results["records"]:
        derived = (f"loss={r['final_loss']:.4f};"
                   f"gap={r['loss_gap_vs_hier'] * 100:.1f}%;"
                   f"vs_hier={r['savings_vs_hier']:.1f}x;"
                   f"vs_flat={r['savings_vs_flat']:.1f}x")
        emit(f"compress_sweep/{r['method']}",
             r["cloud_uplink_bytes"] / 1e3, derived)
    acc = results["acceptance"]
    if acc is not None:
        emit("compress_sweep/acceptance", 0.0,
             f"best={acc['method']};vs_hier={acc['savings_vs_hier']:.1f}x;"
             f"gap={acc['loss_gap_vs_hier'] * 100:.1f}%;"
             f"pass={acc['meets_4x_at_3pct']}")
    return results
