"""Bench-regression gate: diff fresh BENCH_*.json against committed baselines.

Stdlib-only (runs before/without the repro package) so CI can invoke it as a
plain script:

    python benchmarks/check_regression.py --baseline bench-baseline --fresh .

Compares every ``BENCH_*.json`` present in the baseline dir against its
freshly generated twin.  Records are matched by their identity keys (the
non-numeric fields plus declared config numbers like ``ratio``); metrics are
classed by name:

  * byte counts and savings ratios — deterministic accounting, compared
    near-exactly (they are THE regression signal: a wire-format or ledger
    change shows up here first);
  * losses / accuracies / virtual times — deterministic per platform but
    float-sensitive across jax versions and BLAS backends, compared within a
    generous relative band;
  * real wall-clock fields — ignored (machine-dependent).

A missing fresh file, a missing record, a new NaN, or any out-of-band
metric fails the gate (exit 1) with a per-field report.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from bench_trace import derive_bench_json  # noqa: E402

# metric classification by field-name substring (first match wins).
# IGNORE covers machine-dependent fields: real wall-clock, autotune timings
# and the autotune's backend selection (a faster machine may legitimately
# pick a different backend; the oracle_max_abs_err field is what gates
# kernel correctness).
IGNORE = ("round_time_s", "wall_time", "us_per_call", "time_end",
          "selected", "candidates_timed", "ungated",
          # fleet throughput / host-memory columns (machine-dependent);
          # listed before the "devices" EXACT match below on purpose
          "devices_per_s", "peak_rss",
          # serving wall-clock columns: raw tokens/s, the loop-vs-engine
          # speedup ratio, and publish→adopt swap stalls all move with the
          # machine; the gated serving facts are the meets_* booleans,
          # which _classify checks BEFORE this list so no ignore substring
          # can swallow an acceptance flag (note "tok_per_s" does NOT catch
          # the deterministic virtual-clock column "tokens_per_virtual_s",
          # and "speedup_vs_loop" does NOT catch the min_speedup_x config)
          "tok_per_s", "speedup_vs_loop", "stall")
EXACT = ("bytes", "savings", "gateways", "devices", "rounds", "num_",
         "meets_")
LOOSE_REL = 0.35        # losses / accs / virtual times across jax versions
LOOSE_ABS = 0.05
EXACT_REL = 1e-6

# numeric fields that are part of a record's identity, not metrics
IDENTITY_NUM = ("ratio", "u_frac", "depth", "gateways", "fleet_slowdown",
                "fleet_size", "target_acc", "K", "n", "m", "k", "frac")


def _classify(key: str):
    # acceptance booleans are THE gated facts — classify them ahead of the
    # IGNORE substrings so e.g. "stall"/"speedup_vs_loop" can never swallow
    # a meets_* flag
    if key.startswith("meets_"):
        return EXACT_REL, 0.0
    for pat in IGNORE:
        if pat in key:
            return None
    for pat in EXACT:
        if pat in key:
            return EXACT_REL, 0.0
    return LOOSE_REL, LOOSE_ABS


def _identity(record: dict) -> tuple:
    parts = []
    for key in sorted(record):
        val = record[key]
        if _classify(key) is None:
            continue                 # ignored fields never key identity
        if isinstance(val, str) or key in IDENTITY_NUM:
            parts.append((key, val))
    return tuple(parts)


def _check_value(path: str, key: str, old, new, problems: list) -> None:
    if _classify(key) is None:       # machine-dependent: never gated
        return
    if isinstance(old, str) or isinstance(old, bool) or old is None:
        if old != new:
            problems.append(f"{path}.{key}: '{old}' -> '{new}'")
        return
    if not isinstance(old, (int, float)):
        return
    rel, abs_tol = _classify(key)
    if new is None or (isinstance(new, float) and math.isnan(new)):
        problems.append(f"{path}.{key}: {old} -> {new}")
        return
    tol = max(abs(old) * rel, abs_tol)
    if abs(float(new) - float(old)) > tol:
        problems.append(f"{path}.{key}: {old} -> {new} (tol {tol:.3g})")


def _check_records(name: str, old: list, new: list, problems: list) -> None:
    fresh = {_identity(r): r for r in new}
    for rec in old:
        ident = _identity(rec)
        twin = fresh.get(ident)
        if twin is None:
            problems.append(f"{name}: record {dict(ident)} missing from "
                            "fresh run")
            continue
        for key, val in rec.items():
            _check_value(f"{name}:{dict(ident).get('method', ident)}",
                         key, val, twin.get(key), problems)


def compare(baseline_path: str, fresh_path: str, problems: list) -> None:
    name = os.path.basename(baseline_path)
    with open(baseline_path) as f:
        old = json.load(f)
    if os.path.exists(fresh_path):
        with open(fresh_path) as f:
            new = json.load(f)
    else:
        # fall back to the jsonl trace twin — same payload, since the JSON
        # is itself derived from the trace by run.py
        trace = fresh_path[:-len(".json")] + ".jsonl"
        if not os.path.exists(trace):
            problems.append(f"{name}: fresh file missing (bench did not "
                            "run?)")
            return
        new = derive_bench_json(trace)
    for key, val in old.items():
        if key == "records":
            _check_records(name, val, new.get("records", []), problems)
        elif isinstance(val, dict):        # e.g. compress acceptance block
            twin = new.get(key) or {}
            for k2, v2 in val.items():
                _check_value(f"{name}.{key}", k2, v2, twin.get(k2), problems)
        else:
            _check_value(name, key, val, new.get(key), problems)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="dir holding the committed BENCH_*.json")
    ap.add_argument("--fresh", required=True,
                    help="dir holding the freshly generated BENCH_*.json")
    args = ap.parse_args()

    baselines = sorted(glob.glob(os.path.join(args.baseline, "BENCH_*.json")))
    if not baselines:
        print(f"no BENCH_*.json under {args.baseline}", file=sys.stderr)
        return 1
    problems: list = []
    for b in baselines:
        compare(b, os.path.join(args.fresh, os.path.basename(b)), problems)
    if problems:
        print(f"bench regression gate: {len(problems)} problem(s)")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"bench regression gate: {len(baselines)} file(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
