"""Render a markdown job summary from streamed bench traces.

    python benchmarks/summarize_trace.py BENCH_*.jsonl >> "$GITHUB_STEP_SUMMARY"

Stdlib-only (like ``check_regression.py``) so CI can run it without jax or
the repro package.  For each trace it prints the bench's headline records
table (identity columns first, then the gated metrics: losses, byte
accounting, savings, round times), the trace's wall-clock span derived from
event ``t_wall`` stamps, and — where the trace carries them — the kernel
autotune decisions that fired during the run.  Replaces the ad-hoc inline
python that used to live in ``ci.yml``.
"""
from __future__ import annotations

import os
import sys
from typing import Any, Dict, List

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from bench_trace import derive_bench_json, iter_events  # noqa: E402

# identity columns lead the table; metric columns follow in this order.
# Only columns present in at least one record are rendered.
IDENTITY_COLS = ("scenario", "topology", "method", "fleet_slowdown",
                 "dataset", "op", "shape", "mode", "scheme", "ratio",
                 "depth", "gateways")
METRIC_COLS = ("final_loss", "final_acc", "best_acc",
               "virtual_time_to_target_s", "loss_gap_vs_flat",
               "loss_gap_vs_sync", "loss_gap_vs_dense",
               "loss_gap_streamed_vs_fused", "oracle_max_abs_err",
               "cloud_uplink_bytes", "uplink_bytes", "total_bytes",
               "peak_round_matrix_bytes", "dense_round_matrix_bytes",
               "uplink_savings", "peak_savings_vs_dense", "savings",
               "meets_mem_target", "t_virtual_end",
               "steady_wall_time_per_round_s", "compile_wall_time_s")
MAX_COLS = 9


def _fmt(key: str, val: Any) -> str:
    if val is None:
        return ""
    if isinstance(val, bool) or isinstance(val, str):
        return str(val)
    if isinstance(val, (int, float)):
        if "bytes" in key:
            return f"{val / 2**20:.2f} MB" if val >= 2**20 \
                else f"{val / 1024:.1f} KB"
        if "savings" in key or "ratio" in key.lower():
            return f"{val:.2f}x"
        if abs(val) != 0 and (abs(val) < 1e-3 or abs(val) >= 1e5):
            return f"{val:.2e}"
        return f"{val:.4g}"
    return str(val)


def _records_table(records: List[dict]) -> List[str]:
    present = set()
    for r in records:
        present.update(r)
    cols = [c for c in IDENTITY_COLS if c in present]
    cols += [c for c in METRIC_COLS if c in present][:MAX_COLS - len(cols)]
    if not cols:
        return []
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "---|" * len(cols)]
    for r in records:
        lines.append("| " + " | ".join(_fmt(c, r.get(c)) for c in cols)
                     + " |")
    return lines


def _autotune_table(events: List[Dict[str, Any]]) -> List[str]:
    picks = [e["metrics"] for e in events
             if "kernels/autotune/op" in e.get("metrics", {})]
    if not picks:
        return []
    lines = ["", "**Autotune picks**", "",
             "| op | bucket | backend | forced |", "|---|---|---|---|"]
    for m in picks:
        lines.append(f"| {m['kernels/autotune/op']} "
                     f"| `{m.get('kernels/autotune/bucket', '')}` "
                     f"| {m.get('kernels/autotune/backend', '')} "
                     f"| {m.get('kernels/autotune/forced', '')} |")
    return lines


def summarize(path: str) -> List[str]:
    events = list(iter_events(path))
    payload = derive_bench_json(path)
    name = os.path.basename(path)[len("BENCH_"):-len(".jsonl")] \
        if os.path.basename(path).startswith("BENCH_") \
        else os.path.basename(path)
    lines = [f"### {payload.get('benchmark', name)} "
             f"({len(events)} events)"]
    walls = [e["t_wall"] for e in events if "t_wall" in e]
    if len(walls) >= 2:
        lines.append(f"trace span: {max(walls) - min(walls):.1f}s wall")
    scalars = {k: v for k, v in payload.items()
               if not isinstance(v, (list, dict)) and k != "benchmark"}
    if scalars:
        lines.append(", ".join(f"{k}={_fmt(k, v)}"
                               for k, v in sorted(scalars.items())))
    lines.append("")
    lines += _records_table(payload.get("records", []))
    lines += _autotune_table(events)
    lines.append("")
    return lines


def main(argv: List[str]) -> int:
    paths = [p for p in argv if os.path.exists(p)]
    if not paths:
        print("summarize_trace: no trace files found", file=sys.stderr)
        return 1
    for path in sorted(paths):
        print("\n".join(summarize(path)))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
