"""Render a markdown job summary from streamed bench traces.

    python benchmarks/summarize_trace.py BENCH_*.jsonl >> "$GITHUB_STEP_SUMMARY"

Stdlib-only (like ``check_regression.py``) so CI can run it without jax or
the repro package.  For each trace it prints the bench's headline records
table (identity columns first, then the gated metrics: losses, byte
accounting, savings, round times), the trace's wall-clock span derived from
event ``t_wall`` stamps, the slowest spans recorded by ``repro.obs.spans``
(where time went: compile vs solve vs eval, on both clocks), and — where
the trace carries them — the kernel autotune decisions that fired during
the run.  Replaces the ad-hoc inline python that used to live in
``ci.yml``.

Each trace is read in ONE streaming pass (a long fleet trace never
materializes), and a missing, empty or truncated trace is a hard error:
one line on stderr naming the file and the problem, non-zero exit — CI
fails loudly instead of summarizing a half-written stream as if it were
the whole run.
"""
from __future__ import annotations

import heapq
import json
import os
import sys
from typing import Any, Dict, Iterator, List, Tuple

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from bench_trace import BenchFold, SPAN_RESERVED, span_fields  # noqa: E402

# identity columns lead the table; metric columns follow in this order.
# Only columns present in at least one record are rendered.
IDENTITY_COLS = ("scenario", "topology", "method", "fleet_slowdown",
                 "fleet_size", "dataset", "op", "shape", "mode", "scheme",
                 "ratio", "depth", "gateways", "attack", "frac", "churn")
METRIC_COLS = ("final_loss", "final_train_loss", "devices_per_round",
               "devices_per_s", "warm_round_wall_time_ms", "peak_rss_mb",
               "loss_gap_vs_event",
               "final_loss_ungated", "inflation_ungated",
               "num_dropped", "final_acc", "best_acc",
               "virtual_time_to_target_s", "loss_gap_vs_flat",
               "loss_gap_vs_sync", "loss_gap_vs_dense",
               "loss_gap_streamed_vs_fused", "oracle_max_abs_err",
               "cloud_uplink_bytes", "uplink_bytes", "total_bytes",
               "peak_round_matrix_bytes", "dense_round_matrix_bytes",
               "uplink_savings", "peak_savings_vs_dense", "savings",
               "meets_mem_target", "t_virtual_end",
               "steady_wall_time_per_round_s", "compile_wall_time_s",
               # serving columns (PR-10): loop-vs-engine throughput, swap
               # stalls, occupancy, and model staleness under hot-swaps
               "seed_tok_per_s", "engine_tok_per_s", "speedup_vs_loop",
               "meets_speedup_5x", "tokens_per_virtual_s",
               "swap_stall_s_max", "num_swaps", "slot_occupancy_mean",
               "staleness_virtual_mean_s", "served_loss_mean",
               "loss_match_max_abs_err", "meets_loss_match")
MAX_COLS = 9
TOP_SPANS = 10


class TraceError(Exception):
    """A trace that cannot be summarized (missing/empty/truncated)."""


def _fmt(key: str, val: Any) -> str:
    if val is None:
        return ""
    if isinstance(val, bool) or isinstance(val, str):
        return str(val)
    if isinstance(val, (int, float)):
        if "bytes" in key:
            return f"{val / 2**20:.2f} MB" if val >= 2**20 \
                else f"{val / 1024:.1f} KB"
        if "savings" in key or "ratio" in key.lower():
            return f"{val:.2f}x"
        if abs(val) != 0 and (abs(val) < 1e-3 or abs(val) >= 1e5):
            return f"{val:.2e}"
        return f"{val:.4g}"
    return str(val)


def _records_table(records: List[dict]) -> List[str]:
    present = set()
    for r in records:
        present.update(r)
    cols = [c for c in IDENTITY_COLS if c in present]
    cols += [c for c in METRIC_COLS if c in present][:MAX_COLS - len(cols)]
    if not cols:
        return []
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "---|" * len(cols)]
    for r in records:
        lines.append("| " + " | ".join(_fmt(c, r.get(c)) for c in cols)
                     + " |")
    return lines


def _autotune_table(picks: List[Dict[str, Any]]) -> List[str]:
    if not picks:
        return []
    lines = ["", "**Autotune picks**", "",
             "| op | bucket | backend | forced |", "|---|---|---|---|"]
    for m in picks:
        lines.append(f"| {m['kernels/autotune/op']} "
                     f"| `{m.get('kernels/autotune/bucket', '')}` "
                     f"| {m.get('kernels/autotune/backend', '')} "
                     f"| {m.get('kernels/autotune/forced', '')} |")
    return lines


def _dur(v: Any) -> str:
    if not isinstance(v, (int, float)):
        return ""
    return f"{v * 1e3:.1f} ms" if v < 1.0 else f"{v:.2f} s"


def slowest_spans_table(spans: List[Dict[str, Any]],
                        total: int) -> List[str]:
    """Markdown table of the slowest spans (wall clock), dual-clock
    columns plus the caller tags — the CI job-summary triage view."""
    if not spans:
        return []
    lines = ["", f"**Slowest spans** (top {len(spans)} of {total})", "",
             "| span | wall | virtual | tags |", "|---|---|---|---|"]
    for f in spans:
        tags = ", ".join(f"{k}={f[k]}" for k in sorted(f)
                         if k not in SPAN_RESERVED)
        lines.append(f"| `{f.get('path', f.get('name', '?'))}` "
                     f"| {_dur(f.get('dur_wall_s'))} "
                     f"| {_dur(f.get('dur_virtual_s'))} "
                     f"| {tags} |")
    return lines


def _iter_raw(path: str) -> Iterator[Tuple[int, Dict[str, Any]]]:
    with open(path) as fh:
        for lineno, line in enumerate(fh, start=1):
            if not line.strip():
                continue
            try:
                yield lineno, json.loads(line)
            except json.JSONDecodeError as exc:
                raise TraceError(f"{path}: truncated or corrupt trace at "
                                 f"line {lineno}: {exc.msg}") from exc


def summarize(path: str) -> List[str]:
    """One streaming pass over one trace → markdown lines.  Raises
    :class:`TraceError` on a missing, empty or truncated trace."""
    if not os.path.exists(path):
        raise TraceError(f"{path}: no such trace file")
    if os.path.getsize(path) == 0:
        raise TraceError(f"{path}: trace is empty (0 bytes)")
    fold = BenchFold()
    n_events = n_spans = 0
    wall_min = wall_max = None
    autotune: List[Dict[str, Any]] = []
    slow: List[Tuple[float, int, Dict[str, Any]]] = []   # min-heap of top-K
    for lineno, event in _iter_raw(path):
        n_events += 1
        t_wall = event.get("t_wall")
        if isinstance(t_wall, (int, float)):
            wall_min = t_wall if wall_min is None else min(wall_min, t_wall)
            wall_max = t_wall if wall_max is None else max(wall_max, t_wall)
        fold.add(event)
        m = event.get("metrics", {})
        if "kernels/autotune/op" in m:
            autotune.append(m)
        if event.get("kind") == "span":
            f = span_fields(event)
            n_spans += 1
            if f.get("flat"):
                # a flat span's wall interval brackets unrelated host work
                # (it lives between scheduler events); only its virtual
                # duration means anything, so it stays out of the
                # wall-sorted triage table
                continue
            item = (float(f.get("dur_wall_s", 0.0)), n_spans, f)
            if len(slow) < TOP_SPANS:
                heapq.heappush(slow, item)
            else:
                heapq.heappushpop(slow, item)
    if n_events == 0:
        raise TraceError(f"{path}: trace has no events")
    payload = fold.payload()
    name = os.path.basename(path)[len("BENCH_"):-len(".jsonl")] \
        if os.path.basename(path).startswith("BENCH_") \
        else os.path.basename(path)
    lines = [f"### {payload.get('benchmark', name)} "
             f"({n_events} events, {n_spans} spans)"]
    if wall_min is not None and wall_max is not None:
        lines.append(f"trace span: {wall_max - wall_min:.1f}s wall")
    scalars = {k: v for k, v in payload.items()
               if not isinstance(v, (list, dict)) and k != "benchmark"}
    if scalars:
        lines.append(", ".join(f"{k}={_fmt(k, v)}"
                               for k, v in sorted(scalars.items())))
    lines.append("")
    lines += _records_table(payload.get("records", []))
    # acceptance-style blocks (dict-valued payload entries): the gated
    # headline numbers, e.g. the robust suite's loss-inflation margins
    for key in sorted(payload):
        val = payload[key]
        if key == "records" or not isinstance(val, dict):
            continue
        lines.append("")
        lines.append(f"**{key}**: " + ", ".join(
            f"{k}={_fmt(k, v)}" for k, v in sorted(val.items())))
    lines += slowest_spans_table(
        [f for _, _, f in sorted(slow, reverse=True)], n_spans)
    lines += _autotune_table(autotune)
    lines.append("")
    return lines


def main(argv: List[str]) -> int:
    if not argv:
        print("summarize_trace: no trace files given", file=sys.stderr)
        return 1
    rc = 0
    for path in sorted(argv):
        try:
            print("\n".join(summarize(path)))
        except TraceError as exc:
            print(f"summarize_trace: {exc}", file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
