"""Diff two bench traces by span path — which stage moved, on which clock.

    python benchmarks/trace_diff.py BASE.jsonl NEW.jsonl [--top N]

A bench regression report ("hier got 8% slower") answers *whether* a run
moved, not *where*.  Both traces carry the dual-clock spans recorded by
``repro.obs.spans``; this tool aggregates each trace per span path (count,
total wall seconds, total virtual seconds) and prints one aligned markdown
table sorted by absolute wall-time delta — compile spans, solve stages,
link transfers and eval blocks each on their own row, so "the hier bench
regressed" becomes "``round/event_loop/gateway/stage_summary_compile``
gained 300 ms".  Paths present in only one trace render with a ``—`` on
the other side (a stage that appeared/disappeared is usually the story).

Stdlib-only (like ``check_regression.py`` / ``summarize_trace.py``) so CI
diffs the committed baseline trace against the fresh run without jax.
Missing or unreadable trace files exit non-zero with a one-line error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from bench_trace import iter_spans  # noqa: E402


class PathStats:
    __slots__ = ("count", "wall_s", "virtual_s")

    def __init__(self) -> None:
        self.count = 0
        self.wall_s = 0.0
        self.virtual_s = 0.0


def collect(path: str) -> Dict[str, PathStats]:
    """Aggregate one trace's spans per span path (one streaming pass)."""
    stats: Dict[str, PathStats] = {}
    for f in iter_spans(path):
        key = str(f.get("path", f.get("name", "?")))
        st = stats.get(key)
        if st is None:
            st = stats[key] = PathStats()
        st.count += 1
        # a flat span (scheduler task/transfer lifetime) brackets unrelated
        # host work between its begin and end events — its wall interval is
        # not host time spent, so only nested spans feed the wall columns
        if not f.get("flat"):
            st.wall_s += float(f.get("dur_wall_s", 0.0))
        st.virtual_s += float(f.get("dur_virtual_s", 0.0))
    return stats


def _ms(v: Optional[float]) -> str:
    return "—" if v is None else f"{v * 1e3:.1f}"


def _delta(a: Optional[float], b: Optional[float]) -> str:
    if a is None or b is None:
        return "—"
    d = b - a
    pct = f" ({d / a * 100:+.1f}%)" if a > 1e-9 else ""
    return f"{d * 1e3:+.1f}{pct}"


def diff_lines(base: Dict[str, PathStats], new: Dict[str, PathStats],
               base_name: str, new_name: str, top: int) -> List[str]:
    paths = sorted(set(base) | set(new))

    def sort_key(p: str) -> float:
        a = base[p].wall_s if p in base else 0.0
        b = new[p].wall_s if p in new else 0.0
        return abs(b - a)

    paths.sort(key=sort_key, reverse=True)
    shown = paths[:top]
    lines = [f"### trace diff: `{base_name}` → `{new_name}`", "",
             "| span path | count | wall base (ms) | wall new (ms) "
             "| Δ wall (ms) | virt base (ms) | virt new (ms) |",
             "|---|---|---|---|---|---|---|"]
    for p in shown:
        a, b = base.get(p), new.get(p)
        counts = f"{a.count if a else 0}→{b.count if b else 0}"
        lines.append(
            f"| `{p}` | {counts} "
            f"| {_ms(a.wall_s if a else None)} "
            f"| {_ms(b.wall_s if b else None)} "
            f"| {_delta(a.wall_s if a else None, b.wall_s if b else None)} "
            f"| {_ms(a.virtual_s if a else None)} "
            f"| {_ms(b.virtual_s if b else None)} |")
    tw_a = sum(s.wall_s for s in base.values())
    tw_b = sum(s.wall_s for s in new.values())
    lines += ["",
              f"total span wall: {tw_a * 1e3:.1f} ms → {tw_b * 1e3:.1f} ms "
              f"(Δ {_delta(tw_a, tw_b)} ms); "
              f"{len(paths)} span paths ({len(paths) - len(shown)} below "
              f"the top-{top} cut)", ""]
    return lines


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="trace_diff",
        description="Diff two bench traces by span path "
                    "(per-stage wall/virtual deltas).")
    ap.add_argument("base", help="baseline trace (.jsonl)")
    ap.add_argument("new", help="new trace (.jsonl)")
    ap.add_argument("--top", type=int, default=20,
                    help="rows to show, sorted by |Δ wall| (default 20)")
    args = ap.parse_args(argv)

    stats = {}
    for path in (args.base, args.new):
        if not os.path.exists(path):
            print(f"trace_diff: {path}: no such trace file", file=sys.stderr)
            return 2
        try:
            stats[path] = collect(path)
        except json.JSONDecodeError as exc:
            print(f"trace_diff: {path}: truncated or corrupt trace: "
                  f"{exc.msg}", file=sys.stderr)
            return 2
    if not stats[args.base] and not stats[args.new]:
        print("trace_diff: no spans in either trace (were they recorded "
              "before span tracing?)", file=sys.stderr)
        return 1
    print("\n".join(diff_lines(stats[args.base], stats[args.new],
                               os.path.basename(args.base),
                               os.path.basename(args.new), args.top)))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
